"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

State-space duality: within a chunk of Q timesteps the recurrence is
evaluated in its dual quadratic (attention-like) form — two MXU matmuls over
(Q x Q) and (Q x N) tiles — while the chunk-to-chunk state (P x N per head)
is carried sequentially in VMEM scratch across the last grid dimension.

Layout: the wrapper flattens (batch, head) into the first grid dim; B/C
projections are shared across heads (single SSD group) and indexed via the
BlockSpec index map. Validated in interpret mode against the sequential
recurrence oracle ``ref.ssd_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                h_scr, *, chunk: int, nheads: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 128) col 0 valid
    dt = dt[:, :1]                            # (Q, 1)
    A = a_ref[0, 0]                           # scalar for this head
    Bm = b_ref[0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, N)

    da = dt * A                               # (Q,1)
    cs = jnp.cumsum(da, axis=0)               # (Q,1)
    seg = cs[-1:, :]                          # (1,1) total chunk decay (log)

    # intra-chunk dual form
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    decay = cs - cs.T                          # (Q,Q) log decay i<-j
    iot_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iot_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(iot_i >= iot_j, jnp.exp(decay), 0.0)
    M = scores * L * dt.T                      # (Q,Q), dt_j on columns
    y_intra = jax.lax.dot(M, x, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    h_prev = h_scr[...]                        # (P, N)
    y_inter = jnp.exp(cs) * jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)    # (Q, P)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = exp(seg) * h_prev + sum_j exp(seg - cs_j) dt_j B_j x_j
    w = jnp.exp(seg - cs) * dt                 # (Q,1)
    new_state = jnp.exp(seg) * h_prev + jax.lax.dot_general(
        x * w, Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)    # (P,N)
    h_scr[...] = new_state

    @pl.when(ci == nc - 1)
    def _final():
        state_ref[0] = new_state.astype(state_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B,S,N) shared across heads.

    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, "seq len must divide the chunk size"
    nc = S // Q

    # flatten (B,H) into the parallel grid dim; chunk dim is sequential
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = jnp.broadcast_to(dt.transpose(0, 2, 1).reshape(B * H, S)[..., None],
                           (B * H, S, 128))
    af = jnp.tile(A, B).reshape(B * H, 1)

    kernel = functools.partial(_ssd_kernel, chunk=Q, nheads=H)
    y, state = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, Q, 128), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
            pl.BlockSpec((1, Q, N), lambda i, c: (i // H, c, 0)),
            pl.BlockSpec((1, Q, N), lambda i, c: (i // H, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, P, N), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, P, N), x.dtype),
        ],
        scratch_shapes=_scratch(P, N),
        interpret=interpret,
    )(xf, dtf, af, Bm, Cm)

    y = y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    state = state.reshape(B, H, P, N)
    return y, state


def _scratch(P, N):
    from jax.experimental.pallas import tpu as pltpu
    return [pltpu.VMEM((P, N), jnp.float32)]
