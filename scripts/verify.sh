#!/usr/bin/env bash
# Tier-1 verification: full test suite + benchmark smoke.
# Usage: scripts/verify.sh [--fast]   (--fast deselects @slow tests)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=()
if [[ "${1:-}" == "--fast" ]]; then
    MARK=(-m "not slow")
fi

python -m pytest -x -q "${MARK[@]}"
# dispatch-count regression gate: O(1) jitted dispatches per window, no
# per-DC / per-replica loops (redundant with the suite above, but kept as
# an explicit, individually-runnable CI gate)
python -m pytest -q tests/test_dispatch_gate.py
# experiment-API gate: SweepSpec preset == legacy grid config-for-config,
# legacy run_sweep shim emits identical results, SweepResult JSON
# round-trips (also exercised end-to-end by bench_sweep_api below, which
# runs a tiny preset and writes results/benchmarks/sweep_api.json)
python -m pytest -q tests/test_experiment.py
# parallel-sweep gates: partitioner/backends/golden-value suites, then the
# parity diff under 8 fake CPU devices — a sharded run must reproduce the
# sequential SweepResult bitwise (the flag must precede jax init, so the
# gate owns its process; DESIGN.md §7)
python -m pytest -q -m "not slow" tests/test_parallel_sweep.py \
    tests/test_golden_tables.py
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python scripts/parallel_parity.py --preset smoke --windows 4 \
    --expect-devices 8 --backends devices:n=8,processes:n=2
python -m benchmarks.run --quick --skip-tables
