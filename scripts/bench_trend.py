#!/usr/bin/env python
"""Nightly bench-trend gate: the quick paper-tables wall time may not
regress past the committed trajectory.

Re-measures the ``paper_tables --quick`` cold (fresh jit cache) and warm
(persistent jit cache) subprocess wall times — the same measurement
``benchmarks/run.py::bench_greedytl_incremental`` records into
BENCH_greedytl.json on full runs — and fails when either exceeds the
latest trajectory entry by more than ``--threshold`` (default 1.25x,
i.e. a >25% regression). Writes the measurement next to the other bench
artifacts as results/benchmarks/bench_trend.json so the nightly workflow
uploads a comparable trend point per run.

    python scripts/bench_trend.py --threshold 1.25

Wired into .github/workflows/nightly-bench.yml (kernel selection
unpinned there: REPRO_KERNEL_FORCE is deliberately NOT set, so the
autotuner path the benchmarks exercise is the one users get).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))
sys.path.insert(0, ROOT)

TABLES_CODE = ("import time; t0 = time.time(); "
               "from benchmarks.paper_tables import run_all; "
               "run_all(quick=True); print('WALL_S', time.time() - t0)")


def run_tables_once(cache_dir: str) -> float:
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep
               + os.environ.get("PYTHONPATH", ""),
               JAX_COMPILATION_CACHE_DIR=cache_dir)
    out = subprocess.run([sys.executable, "-c", TABLES_CODE], cwd=ROOT,
                         env=env, capture_output=True, text=True,
                         check=True)
    return float(out.stdout.strip().split()[-1])


def baseline_entry(trajectory):
    """Latest trajectory entry that carries table timings (older entries
    may only record refine latency)."""
    for row in reversed(trajectory):
        if "paper_tables_quick_cold_s" in row:
            return row
    return None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--threshold", type=float, default=1.25,
                    help="fail when measured/baseline exceeds this "
                         "ratio on either axis")
    ap.add_argument("--baseline", default=os.path.join(
        ROOT, "BENCH_greedytl.json"))
    args = ap.parse_args()

    from benchmarks.paper_tables import RESULTS_DIR

    with open(args.baseline) as f:
        base = baseline_entry(json.load(f)["trajectory"])
    if base is None:
        print("bench trend: no trajectory entry carries table timings — "
              "nothing to gate against")
        return 1

    # The quick subprocess writes a reduced paper_tables.json; keep the
    # committed artifact intact (same guard as bench_greedytl_incremental).
    tables_json = os.path.join(RESULTS_DIR, "paper_tables.json")
    keep = open(tables_json).read() if os.path.exists(tables_json) \
        else None
    try:
        with tempfile.TemporaryDirectory() as cd:
            cold = run_tables_once(cd)
            warm = run_tables_once(cd)
    finally:
        if keep is not None:
            with open(tables_json, "w") as f:
                f.write(keep)

    rc = 0
    report = {"baseline_label": base["label"],
              "threshold": args.threshold,
              "kernel_force": os.environ.get("REPRO_KERNEL_FORCE", ""),
              "measured_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                            time.gmtime()),
              "axes": {}}
    for axis, measured in (("cold", cold), ("warm", warm)):
        ref = base[f"paper_tables_quick_{axis}_s"]
        ratio = measured / ref
        ok = ratio <= args.threshold
        report["axes"][axis] = {"measured_s": round(measured, 1),
                                "baseline_s": ref,
                                "ratio": round(ratio, 3), "ok": ok}
        state = "OK" if ok else "REGRESSION"
        print(f"bench trend [{axis}]: {state} — {measured:.1f}s vs "
              f"{base['label']} baseline {ref}s "
              f"(ratio {ratio:.2f}, threshold {args.threshold})")
        if not ok:
            rc = 1

    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, "bench_trend.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
        f.write("\n")
    print(f"bench trend: wrote {os.path.relpath(out_path, ROOT)}")
    if rc == 0:
        print("bench trend: quick paper-tables wall time within "
              f"{args.threshold}x of the committed trajectory")
    return rc


if __name__ == "__main__":
    sys.exit(main())
