"""Energy model (paper Section 5.2, Table 1).

``E = P * t`` with ``t = S / B`` — power (mW) times transfer duration. Every
logical transfer is recorded in an event ledger, split by purpose
(collection vs learning), so the per-table breakdowns (paper Tables 2-6) come
straight out of the ledger.

Accounting conventions (the paper leaves these implicit; see DESIGN.md §2 —
the per-technology relay/mains-power rules are implemented once, in
:mod:`repro.core.topology`):

* Only battery-powered endpoints are counted. The edge server is mains
  powered: transfers to it count the device's tx only; transfers *from* it
  count the device's rx only.
* 4G/NB-IoT go through infrastructure: one tx + one rx per unicast.
* 802.11g uses a WiFi-Direct-style star topology: one mule is the Access
  Point. A unicast between two non-AP mules is relayed: 2 tx + 2 rx, all on
  battery. If the AP is an endpoint: 1 tx + 1 rx.
* Observations on the wire are 54 float64 features + 1-byte label (433 B,
  calibrated to the paper's 34 477 mJ Edge-Only benchmark); models are
  float32 (7 x 55 x 4 = 1 540 B).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List


@dataclass(frozen=True)
class Tech:
    name: str
    tx_mw: float
    up_mbps: float
    rx_mw: float
    down_mbps: float

    def tx_mj(self, nbytes: float) -> float:
        return self.tx_mw * (nbytes * 8.0 / (self.up_mbps * 1e6))

    def rx_mj(self, nbytes: float) -> float:
        return self.rx_mw * (nbytes * 8.0 / (self.down_mbps * 1e6))


# Table 1 of the paper
TECHS: Dict[str, Tech] = {
    "4g": Tech("4g", 2100.0, 75.0, 2100.0, 35.0),
    "nbiot": Tech("nbiot", 199.0, 0.2, 199.52, 0.2),
    "802.15.4": Tech("802.15.4", 3.0, 0.12, 3.0, 0.12),
    "wifi": Tech("wifi", 1080.0, 48.0, 740.0, 48.0),
}

OBS_BYTES = 54 * 8 + 1        # 433 B (calibrated, DESIGN.md §2)
MODEL_BYTES = 55 * 7 * 4      # 1 540 B linear model, float32
INDEX_BYTES = 8               # entropy index / center id messages


@dataclass
class Ledger:
    events: List[dict] = field(default_factory=list)

    def add(self, tech: str, nbytes: float, *, purpose: str,
            n_tx: int = 1, n_rx: int = 1, what: str = "") -> float:
        t = TECHS[tech]
        mj = n_tx * t.tx_mj(nbytes) + n_rx * t.rx_mj(nbytes)
        self.events.append({"tech": tech, "bytes": nbytes, "purpose": purpose,
                            "n_tx": n_tx, "n_rx": n_rx, "mj": mj,
                            "what": what})
        return mj

    # -- high-level events ---------------------------------------------------
    def collect_to_edge(self, n_obs: int) -> float:
        """Sensor -> edge server over NB-IoT (tx only; ES is mains powered)."""
        return self.add("nbiot", n_obs * OBS_BYTES, purpose="collection",
                        n_tx=1, n_rx=0, what="sensor->ES")

    def collect_to_mule(self, n_obs: int) -> float:
        """Sensor -> SmartMule over 802.15.4 (both endpoints on battery)."""
        return self.add("802.15.4", n_obs * OBS_BYTES, purpose="collection",
                        n_tx=1, n_rx=1, what="sensor->SM")

    def unicast(self, tech: str, nbytes: float, *, src_is_es=False,
                dst_is_es=False, src_is_ap=False, dst_is_ap=False,
                purpose="learning", what="model") -> float:
        """One unicast between Data Collectors.

        Flag-based convenience wrapper: the per-technology relay/mains-power
        rules live in :mod:`repro.core.topology` (the single source of
        truth); algorithm code should charge against a
        :class:`~repro.core.topology.Topology` directly.
        """
        from repro.core.topology import Node, transfer_counts
        n_tx, n_rx = transfer_counts(
            tech, Node("src", is_es=src_is_es, is_ap=src_is_ap),
            Node("dst", is_es=dst_is_es, is_ap=dst_is_ap))
        return self.add(tech, nbytes, purpose=purpose, n_tx=n_tx, n_rx=n_rx,
                        what=what)

    # -- summaries -----------------------------------------------------------
    def total(self, purpose: str = None) -> float:
        return sum(e["mj"] for e in self.events
                   if purpose is None or e["purpose"] == purpose)

    def by_purpose(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.events:
            out[e["purpose"]] = out.get(e["purpose"], 0.0) + e["mj"]
        return out

    def by_tech(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.events:
            out[e["tech"]] = out.get(e["tech"], 0.0) + e["mj"]
        return out
