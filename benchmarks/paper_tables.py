"""Reproduction of the paper's tables/figures (one function per table).

All energies in mJ, F-measures on the held-out test set, losses relative to
our own Edge-Only run (exactly how the paper computes its losses). Results
are cached under results/benchmarks/ as JSON; ``--quick`` runs fewer windows
and seeds for CI-speed smoke validation.

The grid is the ``"paper_tables"`` :mod:`repro.core.experiment` preset —
one declarative ``SweepSpec`` whose expansion matches the legacy
hand-rolled row list config for config — evaluated by ONE
``SweepSpec.run(stack="auto")`` call: every stack-compatible row x seed
replica (same algorithm, any mix of seeds, technologies, p_edge,
allocation and aggregation settings — derived from ``host_side`` field
metadata) runs in lockstep on a shared fleet axis, so the sweep pays
O(sample buckets) jitted dispatches per window for a whole table column
group instead of O(rows x seeds).
"""
from __future__ import annotations

import json
import os
import time

from repro.core.experiment import get_preset
from repro.data.synthetic_covtype import make_covtype_like

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "benchmarks")


def run_all(windows: int = 100, n_seeds: int = 3, quick: bool = False,
            engine: str = "fleet"):
    if quick:
        windows, n_seeds = 30, 1
    data = make_covtype_like(seed=0)
    spec = get_preset("paper_tables", windows=windows, n_seeds=n_seeds,
                      engine=engine)
    out = {"windows": windows, "n_seeds": n_seeds, "engine": engine}

    t0 = time.time()
    print(f"sweeping {len(spec.rows())} rows x {n_seeds} seed(s), {windows} "
          f"windows, replica-stacked (rows print when the sweep returns)",
          flush=True)
    result = spec.run(data, stack="auto")
    out["sweep_seconds"] = round(time.time() - t0, 1)
    print(f"sweep done in {out['sweep_seconds']}s", flush=True)

    ref = None
    for label in result.labels():
        r = result.summary(label)
        if label == "fig2_edge_only":
            ref = r
        else:
            r["gain_pct"] = 100.0 * (1 - r["energy_mj"] / ref["energy_mj"])
            r["acc_loss_pct"] = (100.0 * (ref["f1"] - r["f1"])
                                 / max(ref["f1"], 1e-9))
            print(f"{label:34s} E={r['energy_mj']:8.0f} mJ "
                  f"gain={r['gain_pct']:5.1f}% "
                  f"F1={r['f1']:.3f} loss={r['acc_loss_pct']:4.1f}%",
                  flush=True)
        out[label] = r

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "paper_tables.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out
