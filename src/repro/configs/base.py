"""Configuration system for the repro framework.

Every assigned architecture is described by a :class:`ModelConfig`. Configs are
pure data (dataclasses) so they can be hashed into jit static args, printed into
EXPERIMENTS.md, and reduced for CPU smoke tests via :meth:`ModelConfig.reduced`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Input shapes (assigned, fixed by the task)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Sub-configs for architecture families
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    d_expert: int = 0            # per-expert FFN hidden size
    num_shared_experts: int = 0  # deepseek-style always-on experts
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    first_k_dense: int = 0       # deepseek: first k layers are dense MLP
    dense_d_ff: int = 0          # hidden size of those dense layers


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-V3)."""
    q_lora_rank: int = 0          # 0 => full-rank q projection
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD."""
    state_dim: int = 128
    head_dim: int = 64            # P in SSD
    num_heads: int = 0            # derived d_inner // head_dim if 0
    expand: int = 2
    chunk_size: int = 256
    conv_width: int = 4


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin RG-LRU hybrid."""
    lru_width: int = 0            # 0 => d_model
    window: int = 2_048           # local attention window
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")
    conv_width: int = 4


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: precomputed embeddings of the right shape."""
    kind: str = "none"            # 'none' | 'audio' | 'vision'
    num_tokens: int = 0           # frontend tokens prepended / encoder frames
    embed_dim: int = 0            # 0 => d_model


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # 'dense'|'moe'|'ssm'|'hybrid'|'encdec'|'vlm'|'audio'
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // num_heads
    source: str = ""              # citation (arXiv / HF model card)

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0       # 0 => full attention
    causal: bool = True

    # family sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)

    # enc-dec
    num_encoder_layers: int = 0
    encoder_seq_len: int = 0      # fixed encoder length (whisper: 1500)

    # extras
    num_mtp_modules: int = 0      # deepseek multi-token prediction
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    # numerics / impl toggles
    dtype: str = "bfloat16"
    remat: str = "full"           # 'none' | 'full' | 'dots'
    attention_impl: str = "xla"   # 'xla' | 'pallas'
    # §Perf: shard attention over query positions ('qseq' -> model axis) —
    # rescues archs whose head count does not divide the model axis
    context_parallel_attention: bool = False
    # 'gather' or 'one_hot': one-hot matmul embedding avoids GSPMD's gather
    # resharding pathology under the stacked-hypothesis (vmapped) trainer
    embedding_impl: str = "gather"
    # 'model' (train/prefill) or 'both' (decode): mesh axes for the MoE
    # dispatch buffer / expert weights (must agree — §Perf iteration 1b/1c)
    expert_parallel: str = "model"

    # serving capability flags
    supports_long_context: bool = False   # sub-quadratic decode at 500k
    supports_decode: bool = True
    max_decode_kv: int = 0        # 0 => unlimited; whisper caps decoder ctx

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived quantities -------------------------------------------------
    @property
    def q_heads_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    def param_count(self) -> int:
        """Approximate total parameter count (used for rooflines / MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                                    # embedding
        if not self.tie_embeddings:
            total += v * d                               # lm head
        total += self._block_params() * self.num_layers
        if self.moe is not None and self.moe.first_k_dense:
            # first k layers use a dense MLP instead of the MoE FFN
            moe_ffn = self._ffn_params()
            dense_ffn = 3 * d * (self.moe.dense_d_ff or self.d_ff)
            total += (dense_ffn - moe_ffn) * self.moe.first_k_dense
        if self.num_encoder_layers:
            total += self._encoder_block_params() * self.num_encoder_layers
        if self.num_mtp_modules:
            total += self._block_params() * self.num_mtp_modules + 2 * d * d
        return total

    def active_param_count(self) -> int:
        """Params active per token (MoE: only routed top-k + shared experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        m = self.moe
        moe_all = 3 * d * m.d_expert * m.num_experts
        moe_active = 3 * d * m.d_expert * (m.top_k + m.num_shared_experts)
        shared = 3 * d * m.d_expert * m.num_shared_experts
        per_layer_delta = (moe_all + shared) - moe_active
        return self.param_count() - per_layer_delta * self._num_moe_layers()

    def _num_moe_layers(self) -> int:
        if self.moe is None:
            return 0
        return self.num_layers - self.moe.first_k_dense + self.num_mtp_modules

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        if self.mla is not None:
            m = self.mla
            qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
            q_in = m.q_lora_rank if m.q_lora_rank else d
            p = 0
            if m.q_lora_rank:
                p += d * m.q_lora_rank
            p += q_in * self.num_heads * qk_head
            p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            p += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            p += self.num_heads * m.v_head_dim * d
            return p
        p = d * self.num_heads * hd            # q
        p += 2 * d * self.num_kv_heads * hd    # k, v
        p += self.num_heads * hd * d           # o
        if self.qkv_bias:
            p += (self.num_heads + 2 * self.num_kv_heads) * hd
        return p

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            m = self.moe
            p = d * m.num_experts                                  # router
            p += 3 * d * m.d_expert * m.num_experts                # routed (gated mlp)
            p += 3 * d * m.d_expert * m.num_shared_experts         # shared
            return p
        return 3 * d * self.d_ff                                   # gated mlp

    def _block_params(self) -> int:
        d = self.d_model
        if self.family == "ssm":
            s = self.ssm
            d_inner = s.expand * d
            nheads = s.num_heads or d_inner // s.head_dim
            p = d * (2 * d_inner + 2 * s.state_dim + nheads)   # in_proj (z,x,B,C,dt)
            p += d_inner * d                                   # out proj
            p += s.conv_width * (d_inner + 2 * s.state_dim)    # conv
            p += 2 * nheads + 2 * d                            # A, D, norms
            return p
        if self.family == "hybrid":
            r = self.rglru
            w = r.lru_width or d
            n_rec = sum(1 for x in r.pattern if x == "rglru")
            n_att = len(r.pattern) - n_rec
            rec = d * w * 3 + w * d + 3 * w + r.conv_width * w   # in/gates/out/conv
            att = self._attn_params()
            per = (n_rec * rec + n_att * att) / len(r.pattern)
            return int(per + self._ffn_params() + 2 * d)
        return self._attn_params() + self._ffn_params() + 2 * d

    def _encoder_block_params(self) -> int:
        return self._attn_params() + self._ffn_params() + 2 * self.d_model

    # -- reduced variant for CPU smoke tests --------------------------------
    def reduced(self) -> "ModelConfig":
        kw = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 256),
            remat="none",
            dtype="float32",
        )
        if self.num_kv_heads == self.num_heads:
            kw["num_kv_heads"] = kw["num_heads"]
        if self.num_kv_heads == 1:
            kw["num_kv_heads"] = 1
        if self.moe is not None:
            kw["moe"] = replace(self.moe, num_experts=4, top_k=2, d_expert=64,
                                first_k_dense=min(self.moe.first_k_dense, 1),
                                dense_d_ff=min(self.moe.dense_d_ff, 256))
        if self.mla is not None:
            kw["mla"] = replace(
                self.mla, q_lora_rank=(32 if self.mla.q_lora_rank else 0),
                kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                v_head_dim=16)
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, state_dim=16, head_dim=16, num_heads=0,
                                chunk_size=32)
        if self.rglru is not None:
            kw["rglru"] = replace(self.rglru, lru_width=0, window=32)
        if self.num_encoder_layers:
            kw["num_encoder_layers"] = 2
            kw["encoder_seq_len"] = min(self.encoder_seq_len, 64)
        if self.frontend.kind != "none":
            kw["frontend"] = replace(self.frontend, num_tokens=16, embed_dim=0)
        if self.num_mtp_modules:
            kw["num_mtp_modules"] = 1
        if self.sliding_window:
            kw["sliding_window"] = 32
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Train / HTL configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1_000
    min_lr_ratio: float = 0.1


@dataclass(frozen=True)
class HTLConfig:
    """Hypothesis-transfer training (the paper's technique, datacenter scale)."""
    mode: str = "a2a"             # 'a2a' | 'star' | 'sync' (baseline, no HTL)
    num_collectors: int = 4       # L virtual Data Collectors on the dc axis
    local_steps: int = 8          # H steps between hypothesis-transfer rounds
    mixing_steps: int = 8         # GreedyTL-style simplex mixing iterations
    mixing_lr: float = 0.5
    # 'gd': projected-gradient through the mixed model (closest to GreedyTL);
    # 'loss_softmax': weight each hypothesis by exp(-local_loss/tau) — first-
    # order variant that avoids differentiating through the mixture (§Perf:
    # sidesteps a GSPMD resharding pathology on vmapped gathers, XLA
    # b/433785288)
    mixing_mode: str = "gd"
    mixing_tau: float = 0.1
    unbalanced_zipf_alpha: float = 0.0   # >0 => Zipf token allocation across DCs
    aggregation_threshold: float = 0.0   # paper's data-aggregation heuristic


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    shape: InputShape
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    htl: Optional[HTLConfig] = None
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import all config modules lazily
        from repro.configs import ALL_ARCHS  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    from repro.configs import ALL_ARCHS  # noqa: F401
    return dict(_REGISTRY)


def asdict(cfg) -> dict:
    return dataclasses.asdict(cfg)
