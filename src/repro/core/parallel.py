"""Device-/process-sharded sweep execution with bitwise-parity guarantees.

The paper's headline numbers are sweeps (Tables 2-6: algorithm x technology
x ``p_edge`` x aggregation x seeds), and until now a sweep ran its
stacking groups sequentially on one host. This module scales the grid out
while keeping the repo's reproducibility contract — a parallel run must be
*JSON-identical* to the sequential run, so parallelism can never change a
published table:

* :func:`partition_runs` — a deterministic partitioner over
  ``SweepSpec.configs()`` rows. Rows are grouped by
  :func:`repro.core.scenario.stack_key` (groups are **never split** across
  shards, so every shard keeps its replica-stacking wins), each group is
  costed at ``windows x replicas`` (:func:`run_cost`), and groups are
  placed greedy-LPT onto the least-loaded shard. Group order is derived
  from (cost, canonical key) — not input order — so the partition is
  invariant to row permutations (tests/test_parallel_sweep.py).
* two execution backends behind the shared spec-string grammar of
  :mod:`repro.core.registry` (``get_executor("devices:n=8")``):

  - ``devices`` — shards run concurrently from one thread per shard, each
    pinned to a ``jax.devices()`` entry via ``jax.default_device`` (the
    stacked replica axis of every group stays whole on its shard's
    device). Testable on CPU via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
  - ``processes`` — a spawn-based worker pool runs whole shards and ships
    each shard's :class:`~repro.core.experiment.SweepResult` back as a
    JSON payload (plus its jitted-dispatch counts); the parent merges
    payloads into one order-stable result. Worker traffic is guarded by
    :func:`assert_host_only`: no jax device buffers ever cross the pool
    boundary, and per-worker jit/eval caches are process-isolated by
    construction.

Both backends run every group through exactly the same stacked engines in
exactly the same within-group order as ``parallel="none"``, so results are
bitwise identical, not merely close (the parallel-parity gate in
scripts/verify.sh diffs the serialized JSON). Dispatch counts are threaded
back to the parent counter (:func:`repro.core.dispatch.
merge_dispatch_counts`), so the O(buckets)-dispatches-per-window CI gate
holds per shard too. See DESIGN.md §7.

A third out-of-process backend, ``hosts`` (:mod:`repro.core.launcher`,
DESIGN.md §8), scales the same partition/merge beyond one machine:
shards ship as JSON payloads produced by the shared shard runner
(:func:`run_shard_payload`) to local-subprocess / ssh / slurm worker
channels, with shard-level retry on worker loss — same bitwise contract,
gated by scripts/hosts_parity.py.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dispatch import dispatch_counts, merge_dispatch_counts
from repro.core.registry import register_factory, resolve_spec
from repro.core.scenario import (ScenarioConfig, ScenarioResult, run_sweep,
                                 stack_groups, stack_key)
from repro.data.synthetic_covtype import Dataset


# ---------------------------------------------------------------------------
# cost model + partitioner
# ---------------------------------------------------------------------------

def run_cost(cfg: ScenarioConfig) -> float:
    """Estimated cost of one run: its window count. A stacking group of R
    replicas therefore costs ``windows x R`` — the group runs one stacked
    dispatch set per window, and per-window host work grows with R."""
    return float(cfg.windows)


def partition_runs(cfgs: Sequence[ScenarioConfig], n_shards: int, *,
                   key_fn: Callable[[ScenarioConfig], Any] = stack_key,
                   cost_fn: Callable[[ScenarioConfig], float] = run_cost
                   ) -> List[List[int]]:
    """Split run indices into ``n_shards`` shards, stack-key groups atomic.

    Contract (property-tested):

    * every index appears in exactly one shard;
    * rows with equal ``key_fn`` stay on one shard (so replica stacking
      inside :func:`~repro.core.scenario.run_sweep` sees the same groups a
      sequential run would);
    * greedy LPT balance: the max shard cost is at most twice the ideal
      ``max(total / n_shards, max_group_cost)``;
    * the grouping of configs onto shards is invariant to the input order
      of the rows (groups are placed in (cost desc, canonical key) order,
      never first-appearance order).

    Shards may be empty when there are fewer groups than shards. Within a
    shard, indices stay ascending, so per-shard execution preserves the
    original relative run order.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    placed = sorted(
        ((sum(cost_fn(cfgs[i]) for i in idxs),
          repr(key_fn(cfgs[idxs[0]])), idxs)
         for idxs in stack_groups(cfgs, key_fn)),
        key=lambda rec: (-rec[0], rec[1]))
    loads = [0.0] * n_shards
    shards: List[List[int]] = [[] for _ in range(n_shards)]
    for cost, _, idxs in placed:
        k = min(range(n_shards), key=lambda j: loads[j])
        loads[k] += cost
        shards[k].extend(idxs)
    for s in shards:
        s.sort()
    return shards


# ---------------------------------------------------------------------------
# host-only payload guard (the process-pool boundary)
# ---------------------------------------------------------------------------

def assert_host_only(obj: Any, where: str = "payload") -> None:
    """Refuse jax device buffers in inter-process payloads.

    Pickling a ``jax.Array`` drags a device buffer (and on real hardware a
    device sync) through the worker queue; every array crossing the pool
    boundary must be host-side numpy. Walks nested containers; numpy
    arrays, dataclass-like plain values and strings pass."""
    import jax

    stack = [obj]
    while stack:
        o = stack.pop()
        if isinstance(o, jax.Array):
            raise TypeError(
                f"jax device buffer in inter-process {where}: "
                f"{type(o).__name__} with shape {getattr(o, 'shape', '?')}; "
                f"convert to numpy before crossing the pool boundary")
        if isinstance(o, np.ndarray):
            continue
        if isinstance(o, dict):
            stack.extend(o.keys())
            stack.extend(o.values())
        elif isinstance(o, (list, tuple, set, frozenset)):
            stack.extend(o)
        elif dataclasses_fields := getattr(o, "__dataclass_fields__", None):
            stack.extend(getattr(o, f) for f in dataclasses_fields)


# ---------------------------------------------------------------------------
# execution backends
# ---------------------------------------------------------------------------

class SweepExecutor:
    """Backend protocol: evaluate labelled runs, results in input order."""

    def execute(self, labels: Sequence[str],
                cfgs: Sequence[ScenarioConfig], data: Dataset, *,
                stack: bool) -> List[ScenarioResult]:
        raise NotImplementedError

    def execute_with_meta(self, labels: Sequence[str],
                          cfgs: Sequence[ScenarioConfig], data: Dataset, *,
                          stack: bool
                          ) -> Tuple[List[ScenarioResult], Dict[str, Any]]:
        """Evaluate and additionally return execution metadata (attempt
        logs, channel info, ...) for ``SweepResult.meta``. Metadata is a
        side channel: it never enters the serialized result, so backends
        that populate it keep the bitwise-parity contract intact. The
        default backend has nothing to report."""
        return self.execute(labels, cfgs, data, stack=stack), {}


class _SequentialExecutor(SweepExecutor):
    """``parallel="none"``: the existing single-host path, verbatim."""

    def execute(self, labels, cfgs, data, *, stack):
        return run_sweep(list(cfgs), data, stack_seeds=stack)


class _DeviceShardExecutor(SweepExecutor):
    """``parallel="devices:n=K"``: K shards, one thread per shard, each
    pinned to a ``jax.devices()`` entry (round-robin when K exceeds the
    device count). Every shard runs the standard stacked ``run_sweep``
    under ``jax.default_device``, so the computation per group is the
    sequential computation placed on a different device — values are
    bitwise identical, only placement and overlap change."""

    def __init__(self, n: Optional[int] = None):
        if n is not None and n < 1:
            raise ValueError(f"devices executor needs n >= 1, got {n}")
        self.n = n

    def execute(self, labels, cfgs, data, *, stack):
        import jax

        devices = jax.devices()
        n = self.n if self.n is not None else len(devices)
        shards = [s for s in partition_runs(cfgs, n) if s]
        results: List[Optional[ScenarioResult]] = [None] * len(cfgs)

        def run_shard(k: int) -> List[ScenarioResult]:
            with jax.default_device(devices[k % len(devices)]):
                return run_sweep([cfgs[i] for i in shards[k]], data,
                                 stack_seeds=stack)

        if len(shards) <= 1:
            outs = [run_shard(k) for k in range(len(shards))]
        else:
            workers = max(1, min(len(shards), len(devices)))
            with ThreadPoolExecutor(max_workers=workers) as pool:
                outs = list(pool.map(run_shard, range(len(shards))))
        for idxs, rs in zip(shards, outs):
            for i, r in zip(idxs, rs):
                results[i] = r
        return results


def run_shard_payload(labels: Sequence[str], cfgs: Sequence[ScenarioConfig],
                      data: Dataset, stack: bool) -> Tuple[str, dict]:
    """Run one whole shard and return its transport-agnostic wire form:
    the shard's :class:`~repro.core.experiment.SweepResult` serialized as
    JSON plus the jitted-dispatch counts the shard incurred. This is the
    single shard-runner shared by every out-of-process backend — the
    spawn-pool worker below and the multi-host launcher workers
    (:mod:`repro.core.launcher`) — so the payload schema cannot drift
    between transports."""
    from repro.core.dispatch import reset_dispatch_counts
    from repro.core.experiment import SweepResult, records_from

    # per-shard counts: one worker may execute several shards, and the
    # parent merges every returned snapshot, so counts must not
    # accumulate across tasks
    reset_dispatch_counts()
    results = run_sweep(list(cfgs), data, stack_seeds=stack)
    records = records_from(labels, results)
    payload = SweepResult(name="shard", records=records).to_json(indent=0)
    return payload, dispatch_counts()


class ShardMerger:
    """Incremental, order-stable merge of per-shard wire payloads.

    The barrier-free counterpart of the all-at-once merge below (and the
    machinery under it): shards write to disjoint run-index slots, so they
    may arrive in *any* order — as NDJSON events stream in from the sweep
    service (:mod:`repro.service`), as launcher retries land late, or
    twice after a client reconnect replays part of a stream — and the
    merged run list is identical to the sequential run's regardless
    (property-tested in tests/test_sweep_service.py). All mutation is
    lock-guarded: one merger may be fed from several streaming jobs'
    threads, and each shard's dispatch counts fold into the process
    counter exactly once even if its payload is replayed."""

    def __init__(self, n_runs: int, shards: Sequence[Sequence[int]]):
        self.shards = [list(s) for s in shards]
        self._results: List[Optional[ScenarioResult]] = [None] * n_runs
        self._done: set = set()
        self._lock = threading.Lock()

    def add(self, shard: int, payload: str, counts: dict) -> bool:
        """Fold one shard's payload in; returns False (and does nothing)
        when that shard was already merged — replays after a reconnect are
        idempotent by construction."""
        from repro.core.experiment import SweepResult

        idxs = self.shards[shard]
        shard_result = SweepResult.from_json(payload)
        if len(shard_result.records) != len(idxs):
            raise ValueError(
                f"shard payload carries {len(shard_result.records)} records "
                f"for a {len(idxs)}-run shard")
        with self._lock:
            if shard in self._done:
                return False
            self._done.add(shard)
            merge_dispatch_counts(counts)
            for i, rec in zip(idxs, shard_result.records):
                self._results[i] = rec.to_scenario_result()
        return True

    def pending(self) -> List[int]:
        with self._lock:
            return [k for k in range(len(self.shards))
                    if k not in self._done]

    def results(self) -> List[ScenarioResult]:
        """The full merged run list; raises if any shard is still missing
        (an incremental merge is only a result once every shard landed)."""
        missing = self.pending()
        if missing:
            raise ValueError(f"shard(s) {missing} not merged yet")
        with self._lock:
            return list(self._results)


def merge_shard_payloads(n_runs: int, shards: Sequence[Sequence[int]],
                         outs: Sequence[Tuple[str, dict]]
                         ) -> List[ScenarioResult]:
    """Order-stable merge of per-shard wire payloads back into the full
    run list: shard k's i-th record lands at the i-th index of shard k's
    partition slot, and every shard's dispatch counts fold into the parent
    counter (so the dispatch CI gate stays observable per shard). Shared
    by the processes backend and the hosts launcher; the streaming sweep
    service merges the same payloads incrementally via
    :class:`ShardMerger` (which this wraps), so the two paths cannot
    drift."""
    merger = ShardMerger(n_runs, shards)
    for k, (payload, counts) in enumerate(outs):
        merger.add(k, payload, counts)
    return merger.results()


def _worker_run_shard(task: Tuple[List[str], List[ScenarioConfig],
                                  Dataset, bool]) -> Tuple[str, dict]:
    """Process-pool worker: run one whole shard via the shared shard
    runner. Runs in a spawned interpreter — jit caches, EvalCache and
    dispatch counters are all process-local, so workers never share (or
    ship) device state."""
    labels, cfgs, data, stack = task
    return run_shard_payload(labels, cfgs, data, stack)


class _ProcessShardExecutor(SweepExecutor):
    """``parallel="processes:n=K"``: a spawn-based pool runs whole shards;
    per-shard ``SweepResult`` JSON payloads merge back order-stably.

    ``spawn`` (not ``fork``) because the parent may hold an initialized
    jax runtime whose internal threads do not survive forking. Inbound
    payloads are host-only (:func:`assert_host_only`), and the shard
    result travels back as serialized JSON text plus a plain count dict,
    so no array object of any kind crosses the queue. Worker dispatch
    counts merge into the parent counter, keeping the dispatch CI gate
    observable per shard."""

    def __init__(self, n: int = 2):
        if n < 1:
            raise ValueError(f"processes executor needs n >= 1, got {n}")
        self.n = n

    def execute(self, labels, cfgs, data, *, stack):
        import multiprocessing as mp

        shards = [s for s in partition_runs(cfgs, self.n) if s]
        tasks = []
        for idxs in shards:
            task = ([labels[i] for i in idxs], [cfgs[i] for i in idxs],
                    data, stack)
            assert_host_only(task, where="shard task")
            tasks.append(task)
        if not shards:
            return []
        # always a real pool — even for one shard — so the isolation
        # contract (worker-local jit/eval caches, host-only queue traffic)
        # does not silently depend on the shard count
        ctx = mp.get_context("spawn")
        with ctx.Pool(processes=min(self.n, len(shards))) as pool:
            outs = pool.map(_worker_run_shard, tasks)
        return merge_shard_payloads(len(cfgs), shards, outs)


# ---------------------------------------------------------------------------
# executor registry (shared spec grammar: "devices:n=8", "processes:n=2",
# "hosts:channel=local,n=4,retries=2")
# ---------------------------------------------------------------------------

def _hosts_factory(**params) -> SweepExecutor:
    """``"hosts:channel=...,n=K,retries=R"``: the multi-host launcher
    (:mod:`repro.core.launcher`) — shards dispatched to independent host
    processes through a pluggable ``HostChannel`` (``local`` subprocesses,
    ``ssh`` remotes, ``slurm`` array jobs) with shard-level retry.
    Imported lazily: the launcher builds on this module."""
    from repro.core.launcher import HostsExecutor
    return HostsExecutor(**params)


EXECUTORS: Dict[str, Callable[..., SweepExecutor]] = {
    "none": _SequentialExecutor,
    "devices": _DeviceShardExecutor,
    "processes": _ProcessShardExecutor,
    "hosts": _hosts_factory,
}

_EXECUTOR_CACHE: Dict[str, SweepExecutor] = {}


def register_executor(name: str,
                      factory: Callable[..., SweepExecutor]) -> None:
    """Register a sweep-executor factory under a spec name."""
    register_factory(EXECUTORS, name, factory, "sweep executor")


def get_executor(spec: str) -> SweepExecutor:
    """Resolve an executor spec string (``"none"``, ``"devices:n=8"``,
    ``"processes:n=2"``) to a cached executor; :class:`KeyError` on
    unknown names / malformed specs, :class:`ValueError` on bad ``n``."""
    return resolve_spec(spec, EXECUTORS, _EXECUTOR_CACHE, "sweep executor")
