"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


def cosine_warmup_schedule(cfg: OptimizerConfig):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = cfg.lr * step / jnp.maximum(1.0, cfg.warmup_steps)
        denom = jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps)
        frac = jnp.clip((step - cfg.warmup_steps) / denom, 0.0, 1.0)
        cos = cfg.min_lr_ratio * cfg.lr + 0.5 * (1 - cfg.min_lr_ratio) * cfg.lr * (
            1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr
