"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 1:2
[arXiv:2402.19427].

38L, d_model=4096, 16H MQA (kv=1), d_ff=12288, vocab=256000. Pattern is
(rglru, rglru, attn) repeating; local attention window 2048. Bounded state
=> runs long_500k decode.
"""
from repro.configs.base import ModelConfig, RGLRUConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    rglru=RGLRUConfig(lru_width=4096, window=2048,
                      pattern=("rglru", "rglru", "attn"), conv_width=4),
    tie_embeddings=True,
    supports_long_context=True,
    source="arXiv:2402.19427",
))
