"""Minimal batched serving engine: prefill once, decode greedily/sampled.

This is the CPU-scale engine used by the examples and integration tests; the
production path is ``repro.launch.serve`` which lowers the same
``decode_step`` under the multi-pod mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.serving.cache_utils import pad_cache


class ServeEngine:
    def __init__(self, model: Model, params, max_new_tokens: int = 32):
        self.model = model
        self.params = params
        self.max_new = max_new_tokens
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step)

    def generate(self, batch: dict, *, temperature: float = 0.0,
                 key: Optional[jax.Array] = None):
        """batch: same structure as training batch (tokens + frontend).

        Returns (B, max_new) generated token ids (greedy if temperature=0).
        """
        cfg = self.model.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        n_front = cfg.frontend.num_tokens if cfg.family == "vlm" else 0
        logits, cache = self._prefill(self.params, batch)
        cache = pad_cache(self.model, cache, self.max_new, B, S + n_front)

        out = []
        pos = S + n_front
        for i in range(self.max_new):
            if temperature > 0.0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(sub, logits / temperature)
            else:
                tok = jnp.argmax(logits, axis=-1)
            out.append(tok)
            logits, cache = self._decode(
                self.params, cache, tok[:, None].astype(jnp.int32),
                jnp.asarray(pos + i, jnp.int32))
        return jnp.stack(out, axis=1)
