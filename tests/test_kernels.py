"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles in ref.py
(interpret mode on CPU — kernel bodies execute in Python)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import mha_reference, rglru_reference, ssd_reference
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ssd_scan import ssd_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,Sq,Skv,d,causal,window",
    [
        (2, 4, 2, 256, 256, 64, True, 0),     # GQA causal
        (1, 8, 8, 128, 384, 64, True, 0),     # MHA, kv longer (decode-ish)
        (2, 4, 1, 256, 256, 128, True, 64),   # MQA + sliding window
        (1, 2, 2, 192, 192, 64, False, 0),    # bidirectional, ragged blocks
        (1, 4, 4, 64, 64, 32, True, 0),       # small head dim
    ])
def test_flash_attention_sweep(B, H, KV, Sq, Skv, d, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, d), dtype)
    k = jax.random.normal(ks[1], (B, KV, Skv, d), dtype)
    v = jax.random.normal(ks[2], (B, KV, Skv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < TOL[dtype], f"err={err}"


def test_flash_attention_q_offset_decode():
    """Decode semantics: 1 query at position T attends to all T+1 keys."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, H, d, T = 2, 4, 64, 128
    q = jax.random.normal(ks[0], (B, H, 1, d))
    k = jax.random.normal(ks[1], (B, H, T, d))
    v = jax.random.normal(ks[2], (B, H, T, d))
    out = flash_attention(q, k, v, causal=True, q_offset=T - 1,
                          interpret=True)
    ref = mha_reference(q, k, v, causal=True, q_offset=T - 1)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 256, 4, 64, 32, 64),
    (1, 128, 2, 32, 64, 128),
    (2, 512, 8, 64, 128, 128),
    (1, 256, 1, 128, 16, 32),
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = (jax.random.normal(ks[3], (B, S, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, N)) * 0.5).astype(dtype)
    y, st = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, sr = ssd_reference(x, dt, A, Bm, Cm)
    scale = float(jnp.max(jnp.abs(yr.astype(jnp.float32)))) + 1e-9
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - yr.astype(jnp.float32)))) / scale
    tol = 3e-5 if dtype == jnp.float32 else 5e-2
    assert err < tol, f"err={err}"
    sscale = float(jnp.max(jnp.abs(sr.astype(jnp.float32)))) + 1e-9
    serr = float(jnp.max(jnp.abs(st.astype(jnp.float32)
                                 - sr.astype(jnp.float32)))) / sscale
    assert serr < tol, f"state err={serr}"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,W,chunk,bw", [
    (2, 256, 256, 64, 128),
    (1, 128, 128, 128, 128),
    (3, 512, 384, 128, 128),
    (1, 64, 512, 32, 256),
])
def test_rglru_scan_sweep(B, S, W, chunk, bw, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))).astype(dtype)
    b = (jax.random.normal(ks[1], (B, S, W)) * 0.5).astype(dtype)
    h = rglru_scan(a, b, chunk=chunk, block_w=bw, interpret=True)
    hr = rglru_reference(a, b)
    err = float(jnp.max(jnp.abs(h.astype(jnp.float32)
                                - hr.astype(jnp.float32))))
    assert err < (1e-4 if dtype == jnp.float32 else 5e-2), f"err={err}"


def test_models_agree_xla_vs_pallas():
    """End-to-end: loss with attention_impl='pallas' == 'xla' reference."""
    import dataclasses

    from repro.configs import get_config
    from repro.data.pipeline import make_lm_batch
    from repro.models import build_model

    for arch in ["llama3.2-3b", "mamba2-1.3b", "recurrentgemma-9b"]:
        cfg = get_config(arch).reduced()
        m_x = build_model(cfg)
        m_p = build_model(dataclasses.replace(cfg, attention_impl="pallas"))
        params = m_x.init(jax.random.PRNGKey(0))
        batch = make_lm_batch(cfg.vocab_size, 2, 128, d_model=cfg.d_model)
        lx, _ = jax.jit(m_x.loss_fn)(params, batch)
        lp, _ = jax.jit(m_p.loss_fn)(params, batch)
        assert abs(float(lx) - float(lp)) < 1e-3, arch
