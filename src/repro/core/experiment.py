"""Experiment API v1: declarative sweeps over scenario grids.

The paper's results are a grid — algorithm x technology x offload fraction
x allocation policy (Tables 2-6) — and every driver in this repo
(benchmarks, ablations, examples, CI smoke) is some slice of such a grid.
This module gives that surface a declarative form:

* :class:`SweepSpec` — named axes over a base :class:`ScenarioConfig`,
  expanded cartesian (nested-loop order) or zipped, with per-row label
  templates, row ``variants`` (an innermost axis of label/override pairs),
  seed replication and union composition, so a whole paper table is one
  literal instead of a hand-rolled loop nest.
* :class:`SweepResult` — the typed result: one :class:`RunRecord` per
  (label, seed) run carrying the full F1 curve and energy-event ledger,
  with JSON round-trip serialization and per-label summary statistics
  (the aggregation previously re-implemented ad hoc by every benchmark).
* named presets (:func:`get_preset`) — the paper's Tables 2-6 grid
  (``"paper_tables"``), the energy/accuracy trade-off example grid, a CI
  smoke grid, and a mesh/BLE/LoRa technology grid over the parameterized
  transport registry.

``SweepSpec.run(data, stack="auto")`` evaluates the grid through
:func:`repro.core.scenario.run_sweep` with metadata-driven replica
stacking (configs differing only in ``host_side`` fields share one
dispatch set per window); ``run_scenario``/``run_sweep`` remain as the
thin compatibility layer underneath, so the two paths are value-identical
by construction (tests/test_experiment.py). ``run(..., parallel=
"devices:n=K" | "processes:n=K")`` shards the grid across devices or
worker processes (:mod:`repro.core.parallel`) with stack-key groups kept
atomic, reproducing the sequential result bitwise (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.energy import Ledger
from repro.core.scenario import (ScenarioConfig, ScenarioResult,
                                 validate_config)
from repro.data.synthetic_covtype import Dataset

LABEL_AXIS = "_label"     # reserved zip-axis name: explicit per-row labels


# ---------------------------------------------------------------------------
# SweepSpec
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SweepSpec:
    """A declarative scenario grid.

    ``axes`` maps config-field names to value tuples; ``mode="cartesian"``
    expands their product in declaration order (first axis outermost,
    exactly a nested ``for`` loop), ``mode="zip"`` walks them in lockstep.
    The reserved axis ``"_label"`` (zip mode) gives explicit row labels;
    otherwise ``label`` is a ``str.format`` template over the axis values,
    falling back to ``name_axis=value_...``. ``variants`` is an innermost
    axis of ``(label_template, {field: value})`` pairs — the idiom for
    paired table rows like "same cell with and without aggregation".
    ``seeds`` replicates every expanded row (seeds innermost, matching the
    legacy benchmark layout); empty means "keep each row's own seed".
    Specs compose by union (:meth:`union`), which simply concatenates
    expansions.
    """

    name: str = "sweep"
    base: ScenarioConfig = field(default_factory=ScenarioConfig)
    axes: Any = ()                  # Mapping | tuple of (name, values)
    mode: str = "cartesian"         # 'cartesian' | 'zip'
    label: str = ""
    variants: Tuple[Tuple[str, Any], ...] = ()
    seeds: Tuple[int, ...] = ()
    subspecs: Tuple["SweepSpec", ...] = ()

    def __post_init__(self):
        axes = self.axes
        if isinstance(axes, Mapping):
            axes = tuple((k, tuple(v)) for k, v in axes.items())
        else:
            axes = tuple((k, tuple(v)) for k, v in axes)
        object.__setattr__(self, "axes", axes)
        object.__setattr__(self, "seeds", tuple(self.seeds))
        object.__setattr__(
            self, "variants",
            tuple((tmpl, dict(ov)) for tmpl, ov in self.variants))
        if self.mode not in ("cartesian", "zip"):
            raise ValueError(f"unknown sweep mode {self.mode!r} "
                             f"(want 'cartesian' or 'zip')")
        if self.subspecs and self.axes:
            raise ValueError("a union SweepSpec cannot carry its own axes")
        names = [n for n, _ in self.axes]
        cfg_fields = {f.name for f in dataclasses.fields(ScenarioConfig)}
        for n in names:
            if n != LABEL_AXIS and n not in cfg_fields:
                raise ValueError(f"unknown sweep axis {n!r}; ScenarioConfig "
                                 f"fields: {sorted(cfg_fields)}")
        if names.count(LABEL_AXIS) and self.mode != "zip":
            raise ValueError("the _label axis requires mode='zip'")
        if self.mode == "zip" and self.axes:
            lens = {len(v) for _, v in self.axes}
            if len(lens) > 1:
                raise ValueError(f"zip-mode axes must have equal lengths, "
                                 f"got {dict((n, len(v)) for n, v in self.axes)}")

    # -- composition --------------------------------------------------------
    @classmethod
    def union(cls, name: str, *specs: "SweepSpec",
              seeds: Sequence[int] = ()) -> "SweepSpec":
        """Concatenate several specs into one grid (expansion order is the
        argument order); ``seeds`` replicates every row of the union.
        Subspecs must not carry their own seeds — expansion works on
        logical rows, so nested seed replication would be silently
        dropped; declare seeds once, on the union."""
        seeded = [s.name for s in specs if s.seeds]
        if seeded:
            raise ValueError(f"subspec(s) {seeded} carry their own seeds; "
                             f"set seeds on the union instead")
        return cls(name=name, subspecs=tuple(specs), seeds=tuple(seeds))

    def with_seeds(self, n_or_seeds) -> "SweepSpec":
        """``3`` -> seeds (0, 1, 2); a sequence is taken verbatim."""
        seeds = (tuple(range(n_or_seeds)) if isinstance(n_or_seeds, int)
                 else tuple(n_or_seeds))
        return dataclasses.replace(self, seeds=seeds)

    # -- expansion ----------------------------------------------------------
    def rows(self) -> List[Tuple[str, ScenarioConfig]]:
        """The logical grid: ``(label, config)`` per row, seeds NOT yet
        replicated. Labels must be unique across the whole grid."""
        out = self._expand()
        seen: Dict[str, int] = {}
        for lbl, _ in out:
            seen[lbl] = seen.get(lbl, 0) + 1
        dups = sorted(lbl for lbl, k in seen.items() if k > 1)
        if dups:
            raise ValueError(f"duplicate sweep labels {dups}; make the "
                             f"label template mention every varying axis")
        return out

    def _expand(self) -> List[Tuple[str, ScenarioConfig]]:
        if self.subspecs:
            return [row for s in self.subspecs for row in s._expand()]
        names = [n for n, _ in self.axes]
        values = [v for _, v in self.axes]
        if not names:
            combos = [()]
        elif self.mode == "zip":
            combos = list(zip(*values))
        else:
            combos = list(itertools.product(*values))
        variants = self.variants or ((self.label, {}),)
        out: List[Tuple[str, ScenarioConfig]] = []
        for vals in combos:
            point = dict(zip(names, vals))
            explicit = point.pop(LABEL_AXIS, None)
            for tmpl, overrides in variants:
                cfg = dataclasses.replace(self.base, **point, **overrides)
                if explicit is not None:
                    lbl = str(explicit)
                elif tmpl:
                    lbl = tmpl.format(**point)
                else:
                    lbl = "_".join([self.name] + [f"{k}={v}"
                                                  for k, v in point.items()])
                out.append((lbl, cfg))
        return out

    def configs(self) -> List[Tuple[str, ScenarioConfig]]:
        """The physical run list: rows replicated over ``seeds`` (seeds
        innermost — ``row0/seed0, row0/seed1, row1/seed0, ...``)."""
        rows = self.rows()
        if not self.seeds:
            return rows
        return [(lbl, dataclasses.replace(cfg, seed=s))
                for lbl, cfg in rows for s in self.seeds]

    # -- wire form + canonical hashing (sweep service, DESIGN.md §12) -------
    WIRE_SCHEMA = 1

    def to_wire(self) -> Dict[str, Any]:
        """JSON-safe dict form of the whole spec tree — the sweep service's
        submit payload (:mod:`repro.service`). Pure data: axes values,
        variant overrides and the base config must already be JSON-safe
        (they are for every ScenarioConfig field), so
        ``from_wire(json.loads(json.dumps(to_wire())))`` reconstructs a
        spec with an identical expansion."""
        return {
            "schema": self.WIRE_SCHEMA,
            "name": self.name,
            "base": dataclasses.asdict(self.base),
            "axes": [[n, list(v)] for n, v in self.axes],
            "mode": self.mode,
            "label": self.label,
            "variants": [[tmpl, dict(ov)] for tmpl, ov in self.variants],
            "seeds": list(self.seeds),
            "subspecs": [s.to_wire() for s in self.subspecs],
        }

    @classmethod
    def from_wire(cls, payload: Mapping[str, Any]) -> "SweepSpec":
        if payload.get("schema") != cls.WIRE_SCHEMA:
            raise ValueError(f"unsupported SweepSpec wire schema "
                             f"{payload.get('schema')!r} (this build reads "
                             f"{cls.WIRE_SCHEMA})")
        return cls(
            name=payload["name"],
            base=ScenarioConfig(**payload["base"]),
            axes=tuple((n, tuple(v)) for n, v in payload["axes"]),
            mode=payload["mode"],
            label=payload["label"],
            variants=tuple((tmpl, dict(ov))
                           for tmpl, ov in payload["variants"]),
            seeds=tuple(payload["seeds"]),
            subspecs=tuple(cls.from_wire(s)
                           for s in payload["subspecs"]))

    def canonical_hash(self) -> str:
        """Content hash of the *physical run list* — the exact-result-cache
        key component (repro.service.cache, DESIGN.md §12).

        Hashes the expanded ``configs()`` (labels + full config dicts) as
        canonical JSON (sorted keys, compact separators), NOT the spec
        tree, so the hash is invariant to dict key order, to process
        restarts (no ids/addresses enter the digest) and to any spec
        refactoring that expands to the same runs — while any axis-value,
        variant, seed or base-field change lands in some config dict and
        changes the digest. Property-tested in tests/test_service_cache.py.
        """
        runs = [[lbl, dataclasses.asdict(cfg)] for lbl, cfg in
                self.configs()]
        blob = json.dumps({"schema": self.WIRE_SCHEMA, "runs": runs},
                          sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode("utf-8")).hexdigest()

    # -- execution ----------------------------------------------------------
    def run(self, data: Dataset, *, stack: str = "auto",
            parallel: str = "none") -> "SweepResult":
        """Evaluate the grid. ``stack="auto"`` runs metadata-derived
        stack-compatible groups replica-stacked (one dispatch set per
        window per group); ``stack="off"`` runs every config
        sequentially. Both go through the same engines, so they agree to
        the engine-parity tolerance.

        ``parallel`` picks the execution backend by spec string
        (:func:`repro.core.parallel.get_executor`): ``"none"`` (this
        host, sequential over stacking groups), ``"devices:n=K"`` (K
        shards threaded over ``jax.devices()``), ``"processes:n=K"``
        (spawned worker pool) or ``"hosts:channel=...,n=K,retries=R"``
        (the multi-host launcher of :mod:`repro.core.launcher`: local
        subprocess / ssh / slurm channels with shard-level retry).
        Stack-key groups are never split across shards, so every backend
        runs the same stacked computations in the same within-group
        order — results are bitwise identical across backends
        (tests/test_parallel_sweep.py, tests/test_launcher.py;
        DESIGN.md §7–§8). Backends may report execution metadata (e.g.
        the launcher's per-shard attempt log) through the out-of-band
        ``SweepResult.meta`` field."""
        from repro.core.parallel import get_executor

        if stack not in ("auto", "off"):
            raise ValueError(f"stack must be 'auto' or 'off', got {stack!r}")
        executor = get_executor(parallel)
        runs = self.configs()
        for _, cfg in runs:
            validate_config(cfg)
        results, exec_meta = executor.execute_with_meta(
            [lbl for lbl, _ in runs], [cfg for _, cfg in runs], data,
            stack=(stack == "auto"))
        records = records_from([lbl for lbl, _ in runs], results)
        out = SweepResult(name=self.name, records=records)
        if exec_meta:
            out.meta.update(exec_meta)
        return out


# ---------------------------------------------------------------------------
# SweepResult
# ---------------------------------------------------------------------------

@dataclass
class RunRecord:
    """One (label, seed) run: config, F1 curve, full energy-event ledger."""
    label: str
    cfg: ScenarioConfig
    f1_curve: List[float]
    events: List[dict]

    def to_scenario_result(self) -> ScenarioResult:
        return ScenarioResult(list(self.f1_curve), Ledger(list(self.events)),
                              self.cfg)


def records_from(labels: Sequence[str], results: Sequence[ScenarioResult]
                 ) -> List[RunRecord]:
    """Label a batch of scenario results — the single record-building path
    for both :meth:`SweepSpec.run` and the process-pool shard workers
    (:mod:`repro.core.parallel`), so the record schema cannot drift
    between backends."""
    return [RunRecord(label=lbl, cfg=r.cfg, f1_curve=list(r.f1_curve),
                      events=list(r.ledger.events))
            for lbl, r in zip(labels, results)]


@dataclass
class SweepResult:
    """Structured sweep output: per-run records + per-label aggregation.

    JSON round-trips losslessly (``from_json(r.to_json()) == r``), so
    benchmark outputs become reloadable artifacts instead of write-only
    dicts.

    ``meta`` is an out-of-band side channel for execution metadata — the
    multi-host launcher's per-shard attempt log lands here
    (``meta["launcher"]``, DESIGN.md §8). It is excluded from equality
    and from ``to_json`` by default, so two runs of the same grid compare
    and serialize identically however (and however faultily) they were
    executed — the bitwise-parity contract never sees it. Pass
    ``include_meta=True`` to serialize it for operator forensics."""
    name: str
    records: List[RunRecord]
    _summaries: Dict[str, Dict[str, Any]] = field(
        default_factory=dict, compare=False, repr=False)
    meta: Dict[str, Any] = field(
        default_factory=dict, compare=False, repr=False)
    SCHEMA = 1

    def labels(self) -> List[str]:
        """Unique labels, first-appearance order."""
        out, seen = [], set()
        for r in self.records:
            if r.label not in seen:
                seen.add(r.label)
                out.append(r.label)
        return out

    def select(self, label: str) -> List[ScenarioResult]:
        rs = [r.to_scenario_result() for r in self.records
              if r.label == label]
        if not rs:
            raise KeyError(f"no runs labelled {label!r}; have "
                           f"{self.labels()}")
        return rs

    def summary(self, label: str) -> Dict[str, Any]:
        """Aggregate a label's seed replicas: converged F1 (mean/std over
        seeds), mean energies by purpose, mean F1 curve — the row format
        of the paper-table benchmarks. Memoized per label (records are
        immutable in practice); callers get a fresh shallow copy, so
        annotating the returned dict never pollutes the cache."""
        cached = self._summaries.get(label)
        if cached is None:
            rs = self.select(label)
            curves = np.array([r.f1_curve for r in rs])
            cached = self._summaries[label] = {
                "f1": float(np.mean([r.converged_f1() for r in rs])),
                "f1_std": float(np.std([r.converged_f1() for r in rs])),
                "energy_mj": float(np.mean([r.energy_total for r in rs])),
                "collection_mj": float(np.mean([r.energy_collection
                                                for r in rs])),
                "learning_mj": float(np.mean([r.energy_learning
                                              for r in rs])),
                "f1_curve": [float(v) for v in curves.mean(axis=0)],
            }
        return dict(cached)

    def summaries(self) -> Dict[str, Dict[str, Any]]:
        return {lbl: self.summary(lbl) for lbl in self.labels()}

    # -- paging (sweep-service result endpoint, DESIGN.md §12) --------------
    def page(self, page: int, per_page: int) -> "SweepResult":
        """A record slice as its own :class:`SweepResult` (records
        ``[page*per_page, (page+1)*per_page)``, original order). Paging
        bookkeeping rides the out-of-band ``meta`` side channel
        (``meta["paging"]``), so a page serializes exactly like any other
        result and the full-result bytes stay the concatenation-free
        parity surface. An out-of-range page is an empty page, not an
        error — clients walk pages until one comes back empty."""
        if page < 0 or per_page < 1:
            raise ValueError(f"need page >= 0 and per_page >= 1, got "
                             f"page={page} per_page={per_page}")
        lo = page * per_page
        out = SweepResult(name=self.name,
                          records=list(self.records[lo:lo + per_page]))
        out.meta["paging"] = {
            "page": page, "per_page": per_page,
            "total_records": len(self.records),
            "total_pages": -(-len(self.records) // per_page),
        }
        return out

    # -- serialization ------------------------------------------------------
    def to_json(self, path: Optional[str] = None, *, indent: int = 1,
                include_meta: bool = False) -> str:
        payload = {
            "schema": self.SCHEMA,
            "name": self.name,
            "records": [{
                "label": r.label,
                "cfg": dataclasses.asdict(r.cfg),
                "f1_curve": [float(v) for v in r.f1_curve],
                "events": r.events,
            } for r in self.records],
        }
        if include_meta and self.meta:
            payload["meta"] = self.meta
        text = json.dumps(payload, indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    @classmethod
    def from_json(cls, text: str) -> "SweepResult":
        payload = json.loads(text)
        if payload.get("schema") != cls.SCHEMA:
            raise ValueError(f"unsupported SweepResult schema "
                             f"{payload.get('schema')!r} "
                             f"(this build reads {cls.SCHEMA})")
        records = [RunRecord(label=r["label"],
                             cfg=ScenarioConfig(**r["cfg"]),
                             f1_curve=list(r["f1_curve"]),
                             events=list(r["events"]))
                   for r in payload["records"]]
        return cls(name=payload["name"], records=records,
                   meta=dict(payload.get("meta") or {}))

    @classmethod
    def load(cls, path: str) -> "SweepResult":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# named presets
# ---------------------------------------------------------------------------

PRESETS: Dict[str, Callable[..., SweepSpec]] = {}


def register_preset(name: str):
    def deco(fn):
        if name in PRESETS and PRESETS[name] is not fn:
            raise ValueError(f"preset {name!r} already registered")
        PRESETS[name] = fn
        return fn
    return deco


def get_preset(name: str, **overrides) -> SweepSpec:
    """Build a named preset grid; ``overrides`` are the preset's knobs
    (typically ``windows=``, ``n_seeds=``, ``engine=``)."""
    if name not in PRESETS:
        raise KeyError(f"no preset named {name!r}; known: "
                       f"{sorted(PRESETS)}")
    return PRESETS[name](**overrides)


@register_preset("paper_tables")
def _paper_tables(windows: int = 100, n_seeds: int = 3,
                  engine: str = "fleet") -> SweepSpec:
    """The paper's full result grid (Fig. 2 + Tables 2-6, 8-9), one row
    per table cell, labels exactly as results/benchmarks/paper_tables.json
    keys. Expansion order matches the legacy hand-rolled grid row for row,
    so the run list — and therefore the replica-stacking group layout —
    is unchanged."""
    base = ScenarioConfig(windows=windows, eval_every=max(1, windows // 20),
                          engine=engine)
    b = lambda **kw: dataclasses.replace(base, **kw)       # noqa: E731
    return SweepSpec.union(
        "paper_tables",
        SweepSpec("fig2", base=b(algo="edge_only"), label="fig2_edge_only"),
        # Table 2: partial data on the edge (StarHTL, 4G between DCs)
        SweepSpec("table2", base=b(algo="star", tech="4g"), mode="zip",
                  axes={"p_edge": (0.5, 0.15, 0.03),
                        LABEL_AXIS: ("table2_edge50pct", "table2_edge15pct",
                                     "table2_edge3pct")}),
        # Table 3: no data on edge, Zipf, A2A/Star x 4G/WiFi
        SweepSpec("table3", base=base,
                  axes={"algo": ("a2a", "star"), "tech": ("4g", "wifi")},
                  label="table3_{algo}_{tech}"),
        # Table 4: + data-aggregation heuristic (Zipf)
        SweepSpec("table4", base=b(aggregate=True),
                  axes={"algo": ("a2a", "star"), "tech": ("4g", "wifi")},
                  label="table4_{algo}_{tech}_agg"),
        # Tables 5/6: uniform initial distribution, +/- aggregation
        SweepSpec("table56", base=b(uniform=True),
                  axes={"algo": ("a2a", "star"), "tech": ("4g", "wifi")},
                  variants=(("table5_{algo}_{tech}_uniform", {}),
                            ("table6_{algo}_{tech}_uniform_agg",
                             {"aggregate": True}))),
        # Tables 8/9: GreedyTL sub-sampling (computational complexity)
        SweepSpec("table89", base=b(tech="wifi"),
                  axes={"n_subsample": (2, 5, 10), "algo": ("a2a", "star")},
                  variants=(("table8_{algo}_n{n_subsample}", {}),
                            ("table9_{algo}_n{n_subsample}_uniform",
                             {"uniform": True}))),
        seeds=range(n_seeds),
    )


@register_preset("energy_tradeoff")
def _energy_tradeoff(windows: int = 30, engine: str = "fleet") -> SweepSpec:
    """The examples/energy_tradeoff.py grid: edge-only reference, partial
    offload, and the HTL variants with/without aggregation."""
    base = ScenarioConfig(windows=windows, engine=engine,
                          eval_every=max(1, windows // 5))
    b = lambda **kw: dataclasses.replace(base, **kw)       # noqa: E731
    return SweepSpec.union(
        "energy_tradeoff",
        SweepSpec("edge", base=b(algo="edge_only"),
                  label="edge-only (NB-IoT)"),
        SweepSpec("partial", base=b(algo="star"), mode="zip",
                  axes={"p_edge": (0.5, 0.15, 0.03),
                        LABEL_AXIS: ("star 4g, 50% on edge",
                                     "star 4g, 15% on edge",
                                     "star 4g, 3% on edge")}),
        SweepSpec("htl", base=base,
                  axes={"algo": ("a2a", "star"), "tech": ("4g", "wifi")},
                  variants=(("{algo} {tech}, 0% on edge", {}),
                            ("{algo} {tech} + aggregation",
                             {"aggregate": True}))),
    )


@register_preset("transport_grid")
def _transport_grid(windows: int = 30, n_seeds: int = 1,
                    engine: str = "fleet") -> SweepSpec:
    """Beyond-paper technology grid over the parameterized transport
    registry (ROADMAP: mesh/BLE/LoRa): multi-hop 802.15.4 mesh depths vs
    BLE vs LoRa spreading factors, for both HTL variants."""
    base = ScenarioConfig(windows=windows, eval_every=max(1, windows // 5),
                          engine=engine)
    return SweepSpec(
        "transport_grid", base=base,
        axes={"algo": ("a2a", "star"),
              "tech": ("mesh:hops=1", "mesh:hops=2", "mesh:hops=3",
                       "ble", "lora:sf=7", "lora:sf=12")},
        label="{algo}_{tech}").with_seeds(n_seeds)


@register_preset("city")
def _city(fleet_size: int = 100_000, windows: int = 3, obs_per_dc: int = 4,
          train_iters: int = 6, n_seeds: int = 1,
          tech: str = "wifi") -> SweepSpec:
    """The million-DC scaling scenario (ROADMAP north-star): a smart-city
    StarHTL fleet of ``fleet_size`` Data Collectors on the scan engine —
    device-resident fleet state, shard_map'd DC axis, one jitted dispatch
    for the whole run (repro.core.cityscan.run_city). Defaults are sized
    for the CI ``city-smoke`` gate: 10^5 DCs, 3 windows, trimmed base-SVM
    iterations."""
    base = ScenarioConfig(windows=windows, eval_every=1, algo="star",
                          engine="scan", tech=tech, fleet_size=fleet_size,
                          obs_per_dc=obs_per_dc, train_iters=train_iters)
    return SweepSpec(
        "city", base=base,
        label=f"city_{fleet_size}dc_{tech}").with_seeds(n_seeds)


@register_preset("churn")
def _churn(windows: int = 8, n_seeds: int = 1,
           engine: str = "fleet") -> SweepSpec:
    """DC churn (DESIGN.md §13): per-DC battery budgets fed back from the
    energy ledger — mules that spend their budget leave the fleet
    mid-scenario. One depleting battery axis x both HTL variants, plus a
    no-battery control row per algorithm so the preset itself exhibits
    the graceful-degradation curve."""
    base = ScenarioConfig(windows=windows, eval_every=1, tech="4g",
                          engine=engine)
    return SweepSpec(
        "churn", base=base,
        axes={"algo": ("star", "a2a"),
              "battery_mj": (None, 40.0, 15.0)},
        label="churn_{algo}_batt{battery_mj}").with_seeds(n_seeds)


@register_preset("drift")
def _drift(windows: int = 10, n_seeds: int = 1,
           engine: str = "fleet") -> SweepSpec:
    """Concept drift (DESIGN.md §13): gradual covariate rotation, abrupt
    label-prior shift, and their composition, against a drift-free
    control — all on the same stream draw, so the F1 gap IS the drift
    effect."""
    base = ScenarioConfig(windows=windows, eval_every=1, algo="star",
                          tech="4g", engine=engine)
    return SweepSpec(
        "drift", base=base,
        axes={"drift": ("none", "rotate", "prior:at=0.5",
                        "rotate_prior")},
        label="drift_{drift}").with_seeds(n_seeds)


@register_preset("byzantine")
def _byzantine(windows: int = 8, n_seeds: int = 1,
               engine: str = "fleet") -> SweepSpec:
    """Faulty collectors vs robust aggregation (DESIGN.md §13): a fraction
    of mule observations arrive mislabelled; the A2A combine either
    averages (paper baseline) or trims the outer models (trimmed mean)."""
    base = ScenarioConfig(windows=windows, eval_every=1, algo="a2a",
                          tech="wifi", engine=engine)
    return SweepSpec(
        "byzantine", base=base,
        axes={"byz_frac": (0.0, 0.25),
              "robust_agg": ("mean", "trim:frac=0.25")},
        label="byz{byz_frac}_{robust_agg}").with_seeds(n_seeds)


@register_preset("mobility")
def _mobility(windows: int = 8, n_seeds: int = 1, engine: str = "fleet",
              trace_dir: str = "results/traces") -> SweepSpec:
    """Mobility-trace collection (DESIGN.md §13): a random-waypoint trace
    (generated on demand into ``trace_dir``, digest-named so regeneration
    is idempotent) drives per-window per-mule loads through the
    ``trace_file:`` collection policy, next to the paper's Zipf and the
    synthetic ``trace:`` policy on the same scenario."""
    from repro.data.mobility import generate_trace

    path = generate_trace(trace_dir, windows=windows, mules=6,
                          sensors=36, seed=0)
    base = ScenarioConfig(windows=windows, eval_every=1, algo="star",
                          tech="4g", engine=engine)
    return SweepSpec(
        "mobility", base=base,
        axes={"collection": ("poisson_zipf", "trace:loads=60-25-15",
                             f"trace_file:path={path}")},
        label="mobility_{collection}").with_seeds(n_seeds)


@register_preset("realism")
def _realism(windows: int = 8, n_seeds: int = 2, engine: str = "fleet",
             trace_dir: str = "results/traces") -> SweepSpec:
    """The full realism matrix (DESIGN.md §13): churn x drift x byzantine
    x mobility rows unioned into one seeded grid — the axis the paper's
    static-fleet evaluation leaves out, runnable through every engine and
    the sweep service like any other preset."""
    return SweepSpec.union(
        "realism",
        _churn(windows=windows, n_seeds=0, engine=engine),
        _drift(windows=windows + 2, n_seeds=0, engine=engine),
        _byzantine(windows=windows, n_seeds=0, engine=engine),
        _mobility(windows=windows, n_seeds=0, engine=engine,
                  trace_dir=trace_dir),
        seeds=range(n_seeds),
    )


@register_preset("pareto")
def _pareto(windows: int = 24, n_seeds: int = 2,
            engine: str = "fleet") -> SweepSpec:
    """The auto-tuner's candidate grid (DESIGN.md §14): the deployment
    space the paper enumerated by hand — transport technologies x HTL
    variant x aggregation heuristic, partial edge offload fractions, and
    collection policies — as one seeded union. Feed it to a search from
    :mod:`repro.core.pareto` (``HalvingSearch``/``get_search``) to get
    the energy/F1 frontier; running it directly is the exhaustive grid
    the searches are benchmarked against."""
    base = ScenarioConfig(windows=windows, eval_every=max(1, windows // 6),
                          engine=engine)
    b = lambda **kw: dataclasses.replace(base, **kw)       # noqa: E731
    return SweepSpec.union(
        "pareto",
        SweepSpec("edge", base=b(algo="edge_only"), label="edge_only"),
        SweepSpec("offload", base=b(algo="star"), mode="zip",
                  axes={"p_edge": (0.5, 0.15, 0.03),
                        LABEL_AXIS: ("star_4g_edge50", "star_4g_edge15",
                                     "star_4g_edge3")}),
        SweepSpec("transports", base=base,
                  axes={"algo": ("star", "a2a"),
                        "tech": ("4g", "wifi", "ble", "lora:sf=7")},
                  variants=(("{algo}_{tech}", {}),
                            ("{algo}_{tech}_agg", {"aggregate": True}))),
        SweepSpec("collection", base=b(algo="star", tech="wifi"),
                  axes={"collection": ("uniform", "bursty:burst=8")},
                  label="star_wifi_{collection}"),
        seeds=range(n_seeds),
    )


@register_preset("smoke")
def _smoke(windows: int = 6, n_seeds: int = 2,
           engine: str = "fleet") -> SweepSpec:
    """Tiny CI grid (scripts/verify.sh): one stackable HTL pair per
    algorithm plus a mesh row, small enough for the verify budget but
    wide enough to cross a stacking-group boundary."""
    base = ScenarioConfig(windows=windows, eval_every=max(1, windows // 3),
                          engine=engine)
    return SweepSpec.union(
        "smoke",
        SweepSpec("smoke_star", base=base,
                  axes={"tech": ("4g", "mesh:hops=2")},
                  label="star_{tech}"),
        SweepSpec("smoke_a2a",
                  base=dataclasses.replace(base, algo="a2a", tech="wifi"),
                  label="a2a_wifi"),
        seeds=range(n_seeds),
    )
