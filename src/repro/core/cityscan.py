"""Million-DC fleet engine: scan-over-windows + shard_map'd DC axis.

Two engines live here, both collapsing a whole scenario into O(1) jitted
dispatches (the fleet engine of :mod:`repro.core.fleet` still drives each
window from Python and round-trips fleet state host<->device per window):

**Paper-scale scan engine** (``engine="scan"``, :func:`run_scenario_scan`).
A host-side *planner* replays the scenario's host work exactly as the fleet
engine would — same rng consumption order (collection, then GreedyTL
subsampling), same per-pair ledger events in the same order, same AP/center
election and single-DC early exits — but instead of dispatching per window
it packs every window's padded fleet blocks into ``(W, ...)`` arrays. One
jitted ``lax.scan`` over windows then fuses base training -> GreedyTL
refine -> EMA into a single carried fleet state ``(w_global, has_global)``,
and evaluation is *streamed*: each window emits an integer confusion matrix
(exact in f32 — counts < 2^24), from which the host recovers the paper's
F1 bitwise (:func:`repro.core.metrics.f_measure_from_confusion`). Ledgers
are host-replayed and therefore exactly equal; F1 parity is at prediction
level (weights agree to float roundoff; the scan-vs-fleet SweepResult JSON
gate in scripts/scan_parity.py pins equality on the smoke and
transport_grid presets).

**City engine** (``engine="scan"`` + ``fleet_size``, :func:`run_city`).
The 10^5-DC smart-city scenario the paper motivates but never runs: a
StarHTL fleet of ``fleet_size`` DCs, each drawing ``obs_per_dc``
observations per window *on device* (per-DC ``fold_in`` PRNG keys, so the
draw is shard-count invariant), sharded over the DC mesh axis
(:func:`repro.sharding.partitioning.fleet_mesh`) with
``jax.experimental.shard_map``. No per-DC Python objects exist; fleet
state stays device-resident across the whole scan; cross-shard reductions
are exact (one-hot ``psum`` for the source pool and center dataset,
lexicographic max for the entropy election), so shard counts 1..8 produce
bitwise-identical results (tests/test_cityscan.py). Energy is charged
analytically: per-role-pair transfer counts from the transport layer times
combinatorial multiplicities — O(1) ledger events per window instead of
the loop/fleet engines' O(L^2). Memory is flat in both window count (scan
reuses one window's buffers) and — per DC — fleet size.

Both engines inline ``_greedytl`` into their jitted scan bodies, so the
greedy refine they compile is the incremental factor carry of DESIGN.md
§11 (fixed-shape padded ``Ut``/``Cc``/``z`` through the inner
``while_loop``; the carry is what keeps the whole-scenario program a
single compilation unit at any greedy depth).

The DC axis is bucket-padded with the PR-1/2 machinery
(:func:`repro.core.fleet.fleet_cap`, multiples of 32) so Poisson fleet
sizes never recompile, and shard counts divide every padded capacity.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache, partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import htl
from repro.core.dispatch import count_dispatch
from repro.core.energy import (INDEX_BYTES, Ledger, MODEL_BYTES, OBS_BYTES)
from repro.core.fleet import fleet_cap
from repro.core.greedytl import _greedytl
from repro.core.htl import DC, M_CAP, apply_aggregation_heuristic
from repro.core.metrics import f_measure_from_confusion
from repro.core.svm import _train_svm, pad_local, sample_cap
from repro.core.topology import Node, Topology, fleet_nodes, get_transport
from repro.data.synthetic_covtype import Dataset, NUM_CLASSES
from repro.sharding.partitioning import FLEET_AXIS, dc_shards, fleet_mesh


# ---------------------------------------------------------------------------
# shared eval plumbing: device test arrays come from the scenario module's
# EvalCache (lazy import; scenario.py imports this module lazily too)
# ---------------------------------------------------------------------------

def _eval_arrays(data: Dataset):
    from repro.core.scenario import _eval_cache
    x_test = _eval_cache.array(
        data, "test", lambda d: jnp.asarray(d.x_test.astype(np.float32)))
    y_oh = _eval_cache.array(
        data, "test_onehot",
        lambda d: jnp.asarray(np.eye(NUM_CLASSES, dtype=np.float32)
                              [np.asarray(d.y_test, np.int64)]))
    return x_test, y_oh


def _train_arrays(data: Dataset):
    from repro.core.scenario import _eval_cache
    xtr = _eval_cache.array(
        data, "train_x", lambda d: jnp.asarray(d.x_train.astype(np.float32)))
    ytr = _eval_cache.array(
        data, "train_y", lambda d: jnp.asarray(d.y_train.astype(np.int32)))
    return xtr, ytr


def _f1_curve(cms: np.ndarray, eval_every: int) -> List[float]:
    """Streamed F1: per-window integer confusion counts -> paper F1."""
    out = []
    for t in range(cms.shape[0]):
        if (t + 1) % eval_every == 0:
            out.append(f_measure_from_confusion(cms[t].astype(np.int64)))
    return out


def _window_cm(w, x_test, y_oh, num_classes: int):
    """One window's streamed eval: confusion counts, exact in f32."""
    scores = x_test @ w[:-1] + w[-1]
    pred = jax.nn.one_hot(jnp.argmax(scores, axis=-1), num_classes)
    return y_oh.T @ pred


# ---------------------------------------------------------------------------
# paper-scale scan engine: host-replay planner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _WindowPlan:
    live: List[DC]                 # non-empty DCs, fleet-engine order
    refine: List[DC]               # a2a: per-DC subsampled; star: [center]
    n_pool: int = 0                # base models entering the source pool
    prev_slot: int = -1            # pool slot of the previous global model
    single: bool = False


def _plan_scenario(cfg, data: Dataset) -> Tuple[List[_WindowPlan], Ledger]:
    """Replay every window's host-side work exactly as run_scenario with the
    fleet engine would: identical rng consumption order (collection policy,
    then per-DC subsampling), identical ledger events in identical order
    (collection; then per-pair m0 exchange / entropy index / center id /
    gather events through the same Topology patterns), identical AP/center
    election and single-DC early exits. Only the jitted numerics are left
    for the scan program."""
    from repro.core.scenario import ChurnBook, build_stream, collect_window

    rng = np.random.default_rng(cfg.seed)
    ledger = Ledger()
    # realism axis rides along for free: the (possibly drifted) stream
    # comes from the shared build_stream, churn/byzantine faults happen
    # inside the shared collect_window — a churned-away window becomes an
    # empty plan, masked by the scan program's ``learn`` flag (alive-state
    # masking: jitted shapes never change, dead fleets are zero rows)
    sx, sy = build_stream(cfg, data, rng)
    churn = None if cfg.battery_mj is None else ChurnBook(cfg.battery_mj)

    plans: List[_WindowPlan] = []
    prev_exists = False
    for t in range(cfg.windows):
        s = slice(t * cfg.obs_per_window, (t + 1) * cfg.obs_per_window)
        dcs = collect_window(cfg, rng, sx[s], sy[s], ledger,
                             window=t, churn=churn)
        if cfg.aggregate:
            dcs = apply_aggregation_heuristic(dcs, ledger, cfg.tech)
        live = [d for d in dcs if d.n > 0]
        if not live:
            plans.append(_WindowPlan([], []))
            continue
        if len(live) == 1:
            plans.append(_WindowPlan(live, [], single=True))
            prev_exists = True
            continue
        ap = htl._ap_name(live)
        topo = Topology(ledger, cfg.tech, fleet_nodes(live, ap))
        if cfg.algo == "a2a":
            topo.exchange_all(MODEL_BYTES, what="m0 exchange")
            refine = [htl._subsample(d, cfg.n_subsample, NUM_CLASSES, rng)
                      for d in live]
            center = next((d for d in live if d.name == ap), live[0])
            topo.gather(topo.node(center.name), MODEL_BYTES, what="m1 gather")
        else:
            topo.exchange_all(INDEX_BYTES, what="entropy index")
            c_idx = int(np.argmax([htl.label_entropy(d.y, NUM_CLASSES)
                                   for d in live]))
            center = live[c_idx]
            topo.broadcast(topo.node(center.name), INDEX_BYTES,
                           what="center id")
            topo.gather(topo.node(center.name), MODEL_BYTES,
                        what="m0 to center")
            refine = [htl._subsample(center, cfg.n_subsample, NUM_CLASSES,
                                     rng)]
        n_pool = min(len(live), M_CAP)
        prev_slot = len(live) if (prev_exists and len(live) < M_CAP) else -1
        plans.append(_WindowPlan(live, refine, n_pool, prev_slot))
        prev_exists = True
    return plans, ledger


def _pack_plan(cfg, plans: List[_WindowPlan]) -> dict:
    """Second pass: pad every window onto one stable (W, ...) block layout
    — DC axis at the bucketed fleet capacity, samples at the max bucketed
    sample capacity over all windows — so one scan program serves every
    Poisson draw of the scenario."""
    W = cfg.windows
    F = NUM_CLASSES  # placeholder; fixed below from data
    max_live = max([len(p.live) for p in plans] + [1])
    L = fleet_cap(max_live)
    cap = max([sample_cap(d.n, cfg.cap) for p in plans for d in p.live]
              + [sample_cap(1, cfg.cap)])
    rcap = max([sample_cap(d.n, cfg.cap) for p in plans for d in p.refine]
               + [sample_cap(1, cfg.cap)])
    feats = [d.x.shape[1] for p in plans for d in p.live]
    F = feats[0] if feats else 1

    xb = np.zeros((W, L, cap, F), np.float32)
    yb = np.zeros((W, L, cap), np.int32)
    mb = np.zeros((W, L, cap), np.float32)
    dcm = np.zeros((W, L), np.float32)
    src_base = np.zeros((W, M_CAP), np.float32)
    src_prev = np.zeros((W, M_CAP), np.float32)
    n_live = np.zeros((W,), np.float32)
    learn = np.zeros((W,), bool)
    single = np.zeros((W,), bool)
    if cfg.algo == "a2a":
        xr = np.zeros((W, L, rcap, F), np.float32)
        yr = np.zeros((W, L, rcap), np.int32)
        mr = np.zeros((W, L, rcap), np.float32)
    else:
        xr = np.zeros((W, rcap, F), np.float32)
        yr = np.zeros((W, rcap), np.int32)
        mr = np.zeros((W, rcap), np.float32)

    for t, p in enumerate(plans):
        for i, d in enumerate(p.live):
            xb[t, i], yb[t, i], mb[t, i] = pad_local(d.x, d.y, cap)
            dcm[t, i] = 1.0
        n_live[t] = len(p.live)
        learn[t] = bool(p.live)
        single[t] = p.single
        if p.single or not p.live:
            continue
        src_base[t, :p.n_pool] = 1.0
        if p.prev_slot >= 0:
            src_prev[t, p.prev_slot] = 1.0
        if cfg.algo == "a2a":
            for i, d in enumerate(p.refine):
                xr[t, i], yr[t, i], mr[t, i] = pad_local(d.x, d.y, rcap)
        else:
            xr[t], yr[t], mr[t] = pad_local(p.refine[0].x, p.refine[0].y,
                                            rcap)
    return {"xb": xb, "yb": yb, "mb": mb, "dcm": dcm, "xr": xr, "yr": yr,
            "mr": mr, "src_base": src_base, "src_prev": src_prev,
            "n_live": n_live, "learn": learn, "single": single}


# ---------------------------------------------------------------------------
# paper-scale scan engine: the jitted program
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _scan_program(algo: str, num_classes: int, iters: int,
                  trim: float = 0.0):
    """One jitted lax.scan over windows; jit re-specializes per block shape
    (W, L, cap, rcap), all of which are bucketed, so the executable cache
    stays small across a sweep. ``trim`` > 0 swaps the A2A combine for the
    coordinate-wise trimmed mean (robust_agg="trim:frac=..."); the trace
    branches at Python level, so ``trim == 0`` compiles the exact
    pre-robust combine graph."""

    def body(carry, inp, eta, x_test, y_oh):
        w, has_g = carry
        base = jax.vmap(
            lambda xi, yi, mi: _train_svm(xi, yi, mi,
                                          num_classes=num_classes,
                                          iters=iters)
        )(inp["xb"], inp["yb"], inp["mb"])               # (L, F+1, C)
        L = base.shape[0]
        basep = (base[:M_CAP] if L >= M_CAP else
                 jnp.concatenate([base, jnp.zeros((M_CAP - L,) +
                                                  base.shape[1:])], axis=0))
        # masked pool build is exact: x + 0 == x bitwise
        src = (basep * inp["src_base"][:, None, None]
               + w[None] * inp["src_prev"][:, None, None])
        src_mask = inp["src_base"] + inp["src_prev"]
        if algo == "a2a":
            refined = jax.lax.map(
                lambda t3: _greedytl(t3[0], t3[1], t3[2], src, src_mask,
                                     num_classes=num_classes)[0],
                (inp["xr"], inp["yr"], inp["mr"]))       # (L, F+1, C)
            nl = jnp.maximum(inp["n_live"], 1.0)
            if trim > 0.0:
                # trimmed-mean combine over the LIVE rows only: dead and
                # padding rows are pushed past every finite value so the
                # per-window sort stacks them at the top, then the kept
                # band [k, n_live - k) is averaged — the device analogue
                # of repro.core.metrics.trimmed_mean (F1 parity with the
                # host engines is at prediction level, like the mean path)
                big = jnp.float32(3.4e38)
                vals = jnp.where(inp["dcm"][:, None, None] > 0,
                                 refined, big)
                srt = jnp.sort(vals, axis=0)
                k = jnp.floor(jnp.float32(trim) * nl)
                pos = jnp.arange(refined.shape[0], dtype=jnp.float32)
                keep = ((pos >= k) & (pos < nl - k)).astype(refined.dtype)
                multi_new = (jnp.einsum("l,lfc->fc", keep, srt)
                             / jnp.maximum(nl - 2.0 * k, 1.0))
            else:
                multi_new = jnp.einsum("l,lfc->fc", inp["dcm"], refined) / nl
        else:
            multi_new = _greedytl(inp["xr"], inp["yr"], inp["mr"], src,
                                  src_mask, num_classes=num_classes)[0]
        single_new = jnp.where(has_g, 0.5 * (base[0] + w), base[0])
        new = jnp.where(inp["single"], single_new, multi_new)
        upd = jnp.where(has_g, (1.0 - eta) * w + eta * new, new)
        w2 = jnp.where(inp["learn"], upd, w)
        has2 = has_g | inp["learn"]
        cm = _window_cm(w2, x_test, y_oh, num_classes)
        return (w2, has2), cm

    @jax.jit
    def program(inputs, eta, x_test, y_oh):
        F = inputs["xb"].shape[-1]
        w0 = jnp.zeros((F + 1, num_classes), jnp.float32)
        carry0 = (w0, jnp.asarray(False))
        _, cms = jax.lax.scan(
            partial(body, eta=eta, x_test=x_test, y_oh=y_oh),
            carry0, inputs)
        return cms

    return program


@count_dispatch("scan_windows")
def _dispatch_scan(program, inputs, eta, x_test, y_oh):
    return program(inputs, eta, x_test, y_oh)


def run_scenario_scan(cfg, data: Dataset):
    """The whole scenario as ONE jitted dispatch (parity path of the scan
    engine — ledgers exactly equal to the fleet engine's, F1 through the
    streamed confusion counts)."""
    from repro.core.scenario import ScenarioResult

    from repro.core.scenario import resolve_robust

    plans, ledger = _plan_scenario(cfg, data)
    inputs = jax.tree.map(jnp.asarray, _pack_plan(cfg, plans))
    x_test, y_oh = _eval_arrays(data)
    program = _scan_program(cfg.algo, NUM_CLASSES, cfg.train_iters,
                            resolve_robust(cfg.robust_agg))
    cms = np.asarray(_dispatch_scan(program, inputs,
                                    jnp.float32(cfg.global_update_rate),
                                    x_test, y_oh))
    return ScenarioResult(_f1_curve(cms, cfg.eval_every), ledger, cfg)


# ---------------------------------------------------------------------------
# city engine: 10^5-DC StarHTL, device-resident, shard_map'd DC axis
# ---------------------------------------------------------------------------

def city_fleet_pad(fleet_size: int) -> int:
    """Padded city DC capacity: the PR-1 bucket policy (multiples of 32),
    which every power-of-two shard count <= 32 divides."""
    return fleet_cap(fleet_size)


def _city_round(w, has_g, x, y, m, alive, gid, l0, eta, x_test, y_oh, *,
                num_classes: int, iters: int, shards: int):
    """One city StarHTL round; identical math sharded or not. ``x``/``y``/
    ``m`` are this window's per-DC datasets (local shard rows), ``gid`` the
    global DC ids, ``alive`` the churn-aware membership mask (valid AND
    battery not yet depleted — without churn it equals the plain validity
    mask and every value below is bitwise what it was pre-churn). All
    cross-DC combination is either an exact one-hot psum (source pool,
    center dataset) or a lexicographic max (entropy election), so the
    round is bitwise shard-count invariant. Returns ``(w2, cm, cg, do)``
    where ``do`` flags whether a learning round ran (>= 2 DCs alive; a
    churned-to-nothing fleet keeps ``w`` untouched)."""
    K = x.shape[1]
    base = jax.vmap(
        lambda xi, yi, mi: _train_svm(xi, yi, mi, num_classes=num_classes,
                                      iters=iters))(x, y, m)

    # entropy-based center election (paper Sec. 4), lexicographic tie-break
    # on the global DC id so every shard layout elects the same center
    cnt = jnp.sum(jax.nn.one_hot(y, num_classes) * m[:, :, None], axis=1)
    tot = jnp.maximum(jnp.sum(cnt, axis=1), 1.0)
    p = cnt / tot[:, None]
    ent = -jnp.sum(jnp.where(p > 0, p * jnp.log(p), 0.0), axis=1) \
        / jnp.log(float(num_classes))
    ent = jnp.where(alive, ent, -1.0)
    li = jnp.argmax(ent)                       # first max = lowest local gid
    ce, cg = ent[li], gid[li]
    n_alive = jnp.sum(alive.astype(jnp.float32))
    if shards > 1:
        es = jax.lax.all_gather(ce, FLEET_AXIS)
        gs = jax.lax.all_gather(cg, FLEET_AXIS)
        ce, cg = es[0], gs[0]
        for i in range(1, shards):
            better = (es[i] > ce) | ((es[i] == ce) & (gs[i] < cg))
            ce = jnp.where(better, es[i], ce)
            cg = jnp.where(better, gs[i], cg)
        n_alive = jax.lax.psum(n_alive, FLEET_AXIS)

    # source pool: base models of the first min(L0, M_CAP) *alive* DCs'
    # slots, gathered by exact one-hot psum (x + 0 == x bitwise); the mask
    # is the same one-hot reduced, so dead DCs' slots leave the pool (with
    # nobody dead it reduces to exactly the old ``slot < min(l0, M_CAP)``)
    slot = jnp.arange(M_CAP, dtype=gid.dtype)
    oh = ((gid[:, None] == slot[None, :]) & (slot[None, :] < l0)
          & alive[:, None]).astype(jnp.float32)
    src = jnp.einsum("lm,lfc->mfc", oh, base)
    src_mask = jnp.sum(oh, axis=0)

    # center's local dataset, same exact one-hot reduction
    coh = (gid == cg).astype(jnp.float32)
    cx = jnp.einsum("l,lkf->kf", coh, x)
    cy = jnp.einsum("l,lk->k", coh, y.astype(jnp.float32))
    if shards > 1:
        src = jax.lax.psum(src, FLEET_AXIS)
        src_mask = jax.lax.psum(src_mask, FLEET_AXIS)
        cx = jax.lax.psum(cx, FLEET_AXIS)
        cy = jax.lax.psum(cy, FLEET_AXIS)

    refined, _ = _greedytl(cx, cy.astype(jnp.int32), jnp.ones((K,)),
                           src, src_mask, num_classes=num_classes)
    do = n_alive >= 2.0
    upd = jnp.where(has_g, (1.0 - eta) * w + eta * refined, refined)
    w2 = jnp.where(do, upd, w)
    cm = _window_cm(w2, x_test, y_oh, num_classes)
    return w2, cm, cg, do


def _draw_window(xtr, ytr, key, t, gid, validf, obs_per_dc: int):
    """Device-side collection: per-DC fold_in keys (shard-count invariant),
    ``obs_per_dc`` uniform draws from the train stream per DC."""
    n_train = xtr.shape[0]
    kt = jax.random.fold_in(key, t)
    keys = jax.vmap(lambda g: jax.random.fold_in(kt, g))(gid)
    idx = jax.vmap(
        lambda k: jax.random.randint(k, (obs_per_dc,), 0, n_train))(keys)
    x = xtr[idx]                                # (Lloc, K, F)
    y = ytr[idx]
    m = jnp.ones(idx.shape, jnp.float32) * validf[:, None]
    return x, y, m


@lru_cache(maxsize=None)
def _city_program(W: int, L: int, K: int, shards: int, num_classes: int,
                  iters: int):
    """The whole city scenario as one jitted shard_map'd scan: collection,
    training, election, refine, EMA and streamed eval never leave the
    device; per-window buffers are scan-local, so peak memory is
    independent of W."""
    mesh = fleet_mesh(shards)
    Lloc = L // shards

    def mapped(xtr, ytr, x_test, y_oh, eta, l0, key, t_die):
        shard = jax.lax.axis_index(FLEET_AXIS).astype(jnp.int32)
        gid = shard * Lloc + jnp.arange(Lloc, dtype=jnp.int32)
        valid = gid < l0
        # per-DC death window (churn; W everywhere = nobody ever dies, so
        # alive == valid and every window computes its pre-churn values)
        t_die_loc = jnp.take(t_die, gid)

        def body(carry, t):
            w, has_g = carry
            alive = valid & (t < t_die_loc)
            alivef = alive.astype(jnp.float32)
            x, y, m = _draw_window(xtr, ytr, key, t, gid, alivef, K)
            w2, cm, cg, do = _city_round(
                w, has_g, x, y, m, alive, gid, l0, eta, x_test, y_oh,
                num_classes=num_classes, iters=iters, shards=shards)
            return (w2, has_g | do), (cm, cg)

        F = xtr.shape[1]
        carry0 = (jnp.zeros((F + 1, num_classes), jnp.float32),
                  jnp.asarray(False))
        _, (cms, centers) = jax.lax.scan(body, carry0,
                                         jnp.arange(W, dtype=jnp.int32))
        return cms, centers

    fn = shard_map(mapped, mesh=mesh,
                   in_specs=(P(), P(), P(), P(), P(), P(), P(), P()),
                   out_specs=(P(), P()), check_rep=False)
    return jax.jit(fn)


@count_dispatch("city_scan")
def _dispatch_city(program, *args):
    return program(*args)


def _charge_city_collection(ledger: Ledger, fleet_size: int,
                            obs_per_dc: int) -> None:
    """One aggregate collection event per window: every DC collects
    ``obs_per_dc`` observations over 802.15.4 (1 tx + 1 rx each), charged
    as event counts so the total equals ``fleet_size`` separate
    ``collect_to_mule`` events."""
    ledger.add("802.15.4", obs_per_dc * OBS_BYTES, purpose="collection",
               n_tx=fleet_size, n_rx=fleet_size, what="sensor->SM (city)")


def _charge_city_learning(ledger: Ledger, tech: str, fleet_size: int,
                          center_is_ap: bool) -> None:
    """Analytic StarHTL learning charge for one window: the loop/fleet
    engines iterate Topology patterns over L(L-1) ordered pairs; at city
    scale we evaluate the transport's per-role-pair (tx, rx) counts on
    three representative nodes and multiply by the pair multiplicities —
    O(1) ledger events per window, totals equal to the pairwise sum."""
    L = fleet_size
    counts = get_transport(tech).counts
    ap, m1, m2 = Node("AP", is_ap=True), Node("SM1"), Node("SM2")

    def add(nbytes, what, pairs):
        tx = rx = 0
        for mult, src, dst in pairs:
            a, b = counts(src, dst)
            tx += mult * a
            rx += mult * b
        ledger.add(tech, nbytes, purpose="learning", n_tx=tx, n_rx=rx,
                   what=what)

    # entropy index exchange: every ordered pair
    add(INDEX_BYTES, "entropy index",
        [(L - 1, ap, m1), (L - 1, m1, ap), ((L - 1) * (L - 2), m1, m2)])
    if center_is_ap:
        add(INDEX_BYTES, "center id", [(L - 1, ap, m1)])
        add(MODEL_BYTES, "m0 to center", [(L - 1, m1, ap)])
    else:
        add(INDEX_BYTES, "center id", [(1, m1, ap), (L - 2, m1, m2)])
        add(MODEL_BYTES, "m0 to center", [(1, ap, m1), (L - 2, m2, m1)])


def _city_death_schedule(cfg, L0: int, L: int) -> np.ndarray:
    """Per-DC death windows of the city churn model (DC ``i`` is alive for
    windows ``t < t_die[i]``; ``windows`` everywhere = nobody ever dies).

    Batteries are heterogeneous — ``battery_mj * (0.5 + U[0, 1))`` per DC
    from a dedicated seeded stream, so depletion staggers instead of the
    whole fleet dying at once — and drain per window is the analytic
    per-DC share of the city charging model (collection rx + learning
    total / L0), evaluated once up front. The schedule is therefore a
    deterministic function of (seed, battery_mj, tech, fleet shape),
    identical across shard counts by construction — the device side only
    ever sees the precomputed ``t_die`` array."""
    W = cfg.windows
    t_die = np.full((L,), W, np.int32)
    if cfg.battery_mj is None:
        return t_die
    from repro.core.energy import resolve_tech
    drng = np.random.default_rng([int(cfg.seed), 0xC17B])
    batt = cfg.battery_mj * (0.5 + drng.random(L0))
    tmp = Ledger()
    _charge_city_learning(tmp, cfg.tech, L0, center_is_ap=False)
    e_w = (resolve_tech("802.15.4").rx_mj(cfg.obs_per_dc * OBS_BYTES)
           + tmp.total() / L0)
    t_die[:L0] = np.minimum(W, np.ceil(batt / e_w)).astype(np.int32)
    return t_die


def run_city(cfg, data: Dataset, *, max_shards: Optional[int] = None):
    """The city scenario: ``cfg.fleet_size`` DCs, ``cfg.obs_per_dc``
    observations each per window, StarHTL, one jitted dispatch for the
    whole run. ``max_shards`` caps the DC-mesh width (default: every
    visible device whose count divides the padded fleet)."""
    from repro.core.scenario import ScenarioResult

    L0, K, W = cfg.fleet_size, cfg.obs_per_dc, cfg.windows
    L = city_fleet_pad(L0)
    shards = dc_shards(L, max_shards)
    xtr, ytr = _train_arrays(data)
    x_test, y_oh = _eval_arrays(data)
    t_die = _city_death_schedule(cfg, L0, L)
    program = _city_program(W, L, K, shards, NUM_CLASSES, cfg.train_iters)
    cms, centers = _dispatch_city(
        program, xtr, ytr, x_test, y_oh,
        jnp.float32(cfg.global_update_rate), jnp.int32(L0),
        jax.random.PRNGKey(cfg.seed), jnp.asarray(t_die))
    cms, centers = np.asarray(cms), np.asarray(centers)

    ledger = Ledger()
    for t in range(W):
        alive = t < t_die[:L0]
        n_alive = int(alive.sum())
        if n_alive > 0:
            _charge_city_collection(ledger, n_alive, K)
        if n_alive >= 2:
            # the analytic AP role falls to the lowest-gid alive DC
            ap_gid = int(np.argmax(alive))
            _charge_city_learning(ledger, cfg.tech, n_alive,
                                  center_is_ap=(int(centers[t]) == ap_gid))
    return ScenarioResult(_f1_curve(cms, cfg.eval_every), ledger, cfg)


# ---------------------------------------------------------------------------
# per-window city reference: host-driven loop, one dispatch + one host sync
# per window, host-side collection shipped to device every window — the
# pre-scan execution pattern, kept as the benchmark comparator
# ---------------------------------------------------------------------------

@lru_cache(maxsize=None)
def _city_round_program(num_classes: int, iters: int):
    @jax.jit
    def fn(w, has_g, x, y, m, alive, gid, l0, eta, x_test, y_oh):
        return _city_round(w, has_g, x, y, m, alive, gid, l0, eta,
                           x_test, y_oh, num_classes=num_classes,
                           iters=iters, shards=1)
    return fn


def run_city_perwindow(cfg, data: Dataset):
    """City scenario on the per-window pattern: every window the host draws
    the fleet's observations, packs and uploads them, dispatches one round
    and syncs the global model back — wall-clock scales with
    ``windows x fleet data volume`` where :func:`run_city` pays one
    dispatch total. Results match :func:`run_city` to float roundoff (the
    rng streams differ by design: host numpy vs device fold_in)."""
    from repro.core.scenario import ScenarioResult

    L0, K, W = cfg.fleet_size, cfg.obs_per_dc, cfg.windows
    L = city_fleet_pad(L0)
    rng = np.random.default_rng(cfg.seed)
    xtr_host = data.x_train.astype(np.float32)
    ytr_host = data.y_train.astype(np.int32)
    x_test, y_oh = _eval_arrays(data)
    gid = jnp.arange(L, dtype=jnp.int32)
    valid_host = np.arange(L) < L0
    t_die = _city_death_schedule(cfg, L0, L)
    program = _city_round_program(NUM_CLASSES, cfg.train_iters)

    ledger = Ledger()
    w = np.zeros((xtr_host.shape[1] + 1, NUM_CLASSES), np.float32)
    has_g = False
    cms = np.zeros((W, NUM_CLASSES, NUM_CLASSES), np.float32)
    for t in range(W):
        alive_host = valid_host & (t < t_die)
        m_host = np.broadcast_to(alive_host[:, None], (L, K)
                                 ).astype(np.float32).copy()
        idx = rng.integers(0, len(ytr_host), size=(L, K))
        xw = xtr_host[idx]                     # host gather, uploaded fresh
        yw = ytr_host[idx]
        w_dev, cm, cg, do = program(jnp.asarray(w), jnp.asarray(has_g),
                                    jnp.asarray(xw), jnp.asarray(yw),
                                    jnp.asarray(m_host),
                                    jnp.asarray(alive_host),
                                    gid, jnp.int32(L0),
                                    jnp.float32(cfg.global_update_rate),
                                    x_test, y_oh)
        w = np.asarray(w_dev)                  # per-window host sync
        has_g = bool(has_g or bool(do))
        cms[t] = np.asarray(cm)
        n_alive = int(alive_host.sum())
        if n_alive > 0:
            _charge_city_collection(ledger, n_alive, K)
        if n_alive >= 2:
            ap_gid = int(np.argmax(alive_host))
            _charge_city_learning(ledger, cfg.tech, n_alive,
                                  center_is_ap=(int(cg) == ap_gid))
    return ScenarioResult(_f1_curve(cms, cfg.eval_every), ledger, cfg)
