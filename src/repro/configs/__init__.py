"""Architecture configs. Importing this package registers every assigned arch.

``--arch`` ids use dashes (e.g. ``llama3.2-3b``); module names use underscores.
"""
from repro.configs import (  # noqa: F401
    deepseek_v3_671b,
    granite_3_8b,
    llama3_2_3b,
    llava_next_mistral_7b,
    mamba2_1_3b,
    minicpm3_4b,
    olmoe_1b_7b,
    qwen2_72b,
    recurrentgemma_9b,
    whisper_medium,
)
from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    HTLConfig,
    InputShape,
    ModelConfig,
    OptimizerConfig,
    TrainConfig,
    get_config,
    list_configs,
)

ALL_ARCHS = [
    "whisper-medium",
    "llava-next-mistral-7b",
    "mamba2-1.3b",
    "qwen2-72b",
    "recurrentgemma-9b",
    "minicpm3-4b",
    "llama3.2-3b",
    "olmoe-1b-7b",
    "granite-3-8b",
    "deepseek-v3-671b",
]
