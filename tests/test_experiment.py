"""Experiment API v1: SweepSpec expansion semantics, preset parity with
the legacy hand-rolled paper grid, SweepResult JSON round-trip, the legacy
run_sweep shim contract, and metadata-driven auto-stacking."""
import dataclasses

import numpy as np
import pytest

from repro.core.experiment import (LABEL_AXIS, SweepResult, SweepSpec,
                                   get_preset)
from repro.core.scenario import (ScenarioConfig, host_side_fields,
                                 run_scenario, run_sweep, _stack_key)
from repro.data.synthetic_covtype import make_covtype_like

DATA = make_covtype_like(seed=0)
BASE = ScenarioConfig(windows=6, eval_every=2)


# ---------------------------------------------------------------------------
# expansion semantics
# ---------------------------------------------------------------------------

def test_cartesian_expansion_is_nested_loop_order():
    spec = SweepSpec("g", base=BASE,
                     axes={"algo": ("a2a", "star"), "tech": ("4g", "wifi")},
                     label="{algo}_{tech}")
    assert [l for l, _ in spec.rows()] == [
        "a2a_4g", "a2a_wifi", "star_4g", "star_wifi"]
    assert all(c.windows == 6 for _, c in spec.rows())


def test_zip_expansion_and_explicit_labels():
    spec = SweepSpec("z", base=BASE, mode="zip",
                     axes={"p_edge": (0.5, 0.15),
                           LABEL_AXIS: ("half", "fifteen")})
    rows = spec.rows()
    assert rows[0][0] == "half" and rows[0][1].p_edge == 0.5
    assert rows[1][0] == "fifteen" and rows[1][1].p_edge == 0.15


def test_variants_are_innermost_axis():
    spec = SweepSpec("v", base=BASE, axes={"tech": ("4g", "wifi")},
                     variants=(("{tech}_plain", {}),
                               ("{tech}_agg", {"aggregate": True})))
    labels = [l for l, _ in spec.rows()]
    assert labels == ["4g_plain", "4g_agg", "wifi_plain", "wifi_agg"]
    cfgs = dict(spec.rows())
    assert not cfgs["wifi_plain"].aggregate and cfgs["wifi_agg"].aggregate


def test_union_concatenates_and_seeds_replicate_innermost():
    u = SweepSpec.union(
        "u",
        SweepSpec("a", base=BASE, label="a"),
        SweepSpec("b", base=dataclasses.replace(BASE, algo="a2a"),
                  label="b"),
        seeds=(0, 1))
    runs = u.configs()
    assert [(l, c.seed) for l, c in runs] == [
        ("a", 0), ("a", 1), ("b", 0), ("b", 1)]


def test_with_seeds_int_and_sequence():
    assert SweepSpec("s", base=BASE).with_seeds(3).seeds == (0, 1, 2)
    assert SweepSpec("s", base=BASE).with_seeds((7, 9)).seeds == (7, 9)


def test_expansion_errors():
    with pytest.raises(ValueError):          # unknown axis name
        SweepSpec("e", base=BASE, axes={"warp_factor": (1,)})
    with pytest.raises(ValueError):          # zip length mismatch
        SweepSpec("e", base=BASE, mode="zip",
                  axes={"p_edge": (0.1, 0.2), "seed": (1,)})
    with pytest.raises(ValueError):          # _label needs zip mode
        SweepSpec("e", base=BASE, axes={LABEL_AXIS: ("x",)})
    with pytest.raises(ValueError):          # bad mode
        SweepSpec("e", base=BASE, mode="diagonal")
    with pytest.raises(ValueError):          # union spec with own axes
        SweepSpec("e", base=BASE, axes={"seed": (1,)},
                  subspecs=(SweepSpec("x", base=BASE),))
    with pytest.raises(ValueError, match="seeds"):   # nested seeds would
        SweepSpec.union("e", SweepSpec("x", base=BASE).with_seeds(3))
    with pytest.raises(ValueError):          # duplicate labels
        SweepSpec("e", base=BASE, axes={"tech": ("4g", "wifi")},
                  label="same").rows()


def test_get_preset_unknown():
    with pytest.raises(KeyError):
        get_preset("no-such-grid")


# ---------------------------------------------------------------------------
# preset parity with the legacy hand-rolled paper grid
# ---------------------------------------------------------------------------

def _legacy_grid(base):
    """The pre-SweepSpec benchmarks/paper_tables.py grid, verbatim."""
    rows = [("fig2_edge_only", dataclasses.replace(base, algo="edge_only"))]
    for frac, lbl in [(0.5, "50"), (0.15, "15"), (0.03, "3")]:
        rows.append((f"table2_edge{lbl}pct",
                     dataclasses.replace(base, algo="star", p_edge=frac,
                                         tech="4g")))
    for algo in ("a2a", "star"):
        for tech in ("4g", "wifi"):
            rows.append((f"table3_{algo}_{tech}",
                         dataclasses.replace(base, algo=algo, tech=tech)))
    for algo in ("a2a", "star"):
        for tech in ("4g", "wifi"):
            rows.append((f"table4_{algo}_{tech}_agg",
                         dataclasses.replace(base, algo=algo, tech=tech,
                                             aggregate=True)))
    for algo in ("a2a", "star"):
        for tech in ("4g", "wifi"):
            rows.append((f"table5_{algo}_{tech}_uniform",
                         dataclasses.replace(base, algo=algo, tech=tech,
                                             uniform=True)))
            rows.append((f"table6_{algo}_{tech}_uniform_agg",
                         dataclasses.replace(base, algo=algo, tech=tech,
                                             uniform=True, aggregate=True)))
    for n_sub in (2, 5, 10):
        for algo in ("a2a", "star"):
            rows.append((f"table8_{algo}_n{n_sub}",
                         dataclasses.replace(base, algo=algo, tech="wifi",
                                             n_subsample=n_sub)))
            rows.append((f"table9_{algo}_n{n_sub}_uniform",
                         dataclasses.replace(base, algo=algo, tech="wifi",
                                             uniform=True,
                                             n_subsample=n_sub)))
    return rows


def test_paper_tables_preset_matches_legacy_grid_exactly():
    """The acceptance contract: the preset expands to the --quick grid
    config for config, labels, order and seed replication included — so
    the new API runs literally the same run_sweep call as the legacy
    pipeline."""
    windows, n_seeds = 30, 1        # the --quick parameters
    base = ScenarioConfig(windows=windows,
                          eval_every=max(1, windows // 20), engine="fleet")
    legacy = [(lbl, dataclasses.replace(cfg, seed=s))
              for lbl, cfg in _legacy_grid(base) for s in range(n_seeds)]
    spec = get_preset("paper_tables", windows=windows, n_seeds=n_seeds)
    assert spec.configs() == legacy
    # and at the paper's full scale
    base = ScenarioConfig(windows=100, eval_every=5, engine="fleet")
    legacy = [(lbl, dataclasses.replace(cfg, seed=s))
              for lbl, cfg in _legacy_grid(base) for s in range(3)]
    assert get_preset("paper_tables").configs() == legacy


# ---------------------------------------------------------------------------
# run + legacy shim parity + serialization
# ---------------------------------------------------------------------------

def _small_spec():
    return SweepSpec.union(
        "small",
        SweepSpec("star", base=BASE, axes={"tech": ("4g", "wifi")},
                  label="star_{tech}"),
        SweepSpec("a2a", base=dataclasses.replace(BASE, algo="a2a"),
                  label="a2a_4g"),
        seeds=(0, 1))


def test_run_matches_legacy_run_sweep_shim():
    """SweepSpec.run and the legacy run_sweep path must emit identical
    results — same configs, same order, same stacking — for both stack
    modes."""
    spec = _small_spec()
    cfgs = [c for _, c in spec.configs()]
    for stack, legacy_flag in (("auto", True), ("off", False)):
        res = spec.run(DATA, stack=stack)
        legacy = run_sweep(cfgs, DATA, stack_seeds=legacy_flag)
        assert len(res.records) == len(legacy)
        for rec, ref in zip(res.records, legacy):
            assert rec.cfg == ref.cfg
            assert rec.f1_curve == list(ref.f1_curve)
            assert rec.events == ref.ledger.events


def test_stack_auto_matches_off_within_parity_tolerance():
    spec = _small_spec()
    auto = spec.run(DATA, stack="auto")
    off = spec.run(DATA, stack="off")
    for a, b in zip(auto.records, off.records):
        np.testing.assert_allclose(a.f1_curve, b.f1_curve, atol=1e-4)
        assert (sum(e["mj"] for e in a.events)
                == pytest.approx(sum(e["mj"] for e in b.events)))
    with pytest.raises(ValueError):
        spec.run(DATA, stack="sometimes")


def test_sweep_result_json_round_trip_and_summary():
    spec = _small_spec()
    res = spec.run(DATA, stack="auto")
    clone = SweepResult.from_json(res.to_json())
    assert clone == res
    assert clone.labels() == ["star_4g", "star_wifi", "a2a_4g"]

    s = res.summary("star_4g")
    rs = res.select("star_4g")
    assert len(rs) == 2            # two seeds
    assert s["f1"] == pytest.approx(
        np.mean([r.converged_f1() for r in rs]))
    assert s["energy_mj"] == pytest.approx(
        np.mean([r.energy_total for r in rs]))
    assert len(s["f1_curve"]) == len(rs[0].f1_curve)
    with pytest.raises(KeyError):
        res.summary("nope")


def test_sweep_result_rejects_unknown_schema():
    bad = '{"schema": 99, "name": "x", "records": []}'
    with pytest.raises(ValueError):
        SweepResult.from_json(bad)


def test_run_record_reconstructs_scenario_result():
    res = SweepSpec("one", base=BASE, label="one").run(DATA)
    sr = res.records[0].to_scenario_result()
    ref = run_scenario(res.records[0].cfg, DATA)
    assert sr.f1_curve == ref.f1_curve
    assert sr.energy_total == pytest.approx(ref.energy_total)


def test_run_validates_configs_up_front():
    spec = SweepSpec("bad", base=dataclasses.replace(
        BASE, p_edge=1.0, include_es_in_learning=False), label="bad")
    with pytest.raises(ValueError, match="empty fleet"):
        spec.run(DATA)
    spec = SweepSpec("bad2", base=dataclasses.replace(BASE, tech="warp"),
                     label="bad2")
    with pytest.raises(KeyError):
        spec.run(DATA)


# ---------------------------------------------------------------------------
# metadata-driven auto-stacking
# ---------------------------------------------------------------------------

def test_host_side_metadata_drives_stack_key():
    """The stack key is derived from ScenarioConfig field metadata: every
    host_side field normalizes to its default, every other field splits
    the group."""
    hs = set(host_side_fields())
    assert {"seed", "tech", "p_edge", "uniform", "aggregate", "n_subsample",
            "zipf_alpha", "lam_poisson", "global_update_rate",
            "include_es_in_learning", "collection",
            "battery_mj", "drift", "byz_frac", "robust_agg"} == hs
    defaults = ScenarioConfig()
    for name in hs:
        varied = dataclasses.replace(
            BASE, **{name: _varied_value(name, getattr(defaults, name))})
        assert _stack_key(varied) == _stack_key(BASE), name
    for name in ("algo", "engine", "windows", "cap", "eval_every",
                 "obs_per_window"):
        varied = dataclasses.replace(
            BASE, **{name: _varied_value(name, getattr(defaults, name))})
        assert _stack_key(varied) != _stack_key(BASE), name


def _varied_value(name, default):
    if name == "algo":
        return "a2a"
    if name == "engine":
        return "loop"
    if name == "tech":
        return "mesh:hops=2"
    if name == "collection":
        return "bursty:burst=4"
    if name == "drift":
        return "rotate"
    if name == "robust_agg":
        return "trim:frac=0.25"
    if isinstance(default, bool):
        return not default
    if default is None:
        return 5
    if isinstance(default, int):
        return default + 3
    if isinstance(default, float):
        return default + 0.07
    raise AssertionError(name)


def test_new_policy_and_transport_fields_stack_with_baseline():
    """Replicas varying only in collection policy / transport spec stack
    into one group and still match their sequential runs."""
    cfgs = [BASE,
            dataclasses.replace(BASE, collection="bursty:burst=4", seed=1),
            dataclasses.replace(BASE, tech="mesh:hops=3", seed=2),
            dataclasses.replace(BASE, collection="trace:loads=60-25-15",
                                tech="ble", seed=3)]
    assert len({_stack_key(c) for c in cfgs}) == 1
    stacked = run_sweep(cfgs, DATA, stack_seeds=True)
    for cfg, r in zip(cfgs, stacked):
        ref = run_scenario(cfg, DATA)
        np.testing.assert_allclose(r.f1_curve, ref.f1_curve, atol=1e-4)
        assert r.ledger.by_purpose() == ref.ledger.by_purpose()
        assert r.ledger.by_tech() == ref.ledger.by_tech()
