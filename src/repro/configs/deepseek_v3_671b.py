"""deepseek-v3-671b — MoE with MLA, 1 shared + 256 routed experts (top-8),
multi-token prediction [arXiv:2412.19437].

61L, d_model=7168, 128H (MLA latent cache), routed expert d_ff=2048,
vocab=129280. First 3 layers are dense MLP (d_ff=18432).
"""
from repro.configs.base import MLAConfig, MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=2048,                     # routed-expert hidden size (as assigned)
    vocab_size=129280,
    head_dim=128,
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048,
                  num_shared_experts=1, capacity_factor=1.25,
                  first_k_dense=3, dense_d_ff=18432),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    num_mtp_modules=1,
    rope_theta=10_000.0,
    supports_long_context=False,
    source="arXiv:2412.19437",
))
