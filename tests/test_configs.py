"""Config registry: all 10 assigned architectures, exact dims, param counts."""
import pytest

from repro.configs import ALL_ARCHS, INPUT_SHAPES, get_config, list_configs

EXPECTED = {
    "whisper-medium": dict(num_layers=24, d_model=1024, num_heads=16,
                           num_kv_heads=16, d_ff=4096, vocab_size=51865),
    "llava-next-mistral-7b": dict(num_layers=32, d_model=4096, num_heads=32,
                                  num_kv_heads=8, d_ff=14336,
                                  vocab_size=32000),
    "mamba2-1.3b": dict(num_layers=48, d_model=2048, vocab_size=50280),
    "qwen2-72b": dict(num_layers=80, d_model=8192, num_heads=64,
                      num_kv_heads=8, d_ff=29568, vocab_size=152064,
                      qkv_bias=True),
    "recurrentgemma-9b": dict(num_layers=38, d_model=4096, num_heads=16,
                              num_kv_heads=1, d_ff=12288, vocab_size=256000),
    "minicpm3-4b": dict(num_layers=62, d_model=2560, num_heads=40,
                        d_ff=6400, vocab_size=73448),
    "llama3.2-3b": dict(num_layers=28, d_model=3072, num_heads=24,
                        num_kv_heads=8, d_ff=8192, vocab_size=128256),
    "olmoe-1b-7b": dict(num_layers=16, d_model=2048, num_heads=16,
                        num_kv_heads=16, d_ff=1024, vocab_size=50304),
    "granite-3-8b": dict(num_layers=40, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12800, vocab_size=49155),
    "deepseek-v3-671b": dict(num_layers=61, d_model=7168, num_heads=128,
                             d_ff=2048, vocab_size=129280),
}

# total-parameter sanity bands (billions)
PARAM_BANDS = {
    "whisper-medium": (0.6, 1.2), "llava-next-mistral-7b": (6.5, 8.0),
    "mamba2-1.3b": (1.1, 1.6), "qwen2-72b": (68, 77),
    "recurrentgemma-9b": (8, 10.5), "minicpm3-4b": (3.4, 4.6),
    "llama3.2-3b": (2.8, 3.7), "olmoe-1b-7b": (6.2, 7.6),
    "granite-3-8b": (7.3, 9.0), "deepseek-v3-671b": (630, 720),
}


def test_all_archs_registered():
    cfgs = list_configs()
    assert set(ALL_ARCHS) <= set(cfgs)
    assert len(ALL_ARCHS) == 10


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_exact_dims(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, f"{arch}.{k}"
    assert cfg.source


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_counts(arch):
    lo, hi = PARAM_BANDS[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_moe_active_params():
    ds = get_config("deepseek-v3-671b")
    assert 30e9 <= ds.active_param_count() <= 45e9      # ~37B
    ol = get_config("olmoe-1b-7b")
    assert 1.0e9 <= ol.active_param_count() <= 1.6e9    # ~1.3B


def test_input_shapes():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_variants_small(arch):
    r = get_config(arch).reduced()
    assert r.num_layers == 2
    assert r.d_model <= 512
    if r.moe is not None:
        assert r.moe.num_experts <= 4
