import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Must precede any jax-touching import (see dryrun.py).

"""§Perf pair 3 — the paper's technique at datacenter scale.

Lowers one HTL round (local phase + hypothesis transfer) of the trainer on
the 2-pod production mesh, with the stacked Data-Collector dim sharded over
the 'pod' axis, and measures pod-crossing (DCN) collective bytes against the
synchronous data-parallel baseline. This is the paper's Table-3 experiment
with radios replaced by the ICI/DCN hierarchy.

    python -m repro.launch.htl_dryrun [--mode star|a2a|sync] [--local-steps N]
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import HTLConfig, OptimizerConfig
from repro.core.htl_trainer import HTLTrainer
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import param_specs
from repro.launch.train import make_train_step
from repro.models.model import build_model
from repro.optim.adamw import AdamWState
from repro.roofline.hlo import analyze_hlo
from repro.sharding.partitioning import use_compute_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "perf")


def _stacked_specs(ps, L, mesh):
    def stack(s):
        spec = s.sharding.spec
        return jax.ShapeDtypeStruct(
            (L,) + s.shape, s.dtype,
            sharding=NamedSharding(mesh, P("pod", *spec)))
    return jax.tree.map(stack, ps)


def run(mode: str, local_steps: int, arch: str = "llama3.2-3b",
        seq: int = 4096, global_batch: int = 256):
    mesh = make_production_mesh(multi_pod=True)
    L = mesh.shape["pod"]
    cfg = get_config(arch)
    if os.environ.get("REPRO_ONEHOT_EMBED"):
        cfg = dataclasses.replace(cfg, embedding_impl="one_hot")
    model = build_model(cfg)
    opt_cfg = OptimizerConfig()
    out = {"mode": mode, "arch": arch, "local_steps": local_steps,
           "num_collectors": L}

    with use_compute_mesh(mesh):
        if mode == "sync":
            from repro.configs.base import INPUT_SHAPES
            from repro.launch.specs import input_specs
            shape = INPUT_SHAPES["train_4k"]
            specs = input_specs(cfg, shape, mesh, model)
            step = make_train_step(model, opt_cfg)
            t0 = time.time()
            compiled = jax.jit(step, donate_argnums=(0, 1)).lower(
                specs["params"], specs["opt_state"], specs["batch"],
                specs["step"]).compile()
            out["compile_s"] = time.time() - t0
            ana = analyze_hlo(compiled.as_text())
            # per-step DCN traffic x local_steps for an apples comparison
            out["dcn_bytes_per_round"] = ana["collectives"]["dcn_bytes"] * \
                local_steps
            out["total_bytes_per_round"] = (
                ana["collectives"]["total_bytes"] * local_steps)
            return out

        htl = HTLConfig(mode=mode, num_collectors=L,
                        local_steps=local_steps, mixing_steps=2,
                        mixing_mode=os.environ.get("REPRO_MIXING", "gd"))
        tr = HTLTrainer(model, opt_cfg, htl)

        ps = param_specs(model, mesh)
        stacked = _stacked_specs(ps, L, mesh)
        opt = AdamWState(
            count=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.float32, sharding=s.sharding), stacked),
            nu=jax.tree.map(lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.float32, sharding=s.sharding), stacked))
        b_per = global_batch // L
        tok = jax.ShapeDtypeStruct(
            (local_steps, L, b_per, seq), jnp.int32,
            sharding=NamedSharding(mesh, P(None, "pod", "data")))
        batches = {"tokens": tok, "targets": tok}
        mix_seq = int(os.environ.get("REPRO_MIX_SEQ", seq))
        mix = {k: jax.ShapeDtypeStruct(
            (L, b_per, mix_seq), jnp.int32,
            sharding=NamedSharding(mesh, P("pod", "data"))) for k in
            ("tokens", "targets")}
        out["mix_seq"] = mix_seq

        from repro.core.htl_trainer import HTLState
        state = HTLState(stacked, opt, jax.ShapeDtypeStruct((), jnp.int32))

        t0 = time.time()
        if os.environ.get("REPRO_PODWISE"):
            local_fn = lambda st, b: tr.local_phase_podwise(st, b, mesh)
            out["podwise"] = True
        else:
            local_fn = tr.local_phase
        local_c = jax.jit(local_fn, donate_argnums=(0,)).lower(
            state, batches).compile()
        out["compile_local_s"] = time.time() - t0
        t0 = time.time()
        transfer_c = jax.jit(tr.transfer_phase, donate_argnums=(0,)).lower(
            state, mix).compile()
        out["compile_transfer_s"] = time.time() - t0

        a_local = analyze_hlo(local_c.as_text())
        a_transfer = analyze_hlo(transfer_c.as_text())
        out["dcn_bytes_per_round"] = (a_local["collectives"]["dcn_bytes"]
                                      + a_transfer["collectives"]["dcn_bytes"])
        out["dcn_local"] = a_local["collectives"]["dcn_bytes"]
        out["dcn_transfer"] = a_transfer["collectives"]["dcn_bytes"]
        out["total_bytes_per_round"] = (
            a_local["collectives"]["total_bytes"]
            + a_transfer["collectives"]["total_bytes"])
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="all",
                    choices=["sync", "star", "a2a", "all"])
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--arch", default="llama3.2-3b")
    args = ap.parse_args()
    modes = ["sync", "star", "a2a"] if args.mode == "all" else [args.mode]
    os.makedirs(RESULTS, exist_ok=True)
    results = {}
    for m in modes:
        r = run(m, args.local_steps, args.arch)
        results[m] = r
        print(f"{m:5s}: DCN/round {r['dcn_bytes_per_round']:.4g} B "
              f"(total {r['total_bytes_per_round']:.4g} B)", flush=True)
    if "sync" in results:
        for m in ("star", "a2a"):
            if m in results:
                ratio = results[m]["dcn_bytes_per_round"] / max(
                    1.0, results["sync"]["dcn_bytes_per_round"])
                print(f"{m} DCN ratio vs sync (H={args.local_steps}): "
                      f"{ratio:.4f}")
                results[m]["dcn_ratio_vs_sync"] = ratio
    with open(os.path.join(RESULTS, f"htl_round_{args.arch}.json"),
              "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
