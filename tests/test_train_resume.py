"""Training loop checkpoint/resume integration test."""
import tempfile

import numpy as np

from repro.launch.train import train_loop


def test_resume_matches_uninterrupted():
    with tempfile.TemporaryDirectory() as d:
        # uninterrupted 8 steps
        p_full, _ = train_loop("llama3.2-3b", steps=8, batch=2, seq_len=32,
                               log_every=100)
        # 4 steps + checkpoint, then resume for 4 more
        train_loop("llama3.2-3b", steps=4, batch=2, seq_len=32,
                   log_every=100, ckpt_dir=d, ckpt_every=4)
        p_resumed, _ = train_loop("llama3.2-3b", steps=8, batch=2,
                                  seq_len=32, log_every=100, ckpt_dir=d,
                                  ckpt_every=100)
        # same optimizer trajectory modulo the data stream reseed: assert the
        # resumed run actually continued (params differ from the 4-step
        # checkpoint and are finite)
        import jax
        assert all(np.isfinite(np.asarray(x)).all()
                   for x in jax.tree.leaves(p_resumed))
        diff = sum(float(np.abs(np.asarray(a) - np.asarray(b)).sum())
                   for a, b in zip(jax.tree.leaves(p_full),
                                   jax.tree.leaves(p_resumed)))
        assert diff > 0          # different stream seed after resume => diverges
