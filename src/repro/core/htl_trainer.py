"""Hypothesis-Transfer training for large models — the paper's technique at
datacenter scale (DESIGN.md §3).

The ``data`` hierarchy maps onto the paper's radio hierarchy: frequent
synchronous gradient exchange stays on cheap links (intra-pod ICI), and the
expensive boundary (inter-pod DCN — the paper's NB-IoT/LTE long-range link)
carries only *hypotheses* (whole models), once every ``local_steps`` steps.

Mechanics (mirrors paper Algorithms 1 & 2, with hypotheses = parameter
pytrees):

* L virtual Data Collectors hold a **stacked** parameter pytree with a
  leading ``(L, ...)`` dim (logical axis ``dc`` -> the ``pod`` mesh axis in
  production, so each pod literally holds its own hypothesis).
* *Step 0*: every DC runs ``local_steps`` AdamW steps on its own disjoint
  token stream (vmapped; inside a pod this is ordinary sync data-parallel).
* *Step 1/2* (A2A): every DC receives all hypotheses (all-gather over the
  ``dc``/pod axis — the only DCN traffic) and runs the **GreedyTL analogue**:
  it learns simplex mixing weights over the L hypotheses by minimising its
  *local* loss of the mixed model (softmax-parametrised projected gradient —
  the differentiable relaxation of greedy subset selection; DESIGN.md §9).
* *Step 3/4* (A2A): refined hypotheses are averaged.
* *StarHTL*: a center is elected by maximum local label (token) entropy —
  the paper's election index — and only the center mixes; the result is
  broadcast.
* ``sync`` mode is the centralised baseline: plain data-parallel AdamW with
  gradient all-reduce over every axis each step (the paper's Edge-Only).

The traffic ledger counts logical DCN transfers exactly like the paper's
energy ledger counts radio transfers; the dry-run's HLO parse provides the
measured per-device collective bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import HTLConfig, OptimizerConfig
from repro.models.model import Model
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_warmup_schedule
from repro.sharding.partitioning import hint


class HTLState(NamedTuple):
    params: Any          # stacked (L, ...) pytree
    opt: AdamWState      # stacked moments
    step: jax.Array


def _stack_tree(tree, L: int):
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None], (L,) + x.shape),
                        tree)


class HTLTrainer:
    """Model-agnostic hypothesis-transfer trainer (vmap over the dc axis).

    The same code runs on one CPU device (examples/tests, L small) and under
    the production mesh (dry-run: the leading dc dim shards over 'pod').
    """

    def __init__(self, model: Model, opt_cfg: OptimizerConfig,
                 htl_cfg: HTLConfig):
        self.model = model
        self.opt_cfg = opt_cfg
        self.htl = htl_cfg
        self._sched = cosine_warmup_schedule(opt_cfg)

    # ------------------------------------------------------------------ init
    def init(self, key) -> HTLState:
        L = self.htl.num_collectors
        params = self.model.init(key)
        if self.htl.mode != "sync":
            params = _stack_tree(params, L)
            # de-correlate initial hypotheses slightly (paper: different
            # local SVMs): small per-DC jitter
            leaves, treedef = jax.tree.flatten(params)
            out = []
            for i, leaf in enumerate(leaves):
                k = jax.random.fold_in(key, 1000 + i)
                noise = 0.01 * jax.random.normal(k, leaf.shape, jnp.float32)
                out.append((leaf.astype(jnp.float32) + noise *
                            jnp.std(leaf.astype(jnp.float32))
                            ).astype(leaf.dtype))
            params = jax.tree.unflatten(treedef, out)
        opt = adamw_init(params)
        return HTLState(params, opt, jnp.zeros((), jnp.int32))

    # ----------------------------------------------------------- local steps
    def _one_local_step(self, params, opt, batch, step):
        """vmapped over the leading dc dim when mode != sync."""
        def single(p, o, b):
            (_, metrics), grads = jax.value_and_grad(
                self.model.loss_fn, has_aux=True)(p, b)
            lr = self._sched(step)
            new_p, new_o, gnorm = adamw_update(grads, o, p, lr, self.opt_cfg)
            return new_p, new_o, metrics["loss"]

        if self.htl.mode == "sync":
            return single(params, opt, batch)
        # optimizer count is a shared scalar; moments are stacked per-DC
        in_axes = (0, AdamWState(count=None, mu=0, nu=0), 0)
        new_p, new_o, loss = jax.vmap(single, in_axes=in_axes,
                                      out_axes=(0, AdamWState(None, 0, 0), 0)
                                      )(params, opt, batch)
        return new_p, new_o, loss

    def local_phase(self, state: HTLState, batches) -> Tuple[HTLState, Any]:
        """batches: pytree with leading (H, L, ...) dims (H local steps)."""
        def body(carry, batch):
            params, opt, step = carry
            params, opt, loss = self._one_local_step(params, opt, batch, step)
            return (params, opt, step + 1), loss

        (params, opt, step), losses = jax.lax.scan(
            body, (state.params, state.opt, state.step), batches)
        return HTLState(params, opt, step), losses

    def local_phase_podwise(self, state: HTLState, batches, mesh):
        """Production local phase: `shard_map` manual over the 'pod' axis so
        each pod trains its own hypothesis with ZERO cross-pod traffic by
        construction (§Perf iteration 3; GSPMD alone reshards vmapped gathers
        across pods — XLA b/433785288)."""
        import jax.sharding as jsh
        P = jsh.PartitionSpec

        def per_pod(params, mu, nu, count, step, batch):
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            opt = AdamWState(count, sq(mu), sq(nu))
            p = sq(params)
            batch = jax.tree.map(lambda x: x[:, 0], batch)  # drop dc dim

            def body(carry, b):
                p, o, s = carry
                (_, metrics), grads = jax.value_and_grad(
                    self.model.loss_fn, has_aux=True)(p, b)
                lr = self._sched(s)
                p, o, _ = adamw_update(grads, o, p, lr, self.opt_cfg)
                return (p, o, s + 1), metrics["loss"]

            (p, o, s), losses = jax.lax.scan(body, (p, opt, step), batch)
            ex = lambda t: jax.tree.map(lambda x: x[None], t)
            return ex(p), ex(o.mu), ex(o.nu), o.count, s, losses[None]

        pod = jax.tree.map(lambda _: P("pod"), state.params)
        podb = jax.tree.map(lambda _: P(None, "pod"), batches)
        fn = jax.shard_map(
            per_pod, mesh=mesh, axis_names=frozenset({"pod"}),
            check_vma=False,
            in_specs=(pod, pod, pod, P(), P(), podb),
            out_specs=(pod, pod, pod, P(), P(), P("pod")))
        p, mu, nu, count, step, losses = fn(
            state.params, state.opt.mu, state.opt.nu, state.opt.count,
            state.step, batches)
        return HTLState(p, AdamWState(count, mu, nu), step), losses

    # ------------------------------------------------------- mixing (GreedyTL)
    def _mix(self, stacked_params, weights):
        """weights: (L,) simplex -> mixed pytree."""
        return jax.tree.map(
            lambda x: jnp.einsum("i,i...->...", weights.astype(jnp.float32),
                                 x.astype(jnp.float32)).astype(x.dtype),
            stacked_params)

    def _mixing_weights(self, stacked_params, mix_batch, self_idx):
        """GreedyTL analogue: simplex weights minimising local loss."""
        L = self.htl.num_collectors

        if self.htl.mixing_mode == "loss_softmax":
            # first-order variant: evaluate every hypothesis on the local
            # batch, weight by exp(-loss/tau)
            def loss_of(p):
                loss, _ = self.model.loss_fn(p, mix_batch)
                return loss
            losses = jax.vmap(loss_of)(stacked_params)      # (L,)
            return jax.nn.softmax(-losses / self.htl.mixing_tau)

        def loss_of_z(z):
            w = jax.nn.softmax(z)
            mixed = self._mix(stacked_params, w)
            loss, _ = self.model.loss_fn(mixed, mix_batch)
            return loss

        z0 = jnp.where(jnp.arange(L) == self_idx, 1.0, 0.0)

        def gd(z, _):
            g = jax.grad(loss_of_z)(z)
            return z - self.htl.mixing_lr * g, None

        z, _ = jax.lax.scan(gd, z0, None, length=self.htl.mixing_steps)
        return jax.nn.softmax(z)

    @staticmethod
    def _token_entropy(tokens, nbins: int = 256):
        """Paper's election index: label entropy -> token-histogram entropy."""
        binned = tokens % nbins
        counts = jnp.zeros(nbins).at[binned.reshape(-1)].add(1.0)
        p = counts / jnp.maximum(1.0, counts.sum())
        return -jnp.sum(jnp.where(p > 0, p * jnp.log(p), 0.0))

    # ------------------------------------------------------- transfer round
    def transfer_phase(self, state: HTLState, mix_batches) -> HTLState:
        """mix_batches: pytree with leading (L, ...) — one mixing batch/DC."""
        mode = self.htl.mode
        if mode == "sync":
            return state
        L = self.htl.num_collectors
        params = state.params

        if mode == "a2a":
            # every DC mixes all hypotheses against its local batch...
            def refine(self_idx, mix_batch):
                w = self._mixing_weights(params, mix_batch, self_idx)
                return self._mix(params, w), w

            refined, weights = jax.vmap(
                refine, in_axes=(0, 0))(jnp.arange(L), mix_batches)
            # ...then refined hypotheses are averaged (paper Step 4)
            avg = jax.tree.map(lambda x: jnp.mean(
                x.astype(jnp.float32), axis=0).astype(x.dtype), refined)
            new_params = _stack_tree(avg, L)
        else:  # star
            ent = jax.vmap(self._token_entropy)(mix_batches["tokens"])
            center = jnp.argmax(ent)
            center_batch = jax.tree.map(lambda x: x[center], mix_batches)
            w = self._mixing_weights(params, center_batch, center)
            mixed = self._mix(params, w)
            new_params = _stack_tree(mixed, L)

        # hypotheses changed discontinuously: second moments stay (scale
        # info), first moments are damped like a warm restart
        new_mu = jax.tree.map(lambda m: 0.5 * m, state.opt.mu)
        return HTLState(new_params, AdamWState(state.opt.count, new_mu,
                                               state.opt.nu), state.step)

    # ------------------------------------------------------------ accounting
    def round_traffic_bytes(self) -> Dict[str, float]:
        """Logical DCN transfers per HTL round vs sync baseline (paper-style
        ledger; the dry-run HLO gives the measured per-device numbers)."""
        from repro.sharding.partitioning import template_bytes
        mb = template_bytes(self.model.template(),
                            jnp.dtype(self.model.cfg.dtype))
        L, H = self.htl.num_collectors, self.htl.local_steps
        out = {"model_bytes": float(mb)}
        if self.htl.mode == "a2a":
            out["htl_round_bytes"] = float(mb) * (L * (L - 1) + (L - 1))
        elif self.htl.mode == "star":
            out["htl_round_bytes"] = float(mb) * (L - 1 + L)  # in + bcast
        else:
            out["htl_round_bytes"] = 0.0
        # sync baseline: ring all-reduce of grads every step ~ 2x model bytes
        out["sync_bytes_same_steps"] = 2.0 * float(mb) * H
        out["traffic_ratio_vs_sync"] = (
            out["htl_round_bytes"] / max(1.0, out["sync_bytes_same_steps"]))
        return out
