#!/usr/bin/env python
"""Sweep-service CI gate: the RPC control plane may never change the
numbers — streamed, faulted, or cached.

Boots the HTTP service in-process (ephemeral port), then for each preset
grid submits the sweep over the wire and checks four things against the
sequential in-process reference (DESIGN.md §12):

1. **clean streamed pass** — shards dispatched through real worker
   subprocesses (``local`` channel), streamed back as NDJSON and merged
   incrementally client-side: merged JSON must be byte-identical;
2. **fault-injected pass** (``--inject-failures``) — one worker is
   really SIGKILLed mid-shard on its first attempt; the retry heals it
   and the streamed merge still matches bitwise (submitted with
   ``cache=bypass`` so the cache cannot mask the fault path);
3. **cache-hit pass** — the same spec submitted again is served from the
   exact result cache: ``cached=true``, the recorded
   ``service.cache.hit`` counter moves, and the served bytes equal the
   recomputed (and sequential) bytes — cache-hit == recompute;
4. the fleet-health counters moved the way the passes imply (shard oks,
   crash failures on the injected pass).

With ``--statsd-e2e`` the gate additionally binds a loopback UDP
listener, points ``REPRO_STATSD_ADDR`` at it *before* any repro import
(the statsd singleton reads the env once), and after the passes drains
every datagram and validates it against the DogStatsD line grammar —
the metrics pipeline checked end to end on the wire, not just
in-process.

    python scripts/service_parity.py --preset smoke --windows 3 \
        --spec "hosts:channel=local,n=2,retries=1" --inject-failures
    python scripts/service_parity.py --preset transport_grid --windows 3 \
        --spec "hosts:channel=inline,n=2,retries=1"

Wired into scripts/verify.sh (gates phase) and the named ``service-smoke``
CI step, mirroring scripts/hosts_parity.py.
"""
from __future__ import annotations

import argparse
import os
import re
import socket
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# DogStatsD line grammar (what a telegraf/datadog agent parses):
#   <name>:<value>|<type>[|#tag:value,tag:value,...]
# with c|g|ms types and dotted metric names (ROADMAP: validate the
# datagram format end to end, not just in-process).
STATSD_LINE = re.compile(
    r"^(?P<name>[A-Za-z][A-Za-z0-9_.]*):"
    r"(?P<value>-?\d+(\.\d+)?([eE][-+]?\d+)?)"
    r"\|(?P<kind>c|g|ms)"
    r"(\|#(?P<tags>[A-Za-z0-9_.]+:[^,|]*(,[A-Za-z0-9_.]+:[^,|]*)*))?$")


def check_statsd(udp: socket.socket) -> int:
    """Drain and validate every UDP datagram the service/launcher
    emitted during the passes: each line must parse against the
    DogStatsD grammar, carry the ``repro.`` namespace, and the traffic
    must include counters AND timers plus the known submit series."""
    time.sleep(0.2)                 # let in-flight loopback packets land
    lines = []
    while True:
        try:
            payload, _ = udp.recvfrom(65536)
        except BlockingIOError:
            break
        lines.append(payload.decode("ascii", "replace"))
    rc = 0
    if not lines:
        print("statsd e2e: no UDP datagrams received — emission never "
              "happened")
        return 1
    bad = [ln for ln in lines if not STATSD_LINE.match(ln)]
    if bad:
        print(f"statsd e2e: {len(bad)}/{len(lines)} datagrams fail the "
              f"DogStatsD grammar, e.g. {bad[0]!r}")
        rc = 1
    names = {STATSD_LINE.match(ln)["name"] for ln in lines
             if STATSD_LINE.match(ln)}
    kinds = {STATSD_LINE.match(ln)["kind"] for ln in lines
             if STATSD_LINE.match(ln)}
    off_ns = sorted(n for n in names if not n.startswith("repro."))
    if off_ns:
        print(f"statsd e2e: series outside the repro. namespace: "
              f"{off_ns[:5]}")
        rc = 1
    for want in ("c", "ms"):
        if want not in kinds:
            print(f"statsd e2e: no |{want} datagram seen (kinds: "
                  f"{sorted(kinds)})")
            rc = 1
    if "repro.service.jobs.submitted" not in names:
        print(f"statsd e2e: repro.service.jobs.submitted missing from "
              f"{len(names)} series")
        rc = 1
    if rc == 0:
        print(f"statsd e2e: OK — {len(lines)} datagrams, {len(names)} "
              f"series, all parse as DogStatsD, kinds "
              f"{sorted(kinds)}")
    return rc


def first_diff(a: str, b: str, context: int = 60) -> str:
    k = next((i for i, (x, y) in enumerate(zip(a, b)) if x != y),
             min(len(a), len(b)))
    return (f"first divergence at byte {k}: "
            f"...{a[max(0, k - context):k + context]!r} vs "
            f"...{b[max(0, k - context):k + context]!r}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--windows", type=int, default=3)
    ap.add_argument("--spec", default="hosts:channel=local,n=2,retries=1",
                    help="hosts backend spec the service dispatches "
                         "through")
    ap.add_argument("--inject-failures", action="store_true",
                    help="add a pass with one worker SIGKILLed mid-shard "
                         "on its first attempt (cache bypassed so the "
                         "fault path really runs)")
    ap.add_argument("--statsd-e2e", action="store_true",
                    help="bind a loopback UDP listener, point "
                         "REPRO_STATSD_ADDR at it, and validate every "
                         "datagram against the DogStatsD grammar")
    args = ap.parse_args()

    udp = None
    if args.statsd_e2e:
        # Must happen before any repro import: the statsd singleton
        # reads REPRO_STATSD_ADDR once at module import.
        udp = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        udp.bind(("127.0.0.1", 0))
        udp.setblocking(False)
        os.environ["REPRO_STATSD_ADDR"] = (
            f"127.0.0.1:{udp.getsockname()[1]}")

    from repro.core.experiment import get_preset
    from repro.data.synthetic_covtype import make_covtype_like
    from repro.service.client import ServiceClient
    from repro.service.server import make_server
    from repro.service.statsd import statsd

    data = make_covtype_like(seed=0)
    spec = get_preset(args.preset, windows=args.windows)
    ref = spec.run(data, parallel="none").to_json()

    httpd, _service = make_server(backend=args.spec)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    client = ServiceClient(httpd.server_address[:2])
    rc = 0

    passes = [("clean streamed", dict(cache="use"), False)]
    if args.inject_failures:
        passes.append(("fault-injected",
                       dict(cache="bypass",
                            backend=f"{args.spec},backoff=0.01,"
                                    f"inject_kill=0"), False))
    passes.append(("cache-hit", dict(cache="use"), True))

    for label, kwargs, want_cached in passes:
        crashes_before = statsd.counter("launcher.shard.failures",
                                        tags={"kind": "crash"})
        hits_before = statsd.counter("service.cache.hit")
        result = client.run(spec, data, **kwargs)
        got = result.to_json()
        svc = result.meta["service"]
        status = client.status(svc["job"])
        if got == ref:
            print(f"service parity [{label}]: OK ({len(ref)} bytes "
                  f"identical, {svc['n_shards']} shard(s), "
                  f"{status['attempts_total']} attempt(s), "
                  f"cached={svc['cached']})")
        else:
            print(f"service parity [{label}]: MISMATCH — "
                  f"{first_diff(ref, got)}")
            rc = 1
        if svc["cached"] != want_cached:
            print(f"service parity [{label}]: cached={svc['cached']}, "
                  f"expected {want_cached}")
            rc = 1
        if want_cached:
            if statsd.counter("service.cache.hit") <= hits_before:
                print(f"service parity [{label}]: service.cache.hit "
                      f"counter did not move")
                rc = 1
            served = client.result_text(svc["job"])
            if served != ref:
                print(f"service parity [{label}]: served cache bytes "
                      f"differ from recompute — {first_diff(ref, served)}")
                rc = 1
        if label == "fault-injected":
            crashed = statsd.counter("launcher.shard.failures",
                                     tags={"kind": "crash"})
            if crashed <= crashes_before:
                print(f"service parity [{label}]: no crash failure "
                      f"recorded — the injected SIGKILL never happened")
                rc = 1

    ok = statsd.counter("launcher.shard.ok")
    if ok < 1:
        print(f"service parity: launcher.shard.ok = {ok}, expected >= 1")
        rc = 1
    httpd.shutdown()
    if udp is not None:
        rc |= check_statsd(udp)
        udp.close()
    if rc == 0:
        print("sweep service: bitwise-identical to sequential — streamed"
              + (", under injected worker SIGKILL"
                 if args.inject_failures else "")
              + ", and from the exact result cache")
    return rc


if __name__ == "__main__":
    sys.exit(main())
