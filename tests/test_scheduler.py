"""Continuous-batching scheduler: per-slot positions, splicing, and
equivalence with sequential generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import make_lm_batch
from repro.models import build_model
from repro.serving import ServeEngine
from repro.serving.scheduler import ContinuousBatcher, Request


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-1.3b"])
def test_matches_sequential_generation(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # requests with DIFFERENT prompt lengths -> different decode depths
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int64)
               for n in (24, 16, 31, 9)]
    n_new = 5

    batcher = ContinuousBatcher(model, params, slots=2, max_len=64)
    reqs = [Request(i, p, n_new) for i, p in enumerate(prompts)]
    for r in reqs:
        batcher.submit(r)
    batcher.run()
    assert all(r.done for r in reqs)

    # reference: one-at-a-time greedy generation
    eng = ServeEngine(model, params, max_new_tokens=n_new)
    for r, p in zip(reqs, prompts):
        ref = np.asarray(eng.generate(
            {"tokens": jnp.asarray(p[None, :], jnp.int32)}))[0]
        assert r.out[:n_new] == ref.tolist(), (r.rid, r.out, ref.tolist())


def test_per_sequence_positions_decode():
    """Vector pos: two sequences at different depths in one batched decode
    must match their scalar-pos decodes."""
    from repro.serving import pad_cache
    cfg = get_config("llama3.2-3b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    toks = make_lm_batch(cfg.vocab_size, 2, 32, seed=7,
                         d_model=cfg.d_model)["tokens"]

    # scalar-pos references (each row alone)
    refs = []
    lens = [32, 20]
    caches = []
    for i, n in enumerate(lens):
        lg, cache = jax.jit(m.prefill)(params, {"tokens": toks[i:i+1, :n]})
        cache = pad_cache(m, cache, 40 - n, 1, n)
        lg2, _ = jax.jit(m.decode_step)(
            params, cache, jnp.argmax(lg, -1)[:, None].astype(jnp.int32),
            jnp.asarray(n, jnp.int32))
        refs.append(np.asarray(lg2)[0])
        caches.append(cache)

    # batched with per-sequence positions
    batched = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=1),
                           caches[0], caches[1])
    lg0, _ = jax.jit(m.prefill)(params, {"tokens": toks[0:1, :lens[0]]})
    lg1, _ = jax.jit(m.prefill)(params, {"tokens": toks[1:2, :lens[1]]})
    tok = jnp.concatenate([jnp.argmax(lg0, -1), jnp.argmax(lg1, -1)]
                          )[:, None].astype(jnp.int32)
    lgb, _ = jax.jit(m.decode_step)(params, batched, tok,
                                    jnp.asarray(lens, jnp.int32))
    out = np.asarray(lgb)
    np.testing.assert_allclose(out[0], refs[0], atol=2e-3)
    np.testing.assert_allclose(out[1], refs[1], atol=2e-3)
