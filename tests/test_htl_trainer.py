"""Hypothesis-transfer trainer for LMs: convergence, modes, traffic ledger."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import HTLConfig, OptimizerConfig
from repro.core.htl_trainer import HTLTrainer
from repro.data.pipeline import TokenStream
from repro.models import build_model

CFG = dataclasses.replace(
    get_config("llama3.2-3b").reduced(), num_layers=2, d_model=64,
    num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128, vocab_size=256)
MODEL = build_model(CFG)
L, H, B, S = 4, 4, 4, 64


def _trainer(mode):
    return HTLTrainer(MODEL, OptimizerConfig(lr=3e-3, warmup_steps=10,
                                             total_steps=300),
                      HTLConfig(mode=mode, num_collectors=L, local_steps=H,
                                mixing_steps=4))


def _batches(stream, h):
    toks = np.stack([stream.tokens(L * B * (S + 1)).reshape(L, B, S + 1)
                     for _ in range(h)])
    return {"tokens": jnp.asarray(toks[..., :-1]),
            "targets": jnp.asarray(toks[..., 1:])}


@pytest.mark.parametrize("mode", ["a2a", "star"])
def test_htl_training_converges(mode):
    tr = _trainer(mode)
    state = tr.init(jax.random.PRNGKey(0))
    stream = TokenStream(CFG.vocab_size, seed=1)
    local = jax.jit(tr.local_phase)
    transfer = jax.jit(tr.transfer_phase)
    losses = []
    for _ in range(5):
        state, ls = local(state, _batches(stream, H))
        state = transfer(state, jax.tree.map(lambda x: x[0],
                                             _batches(stream, 1)))
        losses.append(float(ls.mean()))
    assert losses[-1] < losses[0] - 0.3, losses
    # all DC hypotheses identical after a transfer round (avg / broadcast)
    p0 = jax.tree.leaves(state.params)[0]
    assert bool(jnp.allclose(p0[0], p0[1]))


def test_transfer_keeps_finite():
    tr = _trainer("a2a")
    state = tr.init(jax.random.PRNGKey(0))
    stream = TokenStream(CFG.vocab_size, seed=2)
    state, _ = jax.jit(tr.local_phase)(state, _batches(stream, H))
    state = jax.jit(tr.transfer_phase)(state, jax.tree.map(
        lambda x: x[0], _batches(stream, 1)))
    assert all(bool(jnp.isfinite(x).all()) for x in
               jax.tree.leaves(state.params))


def test_traffic_ledger_scaling():
    """HTL round traffic is O(L^2) for A2A, O(L) for Star, and the ratio to
    the sync baseline falls as 1/local_steps — the paper's economics."""
    t8 = _trainer("a2a")
    r8 = t8.round_traffic_bytes()
    mb = r8["model_bytes"]
    assert r8["htl_round_bytes"] == mb * (L * (L - 1) + (L - 1))

    star = _trainer("star").round_traffic_bytes()
    assert star["htl_round_bytes"] < r8["htl_round_bytes"]

    long_h = HTLTrainer(MODEL, OptimizerConfig(),
                        HTLConfig(mode="a2a", num_collectors=L,
                                  local_steps=64))
    assert long_h.round_traffic_bytes()["traffic_ratio_vs_sync"] < \
        r8["traffic_ratio_vs_sync"]


def test_sync_mode_is_plain_training():
    tr = HTLTrainer(MODEL, OptimizerConfig(lr=3e-3),
                    HTLConfig(mode="sync", num_collectors=1, local_steps=H))
    state = tr.init(jax.random.PRNGKey(0))
    # sync params are unstacked
    assert jax.tree.leaves(state.params)[0].ndim == \
        jax.tree.leaves(MODEL.init(jax.random.PRNGKey(0)))[0].ndim
    assert tr.round_traffic_bytes()["htl_round_bytes"] == 0.0
