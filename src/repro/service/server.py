"""The sweep service: a streaming HTTP RPC control plane over sweeps.

This is the step from "library" to "system" (ROADMAP): instead of every
experiment being a blocking in-process ``SweepSpec.run`` whose launcher
barriers on all shards, a long-running server accepts ``SweepSpec`` JSON
over plain HTTP, dispatches partition shards through the existing
executor/`HostChannel` machinery (:mod:`repro.core.launcher`), and
**streams each shard's ``SweepResult`` payload back the moment it
lands** — NDJSON, one event per line — so the client performs an
incremental order-stable merge (:class:`repro.core.parallel.ShardMerger`)
and the all-shards barrier disappears from the client's critical path.
Everything is stdlib: ``http.server`` threads, JSON bodies, no framework.

Wire protocol (DESIGN.md §12 has the full catalogue):

* ``POST /v1/jobs`` — body ``{"schema": 1, "spec": SweepSpec.to_wire(),
  "data": encode_dataset(...), "stack": "auto"|"off",
  "backend": "hosts:...", "cache": "use"|"bypass"|"off"}``. Replies with
  the job id, the shard partition (the client needs it to merge), the
  canonical cache key, and ``cached: true`` when the exact result cache
  already holds the bytes.
* ``GET /v1/jobs/<id>`` — job status (state, shards done/total,
  attempt counts).
* ``GET /v1/jobs/<id>/stream?cursor=K`` — NDJSON event stream starting
  at sequence ``K``. Events are persisted per job, so a disconnected
  client resumes by re-requesting with the last seen cursor — replays
  are idempotent at the merger. ``max_events=N`` bounds one response
  (operational knob + the reconnect test hook).
* ``GET /v1/jobs/<id>/results`` — the merged ``SweepResult`` JSON,
  **verbatim bytes** (the parity surface); ``?page=N&per_page=M`` pages
  large results via :meth:`SweepResult.page`.
* ``POST /v1/jobs/<id>/cancel`` — body ``{"cancel_token": ...}`` with
  the token the submit reply returned; sets the job's stop event so no
  new shard attempt starts (:meth:`HostsExecutor.execute_with_meta`).
  A missing or wrong token is a 403: only the submitter (or whoever it
  shares the token with) can cancel a job.
* ``GET /v1/metrics`` — the statsd snapshot + cache stats;
  ``GET /v1/healthz`` — liveness + queue depth.

Determinism: a job's merged JSON is produced by exactly the machinery
the launcher gate already proves bitwise — the shared shard runner, the
shared partitioner, the shared order-stable merge — so the served bytes
equal the sequential in-process run's bytes, clean, under worker
SIGKILL, and on a cache hit (gated by scripts/service_parity.py).
Request/response payloads are guarded by
:func:`repro.core.parallel.assert_host_only`: no jax device buffer (or
pickled ``EvalCache``) can cross the service boundary in either
direction.
"""
from __future__ import annotations

import json
import secrets
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, List, Optional, Tuple
from urllib.parse import parse_qs, urlparse

from repro.core.experiment import SweepResult, SweepSpec, records_from
from repro.core.launcher import HostsExecutor, LauncherError, get_channel
from repro.core.parallel import (ShardMerger, assert_host_only,
                                 partition_runs)
from repro.core.pareto import SearchCancelled, get_search
from repro.core.registry import parse_spec
from repro.core.scenario import validate_config
from repro.service.cache import ResultCache, cache_key, dataset_digest
from repro.service.statsd import statsd

SERVICE_SCHEMA = 1
DEFAULT_BACKEND = "hosts:channel=inline,n=2"


class ServiceError(RuntimeError):
    """A request the service rejected; ``status`` is the HTTP code."""

    def __init__(self, status: int, detail: str):
        super().__init__(detail)
        self.status = status
        self.detail = detail


class Job:
    """One submitted sweep: identity, partition, event log, lifecycle.

    The event log is the streaming source of truth — every per-shard
    payload and the terminal event are appended under the condition
    variable and kept for the job's lifetime, which is what makes streams
    resumable from any cursor (a reconnecting client never misses a
    shard; replays are de-duplicated by the client's merger)."""

    def __init__(self, job_id: str, spec: SweepSpec, stack: str,
                 shards: List[List[int]], key: str, cache_mode: str,
                 backend: str, search: str = ""):
        self.id = job_id
        self.spec = spec
        self.stack = stack
        self.shards = shards
        self.key = key
        self.cache_mode = cache_mode
        self.backend = backend
        # "" = plain sweep; otherwise the canonical search spec — the
        # job runs a Pareto search (DESIGN.md §14) and streams `rung`
        # events instead of per-shard payloads
        self.search = search
        self.state = "queued"   # queued|running|done|failed|cancelled
        self.cached = False
        # capability token: returned once in the submit reply, required
        # by /cancel — never exposed via status()/metrics
        self.cancel_token = secrets.token_hex(16)
        self.events: List[Dict[str, Any]] = []
        self.cond = threading.Condition()
        self.stop = threading.Event()
        self.result_text: Optional[str] = None
        self.error: Optional[str] = None
        self.shards_done = 0
        self.attempts_total = 0
        self.t_submit = time.monotonic()
        self.t_first_shard: Optional[float] = None

    def append_event(self, event: Dict[str, Any]) -> None:
        with self.cond:
            event["seq"] = len(self.events)
            self.events.append(event)
            self.cond.notify_all()

    def finish(self, state: str, *, cached: bool = False,
               error: Optional[str] = None) -> None:
        with self.cond:
            self.state = state
            self.cached = cached
            self.error = error
        kind = "done" if state == "done" else "error"
        event: Dict[str, Any] = {"event": kind, "state": state,
                                 "cached": cached}
        if error is not None:
            event["error"] = error
        self.append_event(event)

    def terminal(self) -> bool:
        return self.state in ("done", "failed", "cancelled")

    def wait_events(self, cursor: int, timeout: float = 10.0
                    ) -> List[Dict[str, Any]]:
        """Events from ``cursor`` on; blocks up to ``timeout`` for a new
        one when the log is drained and the job still runs."""
        deadline = time.monotonic() + timeout
        with self.cond:
            while len(self.events) <= cursor and not self.terminal():
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self.cond.wait(remaining)
            return list(self.events[cursor:])

    def status(self) -> Dict[str, Any]:
        with self.cond:
            return {"job": self.id, "state": self.state,
                    "cached": self.cached, "name": self.spec.name,
                    "kind": "search" if self.search else "sweep",
                    "search": self.search,
                    "n_shards": len(self.shards),
                    "shards_done": self.shards_done,
                    "attempts_total": self.attempts_total,
                    "events": len(self.events), "error": self.error,
                    "key": self.key, "backend": self.backend}


class SweepService:
    """Job manager: submit → dispatch shards (streaming) → cache result.

    ``backend`` is a ``hosts`` executor spec (the nested grammar of
    DESIGN.md §8) — channels ARE the service's execution backends; the
    default ``inline`` channel runs shards in-process, so a warm server
    answers small jobs without per-worker import+jit cost. ``max_jobs``
    bounds concurrently *running* jobs (a semaphore; excess jobs queue,
    visible as the ``service.jobs.queued`` gauge)."""

    def __init__(self, backend: str = DEFAULT_BACKEND,
                 cache: Optional[ResultCache] = None, max_jobs: int = 2):
        if max_jobs < 1:
            raise ValueError(f"max_jobs must be >= 1, got {max_jobs}")
        self.backend = backend
        self.cache = cache if cache is not None else ResultCache()
        self._jobs: Dict[str, Job] = {}
        self._lock = threading.Lock()
        self._sem = threading.Semaphore(max_jobs)
        self._n_jobs = 0
        self._queued = 0
        self._running = 0
        self._executor(backend)     # fail fast on a bad default backend

    # -- backend resolution -------------------------------------------------
    @staticmethod
    def _executor(spec: str) -> HostsExecutor:
        """A *fresh* hosts executor per job (never the shared
        ``get_executor`` cache: per-job fault-injection params must not
        leak into other jobs). Only ``hosts`` specs stream per shard, so
        only they are accepted as service backends."""
        name, params = parse_spec(spec)
        if name != "hosts":
            raise ServiceError(
                400, f"service backend must be a 'hosts:...' executor "
                     f"spec (channels are the service backends), got "
                     f"{spec!r}")
        try:
            executor = HostsExecutor(**params)
            executor._resolve_channel()     # fail fast on a bad channel
            return executor
        except (TypeError, ValueError, KeyError) as e:
            raise ServiceError(400, f"bad backend spec {spec!r}: {e}")

    @staticmethod
    def _shard_count(ex: HostsExecutor) -> int:
        if ex.n is not None:
            return ex.n
        channel = ex.channel if not isinstance(ex.channel, str) \
            else get_channel(ex.channel)
        return max(1, len(channel.slots()))

    # -- lifecycle ----------------------------------------------------------
    def submit(self, payload: Dict[str, Any]) -> Dict[str, Any]:
        if payload.get("schema") != SERVICE_SCHEMA:
            raise ServiceError(400, f"unsupported submit schema "
                                    f"{payload.get('schema')!r} (this "
                                    f"service speaks {SERVICE_SCHEMA})")
        assert_host_only(payload, where="service request")
        stack = payload.get("stack", "auto")
        if stack not in ("auto", "off"):
            raise ServiceError(400, f"stack must be 'auto' or 'off', got "
                                    f"{stack!r}")
        cache_mode = payload.get("cache", "use")
        if cache_mode not in ("use", "bypass", "off"):
            raise ServiceError(400, f"cache must be use|bypass|off, got "
                                    f"{cache_mode!r}")
        backend = payload.get("backend", self.backend)
        encoded = payload.get("data")
        if not isinstance(encoded, dict) or encoded.get("kind") != "arrays":
            raise ServiceError(400, "submit payload needs an encoded "
                                    "dataset under 'data' (launcher wire "
                                    "codec)")
        search = payload.get("search", "")
        search_spec = ""
        if search:
            try:
                search_spec = get_search(search).spec
            except (KeyError, ValueError) as e:
                raise ServiceError(400, f"bad search spec {search!r}: {e}")
        try:
            spec = SweepSpec.from_wire(payload["spec"])
            runs = spec.configs()
            for _, cfg in runs:
                validate_config(cfg)
        except (KeyError, TypeError, ValueError) as e:
            raise ServiceError(400, f"bad SweepSpec payload: {e}")
        executor = self._executor(backend)
        cfgs = [c for _, c in runs]
        # search jobs stream rung events, not per-shard payloads: the
        # executor shards each rung internally, so the submit reply
        # carries no client-mergeable partition
        shards = [] if search_spec else \
            [list(s) for s in
             partition_runs(cfgs, self._shard_count(executor)) if s]
        key = cache_key(spec.canonical_hash(), dataset_digest(encoded),
                        stack, search=search_spec)
        with self._lock:
            self._n_jobs += 1
            job_id = f"job-{self._n_jobs:06d}"
            job = Job(job_id, spec, stack, shards, key, cache_mode,
                      backend, search=search_spec)
            self._jobs[job_id] = job
        statsd.increment("service.jobs.submitted")
        if search_spec:
            statsd.increment("service.jobs.search")

        cached_text = (self.cache.get(key) if cache_mode == "use" else
                       None)
        if cached_text is not None:
            job.result_text = cached_text
            job.shards_done = len(shards)
            job.finish("done", cached=True)
            statsd.increment("service.jobs.completed")
        else:
            with self._lock:
                self._queued += 1
            self._update_gauges()
            thread = threading.Thread(
                target=self._run_job, args=(job, executor, encoded),
                name=f"sweep-{job_id}", daemon=True)
            thread.start()
        return {"schema": SERVICE_SCHEMA, "job": job.id,
                "cached": job.cached, "name": spec.name,
                "kind": "search" if search_spec else "sweep",
                "search": search_spec,
                "n_runs": len(runs), "n_shards": len(shards),
                "shards": job.shards, "key": key,
                "cancel_token": job.cancel_token}

    def _update_gauges(self) -> None:
        with self._lock:
            queued, running = self._queued, self._running
        statsd.gauge("service.jobs.queued", queued)
        statsd.gauge("service.jobs.running", running)

    def _run_job(self, job: Job, executor: HostsExecutor,
                 encoded: Dict[str, Any]) -> None:
        from repro.core.launcher import decode_dataset

        with self._sem:
            with self._lock:
                self._queued -= 1
                self._running += 1
            self._update_gauges()
            with job.cond:
                job.state = "running"
            t0 = time.monotonic()
            try:
                data = decode_dataset(encoded)
                if job.search:
                    return self._run_search(job, executor, data, t0)
                runs = job.spec.configs()
                labels = [l for l, _ in runs]
                cfgs = [c for _, c in runs]

                def on_shard(k: int, response: Dict[str, Any]) -> None:
                    assert_host_only(response,
                                     where="service stream event")
                    if job.t_first_shard is None:
                        job.t_first_shard = time.monotonic()
                        statsd.timing(
                            "service.job.time_to_first_shard_ms",
                            (job.t_first_shard - t0) * 1e3)
                    with job.cond:
                        job.shards_done += 1
                    job.append_event({
                        "event": "shard", "shard": k,
                        "runs": job.shards[k],
                        "result": response["result"],
                        "dispatch_counts": response["dispatch_counts"]})

                results, meta = executor.execute_with_meta(
                    labels, cfgs, data, stack=(job.stack == "auto"),
                    on_shard=on_shard, stop=job.stop)
                job.attempts_total = \
                    meta.get("launcher", {}).get("attempts_total", 0)
                merged = SweepResult(name=job.spec.name,
                                     records=records_from(labels, results))
                job.result_text = merged.to_json()
                if job.cache_mode != "off":
                    self.cache.put(job.key, job.result_text)
                job.finish("done")
                statsd.increment("service.jobs.completed")
            except SearchCancelled as e:
                job.finish("cancelled", error=str(e))
                statsd.increment("service.jobs.cancelled")
            except LauncherError as e:
                state = "cancelled" if job.stop.is_set() else "failed"
                job.finish(state, error=str(e))
                statsd.increment(f"service.jobs.{state}")
            except Exception as e:                     # noqa: BLE001
                job.finish("failed", error=f"{type(e).__name__}: {e}")
                statsd.increment("service.jobs.failed")
            finally:
                statsd.timing("service.job.wall_ms",
                              (time.monotonic() - t0) * 1e3)
                with self._lock:
                    self._running -= 1
                self._update_gauges()

    def _run_search(self, job: Job, executor: HostsExecutor,
                    data: Any, t0: float) -> None:
        """A Pareto-search job (DESIGN.md §14): the search drives the
        job's *fresh* executor rung by rung (fault-injection params
        stay job-local, exactly like plain sweeps), streaming one
        ``rung`` event per rung instead of per-shard payloads. The
        stored/cached bytes are the ``ParetoResult`` JSON — whose
        embedded ``frontier_result`` is bitwise a plain ``SweepSpec.run``
        of the frontier configs, so cache hits stay exact."""
        search = get_search(job.search)

        def on_rung(record: Dict[str, Any]) -> None:
            assert_host_only(record, where="service stream event")
            if job.t_first_shard is None:
                job.t_first_shard = time.monotonic()
            with job.cond:
                job.shards_done += 1      # rungs done, for status()
            job.append_event(dict(record, event="rung"))

        result = search.run(job.spec, data, stack=job.stack,
                            parallel=executor, on_rung=on_rung,
                            stop=job.stop)
        job.result_text = result.to_json()
        if job.cache_mode != "off":
            self.cache.put(job.key, job.result_text)
        job.finish("done")
        statsd.increment("service.jobs.completed")
        statsd.timing("service.search.wall_ms",
                      (time.monotonic() - t0) * 1e3)

    # -- queries ------------------------------------------------------------
    def job(self, job_id: str) -> Job:
        with self._lock:
            job = self._jobs.get(job_id)
        if job is None:
            raise ServiceError(404, f"no job {job_id!r}")
        return job

    def cancel(self, job_id: str,
               cancel_token: Optional[str] = None) -> Dict[str, Any]:
        job = self.job(job_id)
        # constant-time compare; missing/non-string tokens fail the same
        # way as wrong ones, so a 403 leaks nothing about the token
        if not (isinstance(cancel_token, str)
                and secrets.compare_digest(cancel_token,
                                           job.cancel_token)):
            statsd.increment("service.cancel.denied")
            raise ServiceError(403, f"cancel of {job_id} requires the "
                                    f"cancel_token from its submit reply")
        job.stop.set()
        if job.state == "queued":
            # not yet picked up: the runner thread will fail fast on the
            # stop event before dispatching any shard
            pass
        return job.status()

    def result_text(self, job_id: str) -> str:
        job = self.job(job_id)
        if job.state != "done" or job.result_text is None:
            raise ServiceError(409, f"job {job_id} is {job.state}; "
                                    f"results exist only for done jobs")
        return job.result_text

    def result_page(self, job_id: str, page: int, per_page: int) -> str:
        if self.job(job_id).search:
            raise ServiceError(400, f"job {job_id} is a search; its "
                                    f"ParetoResult does not page — GET "
                                    f"the full result")
        full = SweepResult.from_json(self.result_text(job_id))
        try:
            return full.page(page, per_page).to_json(include_meta=True)
        except ValueError as e:
            raise ServiceError(400, str(e))

    def metrics(self) -> Dict[str, Any]:
        with self._lock:
            jobs = {"total": self._n_jobs, "queued": self._queued,
                    "running": self._running}
        return {"schema": SERVICE_SCHEMA, "statsd": statsd.snapshot(),
                "cache": self.cache.stats(), "jobs": jobs}

    def health(self) -> Dict[str, Any]:
        with self._lock:
            depth = self._queued + self._running
        return {"status": "ok", "queue_depth": depth,
                "backend": self.backend}


# ---------------------------------------------------------------------------
# HTTP layer
# ---------------------------------------------------------------------------

class _Handler(BaseHTTPRequestHandler):
    # HTTP/1.0: streamed responses are delimited by connection close, so
    # the NDJSON stream needs no chunked framing and any stdlib client
    # reads it line by line until EOF
    protocol_version = "HTTP/1.0"

    @property
    def service(self) -> SweepService:
        return self.server.service          # type: ignore[attr-defined]

    def log_message(self, fmt, *args):      # quiet: statsd is the signal
        pass

    # -- helpers ------------------------------------------------------------
    def _send_json(self, obj: Any, status: int = 200) -> None:
        body = json.dumps(obj).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_text(self, text: str, status: int = 200,
                   ctype: str = "application/json") -> None:
        body = text.encode()
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _body_json(self) -> Dict[str, Any]:
        length = int(self.headers.get("Content-Length") or 0)
        if length <= 0:
            raise ServiceError(400, "missing request body")
        try:
            return json.loads(self.rfile.read(length))
        except json.JSONDecodeError as e:
            raise ServiceError(400, f"request body is not JSON: {e}")

    def _route(self) -> Tuple[str, List[str], Dict[str, List[str]]]:
        url = urlparse(self.path)
        parts = [p for p in url.path.split("/") if p]
        return url.path, parts, parse_qs(url.query)

    def _qs_int(self, qs, name, default):
        try:
            return int(qs.get(name, [default])[0])
        except ValueError:
            raise ServiceError(400, f"query param {name} must be an int")

    # -- verbs --------------------------------------------------------------
    def do_POST(self):          # noqa: N802 (stdlib naming)
        path, parts, _ = self._route()
        try:
            if parts == ["v1", "jobs"]:
                with statsd.timed("service.http.submit_ms"):
                    return self._send_json(
                        self.service.submit(self._body_json()))
            if len(parts) == 4 and parts[:2] == ["v1", "jobs"] \
                    and parts[3] == "cancel":
                # lenient parse: an empty/malformed body means "no
                # token", which the service turns into a 403 (not a 400
                # — authorization, not framing, is what's missing)
                length = int(self.headers.get("Content-Length") or 0)
                try:
                    body = json.loads(self.rfile.read(length)) \
                        if length > 0 else {}
                except json.JSONDecodeError:
                    body = {}
                token = body.get("cancel_token") \
                    if isinstance(body, dict) else None
                return self._send_json(
                    self.service.cancel(parts[2], token))
            raise ServiceError(404, f"no POST route {path!r}")
        except ServiceError as e:
            return self._send_json({"error": e.detail}, status=e.status)
        except Exception as e:                         # noqa: BLE001
            return self._send_json(
                {"error": f"{type(e).__name__}: {e}"}, status=500)

    def do_GET(self):           # noqa: N802
        path, parts, qs = self._route()
        try:
            if parts == ["v1", "healthz"]:
                return self._send_json(self.service.health())
            if parts == ["v1", "metrics"]:
                return self._send_json(self.service.metrics())
            if len(parts) == 3 and parts[:2] == ["v1", "jobs"]:
                return self._send_json(
                    self.service.job(parts[2]).status())
            if len(parts) == 4 and parts[:2] == ["v1", "jobs"]:
                job_id, tail = parts[2], parts[3]
                if tail == "stream":
                    return self._stream(job_id, qs)
                if tail == "results":
                    if "page" in qs or "per_page" in qs:
                        page = self._qs_int(qs, "page", 0)
                        per = self._qs_int(qs, "per_page", 50)
                        return self._send_text(
                            self.service.result_page(job_id, page, per))
                    # full result: the stored bytes VERBATIM — this is
                    # the parity (and cache-exactness) surface
                    return self._send_text(
                        self.service.result_text(job_id))
            raise ServiceError(404, f"no GET route {path!r}")
        except ServiceError as e:
            return self._send_json({"error": e.detail}, status=e.status)
        except (BrokenPipeError, ConnectionResetError):
            pass                  # streaming client went away mid-write
        except Exception as e:                         # noqa: BLE001
            return self._send_json(
                {"error": f"{type(e).__name__}: {e}"}, status=500)

    # -- the NDJSON stream --------------------------------------------------
    def _stream(self, job_id: str, qs) -> None:
        job = self.service.job(job_id)
        cursor = self._qs_int(qs, "cursor", 0)
        max_events = self._qs_int(qs, "max_events", 0)   # 0 = unbounded
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.end_headers()                # no length: close delimits
        statsd.increment("service.stream.connections")
        sent = 0
        try:
            while True:
                events = job.wait_events(cursor, timeout=5.0)
                if not events and job.terminal():
                    return       # cursor already past the terminal event
                for event in events:
                    self.wfile.write(
                        (json.dumps(event) + "\n").encode())
                    self.wfile.flush()
                    cursor = event["seq"] + 1
                    sent += 1
                    statsd.increment("service.stream.events")
                    if event["event"] in ("done", "error"):
                        return
                    if max_events and sent >= max_events:
                        return           # bounded response; client
                                         # reconnects with its cursor
        except (BrokenPipeError, ConnectionResetError):
            statsd.increment("service.stream.disconnects")


def make_server(host: str = "127.0.0.1", port: int = 0,
                service: Optional[SweepService] = None, **service_kw
                ) -> Tuple[ThreadingHTTPServer, SweepService]:
    """Bind a threading HTTP server around a :class:`SweepService`
    (``port=0`` picks a free port — tests and the parity gate use this).
    The caller drives ``serve_forever`` (usually on a daemon thread)."""
    service = service if service is not None else SweepService(**service_kw)
    httpd = ThreadingHTTPServer((host, port), _Handler)
    httpd.daemon_threads = True
    httpd.service = service                 # type: ignore[attr-defined]
    return httpd, service


def service_from_spec(spec: str) -> Tuple[ThreadingHTTPServer, SweepService]:
    """Build server+service from one nested-grammar spec string
    (DESIGN.md §12), e.g.::

        serve:port=8080;backend=hosts:channel=local,n=4;cache_dir=results/sweep_cache

    ``";"``-separated parameters with list continuation, so the embedded
    executor/channel specs nest without escaping (the same grammar as
    channel specs, §8)."""
    name, params = parse_spec(spec, sep=";", merge_unkeyed=True)
    if name != "serve":
        raise ValueError(f"service spec must start with 'serve', got "
                         f"{spec!r}")
    cache_dir = params.pop("cache_dir", None)
    cache = ResultCache(directory=cache_dir) if cache_dir else None
    host = str(params.pop("host", "127.0.0.1"))
    port = int(params.pop("port", 0))
    return make_server(host=host, port=port, cache=cache, **params)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro.service.server",
        description="Streaming sweep service (DESIGN.md §12)")
    ap.add_argument("--spec", default=None,
                    help="full service spec, e.g. "
                         "'serve:port=8080;backend=hosts:channel=local,"
                         "n=4'")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--backend", default=DEFAULT_BACKEND)
    ap.add_argument("--cache-dir", default=None)
    args = ap.parse_args(argv)

    if args.spec:
        httpd, service = service_from_spec(args.spec)
    else:
        cache = (ResultCache(directory=args.cache_dir)
                 if args.cache_dir else None)
        httpd, service = make_server(host=args.host, port=args.port,
                                     backend=args.backend, cache=cache)
    host, port = httpd.server_address[:2]
    print(f"sweep service listening on {host}:{port} "
          f"(backend {service.backend})", flush=True)
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
