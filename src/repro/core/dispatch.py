"""Jitted-dispatch accounting for the HTL engines.

The fleet engine's contract is O(1) jitted dispatches per collection window
(vs one per DC — or per seed replica — in the loop engine), and the sweep
layer's contract is that seed stacking does not multiply dispatches by the
seed count. Those are easy properties to silently regress (one refactor that
re-introduces a Python loop over DCs around a jitted call), so every jitted
entry point of the algorithm layer is wrapped with :func:`count_dispatch`
and a CI gate (tests/test_dispatch_gate.py, run by scripts/verify.sh)
asserts the counts.

A "dispatch" here is one Python-level call into a jitted entry point — the
unit of host-sync / executable-launch overhead the fleet engine exists to
amortise. Counting wraps the function object itself, so the gate also
catches loops hidden inside helper modules, not just the engine drivers.
"""
from __future__ import annotations

import threading
from collections import Counter
from contextlib import contextmanager
from functools import wraps
from typing import Mapping

_COUNTS: Counter = Counter()
# The parallel sweep executor (repro.core.parallel) dispatches shards from
# several threads (devices backend) and merges counts shipped back from
# worker processes (processes backend), so all counter mutation is locked.
_LOCK = threading.Lock()


def count_dispatch(name: str):
    """Decorator: count Python-level calls into a jitted entry point."""
    def deco(fn):
        @wraps(fn)
        def wrapper(*args, **kwargs):
            with _LOCK:
                _COUNTS[name] += 1
            return fn(*args, **kwargs)
        wrapper.__wrapped__ = fn
        return wrapper
    return deco


def reset_dispatch_counts() -> None:
    with _LOCK:
        _COUNTS.clear()


def dispatch_counts() -> dict:
    """Snapshot of {entry-point name: call count} since the last reset."""
    with _LOCK:
        return dict(_COUNTS)


@contextmanager
def dispatch_scope():
    """Yield a dict that, on exit, holds the dispatch-count DELTA of the
    enclosed block (names with zero delta are omitted). Reads snapshots
    instead of resetting the global counter, so scopes nest and compose
    with the CI gate's own reset/inspect cycle. The gate uses this to pin
    exact per-call dispatch profiles — e.g. that a deep greedy refine is
    ONE jitted dispatch no matter how many candidates it accepts."""
    before = dispatch_counts()
    delta: dict = {}
    try:
        yield delta
    finally:
        for name, count in dispatch_counts().items():
            d = count - before.get(name, 0)
            if d:
                delta[name] = d


def merge_dispatch_counts(counts: Mapping[str, int]) -> None:
    """Fold a worker process's dispatch counts into this process's counter,
    so sharded sweeps stay observable by the dispatch CI gate: the merged
    total bounds per-shard work (each shard's own counts are a subset)."""
    with _LOCK:
        for name, k in counts.items():
            _COUNTS[name] += int(k)
