"""Input-spec construction + workload-specialised sharding rules."""
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, INPUT_SHAPES, get_config
from repro.launch.specs import (arch_for_shape, param_rules_for,
                                shape_supported)


class FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)


MESH = FakeMesh(data=16, model=16)


def test_long_500k_support_matrix():
    runs = {a for a in ALL_ARCHS
            if shape_supported(get_config(a), INPUT_SHAPES["long_500k"])[0]}
    assert runs == {"mamba2-1.3b", "recurrentgemma-9b", "llama3.2-3b"}
    # every skip carries a reason
    for a in set(ALL_ARCHS) - runs:
        ok, reason = shape_supported(get_config(a), INPUT_SHAPES["long_500k"])
        assert not ok and "quadratic" in reason


def test_llama_long_context_variant():
    cfg = arch_for_shape(get_config("llama3.2-3b"), INPUT_SHAPES["long_500k"])
    assert cfg.sliding_window == 8192
    # other shapes keep full attention
    cfg4k = arch_for_shape(get_config("llama3.2-3b"),
                           INPUT_SHAPES["train_4k"])
    assert cfg4k.sliding_window == 0


def test_all_other_shapes_supported_everywhere():
    for a in ALL_ARCHS:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_supported(get_config(a), INPUT_SHAPES[s])[0]


def test_decode_rules_weight_stationary():
    r_train = param_rules_for(MESH, INPUT_SHAPES["train_4k"])
    r_dec = param_rules_for(MESH, INPUT_SHAPES["decode_32k"])
    assert r_train["embed"] == "data"          # FSDP for training
    assert r_dec["embed"] is None              # TP-only for decode
    assert r_dec["experts"] == ("data", "model")
    # opt-out restores the paper-faithful baseline
    r_base = param_rules_for(MESH, INPUT_SHAPES["decode_32k"],
                             weight_stationary_decode=False)
    assert r_base["embed"] == "data"


def test_vlm_seq_budget_includes_frontend():
    """VLM total context = image prefix + text; text len is the remainder."""
    from repro.launch.specs import batch_specs
    import jax
    cfg = get_config("llava-next-mistral-7b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    bs = batch_specs(cfg, INPUT_SHAPES["train_4k"], mesh)
    n_front = cfg.frontend.num_tokens
    assert bs["tokens"].shape == (256, 4096 - n_front)
    assert bs["frontend_embeds"].shape == (256, n_front, cfg.d_model)


def test_whisper_batch_includes_encoder():
    from repro.launch.specs import batch_specs
    import jax
    cfg = get_config("whisper-medium")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    bs = batch_specs(cfg, INPUT_SHAPES["prefill_32k"], mesh)
    assert bs["encoder_embeds"].shape == (32, 1500, 1024)
