#!/usr/bin/env python
"""Sweep-service CI gate: the RPC control plane may never change the
numbers — streamed, faulted, or cached.

Boots the HTTP service in-process (ephemeral port), then for each preset
grid submits the sweep over the wire and checks four things against the
sequential in-process reference (DESIGN.md §12):

1. **clean streamed pass** — shards dispatched through real worker
   subprocesses (``local`` channel), streamed back as NDJSON and merged
   incrementally client-side: merged JSON must be byte-identical;
2. **fault-injected pass** (``--inject-failures``) — one worker is
   really SIGKILLed mid-shard on its first attempt; the retry heals it
   and the streamed merge still matches bitwise (submitted with
   ``cache=bypass`` so the cache cannot mask the fault path);
3. **cache-hit pass** — the same spec submitted again is served from the
   exact result cache: ``cached=true``, the recorded
   ``service.cache.hit`` counter moves, and the served bytes equal the
   recomputed (and sequential) bytes — cache-hit == recompute;
4. the fleet-health counters moved the way the passes imply (shard oks,
   crash failures on the injected pass).

    python scripts/service_parity.py --preset smoke --windows 3 \
        --spec "hosts:channel=local,n=2,retries=1" --inject-failures
    python scripts/service_parity.py --preset transport_grid --windows 3 \
        --spec "hosts:channel=inline,n=2,retries=1"

Wired into scripts/verify.sh (gates phase) and the named ``service-smoke``
CI step, mirroring scripts/hosts_parity.py.
"""
from __future__ import annotations

import argparse
import os
import sys
import threading

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def first_diff(a: str, b: str, context: int = 60) -> str:
    k = next((i for i, (x, y) in enumerate(zip(a, b)) if x != y),
             min(len(a), len(b)))
    return (f"first divergence at byte {k}: "
            f"...{a[max(0, k - context):k + context]!r} vs "
            f"...{b[max(0, k - context):k + context]!r}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--windows", type=int, default=3)
    ap.add_argument("--spec", default="hosts:channel=local,n=2,retries=1",
                    help="hosts backend spec the service dispatches "
                         "through")
    ap.add_argument("--inject-failures", action="store_true",
                    help="add a pass with one worker SIGKILLed mid-shard "
                         "on its first attempt (cache bypassed so the "
                         "fault path really runs)")
    args = ap.parse_args()

    from repro.core.experiment import get_preset
    from repro.data.synthetic_covtype import make_covtype_like
    from repro.service.client import ServiceClient
    from repro.service.server import make_server
    from repro.service.statsd import statsd

    data = make_covtype_like(seed=0)
    spec = get_preset(args.preset, windows=args.windows)
    ref = spec.run(data, parallel="none").to_json()

    httpd, _service = make_server(backend=args.spec)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    client = ServiceClient(httpd.server_address[:2])
    rc = 0

    passes = [("clean streamed", dict(cache="use"), False)]
    if args.inject_failures:
        passes.append(("fault-injected",
                       dict(cache="bypass",
                            backend=f"{args.spec},backoff=0.01,"
                                    f"inject_kill=0"), False))
    passes.append(("cache-hit", dict(cache="use"), True))

    for label, kwargs, want_cached in passes:
        crashes_before = statsd.counter("launcher.shard.failures",
                                        tags={"kind": "crash"})
        hits_before = statsd.counter("service.cache.hit")
        result = client.run(spec, data, **kwargs)
        got = result.to_json()
        svc = result.meta["service"]
        status = client.status(svc["job"])
        if got == ref:
            print(f"service parity [{label}]: OK ({len(ref)} bytes "
                  f"identical, {svc['n_shards']} shard(s), "
                  f"{status['attempts_total']} attempt(s), "
                  f"cached={svc['cached']})")
        else:
            print(f"service parity [{label}]: MISMATCH — "
                  f"{first_diff(ref, got)}")
            rc = 1
        if svc["cached"] != want_cached:
            print(f"service parity [{label}]: cached={svc['cached']}, "
                  f"expected {want_cached}")
            rc = 1
        if want_cached:
            if statsd.counter("service.cache.hit") <= hits_before:
                print(f"service parity [{label}]: service.cache.hit "
                      f"counter did not move")
                rc = 1
            served = client.result_text(svc["job"])
            if served != ref:
                print(f"service parity [{label}]: served cache bytes "
                      f"differ from recompute — {first_diff(ref, served)}")
                rc = 1
        if label == "fault-injected":
            crashed = statsd.counter("launcher.shard.failures",
                                     tags={"kind": "crash"})
            if crashed <= crashes_before:
                print(f"service parity [{label}]: no crash failure "
                      f"recorded — the injected SIGKILL never happened")
                rc = 1

    ok = statsd.counter("launcher.shard.ok")
    if ok < 1:
        print(f"service parity: launcher.shard.ok = {ok}, expected >= 1")
        rc = 1
    httpd.shutdown()
    if rc == 0:
        print("sweep service: bitwise-identical to sequential — streamed"
              + (", under injected worker SIGKILL"
                 if args.inject_failures else "")
              + ", and from the exact result cache")
    return rc


if __name__ == "__main__":
    sys.exit(main())
