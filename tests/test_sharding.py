"""Partitioning rules: divisibility fallback, axis dedup, template plumbing —
with hypothesis property tests over random shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_ARCHS, get_config
from repro.models import build_model
from repro.sharding.partitioning import (DEFAULT_RULES, ParamSpec,
                                         init_params, logical_to_pspec,
                                         param_pspecs, param_shape_structs,
                                         template_bytes)


class FakeMesh:
    """Stand-in with just .shape (logical_to_pspec only uses that)."""
    def __init__(self, **axes):
        self.shape = dict(axes)


MESH = FakeMesh(data=16, model=16)
MESH3 = FakeMesh(pod=2, data=16, model=16)


def test_divisible_dims_shard():
    spec = logical_to_pspec(("embed", "mlp"), (4096, 8192), MESH,
                            DEFAULT_RULES)
    assert spec == P("data", "model")


def test_non_divisible_replicates():
    # 24 heads % 16 -> replicated
    spec = logical_to_pspec(("embed", "heads", "head_dim"), (3072, 24, 128),
                            MESH, DEFAULT_RULES)
    assert spec == P("data")


def test_axis_never_reused():
    # batch takes 'data'; cache_len wants 'model'; kv_heads would want
    # 'model' too but it's taken -> replicated
    rules = dict(DEFAULT_RULES)
    spec = logical_to_pspec(("batch", "cache_len", "kv_heads", None),
                            (128, 32768, 16, 128), MESH, rules)
    assert spec == P("data", "model")


def test_batch_multi_pod():
    from repro.sharding.partitioning import MULTIPOD_RULES
    spec = logical_to_pspec(("batch", None), (256, 4096), MESH3,
                            MULTIPOD_RULES)
    assert spec == P(("pod", "data"))


@given(dim=st.integers(min_value=1, max_value=10**6))
@settings(max_examples=100, deadline=None)
def test_sharded_dims_always_divisible(dim):
    spec = logical_to_pspec(("mlp",), (dim,), MESH, DEFAULT_RULES)
    if spec and spec[0] is not None:
        assert dim % MESH.shape["model"] == 0


@given(shape=st.lists(st.sampled_from([1, 2, 7, 16, 24, 128, 256, 4096]),
                      min_size=1, max_size=4),
       axes=st.lists(st.sampled_from(
           [None, "batch", "embed", "heads", "mlp", "vocab", "experts"]),
           min_size=1, max_size=4))
@settings(max_examples=100, deadline=None)
def test_pspec_properties(shape, axes):
    n = min(len(shape), len(axes))
    shape, axes = tuple(shape[:n]), tuple(axes[:n])
    spec = logical_to_pspec(axes, shape, MESH, DEFAULT_RULES)
    # no mesh axis used twice
    used = [a for a in spec if a is not None]
    flat = []
    for a in used:
        flat.extend(a if isinstance(a, tuple) else (a,))
    assert len(flat) == len(set(flat))
    # every sharded dim is divisible
    for dim, a in zip(shape, tuple(spec) + (None,) * 4):
        if a is not None:
            sz = np.prod([MESH.shape[x] for x in
                          (a if isinstance(a, tuple) else (a,))])
            assert dim % sz == 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_template_consistency(arch):
    """Template <-> pspecs <-> shape-structs are structurally consistent and
    the template's byte count matches actual initialized params."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    t = model.template()
    specs = param_pspecs(t, MESH, DEFAULT_RULES)
    structs = param_shape_structs(t, jnp.dtype(cfg.dtype))
    assert jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P)) \
        .num_leaves == jax.tree.structure(structs).num_leaves
    params = model.init(jax.random.PRNGKey(0))
    tb = template_bytes(t, jnp.dtype("float32"))
    pb = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
    assert tb == pb


def test_init_deterministic():
    cfg = get_config("llama3.2-3b").reduced()
    m = build_model(cfg)
    p1 = m.init(jax.random.PRNGKey(7))
    p2 = m.init(jax.random.PRNGKey(7))
    assert all(bool(jnp.array_equal(a, b)) for a, b in
               zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    p3 = m.init(jax.random.PRNGKey(8))
    assert any(not bool(jnp.array_equal(a, b)) for a, b in
               zip(jax.tree.leaves(p1), jax.tree.leaves(p3)))
