"""Static config validation: for every (arch x shape x mesh), report which
logical axes actually shard and which silently replicate (divisibility), the
estimated per-device parameter/optimizer/cache memory, and whether it fits
the 16 GB v5e HBM. Pure metadata — no device allocation, no compile.

    PYTHONPATH=src python -m repro.launch.validate [--arch ...]
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, INPUT_SHAPES, get_config
from repro.launch.specs import arch_for_shape, param_rules_for, shape_supported
from repro.models.model import build_model
from repro.sharding.partitioning import ParamSpec, logical_to_pspec

HBM_BYTES = 16 * 2**30


class _MeshMeta:
    """Just the axis sizes (logical_to_pspec only needs .shape)."""

    def __init__(self, multi_pod=False):
        self.shape = ({"pod": 2, "data": 16, "model": 16} if multi_pod
                      else {"data": 16, "model": 16})


def _tree_device_bytes(template, rules, mesh, default_itemsize=2) -> float:
    """Per-device bytes after sharding (replicated dims count fully)."""
    import jax
    leaves = jax.tree.leaves(template,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
    total = 0.0
    n_repl_leaves = 0
    for s in leaves:
        spec = logical_to_pspec(s.axes, s.shape, mesh, rules)
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                shards *= mesh.shape[a]
        itemsize = jnp.dtype(s.dtype).itemsize if s.dtype else default_itemsize
        total += int(np.prod(s.shape)) * itemsize / shards
        if shards == 1:
            n_repl_leaves += 1
    return total, n_repl_leaves, len(leaves)


def validate(arch: str, shape_name: str, multi_pod=False) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg0 = get_config(arch)
    ok, reason = shape_supported(cfg0, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": reason}
    cfg = arch_for_shape(cfg0, shape)
    model = build_model(cfg)
    mesh = _MeshMeta(multi_pod)
    rules = param_rules_for(mesh, shape, cfg)

    p_bytes, p_repl, p_n = _tree_device_bytes(model.template(), rules, mesh)
    out = {"arch": arch, "shape": shape_name, "status": "ok",
           "params_gib": p_bytes / 2**30,
           "replicated_weight_leaves": f"{p_repl}/{p_n}"}
    total = p_bytes
    if shape.kind == "train":
        total += p_bytes + 2 * p_bytes * 2      # grads bf16 + adam f32 m,v
        out["train_state_gib"] = total / 2**30
    if shape.kind == "decode":
        c_bytes, _, _ = _tree_device_bytes(
            model.cache_template(shape.global_batch, shape.seq_len), rules,
            mesh)
        out["cache_gib"] = c_bytes / 2**30
        total += c_bytes
    out["fits_16gb"] = bool(total <= HBM_BYTES)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else ALL_ARCHS
    n_bad = 0
    for a in archs:
        for s in INPUT_SHAPES:
            r = validate(a, s, args.multi_pod)
            if r["status"] == "skip":
                continue
            flag = "" if r["fits_16gb"] else "  ** EXCEEDS 16GB HBM **"
            if not r["fits_16gb"]:
                n_bad += 1
            extra = ""
            if "train_state_gib" in r:
                extra = f" train-state {r['train_state_gib']:.1f} GiB"
            if "cache_gib" in r:
                extra = f" cache {r['cache_gib']:.1f} GiB"
            print(f"{a:24s} {s:11s} params/dev {r['params_gib']:7.2f} GiB"
                  f"{extra} repl {r['replicated_weight_leaves']}{flag}")
    print(f"\n{n_bad} combos exceed single-chip HBM "
          f"(expected for 671B training on one pod)")


if __name__ == "__main__":
    main()
