"""Serving engine + checkpointer round-trips."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.checkpoint.checkpointer import checkpoint_step
from repro.configs import get_config
from repro.data.pipeline import make_lm_batch
from repro.models import build_model
from repro.serving import ServeEngine, cache_bytes


def test_serve_engine_generates():
    cfg = get_config("llama3.2-3b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, max_new_tokens=6)
    batch = make_lm_batch(cfg.vocab_size, 2, 32, d_model=cfg.d_model)
    out = eng.generate({"tokens": batch["tokens"]})
    assert out.shape == (2, 6)
    assert (np.asarray(out) >= 0).all()
    assert (np.asarray(out) < cfg.vocab_size).all()
    # greedy decode is deterministic
    out2 = eng.generate({"tokens": batch["tokens"]})
    assert bool(jnp.array_equal(out, out2))


def test_serve_engine_ssm():
    cfg = get_config("mamba2-1.3b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, max_new_tokens=4)
    batch = make_lm_batch(cfg.vocab_size, 1, 32, d_model=cfg.d_model)
    out = eng.generate({"tokens": batch["tokens"]})
    assert out.shape == (1, 4)


def test_cache_bytes_scales_with_len():
    cfg = get_config("qwen2-72b")
    m = build_model(cfg)
    b1 = cache_bytes(m, 1, 1024)
    b2 = cache_bytes(m, 1, 2048)
    assert abs(b2 / b1 - 2.0) < 0.01


def test_mla_cache_is_small():
    """MLA's latent cache must be much smaller than GQA's at equal depth."""
    mini = get_config("minicpm3-4b")
    m = build_model(mini)
    mla_per_tok = cache_bytes(m, 1, 1024) / 1024
    # equivalent GQA cache for the same dims: L * 2 * kv * hd * 2B
    gqa_per_tok = mini.num_layers * 2 * mini.num_kv_heads * \
        mini.head_dim * 2
    assert mla_per_tok < gqa_per_tok / 8


def test_checkpoint_roundtrip():
    cfg = get_config("olmoe-1b-7b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, step=42)
        assert checkpoint_step(d) == 42
        like = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
        restored = load_checkpoint(d, like)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
