"""Sweep service (DESIGN.md §12): streaming RPC server/client, incremental
order-stable merge, cursor-resumable streams, cancellation, paging, the
host-only boundary guard and the statsd metrics plumbing.

The hard promise under test: a sweep submitted over HTTP and merged
incrementally from streamed per-shard NDJSON events is **byte-identical**
to the sequential in-process run — in any shard arrival order, across a
mid-stream disconnect/reconnect (cursor resume + idempotent replay), on
the retry path after an injected fault, and when served from the exact
result cache.
"""
import functools
import itertools
import json
import threading
import time

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import launcher
from repro.core.dispatch import dispatch_counts, reset_dispatch_counts
from repro.core.experiment import SweepResult, get_preset, records_from
from repro.core.launcher import (CHANNELS, ChannelError, HostChannel,
                                 InlineChannel, get_channel,
                                 register_channel)
from repro.core.parallel import ShardMerger, partition_runs, run_shard_payload
from repro.data.synthetic_covtype import make_covtype_like
from repro.service.client import ClientError, ServiceClient
from repro.service.server import (SERVICE_SCHEMA, ServiceError, SweepService,
                                  make_server, service_from_spec)
from repro.service.statsd import Statsd, statsd

DATA = make_covtype_like(n_total=1400, seed=0)
WINDOWS = 2


@functools.lru_cache(maxsize=None)
def _grid():
    """Shared mini-grid (smoke preset: star x {4g, mesh} + a2a, 2 seeds —
    at least two stack-key groups, so the 2-slot partition really has two
    shards): spec, run list, sequential reference JSON, canned per-shard
    payloads for merger property tests."""
    spec = get_preset("smoke", windows=WINDOWS)
    runs = spec.configs()
    labels = [l for l, _ in runs]
    cfgs = [c for _, c in runs]
    ref_json = spec.run(DATA).to_json()
    shards = [s for s in partition_runs(cfgs, 2) if s]
    canned = []
    for k, idxs in enumerate(shards):
        payload, counts = run_shard_payload(
            [labels[i] for i in idxs], [cfgs[i] for i in idxs], DATA, True)
        canned.append((payload, counts))
    return spec, labels, cfgs, shards, ref_json, canned


@pytest.fixture(scope="module")
def service_endpoint():
    """One live server for the whole module (inline backend: shards run
    in-process, so there is no per-test worker spawn cost)."""
    httpd, service = make_server(backend="hosts:channel=inline,n=2")
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    yield ServiceClient(httpd.server_address[:2]), service
    httpd.shutdown()


# ---------------------------------------------------------------------------
# end-to-end: streamed run == sequential run, bitwise
# ---------------------------------------------------------------------------

def test_streamed_run_matches_sequential_bitwise(service_endpoint):
    client, _ = service_endpoint
    spec, _, _, shards, ref_json, _ = _grid()
    out = client.run(spec, DATA, cache="off")
    assert out.to_json() == ref_json
    svc = out.meta["service"]
    assert svc["cached"] is False
    assert svc["n_shards"] == len(shards) >= 2


def test_cache_hit_serves_identical_bytes(service_endpoint):
    client, service = service_endpoint
    spec, _, _, _, ref_json, _ = _grid()
    first = client.run(spec, DATA)            # miss (or hit from an
    assert first.to_json() == ref_json        # earlier test — both fine)
    hits_before = statsd.counter("service.cache.hit")
    second = client.run(spec, DATA)
    assert second.meta["service"]["cached"] is True
    assert second.to_json() == ref_json
    assert statsd.counter("service.cache.hit") == hits_before + 1
    # the verbatim stored bytes equal a fresh client-side serialization
    job = second.meta["service"]["job"]
    assert client.result_text(job) == ref_json


def test_cache_bypass_recomputes_but_stores(service_endpoint):
    client, service = service_endpoint
    spec, _, _, _, ref_json, _ = _grid()
    client.run(spec, DATA)                    # ensure the entry exists
    out = client.run(spec, DATA, cache="bypass")
    assert out.meta["service"]["cached"] is False
    assert out.to_json() == ref_json


def test_fault_injected_retry_parity_over_http(service_endpoint):
    """Inline channel simulates the crash (a scripted ChannelError — it
    must never SIGKILL the server); the retry re-runs the identical
    payload, so the streamed merge still matches bitwise."""
    client, _ = service_endpoint
    spec, _, _, _, ref_json, _ = _grid()
    fails_before = statsd.counter("launcher.shard.failures",
                                  tags={"kind": "crash"})
    out = client.run(
        spec, DATA, cache="off",
        backend="hosts:channel=inline,n=2,retries=1,inject_kill=0")
    assert out.to_json() == ref_json
    assert statsd.counter("launcher.shard.failures",
                          tags={"kind": "crash"}) > fails_before


# ---------------------------------------------------------------------------
# incremental merge: any arrival order, replays, concurrency
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(perm_i=st.integers(min_value=0, max_value=10 ** 6),
       replay_i=st.integers(min_value=0, max_value=10 ** 6))
def test_shard_merger_any_arrival_order_and_replays(perm_i, replay_i):
    spec, labels, _, shards, ref_json, canned = _grid()
    perms = list(itertools.permutations(range(len(shards))))
    order = perms[perm_i % len(perms)]
    reset_dispatch_counts()
    merger = ShardMerger(len(labels), shards)
    for k in order:
        assert merger.add(k, *canned[k]) is True
    # replay one shard: idempotent, counts must not double
    counts_once = dispatch_counts()
    assert merger.add(order[replay_i % len(order)],
                      *canned[order[replay_i % len(order)]]) is False
    assert dispatch_counts() == counts_once
    merged = SweepResult(name=spec.name,
                         records=records_from(labels, merger.results()))
    assert merged.to_json() == ref_json


def test_shard_merger_concurrent_adds_are_exactly_once():
    """Many threads race to add every shard repeatedly: each shard merges
    exactly once (True returned once), dispatch counts fold once, and the
    merged bytes still match — the lock-guarded counter-merge satellite."""
    spec, labels, _, shards, ref_json, canned = _grid()
    reset_dispatch_counts()
    merger = ShardMerger(len(labels), shards)
    wins = []
    barrier = threading.Barrier(8)

    def feeder():
        barrier.wait()
        for k in range(len(shards)):
            if merger.add(k, *canned[k]):
                wins.append(k)

    threads = [threading.Thread(target=feeder) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sorted(wins) == list(range(len(shards)))     # exactly once each
    expected = {}
    for _, counts in canned:
        for name, v in counts.items():
            expected[name] = expected.get(name, 0) + v
    assert dispatch_counts() == expected
    merged = SweepResult(name=spec.name,
                         records=records_from(labels, merger.results()))
    assert merged.to_json() == ref_json


def test_merger_rejects_wrong_sized_payload_and_reports_pending():
    _, labels, _, shards, _, canned = _grid()
    merger = ShardMerger(len(labels), shards)
    assert merger.pending() == list(range(len(shards)))
    with pytest.raises(ValueError, match="not merged yet"):
        merger.results()
    # shard 0 declared one run short: the canned payload no longer fits
    short = [shards[0][:-1]] + [list(s) for s in shards[1:]]
    with pytest.raises(ValueError, match="records"):
        ShardMerger(len(labels), short).add(0, *canned[0])


# ---------------------------------------------------------------------------
# stream resumption: bounded responses, disconnect + cursor reconnect
# ---------------------------------------------------------------------------

def test_stream_reconnects_with_cursor_and_merges_bitwise(service_endpoint):
    """max_events=1 forces the server to close the connection after every
    event, so the client reconnects once per event with an advancing
    cursor — the merged result must still be byte-identical."""
    client, _ = service_endpoint
    spec, _, _, _, ref_json, _ = _grid()
    conns_before = statsd.counter("service.stream.connections")
    out = client.run(spec, DATA, cache="off", max_events_per_conn=1)
    assert out.to_json() == ref_json
    # one connection per event (shards + terminal) => strictly more than
    # one stream connection was opened
    assert statsd.counter("service.stream.connections") - conns_before >= 3


def test_mid_stream_disconnect_then_manual_cursor_resume(service_endpoint):
    """Drop the stream after the first event (client side), then resume
    from the cursor on a fresh connection: the two segments cover every
    event exactly once-or-more, and the merge is byte-identical."""
    client, _ = service_endpoint
    spec, labels, _, _, ref_json, _ = _grid()
    sub = client.submit(spec, DATA, cache="off")
    merger = ShardMerger(len(labels), sub["shards"])

    first = client.stream_events(sub["job"])
    ev0 = next(e for e in first if e["event"] == "shard")
    merger.add(ev0["shard"], ev0["result"], ev0["dispatch_counts"])
    first.close()                               # simulated disconnect

    for ev in client.stream_events(sub["job"], cursor=ev0["seq"] + 1):
        if ev["event"] == "shard":
            merger.add(ev["shard"], ev["result"], ev["dispatch_counts"])
    merged = SweepResult(name=spec.name,
                         records=records_from(labels, merger.results()))
    assert merged.to_json() == ref_json
    # resuming from cursor 0 replays everything; the merger stays correct
    replays = [e for e in client.stream_events(sub["job"], cursor=0)
               if e["event"] == "shard"]
    assert {e["shard"] for e in replays} == set(range(sub["n_shards"]))
    assert all(merger.add(e["shard"], e["result"],
                          e["dispatch_counts"]) is False for e in replays)


# ---------------------------------------------------------------------------
# lifecycle: status, cancel, paging, errors
# ---------------------------------------------------------------------------

class _GateChannel(HostChannel):
    """Test channel: the first attempt blocks on a class event, so a job
    is reliably observable mid-flight; later attempts run inline."""
    started = threading.Event()
    release = threading.Event()
    _first = threading.Lock()
    _taken = False

    def __init__(self, n: int = 1):
        self.n = n

    def slots(self):
        return [f"gate/{i}" for i in range(self.n)]

    def run(self, slot, request, *, timeout=None, extra_env=None):
        with _GateChannel._first:
            hold = not _GateChannel._taken
            _GateChannel._taken = True
        if hold:
            _GateChannel.started.set()
            assert _GateChannel.release.wait(30), "gate never released"
        return launcher.run_request(request)


@pytest.fixture
def gate_channel():
    # registered per-test so the global CHANNELS registry stays pristine
    # for the rest of the suite (test_launcher asserts its exact contents)
    register_channel("gatetest", _GateChannel)
    try:
        yield
    finally:
        launcher.CHANNELS.pop("gatetest", None)


def test_cancel_stops_pending_shards_and_streams_terminal(
        service_endpoint, gate_channel):
    client, _ = service_endpoint
    spec, _, _, shards, _, _ = _grid()
    assert len(shards) >= 2       # one blocks, one must get cancelled
    # two shards, one slot: shard 0 blocks on the gate while shard 1
    # queues — the cancel must reach shard 1 before it ever dispatches
    sub = client.submit(spec, DATA, cache="off",
                        backend="hosts:channel=gatetest,n=2")
    assert sub["n_shards"] == 2
    assert _GateChannel.started.wait(30)
    assert client.status(sub["job"])["state"] == "running"
    # cancellation is a capability: the job id alone must not suffice
    with pytest.raises(ClientError) as err:
        client.cancel(sub["job"], "not-the-token")
    assert err.value.status == 403
    with pytest.raises(ClientError) as err:
        client._request("POST", f"/v1/jobs/{sub['job']}/cancel")
    assert err.value.status == 403
    assert client.status(sub["job"])["state"] == "running"
    assert "cancel_token" not in client.status(sub["job"])
    client.cancel(sub["job"], sub["cancel_token"])
    _GateChannel.release.set()
    events = list(client.stream_events(sub["job"]))
    assert events[-1]["event"] == "error"
    assert events[-1]["state"] == "cancelled"
    assert client.status(sub["job"])["state"] == "cancelled"
    with pytest.raises(ClientError) as err:
        client.result_text(sub["job"])
    assert err.value.status == 409


def test_results_paging_partitions_the_record_list(service_endpoint):
    client, _ = service_endpoint
    spec, _, _, _, ref_json, _ = _grid()
    job = client.run(spec, DATA).meta["service"]["job"]
    full = SweepResult.from_json(ref_json)
    per = 3
    pages, page = [], 0
    while True:
        chunk = client.result_page(job, page, per)
        if not chunk.records:
            break
        assert chunk.meta["paging"]["total_records"] == len(full.records)
        pages.extend(chunk.records)
        page += 1
    assert [r.label for r in pages] == [r.label for r in full.records]
    assert SweepResult(name=full.name, records=pages).to_json() == ref_json
    with pytest.raises(ClientError) as err:
        client.result_page(job, 0, 0)
    assert err.value.status == 400


def test_http_errors_are_structured(service_endpoint):
    client, _ = service_endpoint
    with pytest.raises(ClientError) as err:
        client.status("job-999999")
    assert err.value.status == 404
    with pytest.raises(ClientError) as err:
        client._request("POST", "/v1/jobs", {"schema": 99})
    assert err.value.status == 400
    with pytest.raises(ClientError) as err:
        client._request("GET", "/v1/nope")
    assert err.value.status == 404


def test_submit_rejects_bad_spec_and_backend(service_endpoint):
    client, _ = service_endpoint
    spec, *_ = _grid()
    with pytest.raises(ClientError) as err:
        client.submit(spec, DATA, backend="processes:n=2")
    assert "hosts" in err.value.detail
    with pytest.raises(ClientError) as err:
        client.submit(spec, DATA, stack="sideways")
    assert err.value.status == 400
    with pytest.raises(ClientError) as err:
        client._request("POST", "/v1/jobs",
                        {"schema": SERVICE_SCHEMA, "spec": {"schema": 1},
                         "data": {"kind": "arrays", "fields": {}}})
    assert err.value.status == 400


# ---------------------------------------------------------------------------
# the host-only service boundary
# ---------------------------------------------------------------------------

def test_device_buffers_never_cross_the_service_boundary():
    """assert_host_only guards both directions: a submit payload (client
    side and server side) and a streamed shard response carrying a jax
    device buffer are refused before they touch the wire."""
    import jax.numpy as jnp

    spec, *_ = _grid()
    poisoned = {"kind": "arrays", "fields": {},
                "sneaky": jnp.zeros((2,))}
    client = ServiceClient(("127.0.0.1", 1))    # never connected
    with pytest.raises(TypeError, match="device buffer"):
        client.submit(spec, poisoned)
    service = SweepService(backend="hosts:channel=inline,n=1")
    with pytest.raises(TypeError, match="device buffer"):
        service.submit({"schema": SERVICE_SCHEMA, "spec": spec.to_wire(),
                        "data": poisoned})


def test_eval_cache_is_not_picklable_across_the_boundary():
    import pickle

    from repro.core.scenario import EvalCache

    with pytest.raises(TypeError):
        pickle.dumps(EvalCache())


# ---------------------------------------------------------------------------
# backends, spec grammar, metrics
# ---------------------------------------------------------------------------

def test_inline_channel_registered_and_serialized():
    assert "inline" in CHANNELS
    ch = get_channel("inline:n=3")
    assert ch.slots() == ["inline/0", "inline/1", "inline/2"]
    assert ch.describe() == "inline:n=3"
    with pytest.raises(ValueError):
        InlineChannel(n=0)
    # simulated fault: a scripted ChannelError, never a real SIGKILL
    with pytest.raises(ChannelError, match="simulated"):
        ch.run("inline/0", {}, extra_env={launcher.INJECT_ENV: "sigkill"})


def test_service_spec_grammar_builds_a_server(tmp_path):
    httpd, service = service_from_spec(
        f"serve:port=0;backend=hosts:channel=inline,n=2;"
        f"cache_dir={tmp_path / 'c'}")
    try:
        assert service.backend == "hosts:channel=inline,n=2"
        assert service.cache.directory == str(tmp_path / "c")
    finally:
        httpd.server_close()
    with pytest.raises(ValueError, match="serve"):
        service_from_spec("listen:port=0")
    with pytest.raises(ServiceError):
        SweepService(backend="hosts:channel=nosuch,n=2")


def test_metrics_endpoint_exposes_fleet_health(service_endpoint):
    client, _ = service_endpoint
    spec, *_ = _grid()
    client.run(spec, DATA)
    m = client.metrics()
    counters, timers = m["statsd"]["counters"], m["statsd"]["timers"]
    assert counters["service.jobs.submitted"] >= 1
    assert counters["launcher.shard.ok"] >= 2
    assert timers["service.job.wall_ms"]["count"] >= 1
    assert timers["launcher.shard.attempt_ms"]["avg_ms"] > 0
    assert m["cache"]["entries"] >= 1
    assert m["jobs"]["total"] >= 1
    assert client.health()["status"] == "ok"


# ---------------------------------------------------------------------------
# statsd unit surface
# ---------------------------------------------------------------------------

def test_statsd_counters_gauges_timers_and_tags():
    s = Statsd()
    s.increment("a")
    s.increment("a", 2)
    s.increment("fail", tags={"kind": "crash"})
    s.gauge("depth", 7)
    s.timing("lat", 10.0)
    s.timing("lat", 30.0)
    snap = s.snapshot()
    assert snap["counters"] == {"a": 3, "fail|kind=crash": 1}
    assert snap["gauges"] == {"depth": 7.0}
    t = snap["timers"]["lat"]
    assert (t["count"], t["min_ms"], t["max_ms"], t["avg_ms"]) == \
        (2, 10.0, 30.0, 20.0)
    assert s.counter("fail", tags={"kind": "crash"}) == 1
    with s.timed("block"):
        time.sleep(0.002)
    assert s.snapshot()["timers"]["block"]["last_ms"] >= 1.0
    s.reset()
    assert s.snapshot()["counters"] == {}


def test_statsd_udp_emission_speaks_the_line_protocol():
    import socket

    sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
    sock.bind(("127.0.0.1", 0))
    sock.settimeout(5)
    port = sock.getsockname()[1]
    s = Statsd(addr=f"127.0.0.1:{port}")
    s.increment("jobs.done", tags={"state": "ok"})
    s.timing("lat", 12.5)
    lines = {sock.recvfrom(4096)[0].decode() for _ in range(2)}
    sock.close()
    assert "repro.jobs.done:1|c|#state:ok" in lines
    assert "repro.lat:12.5|ms" in lines


def test_statsd_bad_address_is_inert():
    s = Statsd(addr="not-an-address")
    s.increment("x")                      # must not raise
    assert s.counter("x") == 1
