"""KV/state cache utilities.

``decode_step`` writes into fixed-size buffers at a position index. After a
prefill of length S, the cache buffers have length S; to keep decoding we pad
them to the target budget once (cheap, one concat) and then decode in place.
Window caches (sliding-window attention, hybrid local attention) roll instead
and never grow.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.sharding.partitioning import ParamSpec


def _cache_len_axes(model: Model, batch: int, seq_len: int) -> dict:
    """Map cache leaf path -> axis index of 'cache_len' (or None)."""
    t = model.cache_template(batch, seq_len)
    flat, _ = jax.tree_util.tree_flatten_with_path(
        t, is_leaf=lambda x: isinstance(x, ParamSpec))
    out = {}
    for path, spec in flat:
        key = tuple(str(getattr(p, "key", p)) for p in path)
        out[key] = spec.axes.index("cache_len") if "cache_len" in spec.axes \
            else None
    return out


def pad_cache(model: Model, cache, n_extra: int, batch: int, seq_len: int):
    """Grow every cache_len axis by ``n_extra`` zero slots (append budget).

    Window caches (length == window) are returned untouched — they roll.
    """
    cfg = model.cfg
    axes = _cache_len_axes(model, batch, seq_len)
    window = cfg.sliding_window or (cfg.rglru.window if cfg.rglru else 0)

    def pad(path, leaf):
        key = tuple(str(getattr(p, "key", p)) for p in path)
        ax = axes.get(key)
        if ax is None:
            return leaf
        if window and leaf.shape[ax] == min(window, seq_len):
            if cfg.rglru is not None or cfg.sliding_window:
                return leaf           # rolling window cache
        if "xk" in key or "xv" in key:
            return leaf               # whisper cross-attn cache is fixed
        pad_widths = [(0, 0)] * leaf.ndim
        pad_widths[ax] = (0, n_extra)
        return jnp.pad(leaf, pad_widths)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree.unflatten(treedef, [pad(p, l) for p, l in flat])


def cache_bytes(model: Model, batch: int, seq_len: int) -> int:
    t = model.cache_template(batch, seq_len)
    leaves = jax.tree.leaves(t, is_leaf=lambda x: isinstance(x, ParamSpec))
    dt = jnp.dtype(model.cfg.dtype)
    return sum(int(np.prod(s.shape)) * (jnp.dtype(s.dtype or dt).itemsize)
               for s in leaves)
