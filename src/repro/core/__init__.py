"""The paper's primary contribution: HTL-based distributed learning with
energy accounting (faithful layer), plus the datacenter-scale hypothesis-
transfer trainer (`htl_trainer`, the TPU-native adaptation — DESIGN.md §3).
"""
from repro.core.energy import Ledger, TECHS, MODEL_BYTES, OBS_BYTES  # noqa: F401
from repro.core.htl import DC, run_window_a2a, run_window_star  # noqa: F401
from repro.core.topology import (  # noqa: F401
    Node,
    Topology,
    TRANSPORTS,
    transfer_counts,
)
from repro.core.scenario import (  # noqa: F401
    ScenarioConfig,
    ScenarioResult,
    run_scenario,
    run_sweep,
)
