"""Energy model: paper Table-1 constants, calibration against the paper's
headline numbers, and hypothesis property tests on the ledger."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.energy import Ledger, MODEL_BYTES, OBS_BYTES, TECHS


def test_table1_constants():
    assert TECHS["4g"].tx_mw == 2100 and TECHS["4g"].up_mbps == 75
    assert TECHS["nbiot"].tx_mw == 199 and TECHS["nbiot"].up_mbps == 0.2
    assert TECHS["802.15.4"].tx_mw == 3
    assert TECHS["wifi"].tx_mw == 1080 and TECHS["wifi"].rx_mw == 740


def test_edge_only_benchmark_calibration():
    """Paper: 10 000 observations over NB-IoT = 34 477 mJ (Section 6.1)."""
    led = Ledger()
    for _ in range(100):
        led.collect_to_edge(100)
    assert led.total() == pytest.approx(34477, rel=0.005)


def test_mule_collection_calibration():
    """Paper: the same 10 000 observations over 802.15.4 = 1 728 mJ."""
    led = Ledger()
    for _ in range(100):
        led.collect_to_mule(100)
    assert led.total() == pytest.approx(1728, rel=0.005)


def test_collection_saving_headline():
    """The >=94% headline saving follows from the technology switch."""
    e_edge, e_mule = Ledger(), Ledger()
    e_edge.collect_to_edge(10000)
    e_mule.collect_to_mule(10000)
    assert 1 - e_mule.total() / e_edge.total() > 0.94


def test_wifi_star_topology_relay():
    """Non-AP unicasts relay through the AP: twice the energy."""
    led = Ledger()
    direct = led.unicast("wifi", MODEL_BYTES, src_is_ap=True)
    relayed = led.unicast("wifi", MODEL_BYTES)
    assert relayed == pytest.approx(2 * direct)


def test_edge_server_is_mains_powered():
    led = Ledger()
    to_es = led.unicast("4g", MODEL_BYTES, dst_is_es=True)
    to_sm = led.unicast("4g", MODEL_BYTES)
    assert to_es < to_sm                      # ES rx not charged
    assert to_es == pytest.approx(TECHS["4g"].tx_mj(MODEL_BYTES))


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@given(nbytes=st.integers(min_value=1, max_value=10**9),
       tech=st.sampled_from(list(TECHS)))
@settings(max_examples=50, deadline=None)
def test_energy_linear_in_bytes(nbytes, tech):
    t = TECHS[tech]
    assert t.tx_mj(2 * nbytes) == pytest.approx(2 * t.tx_mj(nbytes))
    assert t.tx_mj(nbytes) >= 0


@given(nbytes=st.integers(min_value=1, max_value=10**7))
@settings(max_examples=30, deadline=None)
def test_technology_ranking_for_collection(nbytes):
    """802.15.4 must always beat NB-IoT per byte (the paper's key driver)."""
    assert TECHS["802.15.4"].tx_mj(nbytes) < TECHS["nbiot"].tx_mj(nbytes)


@given(events=st.lists(
    st.tuples(st.sampled_from(list(TECHS)),
              st.integers(min_value=1, max_value=10**6),
              st.sampled_from(["collection", "learning"])),
    min_size=0, max_size=30))
@settings(max_examples=30, deadline=None)
def test_ledger_additivity(events):
    led = Ledger()
    total = 0.0
    for tech, nbytes, purpose in events:
        total += led.add(tech, nbytes, purpose=purpose)
    assert led.total() == pytest.approx(total)
    assert led.total() == pytest.approx(
        led.total("collection") + led.total("learning"))
    assert led.total() == pytest.approx(sum(led.by_tech().values()))


def test_observation_wire_size():
    assert OBS_BYTES == 54 * 8 + 1
    assert MODEL_BYTES == 55 * 7 * 4
