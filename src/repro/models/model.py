"""Model assembly for all assigned architectures.

One :class:`Model` wraps a :class:`ModelConfig` and exposes:

* ``template()``        — ParamSpec tree (init / shardings / dry-run structs)
* ``init(key, dtype)``  — materialized parameters
* ``loss_fn``           — training loss (CE + MoE aux + MTP)
* ``prefill``           — full-context forward returning (last_logits, cache)
* ``decode_step``       — one-token serve step against a fixed-size cache
* ``cache_template``    — ParamSpec tree for the serve cache

Layers are stacked and evaluated with ``lax.scan`` (keeps HLO size O(1) in
depth — an 80-layer model compiles like a 1-layer model), with configurable
activation rematerialisation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import blocks, rglru, ssd
from repro.models.blocks import (
    chunked_attention, cross_attention, gqa_attention, gqa_decode,
    gqa_template, mla_attention, mla_decode, mla_template, mlp, mlp_template,
    moe_ffn, moe_template, rmsnorm,
)
from repro.sharding.partitioning import ParamSpec, hint, init_params

MTP_LOSS_COEF = 0.1


def _stack(t, n: int):
    """Add a leading stacked-layers dim to every ParamSpec in a template."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init,
                            s.dtype),
        t, is_leaf=lambda x: isinstance(x, ParamSpec))


def _norm_spec(d):
    return ParamSpec((d,), (None,), "ones")


def _maybe_remat(f, cfg: ModelConfig):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(f, policy=policy)
    return jax.checkpoint(f)


# ---------------------------------------------------------------------------
# Per-family block templates
# ---------------------------------------------------------------------------

def _attn_block_template(cfg: ModelConfig, ffn: str = "mlp") -> dict:
    d = cfg.d_model
    t = {"ln1": _norm_spec(d), "ln2": _norm_spec(d)}
    t["attn"] = mla_template(cfg) if cfg.mla is not None else gqa_template(cfg)
    if ffn == "mlp":
        t["mlp"] = mlp_template(d, cfg.d_ff)
    elif ffn == "moe":
        t["moe"] = moe_template(cfg)
    elif ffn == "dense_first":
        t["mlp"] = mlp_template(d, cfg.moe.dense_d_ff or cfg.d_ff)
    return t


def _encdec_dec_block_template(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "ln1": _norm_spec(d), "attn": gqa_template(cfg),
        "lnx": _norm_spec(d), "xattn": gqa_template(cfg),
        "ln2": _norm_spec(d), "mlp": mlp_template(d, cfg.d_ff),
    }


def _ssm_block_template(cfg: ModelConfig) -> dict:
    return {"ln1": _norm_spec(cfg.d_model), "mixer": ssd.ssd_template(cfg)}


def _hybrid_sublayer(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    mix = rglru.rglru_template(cfg) if kind == "rglru" else gqa_template(cfg)
    return {"ln1": _norm_spec(d), "mix": mix,
            "ln2": _norm_spec(d), "mlp": mlp_template(d, cfg.d_ff)}


# ---------------------------------------------------------------------------
# Block forward functions
# ---------------------------------------------------------------------------

def _attn_block(p, h, cfg: ModelConfig, *, window=None):
    h = hint(h, ("batch", None, None))
    x = rmsnorm(h, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, cache = mla_attention(p["attn"], x, cfg)
    else:
        a, cache = gqa_attention(p["attn"], x, cfg, window=window)
    h = h + a
    x2 = rmsnorm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        if cfg.expert_parallel == "shard_map":
            from repro.models.blocks import moe_ffn_shard_map
            f, aux = moe_ffn_shard_map(p["moe"], x2, cfg)
        else:
            f, aux = moe_ffn(p["moe"], x2, cfg)
    else:
        f, aux = mlp(p["mlp"], x2), 0.0
    return h + f, aux, cache


def _attn_block_decode(p, h, cfg: ModelConfig, cache_slice, pos, *,
                       window_cache=False):
    h = hint(h, ("batch", None, None))
    x = rmsnorm(h, p["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        # latent cache: (B, T, r+rope)
        full = cache_slice["ckv"]
        a, new_entry = _mla_decode_buffered(p["attn"], x, full, pos, cfg)
        new_cache = {"ckv": _write_at(full, new_entry, pos)}
    else:
        ck, cv = cache_slice["k"], cache_slice["v"]
        if window_cache:
            a, (k_new, v_new) = _gqa_decode_window(p["attn"], x, ck, cv, cfg,
                                                   pos)
            new_cache = {"k": jnp.concatenate([ck[:, 1:], k_new], axis=1),
                         "v": jnp.concatenate([cv[:, 1:], v_new], axis=1)}
        else:
            a, (k_new, v_new) = _gqa_decode_buffered(p["attn"], x, ck, cv,
                                                     cfg, pos)
            new_cache = {"k": _write_at(ck, k_new, pos),
                         "v": _write_at(cv, v_new, pos)}
    h = h + a
    x2 = rmsnorm(h, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        f, aux = moe_ffn(p["moe"], x2, cfg)
    else:
        f, aux = mlp(p["mlp"], x2), 0.0
    return h + f, aux, new_cache


def _write_at(c, new, pos):
    """Write a one-token entry into a (B,S,...) buffer at ``pos`` —
    scalar (shared position) or (B,) per-sequence (continuous batching)."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return lax.dynamic_update_slice_in_dim(c, new, pos, axis=1)
    B = c.shape[0]
    return c.at[jnp.arange(B), pos].set(new[:, 0])


def _gqa_decode_buffered(p, x, ck, cv, cfg, pos):
    """Decode against a fixed-size buffer: write at ``pos``, mask > pos."""
    q, k_new, v_new = blocks.gqa_project_qkv(p, x, cfg)
    posb = jnp.broadcast_to(jnp.asarray(pos).reshape(-1, 1) if
                            jnp.asarray(pos).ndim else pos,
                            (x.shape[0], 1))
    q = blocks.apply_rope(q, posb, cfg.rope_theta)
    k_new = blocks.apply_rope(k_new, posb, cfg.rope_theta)
    k = _write_at(ck, k_new, pos)
    v = _write_at(cv, v_new, pos)
    out = chunked_attention(q, k, v, causal=True, window=cfg.sliding_window,
                            q_offset=pos)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k_new, v_new)


def _gqa_decode_window(p, x, ck, cv, cfg, pos):
    """Decode against a rolling window cache (all entries valid)."""
    q, k_new, v_new = blocks.gqa_project_qkv(p, x, cfg)
    posa = jnp.asarray(pos)
    posb = jnp.broadcast_to(posa.reshape(-1, 1) if posa.ndim else posa,
                            (x.shape[0], 1))
    q = blocks.apply_rope(q, posb, cfg.rope_theta)
    k_new = blocks.apply_rope(k_new, posb, cfg.rope_theta)
    k = jnp.concatenate([ck[:, 1:], k_new], axis=1)
    v = jnp.concatenate([cv[:, 1:], v_new], axis=1)
    out = chunked_attention(q, k, v, causal=False, window=0)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k_new, v_new)


def _mla_decode_buffered(p, x, cache, pos, cfg):
    """MLA absorbed decode against a fixed-size latent buffer."""
    import math as _math
    m = cfg.mla
    B = x.shape[0]
    posa = jnp.asarray(pos)
    posb = jnp.broadcast_to(posa.reshape(-1, 1) if posa.ndim else posa,
                            (B, 1))
    q_nope, q_rope = blocks._mla_q(p, x, m, cfg, posb)
    kv_a = x @ p["wkv_a"]
    c_new = rmsnorm(kv_a[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    kr = blocks.apply_rope(kv_a[..., None, m.kv_lora_rank:], posb,
                           cfg.rope_theta)
    new_entry = jnp.concatenate([c_new, kr[:, :, 0, :]], axis=-1)  # (B,1,r+rope)
    cache = _write_at(cache, new_entry, pos)
    c = cache[..., :m.kv_lora_rank]
    k_rope = cache[..., m.kv_lora_rank:]
    wk = p["wkv_b"][..., :m.qk_nope_head_dim]
    wv = p["wkv_b"][..., m.qk_nope_head_dim:]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wk)
    scale = 1.0 / _math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bshr,btr->bsht", q_lat, c,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,btk->bsht", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    t_idx = jnp.arange(cache.shape[1])
    valid = t_idx[None, :] <= posb          # (B,T) — per-sequence positions
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bsht,btr->bshr", probs.astype(c.dtype), c)
    o = jnp.einsum("bshr,rhk->bshk", o_lat, wv)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"]), new_entry


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------------ util
    @property
    def dtype(self):
        return jnp.dtype(self.cfg.dtype)

    def init(self, key, dtype=None):
        return init_params(self.template(), key, dtype or self.dtype)

    # ------------------------------------------------------------- templates
    def template(self) -> dict:
        cfg = self.cfg
        d, v = cfg.d_model, cfg.vocab_size
        t: Dict[str, Any] = {
            "embed": ParamSpec((v, d), ("vocab", "embed"), "embed"),
            "final_norm": _norm_spec(d),
        }
        if not cfg.tie_embeddings:
            t["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))

        fam = cfg.family
        if fam in ("dense", "vlm"):
            t["layers"] = _stack(_attn_block_template(cfg), cfg.num_layers)
        elif fam == "moe":
            fk = cfg.moe.first_k_dense
            if fk:
                t["layers_dense"] = _stack(
                    _attn_block_template(cfg, "dense_first"), fk)
            t["layers"] = _stack(_attn_block_template(cfg, "moe"),
                                 cfg.num_layers - fk)
            if cfg.num_mtp_modules:
                t["mtp"] = {
                    "proj": ParamSpec((2 * d, d), ("embed", None)),
                    "norm_h": _norm_spec(d), "norm_e": _norm_spec(d),
                    "block": _attn_block_template(cfg, "moe"),
                    "final_norm": _norm_spec(d),
                }
        elif fam == "ssm":
            t["layers"] = _stack(_ssm_block_template(cfg), cfg.num_layers)
        elif fam == "hybrid":
            period = {
                "rec1": _hybrid_sublayer(cfg, "rglru"),
                "rec2": _hybrid_sublayer(cfg, "rglru"),
                "att": _hybrid_sublayer(cfg, "attn"),
            }
            n_per, n_tail = self._hybrid_counts()
            t["periods"] = _stack(period, n_per)
            if n_tail:
                t["tail"] = _stack(_hybrid_sublayer(cfg, "rglru"), n_tail)
        elif fam == "audio":
            t["enc_layers"] = _stack(_attn_block_template(cfg),
                                     cfg.num_encoder_layers)
            t["enc_norm"] = _norm_spec(d)
            t["layers"] = _stack(_encdec_dec_block_template(cfg),
                                 cfg.num_layers)
        else:
            raise ValueError(fam)
        return t

    def _hybrid_counts(self) -> Tuple[int, int]:
        L = self.cfg.num_layers
        period = len(self.cfg.rglru.pattern)
        return L // period, L % period

    # ------------------------------------------------------------- embedding
    def _embed(self, params, tokens):
        if self.cfg.embedding_impl == "one_hot":
            oh = jax.nn.one_hot(tokens, self.cfg.vocab_size,
                                dtype=self.dtype)
            h = jnp.einsum("bsv,vd->bsd", oh, params["embed"])
        else:
            h = params["embed"][tokens].astype(self.dtype)
        if self.cfg.family == "hybrid":           # gemma-style scaling
            h = h * jnp.asarray(self.cfg.d_model ** 0.5, self.dtype)
        # keep activations batch-sharded (not FSDP-sharded on d_model)
        return hint(h, ("batch", None, None))

    def _head(self, params, h):
        if self.cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", h, params["embed"])
        return jnp.einsum("bsd,dv->bsv", h, params["lm_head"])

    # ---------------------------------------------------------- trunk passes
    def _trunk(self, params, h, *, collect_cache=False, enc_out=None):
        """Full-sequence pass over all layers. Returns (h, aux, caches)."""
        cfg = self.cfg
        fam = cfg.family

        if fam in ("dense", "vlm", "moe"):
            caches = {}
            aux_total = 0.0
            if fam == "moe" and cfg.moe.first_k_dense:
                def body_d(carry, p_l):
                    h, aux = carry
                    h, a, cache = _attn_block(p_l, h, cfg)
                    return (h, aux + a), cache if collect_cache else None
                body_d = _maybe_remat(body_d, cfg) if cfg.remat != "none" else body_d
                (h, aux_total), cache_d = lax.scan(
                    body_d, (h, 0.0), params["layers_dense"])
                if collect_cache:
                    caches["dense"] = cache_d

            def body(carry, p_l):
                h, aux = carry
                h, a, cache = _attn_block(p_l, h, cfg)
                return (h, aux + a), cache if collect_cache else None
            body = _maybe_remat(body, cfg) if cfg.remat != "none" else body
            (h, aux_total), cache_m = lax.scan(body, (h, aux_total),
                                               params["layers"])
            if collect_cache:
                caches["main"] = cache_m
            return h, aux_total, caches

        if fam == "ssm":
            def body(h, p_l):
                h = hint(h, ("batch", None, None))
                x = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
                y, state = ssd.ssd_forward(p_l["mixer"], x, cfg)
                return h + y, state if collect_cache else None
            body = _maybe_remat(body, cfg) if cfg.remat != "none" else body
            h, states = lax.scan(body, h, params["layers"])
            return h, 0.0, {"main": states}

        if fam == "hybrid":
            win = cfg.rglru.window

            def sub(p, h, kind):
                h = hint(h, ("batch", None, None))
                x = rmsnorm(h, p["ln1"], cfg.norm_eps)
                if kind == "rglru":
                    y, st = rglru.rglru_forward(p["mix"], x, cfg)
                else:
                    y, (k, v) = gqa_attention(p["mix"], x, cfg, window=win)
                    w = min(win, k.shape[1])
                    st = (k[:, -w:], v[:, -w:])
                h = h + y
                h = h + mlp(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps))
                return h, st

            def body(h, p_l):
                h, st1 = sub(p_l["rec1"], h, "rglru")
                h, st2 = sub(p_l["rec2"], h, "rglru")
                h, st3 = sub(p_l["att"], h, "attn")
                sts = (st1, st2, st3) if collect_cache else None
                return h, sts
            body = _maybe_remat(body, cfg) if cfg.remat != "none" else body
            h, period_sts = lax.scan(body, h, params["periods"])
            caches = {"periods": period_sts}
            if "tail" in params:
                def body_t(h, p_l):
                    h, st = sub(p_l, h, "rglru")
                    return h, st if collect_cache else None
                h, tail_sts = lax.scan(body_t, h, params["tail"])
                caches["tail"] = tail_sts
            return h, 0.0, caches

        if fam == "audio":
            # decoder trunk with cross-attention to enc_out
            def body(h, p_l):
                h = hint(h, ("batch", None, None))
                x = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
                a, (k, v) = gqa_attention(p_l["attn"], x, cfg)
                h = h + a
                xq = rmsnorm(h, p_l["lnx"], cfg.norm_eps)
                ek = jnp.einsum("btd,dhk->bthk", enc_out, p_l["xattn"]["wk"])
                ev = jnp.einsum("btd,dhk->bthk", enc_out, p_l["xattn"]["wv"])
                if cfg.qkv_bias:
                    ek = ek + p_l["xattn"]["bk"]
                    ev = ev + p_l["xattn"]["bv"]
                h = h + cross_attention(p_l["xattn"], xq, (ek, ev), cfg)
                h = h + mlp(p_l["mlp"], rmsnorm(h, p_l["ln2"], cfg.norm_eps))
                return h, ((k, v), (ek, ev)) if collect_cache else None
            body = _maybe_remat(body, cfg) if cfg.remat != "none" else body
            h, caches = lax.scan(body, h, params["layers"])
            return h, 0.0, {"main": caches}

        raise ValueError(fam)

    def _encode(self, params, enc_embeds):
        """Whisper encoder over precomputed (stub-frontend) frame embeddings."""
        cfg = self.cfg
        h = enc_embeds.astype(self.dtype)
        # sinusoidal positions
        S, d = h.shape[1], h.shape[2]
        pos = jnp.arange(S)[:, None].astype(jnp.float32)
        dim = jnp.arange(0, d, 2)[None, :].astype(jnp.float32)
        angle = pos / jnp.power(10000.0, dim / d)
        pe = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)
        h = h + pe[None].astype(self.dtype)

        def body(h, p_l):
            h = hint(h, ("batch", None, None))
            x = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
            a, _ = gqa_attention(p_l["attn"], x, cfg, causal=False, rope=False)
            h = h + a
            h = h + mlp(p_l["mlp"], rmsnorm(h, p_l["ln2"], cfg.norm_eps))
            return h, None
        body = _maybe_remat(body, cfg) if cfg.remat != "none" else body
        h, _ = lax.scan(body, h, params["enc_layers"])
        return rmsnorm(h, params["enc_norm"], cfg.norm_eps)

    # -------------------------------------------------------------- training
    def loss_fn(self, params, batch):
        cfg = self.cfg
        tokens, targets = batch["tokens"], batch["targets"]
        h = self._embed(params, tokens)
        enc_out = None
        n_front = 0
        if cfg.family == "audio":
            enc_out = self._encode(params, batch["encoder_embeds"])
        elif cfg.family == "vlm":
            fe = batch["frontend_embeds"].astype(self.dtype)
            n_front = fe.shape[1]
            h = jnp.concatenate([fe, h], axis=1)

        h, aux, _ = self._trunk(params, h, enc_out=enc_out)
        if n_front:
            h = h[:, n_front:]
        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, h)
        loss = _ce(logits, targets)
        metrics = {"ce": loss, "aux": jnp.asarray(aux, jnp.float32)}

        if cfg.num_mtp_modules:
            loss_mtp = self._mtp_loss(params, h, tokens, targets)
            metrics["mtp"] = loss_mtp
            loss = loss + MTP_LOSS_COEF * loss_mtp
        total = loss + aux
        metrics["loss"] = total
        return total, metrics

    def _mtp_loss(self, params, h, tokens, targets):
        """DeepSeek-V3 multi-token prediction: predict t+2 from (h_t, emb_{t+1})."""
        cfg = self.cfg
        m = params["mtp"]
        h_in = rmsnorm(h[:, :-1], m["norm_h"], cfg.norm_eps)
        e_in = rmsnorm(self._embed(params, tokens[:, 1:]), m["norm_e"],
                       cfg.norm_eps)
        x = jnp.concatenate([h_in, e_in], axis=-1) @ m["proj"]
        x2, _, _ = _attn_block(m["block"], x, cfg)
        x2 = rmsnorm(x2, m["final_norm"], cfg.norm_eps)
        logits = self._head(params, x2)
        return _ce(logits, targets[:, 1:])

    # --------------------------------------------------------------- serving
    def prefill(self, params, batch):
        """Returns (last_token_logits, cache)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        h = self._embed(params, tokens)
        enc_out = None
        n_front = 0
        if cfg.family == "audio":
            enc_out = self._encode(params, batch["encoder_embeds"])
        elif cfg.family == "vlm":
            fe = batch["frontend_embeds"].astype(self.dtype)
            n_front = fe.shape[1]
            h = jnp.concatenate([fe, h], axis=1)
        h, _, caches = self._trunk(params, h, collect_cache=True,
                                   enc_out=enc_out)
        h = rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = self._head(params, h)[:, 0]
        return logits, self._pack_cache(caches)

    def _pack_cache(self, caches):
        cfg = self.cfg
        fam = cfg.family
        if cfg.mla is not None:
            parts = [caches["main"]]
            if "dense" in caches:
                parts.insert(0, caches["dense"])
            return {"ckv": jnp.concatenate(parts, 0)}
        if fam in ("dense", "vlm", "moe"):
            k, v = caches["main"]
            if cfg.sliding_window:
                w = min(cfg.sliding_window, k.shape[2])
                k, v = k[:, :, -w:], v[:, :, -w:]
            return {"k": k, "v": v}
        if fam == "ssm":
            st, conv = caches["main"]
            return {"state": st, "conv": conv}
        if fam == "hybrid":
            (h1, c1), (h2, c2), (ak, av) = caches["periods"]
            out = {"rec1_h": h1, "rec1_conv": c1, "rec2_h": h2,
                   "rec2_conv": c2, "att_k": ak, "att_v": av}
            if "tail" in caches:
                th, tc = caches["tail"]
                out["tail_h"] = th
                out["tail_conv"] = tc
            return out
        if fam == "audio":
            (k, v), (ek, ev) = caches["main"]
            return {"k": k, "v": v, "xk": ek, "xv": ev}
        raise ValueError(fam)

    def cache_template(self, batch: int, seq_len: int) -> dict:
        cfg = self.cfg
        fam = cfg.family
        L, B, d = cfg.num_layers, batch, cfg.d_model
        KV, hd = cfg.num_kv_heads, cfg.head_dim
        dt = None  # default model dtype
        S = min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len

        def kv(nl, s, kvh=KV, h=hd):
            ax = ("layers", "batch", "cache_len", "kv_heads", None)
            return (ParamSpec((nl, B, s, kvh, h), ax, "zeros", dt),
                    ParamSpec((nl, B, s, kvh, h), ax, "zeros", dt))

        if cfg.mla is not None:
            m = cfg.mla
            width = m.kv_lora_rank + m.qk_rope_head_dim
            return {"ckv": ParamSpec((L, B, S, width),
                                     ("layers", "batch", "cache_len", None),
                                     "zeros", dt)}
        if fam in ("dense", "vlm", "moe"):
            k, v = kv(L, S)
            return {"k": k, "v": v}
        if fam == "ssm":
            d_in, nh, P, N = ssd.ssd_dims(cfg)
            ch = d_in + 2 * N
            return {
                "state": ParamSpec((L, B, nh, P, N),
                                   ("layers", "batch", "heads", None, None),
                                   "zeros", dt),
                "conv": ParamSpec((L, B, cfg.ssm.conv_width - 1, ch),
                                  ("layers", "batch", None, "mlp"),
                                  "zeros", dt)}
        if fam == "hybrid":
            n_per, n_tail = self._hybrid_counts()
            W = rglru.rglru_width(cfg)
            cw = cfg.rglru.conv_width
            win = min(cfg.rglru.window, seq_len)
            ak, av = kv(n_per, win)
            out = {
                "rec1_h": ParamSpec((n_per, B, W), ("layers", "batch", "lru"),
                                    "zeros", dt),
                "rec1_conv": ParamSpec((n_per, B, cw - 1, W),
                                       ("layers", "batch", None, "lru"),
                                       "zeros", dt),
                "rec2_h": ParamSpec((n_per, B, W), ("layers", "batch", "lru"),
                                    "zeros", dt),
                "rec2_conv": ParamSpec((n_per, B, cw - 1, W),
                                       ("layers", "batch", None, "lru"),
                                       "zeros", dt),
                "att_k": ak, "att_v": av,
            }
            if n_tail:
                out["tail_h"] = ParamSpec((n_tail, B, W),
                                          ("layers", "batch", "lru"),
                                          "zeros", dt)
                out["tail_conv"] = ParamSpec((n_tail, B, cw - 1, W),
                                             ("layers", "batch", None, "lru"),
                                             "zeros", dt)
            return out
        if fam == "audio":
            k, v = kv(L, S)
            xk, xv = kv(L, cfg.encoder_seq_len)
            return {"k": k, "v": v, "xk": xk, "xv": xv}
        raise ValueError(fam)

    def decode_step(self, params, cache, tokens, pos):
        """One serve step: tokens (B,1) int32, pos scalar int32.

        Returns (logits (B,V), new_cache). Attention caches are fixed-size
        buffers written in place at ``pos`` (or rolled, for window caches).
        """
        cfg = self.cfg
        fam = cfg.family
        h = self._embed(params, tokens)
        window_cache = bool(cfg.sliding_window)

        if fam in ("dense", "vlm", "moe"):
            aux_t = 0.0
            new_caches = {}
            if fam == "moe" and cfg.moe.first_k_dense and cfg.mla is not None:
                fk = cfg.moe.first_k_dense
                full = cache["ckv"]
                c_dense, c_moe = full[:fk], full[fk:]

                def body_d(carry, xs):
                    h, aux = carry
                    p_l, c_l = xs
                    h, a, nc = _attn_block_decode(p_l, h, cfg, {"ckv": c_l},
                                                  pos)
                    return (h, aux + a), nc["ckv"]
                (h, aux_t), nc_d = lax.scan(body_d, (h, aux_t),
                                            (params["layers_dense"], c_dense))

                def body_m(carry, xs):
                    h, aux = carry
                    p_l, c_l = xs
                    h, a, nc = _attn_block_decode(p_l, h, cfg, {"ckv": c_l},
                                                  pos)
                    return (h, aux + a), nc["ckv"]
                (h, aux_t), nc_m = lax.scan(body_m, (h, aux_t),
                                            (params["layers"], c_moe))
                new_caches = {"ckv": jnp.concatenate([nc_d, nc_m], 0)}
            else:
                cache_main = ({"ckv": cache["ckv"]} if cfg.mla is not None
                              else {"k": cache["k"], "v": cache["v"]})

                def body(carry, xs):
                    h, aux = carry
                    p_l, c_l = xs
                    h, a, nc = _attn_block_decode(
                        p_l, h, cfg, c_l, pos, window_cache=window_cache)
                    return (h, aux + a), nc
                (h, aux_t), new_caches = lax.scan(
                    body, (h, 0.0), (params["layers"], cache_main))
        elif fam == "ssm":
            def body(h, xs):
                p_l, st, cv = xs
                x = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
                y, (nst, ncv) = ssd.ssd_decode(p_l["mixer"], x, st, cv, cfg)
                return h + y, (nst, ncv)
            h, (nst, ncv) = lax.scan(
                body, h, (params["layers"], cache["state"], cache["conv"]))
            new_caches = {"state": nst, "conv": ncv}
        elif fam == "hybrid":
            def sub_dec(p, h, kind, st):
                x = rmsnorm(h, p["ln1"], cfg.norm_eps)
                if kind == "rglru":
                    hs, cv = st
                    y, (nhs, ncv) = rglru.rglru_decode(p["mix"], x, hs, cv,
                                                       cfg)
                    nst = (nhs, ncv)
                else:
                    ck, cv_ = st
                    y, (kn, vn) = _gqa_decode_window(p["mix"], x, ck, cv_,
                                                     cfg, pos)
                    nst = (jnp.concatenate([ck[:, 1:], kn], 1),
                           jnp.concatenate([cv_[:, 1:], vn], 1))
                h = h + y
                h = h + mlp(p["mlp"], rmsnorm(h, p["ln2"], cfg.norm_eps))
                return h, nst

            def body(h, xs):
                p_l, r1h, r1c, r2h, r2c, ak, av = xs
                h, n1 = sub_dec(p_l["rec1"], h, "rglru", (r1h, r1c))
                h, n2 = sub_dec(p_l["rec2"], h, "rglru", (r2h, r2c))
                h, n3 = sub_dec(p_l["att"], h, "attn", (ak, av))
                return h, (n1[0], n1[1], n2[0], n2[1], n3[0], n3[1])
            h, outs = lax.scan(body, h, (params["periods"], cache["rec1_h"],
                                         cache["rec1_conv"], cache["rec2_h"],
                                         cache["rec2_conv"], cache["att_k"],
                                         cache["att_v"]))
            new_caches = {"rec1_h": outs[0], "rec1_conv": outs[1],
                          "rec2_h": outs[2], "rec2_conv": outs[3],
                          "att_k": outs[4], "att_v": outs[5]}
            if "tail" in params:
                def body_t(h, xs):
                    p_l, th, tc = xs
                    h, nst = sub_dec(p_l, h, "rglru", (th, tc))
                    return h, nst
                h, (nth, ntc) = lax.scan(body_t, h, (params["tail"],
                                                     cache["tail_h"],
                                                     cache["tail_conv"]))
                new_caches["tail_h"] = nth
                new_caches["tail_conv"] = ntc
        elif fam == "audio":
            def body(h, xs):
                p_l, ck, cv, xk, xv = xs
                x = rmsnorm(h, p_l["ln1"], cfg.norm_eps)
                a, (kn, vn) = _gqa_decode_buffered(p_l["attn"], x, ck, cv,
                                                   cfg, pos)
                h = h + a
                xq = rmsnorm(h, p_l["lnx"], cfg.norm_eps)
                h = h + cross_attention(p_l["xattn"], xq, (xk, xv), cfg)
                h = h + mlp(p_l["mlp"], rmsnorm(h, p_l["ln2"], cfg.norm_eps))
                nk = _write_at(ck, kn, pos)
                nv = _write_at(cv, vn, pos)
                return h, (nk, nv)
            h, (nk, nv) = lax.scan(body, h, (params["layers"], cache["k"],
                                             cache["v"], cache["xk"],
                                             cache["xv"]))
            new_caches = {"k": nk, "v": nv, "xk": cache["xk"],
                          "xv": cache["xv"]}
        else:
            raise ValueError(fam)

        h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
        logits = self._head(params, h)[:, 0]
        return logits, new_caches


def _ce(logits, targets):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
