"""Static validator + ZeRO-1 spec densification."""
from jax.sharding import PartitionSpec as P

from repro.launch.specs import _densify_spec
from repro.launch.validate import validate


class FakeMesh:
    def __init__(self, **axes):
        self.shape = dict(axes)


def test_densify_fills_free_axes():
    mesh = FakeMesh(data=16, model=16)
    # (L, D, H, hd): D on data, H replicated (24 % 16), hd divisible
    spec = _densify_spec(P(None, "data", None, None), (28, 3072, 24, 128),
                         mesh)
    assert spec == P(None, "data", None, "model")


def test_densify_no_free_axes():
    mesh = FakeMesh(data=16, model=16)
    spec = _densify_spec(P(None, "data", "model"), (28, 3072, 8192), mesh)
    assert spec == P(None, "data", "model")


def test_validator_deepseek_train_exceeds_hbm():
    r = validate("deepseek-v3-671b", "train_4k")
    assert not r["fits_16gb"]               # documented: needs >1 pod
    r2 = validate("deepseek-v3-671b", "decode_32k")
    assert r2["fits_16gb"]                  # EP-256 + MLA latent cache fits


def test_validator_all_decodes_fit():
    from repro.configs import ALL_ARCHS
    for a in ALL_ARCHS:
        r = validate(a, "decode_32k")
        if r["status"] == "ok":
            assert r["fits_16gb"], (a, r)
