"""Performance metrics exactly as defined in the paper (Section 5.2).

Precision (eq. 3) is the *overall accuracy* (the paper's idiosyncratic
definition), recall (eq. 4) is macro-averaged per-class accuracy, and the
F-measure (eq. 5) is their harmonic mean.
"""
from __future__ import annotations

import numpy as np


def precision(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    return float(np.mean(y_true == y_pred))


def recall(y_true: np.ndarray, y_pred: np.ndarray, num_classes: int) -> float:
    vals = []
    for c in range(num_classes):
        m = y_true == c
        if m.sum() == 0:
            continue
        vals.append(float(np.mean(y_pred[m] == c)))
    return float(np.mean(vals)) if vals else 0.0


def f_measure(y_true: np.ndarray, y_pred: np.ndarray,
              num_classes: int) -> float:
    p = precision(y_true, y_pred)
    r = recall(y_true, y_pred, num_classes)
    if p + r == 0:
        return 0.0
    return 2.0 * p * r / (p + r)


# ---------------------------------------------------------------------------
# confusion-count forms — the streamed-eval path of the scan engine
# (repro.core.cityscan) evaluates on device and brings back only an integer
# confusion matrix per window; these helpers recover the EXACT paper metrics
# from those counts. Bitwise equality with the label-array forms above holds
# because every quantity is an integer/integer float64 division (exact for
# counts < 2^53) followed by the same float ops in the same order
# (tests/test_cityscan.py property-checks the equivalence).
# ---------------------------------------------------------------------------

def confusion_counts(y_true: np.ndarray, y_pred: np.ndarray,
                     num_classes: int) -> np.ndarray:
    """Confusion matrix ``cm[true, pred]`` as int64 counts."""
    cm = np.zeros((num_classes, num_classes), np.int64)
    np.add.at(cm, (np.asarray(y_true, np.int64), np.asarray(y_pred, np.int64)),
              1)
    return cm


def precision_from_confusion(cm: np.ndarray) -> float:
    return float(np.trace(cm) / cm.sum())


def recall_from_confusion(cm: np.ndarray) -> float:
    vals = []
    for c in range(cm.shape[0]):
        row = cm[c].sum()
        if row == 0:
            continue
        vals.append(float(cm[c, c] / row))
    return float(np.mean(vals)) if vals else 0.0


def f_measure_from_confusion(cm: np.ndarray) -> float:
    p = precision_from_confusion(cm)
    r = recall_from_confusion(cm)
    if p + r == 0:
        return 0.0
    return 2.0 * p * r / (p + r)


# ---------------------------------------------------------------------------
# Robust aggregation (DESIGN.md §13): coordinate-wise trimmed mean over the
# leading axis — the all-to-all combine's defence against faulty/byzantine
# DCs. With n contributions and trim fraction ``frac``, ``k = floor(frac*n)``
# extremes are dropped per coordinate from each end; k == 0 degrades to the
# plain mean bit-for-bit (same np.mean call), which is what keeps
# ``robust_agg="mean"`` runs bitwise identical to pre-robust builds.
# ---------------------------------------------------------------------------

def trimmed_mean(stack: np.ndarray, frac: float, axis: int = 0) -> np.ndarray:
    """Coordinate-wise ``frac``-trimmed mean along ``axis``."""
    if not 0.0 <= frac < 0.5:
        raise ValueError(f"trim fraction must be in [0, 0.5), got {frac}")
    n = stack.shape[axis]
    k = int(frac * n)
    if k == 0:
        return np.mean(stack, axis=axis)
    s = np.sort(stack, axis=axis)
    sl = [slice(None)] * s.ndim
    sl[axis] = slice(k, n - k)
    return np.mean(s[tuple(sl)], axis=axis)
