"""Transport/topology layer: who relays, who pays, per technology.

The paper charges every logical transfer between Data Collectors according
to implicit per-technology conventions (DESIGN.md §2). Historically those
conventions lived as if-chains inside ``Ledger.unicast`` and inline loops in
``htl.py``; this module makes them a pluggable layer:

* :class:`Node` — a typed endpoint role: battery-powered SmartMule,
  mains-powered Edge Server (``is_es``), WiFi Access Point (``is_ap``).
* :class:`Transport` — maps a (src, dst) node pair to the number of
  battery-powered tx and rx events one unicast costs. Two built-ins:
  ``InfrastructureTransport`` (4G / NB-IoT / 802.15.4: one tx + one rx,
  mains-powered ES endpoints exempt) and ``ApRelayTransport`` (802.11g
  WiFi-Direct star: mule↔mule traffic relays through the AP, 2 tx + 2 rx
  unless one endpoint *is* the AP).
* :class:`Topology` — binds a technology + node set to a
  :class:`~repro.core.energy.Ledger` and exposes the collective message
  patterns the HTL algorithms use: ``unicast``, ``broadcast``, ``gather``
  and ``exchange_all``.

Transports are addressed by *spec strings* (grammar in
:mod:`repro.core.registry`, DESIGN.md §5): a flat name picks a registered
factory with its defaults (``"4g"``, ``"wifi"``, ``"ble"``), a
parameterized spec configures one (``"mesh:hops=3"``, ``"lora:sf=12"``).
New technologies plug in by registering a factory in
:data:`TRANSPORT_FACTORIES` (plus, if they carry new per-event energies, a
:class:`~repro.core.energy.Tech`) — algorithm code never needs to change.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.core.energy import Ledger
from repro.core.registry import register_factory, resolve_spec


@dataclass(frozen=True)
class Node:
    """A Data Collector endpoint with its energy-accounting roles."""
    name: str
    is_es: bool = False     # mains-powered Edge Server: its radio is free
    is_ap: bool = False     # WiFi Access Point (one mule per window)


class Transport:
    """Battery-powered (n_tx, n_rx) cost of one unicast between two nodes."""

    def counts(self, src: Node, dst: Node) -> Tuple[int, int]:
        raise NotImplementedError


class InfrastructureTransport(Transport):
    """Cellular/LPWAN (4G, NB-IoT) and single-hop 802.15.4: one tx + one rx
    per unicast; a mains-powered ES endpoint costs nothing on its side."""

    def counts(self, src: Node, dst: Node) -> Tuple[int, int]:
        return (0 if src.is_es else 1), (0 if dst.is_es else 1)


class ApRelayTransport(Transport):
    """802.11g WiFi-Direct star: one mule acts as the Access Point. A unicast
    between two non-AP battery nodes is relayed (2 tx + 2 rx, all on
    battery); if either endpoint is the AP it is direct (1 tx + 1 rx). ES
    endpoints fall back to the infrastructure rule (the ES is reached over
    the fixed network, and its own radio is mains powered)."""

    def __init__(self):
        self._infra = InfrastructureTransport()

    def counts(self, src: Node, dst: Node) -> Tuple[int, int]:
        if src.is_es or dst.is_es:
            return self._infra.counts(src, dst)
        hops = 1 if (src.is_ap or dst.is_ap) else 2
        return hops, hops


class LoRaTransport(InfrastructureTransport):
    """LoRa star through a mains-powered gateway: infrastructure counts.
    The spreading factor steers the *energy* layer (bitrate,
    :func:`repro.core.energy.lora_bitrate_mbps`), not the relay
    structure; it is accepted (and range-checked) here so one spec string
    — ``"lora:sf=12"`` — configures both layers."""

    def __init__(self, sf: int = 7):
        super().__init__()
        from repro.core.energy import lora_bitrate_mbps
        lora_bitrate_mbps(sf)          # validate 7..12
        self.sf = int(sf)


class MeshTransport(Transport):
    """Multi-hop 802.15.4 mesh: a unicast traverses ``hops`` links, each a
    battery tx + battery rx (the intermediate relays are battery mules).
    Only the *endpoint* events can be mains-exempt: an ES source skips the
    first tx, an ES destination skips the last rx — so ``hops=1`` charges
    identically to flat ``"802.15.4"`` and ``hops=3`` charges 3x the
    battery tx/rx events between mules. Per-event energy stays the
    802.15.4 Table-1 entry (:func:`repro.core.energy.resolve_tech`)."""

    def __init__(self, hops: int = 1):
        if isinstance(hops, bool) or hops != int(hops) or int(hops) < 1:
            raise ValueError(f"mesh hop count must be a positive integer, "
                             f"got {hops!r}")
        self.hops = int(hops)

    def counts(self, src: Node, dst: Node) -> Tuple[int, int]:
        return (self.hops - (1 if src.is_es else 0),
                self.hops - (1 if dst.is_es else 0))


# Factories keyed by spec *name*; spec parameters become factory kwargs
# ("mesh:hops=3" -> MeshTransport(hops=3)). BLE mirrors WiFi-Direct's star
# (one mule is the GATT central and relays peripheral<->peripheral
# traffic); LoRa is a star through a mains-powered gateway, i.e. the
# infrastructure rule (DESIGN.md §5).
TRANSPORT_FACTORIES: Dict[str, Callable[..., Transport]] = {
    "4g": InfrastructureTransport,
    "nbiot": InfrastructureTransport,
    "802.15.4": InfrastructureTransport,
    "wifi": ApRelayTransport,
    "ble": ApRelayTransport,
    "lora": LoRaTransport,
    "mesh": MeshTransport,
}

_TRANSPORT_CACHE: Dict[str, Transport] = {}


def register_transport(name: str,
                       factory: Callable[..., Transport]) -> None:
    """Register a transport factory under a spec name (idempotent for the
    same factory; raises on a conflicting re-registration)."""
    register_factory(TRANSPORT_FACTORIES, name, factory, "transport")


def get_transport(spec: str) -> Transport:
    """Resolve a transport spec string to a (cached) Transport instance.

    Raises :class:`KeyError` for unknown names or malformed specs, so
    ``Topology`` construction keeps its fail-fast contract."""
    return resolve_spec(spec, TRANSPORT_FACTORIES, _TRANSPORT_CACHE,
                        "transport")


def transfer_counts(tech: str, src: Node, dst: Node) -> Tuple[int, int]:
    """(n_tx, n_rx) one unicast costs on battery, under ``tech``'s rules."""
    return get_transport(tech).counts(src, dst)


class Topology:
    """A window's Data Collector fleet bound to a ledger and a technology.

    All HTL message patterns are expressed against this object so that the
    loop and fleet engines (and any future algorithm) share one accounting
    implementation.
    """

    def __init__(self, ledger: Ledger, tech: str,
                 nodes: Iterable[Node] = ()):
        from repro.core.energy import resolve_tech
        self.transport = get_transport(tech)   # KeyError on unknown spec
        resolve_tech(tech)                     # ... or missing energy entry
        self.ledger = ledger
        self.tech = tech
        self.nodes: List[Node] = list(nodes)

    # -- node bookkeeping ---------------------------------------------------
    def node(self, name: str) -> Node:
        for n in self.nodes:
            if n.name == name:
                return n
        raise KeyError(name)

    @property
    def ap(self) -> Optional[Node]:
        return next((n for n in self.nodes if n.is_ap), None)

    # -- message patterns ---------------------------------------------------
    def unicast(self, src: Node, dst: Node, nbytes: float, *,
                purpose: str = "learning", what: str = "model") -> float:
        n_tx, n_rx = self.transport.counts(src, dst)
        return self.ledger.add(self.tech, nbytes, purpose=purpose,
                               n_tx=n_tx, n_rx=n_rx, what=what,
                               src=src.name, dst=dst.name)

    def broadcast(self, src: Node, nbytes: float, *,
                  purpose: str = "learning", what: str = "model") -> float:
        """src -> every other node (as unicasts; the paper's radios have no
        free broadcast primitive at these ranges)."""
        return sum(self.unicast(src, dst, nbytes, purpose=purpose, what=what)
                   for dst in self.nodes if dst.name != src.name)

    def gather(self, dst: Node, nbytes: float, *,
               purpose: str = "learning", what: str = "model") -> float:
        """Every other node -> dst."""
        return sum(self.unicast(src, dst, nbytes, purpose=purpose, what=what)
                   for src in self.nodes if src.name != dst.name)

    def exchange_all(self, nbytes: float, *, purpose: str = "learning",
                     what: str = "model") -> float:
        """All-to-all: every ordered (src, dst) pair, src != dst."""
        return sum(self.unicast(src, dst, nbytes, purpose=purpose, what=what)
                   for src in self.nodes for dst in self.nodes
                   if src.name != dst.name)


def fleet_nodes(dcs, ap_name: Optional[str]) -> List[Node]:
    """Typed nodes for a window's DC fleet (``dcs`` from repro.core.htl)."""
    return [Node(d.name, is_es=d.is_es, is_ap=(d.name == ap_name))
            for d in dcs]
