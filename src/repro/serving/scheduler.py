"""Continuous-batching serving scheduler (vLLM-style, CPU-scale).

Requests arrive with different prompt lengths and token budgets; the
scheduler keeps a fixed number of decode slots busy: when a sequence
finishes (EOS or budget), its slot is refilled by prefilling the next queued
request and splicing its cache entries into the batch cache at the free slot.

Works with every cache family (KV / MLA-latent / SSM-state / RG-LRU) via the
cache pytrees' batch axis, which `Model.cache_template` exposes as axis 1 of
every leaf ('layers', 'batch', ...).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.cache_utils import pad_cache


@dataclass
class Request:
    rid: int
    tokens: np.ndarray              # (prompt_len,)
    max_new_tokens: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Fixed-slot continuous batching over a shared decode cache."""

    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None):
        if model.cfg.family in ("vlm", "audio"):
            raise NotImplementedError("text-only scheduler")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.prefill)
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * slots
        self.cache = None
        self.pos = np.zeros(slots, np.int64)      # per-slot write position
        self.last_tok = np.zeros(slots, np.int64)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_one(self, req: Request):
        """Prefill a single request and return (next_token, slot_cache)."""
        toks = jnp.asarray(req.tokens[None, :], jnp.int32)
        logits, cache = self._prefill(self.params, {"tokens": toks})
        cache = pad_cache(self.model, cache,
                          self.max_len - len(req.tokens), 1,
                          len(req.tokens))
        return int(jnp.argmax(logits, -1)[0]), cache

    def _splice(self, slot: int, slot_cache):
        """Write a 1-batch cache into the batched cache at ``slot``."""
        if self.cache is None:
            # initialise the batched cache with zeros like slot_cache
            self.cache = jax.tree.map(
                lambda x: jnp.zeros((x.shape[0], self.slots) + x.shape[2:],
                                    x.dtype), slot_cache)
        self.cache = jax.tree.map(
            lambda full, one: full.at[:, slot].set(one[:, 0]),
            self.cache, slot_cache)

    def _refill_slots(self):
        for s in range(self.slots):
            if self.active[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            tok, slot_cache = self._prefill_one(req)
            self._splice(s, slot_cache)
            self.active[s] = req
            self.pos[s] = len(req.tokens)
            self.last_tok[s] = tok
            req.out.append(tok)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One decode step across all busy slots. Returns False when idle."""
        self._refill_slots()
        busy = [s for s in range(self.slots) if self.active[s] is not None]
        if not busy:
            return False
        # single batched decode with PER-SLOT positions (sequences are at
        # different depths); idle slots decode garbage that is ignored
        toks = jnp.asarray(self.last_tok[:, None], jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, toks,
                                          jnp.asarray(self.pos, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s in busy:
            req = self.active[s]
            tok = int(nxt[s])
            req.out.append(tok)
            self.last_tok[s] = tok
            self.pos[s] += 1
            if (len(req.out) >= req.max_new_tokens
                    or (self.eos_id is not None and tok == self.eos_id)
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                self.active[s] = None
        return True

    def run(self, max_steps: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        all_reqs = list(self.queue)
        for _ in range(max_steps):
            if not self.step():
                break
        return [r for r in all_reqs if r.done] or all_reqs
