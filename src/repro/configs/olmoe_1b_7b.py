"""olmoe-1b-7b — MoE with 64 experts, top-8 routing [arXiv:2409.02060].

16L, d_model=2048, 16H (kv=16), per-expert d_ff=1024, vocab=50304.
~1B active / ~7B total parameters.
"""
from repro.configs.base import MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024,
                  num_shared_experts=0, capacity_factor=1.25),
    supports_long_context=False,
    source="arXiv:2409.02060",
))
