"""Transport registry: spec-string grammar round-trips, hand-computed
energy parity for the mesh/BLE/LoRa additions against the DESIGN.md §2
conventions, and scenario-level mesh charging (hops=1 == 802.15.4,
hops=3 == 3x battery tx/rx events)."""
import dataclasses

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.energy import (Ledger, MODEL_BYTES, TECHS,
                               lora_bitrate_mbps, resolve_tech)
from repro.core.registry import format_spec, parse_spec
from repro.core.scenario import ScenarioConfig, run_scenario
from repro.core.topology import (MeshTransport, Node, Topology,
                                 TRANSPORT_FACTORIES, get_transport,
                                 transfer_counts)
from repro.data.synthetic_covtype import make_covtype_like

MULE, MULE2 = Node("SM1"), Node("SM2")
AP = Node("SM3", is_ap=True)
ES = Node("ES", is_es=True)

# one representative spec per registered factory, plus parameterized forms
SPECS = ["4g", "nbiot", "802.15.4", "wifi", "ble", "lora", "lora:sf=7",
         "lora:sf=12", "mesh", "mesh:hops=1", "mesh:hops=2", "mesh:hops=3",
         "mesh:hops=5"]


# ---------------------------------------------------------------------------
# spec grammar
# ---------------------------------------------------------------------------

@given(spec=st.sampled_from(SPECS))
@settings(max_examples=len(SPECS), deadline=None)
def test_registered_specs_parse_and_round_trip(spec):
    name, params = parse_spec(spec)
    assert name in TRANSPORT_FACTORIES
    canonical = format_spec(name, params)
    assert parse_spec(canonical) == (name, params)
    # both spellings resolve, to the same cached instance, with the same
    # counts and the same energy entry
    t, tc = get_transport(spec), get_transport(canonical)
    assert t is tc
    assert t.counts(MULE, MULE2) == tc.counts(MULE, MULE2)
    assert resolve_tech(spec).tx_mw > 0


@pytest.mark.parametrize("bad", ["carrier-pigeon", "mesh:hops", "mesh:",
                                 "", "warp:x=1", "lora:bw=250"])
def test_malformed_or_unknown_specs_raise_keyerror(bad):
    with pytest.raises(KeyError):
        get_transport(bad)


def test_bad_parameter_values_raise():
    with pytest.raises(ValueError):
        get_transport("mesh:hops=0")
    with pytest.raises(ValueError):
        get_transport("lora:sf=6")
    with pytest.raises(ValueError):
        lora_bitrate_mbps(13)
    with pytest.raises(ValueError):          # no fractional SF modes
        lora_bitrate_mbps(7.5)
    with pytest.raises(ValueError):
        resolve_tech("lora:sf=7.5")


def test_fractional_hops_fail_fast_at_validation():
    """Transport and energy layers must agree on rejecting fractional hop
    counts, so a bad spec dies at validate_config — never mid-sweep after
    collection energy was charged."""
    from repro.core.scenario import ScenarioConfig, validate_config
    with pytest.raises(ValueError):
        get_transport("mesh:hops=2.5")
    with pytest.raises(ValueError):
        resolve_tech("mesh:hops=2.5")
    with pytest.raises(ValueError):
        validate_config(ScenarioConfig(tech="mesh:hops=2.5"))


def test_ledger_add_rejects_bad_specs_directly():
    """resolve_tech guards the direct Ledger.add path too — a typoed mesh
    parameter must not silently charge 802.15.4 energy."""
    led = Ledger()
    with pytest.raises(KeyError):
        led.add("mesh:hopz=3", 100.0, purpose="learning")
    with pytest.raises(KeyError):
        led.add("warp", 100.0, purpose="learning")
    with pytest.raises(ValueError):          # bad value, not just bad name
        led.add("mesh:hops=0", 100.0, purpose="learning")
    assert led.events == []
    # the valid spec resolves to the 802.15.4 energy entry (and caches)
    assert resolve_tech("mesh:hops=3") is TECHS["802.15.4"]


def test_spec_params_coerce_types():
    assert parse_spec("mesh:hops=3") == ("mesh", {"hops": 3})
    assert parse_spec("x:a=1.5,b=true,c=foo") == (
        "x", {"a": 1.5, "b": True, "c": "foo"})
    assert format_spec("mesh", {"hops": 3}) == "mesh:hops=3"
    assert format_spec("wifi") == "wifi"


# ---------------------------------------------------------------------------
# mesh: hop-count-dependent charging
# ---------------------------------------------------------------------------

def test_mesh_hops1_matches_802154_counts_and_energy():
    for src, dst in [(MULE, MULE2), (MULE, ES), (ES, MULE)]:
        assert (transfer_counts("mesh:hops=1", src, dst)
                == transfer_counts("802.15.4", src, dst))
    l_mesh, l_flat = Ledger(), Ledger()
    Topology(l_mesh, "mesh:hops=1", [MULE, MULE2]).unicast(
        MULE, MULE2, MODEL_BYTES)
    Topology(l_flat, "802.15.4", [MULE, MULE2]).unicast(
        MULE, MULE2, MODEL_BYTES)
    assert l_mesh.total() == l_flat.total()


def test_mesh_hops_scale_battery_events():
    """hops=h between battery mules: h tx + h rx, at 802.15.4 per-event
    energy — hand-computed from E = P * S/B (DESIGN.md §2)."""
    t = TECHS["802.15.4"]
    per_event = (t.tx_mw * MODEL_BYTES * 8.0 / (t.up_mbps * 1e6)
                 + t.rx_mw * MODEL_BYTES * 8.0 / (t.down_mbps * 1e6))
    for h in (1, 2, 3, 5):
        assert transfer_counts(f"mesh:hops={h}", MULE, MULE2) == (h, h)
        led = Ledger()
        Topology(led, f"mesh:hops={h}", [MULE, MULE2]).unicast(
            MULE, MULE2, MODEL_BYTES)
        assert led.total() == pytest.approx(h * per_event)


def test_mesh_es_endpoints_exempt_one_event():
    """Only the ES *endpoint* event is mains-exempt; the battery relays
    in between always pay."""
    assert transfer_counts("mesh:hops=3", MULE, ES) == (3, 2)
    assert transfer_counts("mesh:hops=3", ES, MULE) == (2, 3)
    assert transfer_counts("mesh:hops=1", MULE, ES) == (1, 0)
    with pytest.raises(ValueError):
        MeshTransport(hops=0)


def test_mesh_scenario_charging_parity_and_scaling():
    """Scenario level (the acceptance contract): tech="mesh:hops=1" is
    indistinguishable from tech="802.15.4"; hops=3 charges exactly 3x the
    learning energy (all-battery fleets, p_edge=0) and identical
    collection energy."""
    data = make_covtype_like(seed=0)
    base = ScenarioConfig(windows=4, eval_every=2, algo="star", seed=1)
    r_flat = run_scenario(dataclasses.replace(base, tech="802.15.4"), data)
    r_h1 = run_scenario(dataclasses.replace(base, tech="mesh:hops=1"), data)
    r_h3 = run_scenario(dataclasses.replace(base, tech="mesh:hops=3"), data)
    assert r_h1.f1_curve == r_flat.f1_curve
    assert r_h1.energy_total == pytest.approx(r_flat.energy_total)
    assert r_h1.ledger.by_purpose() == r_flat.ledger.by_purpose()
    assert r_h3.energy_collection == pytest.approx(r_h1.energy_collection)
    assert r_h3.energy_learning == pytest.approx(3 * r_h1.energy_learning)


# ---------------------------------------------------------------------------
# BLE
# ---------------------------------------------------------------------------

def test_ble_hand_computed_energies():
    """BLE mirrors the WiFi-Direct star (one mule is the GATT central):
    non-central pairs relay (2 tx + 2 rx), central endpoints are direct.
    E = P * S/B with the BLE Tech constants."""
    t = TECHS["ble"]
    tx = t.tx_mw * MODEL_BYTES * 8.0 / (t.up_mbps * 1e6)
    rx = t.rx_mw * MODEL_BYTES * 8.0 / (t.down_mbps * 1e6)
    assert transfer_counts("ble", MULE, MULE2) == (2, 2)
    assert transfer_counts("ble", MULE, AP) == (1, 1)
    assert transfer_counts("ble", MULE, ES) == (1, 0)
    led = Ledger()
    topo = Topology(led, "ble", [MULE, MULE2, AP, ES])
    assert topo.unicast(MULE, MULE2, MODEL_BYTES) == pytest.approx(
        2 * tx + 2 * rx)
    assert topo.unicast(MULE, AP, MODEL_BYTES) == pytest.approx(tx + rx)
    assert topo.unicast(MULE, ES, MODEL_BYTES) == pytest.approx(tx)


# ---------------------------------------------------------------------------
# LoRa
# ---------------------------------------------------------------------------

def test_lora_hand_computed_energies_and_sf_scaling():
    """LoRa is a star through a mains-powered gateway (infrastructure
    counts). Bitrate follows sf * BW / 2^sf * CR, so energy per byte
    scales with the inverse bitrate ratio between spreading factors."""
    assert transfer_counts("lora", MULE, MULE2) == (1, 1)
    assert transfer_counts("lora:sf=12", MULE, ES) == (1, 0)

    rate7 = lora_bitrate_mbps(7)
    assert rate7 == pytest.approx(7 * 125e3 / 2**7 * 0.8 / 1e6)
    t7 = resolve_tech("lora")
    assert t7.up_mbps == pytest.approx(rate7)

    led = Ledger()
    e7 = Topology(led, "lora", [MULE, MULE2]).unicast(
        MULE, MULE2, MODEL_BYTES)
    e12 = Topology(led, "lora:sf=12", [MULE, MULE2]).unicast(
        MULE, MULE2, MODEL_BYTES)
    assert e7 == pytest.approx(
        (t7.tx_mw + t7.rx_mw) * MODEL_BYTES * 8.0 / (rate7 * 1e6))
    assert e12 / e7 == pytest.approx(rate7 / lora_bitrate_mbps(12))
    assert e12 / e7 == pytest.approx((7 / 2**7) / (12 / 2**12))


def test_parameterized_techs_cached_outside_paper_table():
    t = resolve_tech("lora:sf=10")
    assert resolve_tech("lora:sf=10") is t          # cached
    assert "lora:sf=10" not in TECHS                # TECHS stays Table 1
    assert "mesh:hops=3" not in TECHS
    assert resolve_tech("lora") is TECHS["lora"]    # flat names untouched


# ---------------------------------------------------------------------------
# registry hygiene
# ---------------------------------------------------------------------------

def test_transport_cache_returns_same_instance():
    assert get_transport("mesh:hops=3") is get_transport("mesh:hops=3")
    assert get_transport("wifi") is get_transport("wifi")


def test_register_transport_conflict_rejected():
    from repro.core.topology import register_transport
    with pytest.raises(ValueError):
        register_transport("wifi", MeshTransport)
    # idempotent for the same factory
    register_transport("mesh", MeshTransport)


def test_new_transports_run_full_scenarios():
    data = make_covtype_like(seed=0)
    base = ScenarioConfig(windows=3, eval_every=3)
    energies = {}
    for tech in ("ble", "lora:sf=7", "mesh:hops=2"):
        r = run_scenario(dataclasses.replace(base, tech=tech), data)
        assert np.isfinite(r.f1_curve).all()
        assert r.energy_learning > 0
        energies[tech] = r.energy_learning
    # LoRa's kbps-range bitrate dwarfs BLE/mesh per-byte costs
    assert energies["lora:sf=7"] > 100 * energies["ble"]
