"""HLO parsing edge cases: iota replica groups, manual-axis stripping."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo import _line_crosses_pod
from repro.sharding.partitioning import _strip_axes


def test_iota_groups_within_pod():
    # [16,32]<=[32,16]T(1,0): groups of 32 with stride 16 over 512 devices —
    # each group spans ids {j, 16+j, ..., 496+j}: crosses the 256 boundary
    ln = ('%ar = f32[8] all-reduce(%x), replica_groups=[16,32]<=[32,16]T(1,0)'
          ', to_apply=%add')
    assert _line_crosses_pod(ln, pod_size=256)


def test_iota_groups_contiguous_no_cross():
    # [2,256]<=[512]: two contiguous groups of 256 = exactly the two pods
    ln = '%ag = f32[8] all-gather(%x), replica_groups=[2,256]<=[512]'
    assert not _line_crosses_pod(ln, pod_size=256)


def test_iota_groups_cross():
    # [256,2]<=[2,256]T(1,0): pairs (i, i+256) — every group crosses
    ln = '%cp = f32[8] all-to-all(%x), replica_groups=[256,2]<=[2,256]T(1,0)'
    assert _line_crosses_pod(ln, pod_size=256)


def test_explicit_groups():
    assert _line_crosses_pod(
        '%ar = f32[2] all-reduce(%x), replica_groups={{0,256}}', 256)
    assert not _line_crosses_pod(
        '%ar = f32[2] all-reduce(%x), replica_groups={{0,1},{256,257}}', 256)


def test_strip_axes():
    assert _strip_axes(("pod", "data"), {"pod"}) == "data"
    assert _strip_axes("pod", {"pod"}) is None
    assert _strip_axes("data", {"pod"}) == "data"
    assert _strip_axes(None, {"pod"}) is None
    assert _strip_axes(("pod", "data", "model"), {"pod"}) == ("data", "model")
