"""Hypothesis property tests for GreedyTL (the paper's core learner)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.greedytl import (greedytl, greedytl_fleet,
                                 greedytl_fleet_stacked, _loo_ridge_chol,
                                 _score_trials)
from repro.core.svm import svm_scores
from repro.kernels.ref import loo_trials_inv_reference

F, C, M_CAP = 54, 7, 16


def _run(x, y, n_src, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    n = len(y)
    cap = max(32, n)
    xp = np.zeros((cap, F), np.float32)
    xp[:n] = x
    yp = np.zeros(cap, np.int32)
    yp[:n] = y
    mp = np.zeros(cap, np.float32)
    mp[:n] = 1
    src = np.zeros((M_CAP, F + 1, C), np.float32)
    sm = np.zeros(M_CAP, np.float32)
    for i in range(n_src):
        src[i] = rng.normal(0, scale, (F + 1, C))
        sm[i] = 1
    w, sel = greedytl(jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mp),
                      jnp.asarray(src), jnp.asarray(sm), num_classes=C)
    return np.asarray(w), np.asarray(sel), src, sm


@given(n=st.integers(min_value=4, max_value=60),
       n_src=st.integers(min_value=0, max_value=8),
       seed=st.integers(min_value=0, max_value=100))
@settings(max_examples=15, deadline=None)
def test_output_always_finite(n, n_src, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, F)).astype(np.float32)
    y = rng.integers(0, C, n)
    w, sel, _, _ = _run(x, y, n_src, seed)
    assert np.isfinite(w).all()
    assert w.shape == (F + 1, C)
    # selection respects the validity mask
    assert (sel[n_src:] == 0).all()


@given(seed=st.integers(min_value=0, max_value=50))
@settings(max_examples=10, deadline=None)
def test_scale_invariance_of_sources(seed):
    """Source normalisation: scaling a source hypothesis by a constant must
    not change the collapsed model materially (alpha absorbs 1/s)."""
    rng = np.random.default_rng(seed)
    n = 40
    x = rng.normal(0, 1, (n, F)).astype(np.float32)
    y = rng.integers(0, C, n)
    w1, _, src, sm = _run(x, y, 1, seed, scale=1.0)
    # same source, scaled 100x
    cap = max(32, n)
    xp = np.zeros((cap, F), np.float32)
    xp[:n] = x
    yp = np.zeros(cap, np.int32)
    yp[:n] = y
    mp = np.zeros(cap, np.float32)
    mp[:n] = 1
    src2 = src.copy()
    src2[0] *= 100.0
    w2, _ = greedytl(jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mp),
                     jnp.asarray(src2), jnp.asarray(sm), num_classes=C)
    w2 = np.asarray(w2)
    # predictions on the training data agree
    p1 = np.asarray(svm_scores(jnp.asarray(w1), jnp.asarray(x)))
    p2 = np.asarray(svm_scores(jnp.asarray(w2), jnp.asarray(x)))
    assert np.allclose(p1, p2, atol=0.2, rtol=0.1)


def _random_gram_system(D, M, n_rows, seed):
    """Random SPD column-masked ridge system (as Stage 1 builds them)."""
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n_rows, D)).astype(np.float32)
    y = rng.normal(size=n_rows).astype(np.float32)
    rmask = (rng.random(n_rows) < 0.8).astype(np.float32)
    sel = (rng.random(M) < 0.4).astype(np.float32)
    cmask = np.concatenate([sel, np.ones(D - M, np.float32)])
    lam_d = (np.abs(rng.normal(0.8, 0.5, D)) + 1e-3).astype(np.float32)
    A_rm = A * rmask[:, None]
    return (A_rm.T @ A_rm, A_rm.T @ (y * rmask), A_rm, y, rmask, cmask,
            lam_d, sel)


@given(seed=st.integers(min_value=0, max_value=200),
       m=st.sampled_from([2, 8, M_CAP]),
       rows=st.sampled_from([64, 224, 400]))
@settings(max_examples=15, deadline=None)
def test_cholesky_bordering_loo_matches_inverse(seed, m, rows):
    """Property: on random SPD systems, every candidate's Cholesky-bordering
    LOO objective equals the inverse-based formulation to <= 1e-5 rel."""
    AtA, Aty, A_rm, y, rmask, cmask, lam_d, sel = _random_gram_system(
        m + C, m, rows, seed)
    args = tuple(jnp.asarray(v) for v in
                 (AtA, Aty, A_rm, y, rmask, cmask, lam_d))
    fac = np.asarray(_score_trials(*args, m))
    ref = np.asarray(loo_trials_inv_reference(*args, m))
    valid = sel == 0
    if valid.any():
        rel = (np.abs(fac - ref)[valid]
               / np.maximum(np.abs(ref[valid]), 1e-6))
        assert rel.max() < 1e-5, rel.max()


@given(seed=st.integers(min_value=0, max_value=100))
@settings(max_examples=8, deadline=None)
def test_cholesky_solve_matches_inverse_solution(seed):
    """The factorized full solve (used for the final coefficients and the
    Stage-2 correction) matches the inverse-based ridge solution."""
    AtA, Aty, A_rm, y, rmask, cmask, lam_d, _ = _random_gram_system(
        M_CAP + C, M_CAP, 200, seed)
    loo, v = _loo_ridge_chol(*(jnp.asarray(t) for t in
                               (AtA, Aty, A_rm, y, rmask, cmask, lam_d)))
    cm2 = cmask[:, None] * cmask[None, :]
    Ginv = np.linalg.inv(AtA * cm2 + np.diag(lam_d))
    v_ref = (Ginv @ (Aty * cmask)) * cmask
    resid = (A_rm @ v_ref - y) * rmask
    h = np.sum((A_rm * cmask) @ Ginv * (A_rm * cmask), axis=-1)
    loo_ref = np.sum((resid / np.maximum(1.0 - h, 0.1)) ** 2)
    np.testing.assert_allclose(np.asarray(v), v_ref, atol=1e-4)
    assert abs(float(loo) - loo_ref) / max(loo_ref, 1e-6) < 1e-4


def test_fleet_variants_bitwise_match_single_calls():
    """lax.map fleet refiners must stay bitwise equal to per-call greedytl
    (the loop/fleet engine parity contract)."""
    rng = np.random.default_rng(7)
    L, cap = 3, 32
    x = rng.normal(size=(L, cap, F)).astype(np.float32)
    y = rng.integers(0, C, (L, cap)).astype(np.int32)
    m = (rng.random((L, cap)) < 0.6).astype(np.float32)
    src = rng.normal(0, 0.5, (M_CAP, F + 1, C)).astype(np.float32)
    sm = (np.arange(M_CAP) < 5).astype(np.float32)

    singles = [greedytl(jnp.asarray(x[i]), jnp.asarray(y[i]),
                        jnp.asarray(m[i]), jnp.asarray(src),
                        jnp.asarray(sm), num_classes=C) for i in range(L)]
    w_fleet, sel_fleet = greedytl_fleet(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(m), jnp.asarray(src),
        jnp.asarray(sm), num_classes=C)
    srcs = np.broadcast_to(src, (L,) + src.shape)
    sms = np.broadcast_to(sm, (L,) + sm.shape)
    w_stk, sel_stk = greedytl_fleet_stacked(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(m), jnp.asarray(srcs),
        jnp.asarray(sms), num_classes=C)
    for i, (wi, seli) in enumerate(singles):
        assert np.array_equal(np.asarray(w_fleet)[i], np.asarray(wi)), i
        assert np.array_equal(np.asarray(w_stk)[i], np.asarray(wi)), i
        assert np.array_equal(np.asarray(sel_stk)[i], np.asarray(seli)), i


def test_perfect_source_dominates():
    """If a source already classifies the local data perfectly, GreedyTL
    must produce a model at least as accurate on that data."""
    rng = np.random.default_rng(3)
    n = 60
    w_true = rng.normal(0, 1, (F + 1, C)).astype(np.float32)
    x = rng.normal(0, 1, (n, F)).astype(np.float32)
    y = np.asarray(jnp.argmax(svm_scores(jnp.asarray(w_true),
                                         jnp.asarray(x)), -1))
    cap = 64
    xp = np.zeros((cap, F), np.float32)
    xp[:n] = x
    yp = np.zeros(cap, np.int32)
    yp[:n] = y
    mp = np.zeros(cap, np.float32)
    mp[:n] = 1
    src = np.zeros((M_CAP, F + 1, C), np.float32)
    sm = np.zeros(M_CAP, np.float32)
    src[0] = w_true
    sm[0] = 1
    w, sel = greedytl(jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mp),
                      jnp.asarray(src), jnp.asarray(sm), num_classes=C,
                      lam_bias=50.0)
    assert bool(np.asarray(sel)[0])
    pred = np.asarray(jnp.argmax(svm_scores(w, jnp.asarray(x)), -1))
    # scalar-alpha + gated correction recovers most (not all) of a perfect
    # source's boundary on 60 random-label points
    assert (pred == y).mean() > 0.85
