"""Batched fleet engine vs loop reference engine: numerical parity, exact
ledger totals, O(1) dispatch count, and the topology layer's conventions."""
import dataclasses

import numpy as np
import pytest

from repro.core import fleet, htl
from repro.core.energy import Ledger, MODEL_BYTES, TECHS
from repro.core.scenario import ScenarioConfig, run_scenario, run_sweep
from repro.core.svm import SAMPLE_BUCKETS
from repro.core.topology import (Node, Topology, fleet_nodes,
                                 transfer_counts)
from repro.data.synthetic_covtype import make_covtype_like

DATA = make_covtype_like(seed=0)
BASE = ScenarioConfig(windows=6, eval_every=2)

PARITY_CONFIGS = [
    ("star", dataclasses.replace(BASE, algo="star", tech="4g")),
    ("a2a", dataclasses.replace(BASE, algo="a2a", tech="wifi")),
    ("star_agg", dataclasses.replace(BASE, algo="star", tech="wifi",
                                     aggregate=True)),
    ("a2a_agg", dataclasses.replace(BASE, algo="a2a", tech="4g",
                                    aggregate=True, p_edge=0.15)),
    ("a2a_sub", dataclasses.replace(BASE, algo="a2a", tech="wifi",
                                    n_subsample=5)),
]


@pytest.mark.parametrize("label,cfg", PARITY_CONFIGS,
                         ids=[c[0] for c in PARITY_CONFIGS])
def test_engine_parity(label, cfg):
    """The batched engine must reproduce the loop engine's F1 curve
    (atol <= 1e-4) and its ledger totals exactly."""
    r_loop = run_scenario(dataclasses.replace(cfg, engine="loop"), DATA)
    r_fleet = run_scenario(dataclasses.replace(cfg, engine="fleet"), DATA)
    np.testing.assert_allclose(r_fleet.f1_curve, r_loop.f1_curve, atol=1e-4)
    assert r_fleet.ledger.by_tech() == r_loop.ledger.by_tech()
    assert r_fleet.ledger.by_purpose() == r_loop.ledger.by_purpose()


def test_run_sweep_matches_run_scenario():
    cfgs = [dataclasses.replace(BASE, algo=a, seed=s)
            for a in ("star", "a2a") for s in (0, 1)]
    swept = run_sweep(cfgs, DATA)
    for cfg, r in zip(cfgs, swept):
        single = run_scenario(cfg, DATA)
        assert r.f1_curve == single.f1_curve
        assert r.energy_total == single.energy_total


def test_fleet_dispatch_count_is_o1_per_window():
    """Loop engine trains once per DC; fleet engine once per window."""
    counts = {"loop": 0, "fleet": 0}
    orig_train, orig_fleet = htl.train_svm, fleet.train_svm_fleet

    def count_loop(*a, **k):
        counts["loop"] += 1
        return orig_train(*a, **k)

    def count_fleet(*a, **k):
        counts["fleet"] += 1
        return orig_fleet(*a, **k)

    cfg = dataclasses.replace(BASE, algo="a2a", windows=4, eval_every=4)
    try:
        htl.train_svm, fleet.train_svm_fleet = count_loop, count_fleet
        run_scenario(dataclasses.replace(cfg, engine="loop"), DATA)
        loop_calls = counts["loop"]
        run_scenario(dataclasses.replace(cfg, engine="fleet"), DATA)
        fleet_calls = counts["fleet"]
    finally:
        htl.train_svm, fleet.train_svm_fleet = orig_train, orig_fleet
    # at most one dispatch per sample bucket per window, regardless of the
    # Poisson fleet size (the loop engine pays one per DC)
    assert fleet_calls <= 4 * (len(SAMPLE_BUCKETS) + 1)
    assert loop_calls > fleet_calls


def test_stacked_sweep_matches_sequential():
    """Replica-stacked sweeps (seeds and host-side config variants mixed
    into one fleet axis) must reproduce sequential runs: ledgers exactly,
    F1 curves within the engine-parity tolerance."""
    for algo in ("star", "a2a"):
        cfgs = [dataclasses.replace(BASE, algo=algo, seed=s)
                for s in (0, 1, 2)]
        cfgs += [dataclasses.replace(BASE, algo=algo, seed=0, tech="wifi",
                                     n_subsample=5),
                 dataclasses.replace(BASE, algo=algo, seed=1, p_edge=0.15,
                                     aggregate=True)]
        seq = [run_scenario(c, DATA) for c in cfgs]
        stk = run_sweep(cfgs, DATA, stack_seeds=True)
        for a, b in zip(seq, stk):
            np.testing.assert_allclose(b.f1_curve, a.f1_curve, atol=1e-4)
            assert a.ledger.by_purpose() == b.ledger.by_purpose()
            assert a.ledger.by_tech() == b.ledger.by_tech()


def test_stacked_sweep_preserves_order_and_incompatible_groups():
    """A sweep mixing stackable groups, loop-engine configs and edge-only
    configs must return results in input order with correct attribution."""
    cfgs = [dataclasses.replace(BASE, algo="star", seed=0),
            dataclasses.replace(BASE, algo="edge_only", seed=1),
            dataclasses.replace(BASE, algo="star", seed=2),
            dataclasses.replace(BASE, algo="star", seed=0, engine="loop")]
    out = run_sweep(cfgs, DATA, stack_seeds=True)
    for cfg, r in zip(cfgs, out):
        assert r.cfg == cfg
        single = run_scenario(cfg, DATA)
        np.testing.assert_allclose(r.f1_curve, single.f1_curve, atol=1e-4)
        assert r.ledger.by_purpose() == single.ledger.by_purpose()


def test_fleet_cap_buckets():
    assert fleet.fleet_cap(1) == 1      # singleton groups pad nothing (the
    assert fleet.fleet_cap(2) == 2      # big Zipf mule sits alone in its
    assert fleet.fleet_cap(4) == 4      # sample bucket most windows)
    assert fleet.fleet_cap(5) == 8
    assert fleet.fleet_cap(16) == 16
    assert fleet.fleet_cap(17) == 32
    assert fleet.fleet_cap(40) == 64


# ---------------------------------------------------------------------------
# topology layer
# ---------------------------------------------------------------------------

def test_transfer_counts_conventions():
    mule, mule2 = Node("SM1"), Node("SM2")
    ap = Node("SM3", is_ap=True)
    es = Node("ES", is_es=True)
    # infrastructure techs: 1 tx + 1 rx; ES side free
    assert transfer_counts("4g", mule, mule2) == (1, 1)
    assert transfer_counts("4g", mule, es) == (1, 0)
    assert transfer_counts("4g", es, mule) == (0, 1)
    # wifi star: non-AP pairs relay through the AP
    assert transfer_counts("wifi", mule, mule2) == (2, 2)
    assert transfer_counts("wifi", mule, ap) == (1, 1)
    assert transfer_counts("wifi", ap, mule) == (1, 1)
    assert transfer_counts("wifi", mule, es) == (1, 0)


def test_ledger_unicast_delegates_to_transports():
    """The legacy flag API and the typed topology API must charge alike."""
    l1, l2 = Ledger(), Ledger()
    topo = Topology(l2, "wifi", [Node("a"), Node("b", is_ap=True),
                                 Node("c"), Node("ES", is_es=True)])
    l1.unicast("wifi", MODEL_BYTES)                       # a -> c relayed
    topo.unicast(topo.node("a"), topo.node("c"), MODEL_BYTES)
    l1.unicast("wifi", MODEL_BYTES, dst_is_ap=True)       # a -> b direct
    topo.unicast(topo.node("a"), topo.node("b"), MODEL_BYTES)
    l1.unicast("wifi", MODEL_BYTES, dst_is_es=True)       # a -> ES
    topo.unicast(topo.node("a"), topo.node("ES"), MODEL_BYTES)
    assert l1.total() == pytest.approx(l2.total())


def test_topology_collectives_sum_to_unicasts():
    nodes = [Node("a", is_ap=True), Node("b"), Node("c")]
    t1, t2 = Topology(Ledger(), "wifi", nodes), Topology(Ledger(), "wifi",
                                                         nodes)
    t1.exchange_all(100.0)
    for s in nodes:
        for d in nodes:
            if s.name != d.name:
                t2.unicast(s, d, 100.0)
    assert t1.ledger.total() == pytest.approx(t2.ledger.total())
    t1.ledger, t2.ledger = Ledger(), Ledger()
    t1b = Topology(Ledger(), "4g", nodes)
    t1b.broadcast(nodes[0], 50.0)
    t1b.gather(nodes[0], 50.0)
    # 2 peers each way, infrastructure: (1 tx + 1 rx) * 4 transfers
    expected = 4 * (TECHS["4g"].tx_mj(50.0) + TECHS["4g"].rx_mj(50.0))
    assert t1b.ledger.total() == pytest.approx(expected)


def test_unknown_transport_rejected():
    with pytest.raises(KeyError):
        Topology(Ledger(), "carrier-pigeon", [])
    with pytest.raises(KeyError):
        run_scenario(dataclasses.replace(BASE, engine="warp"), DATA)


def test_fleet_nodes_roles():
    dcs = [htl.DC("SM1", DATA.x_train[:5].astype(np.float32),
                  DATA.y_train[:5]),
           htl.DC("ES", DATA.x_train[5:9].astype(np.float32),
                  DATA.y_train[5:9], is_es=True)]
    nodes = fleet_nodes(dcs, "SM1")
    assert nodes[0].is_ap and not nodes[0].is_es
    assert nodes[1].is_es and not nodes[1].is_ap
