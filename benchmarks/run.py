"""Benchmark driver. One section per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows; full numeric payloads are
written to results/benchmarks/*.json.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-tables]
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def bench_paper_tables(quick: bool, engine: str = "fleet"):
    from benchmarks.paper_tables import run_all
    t0 = time.time()
    out = run_all(quick=quick, engine=engine)
    dt = (time.time() - t0) * 1e6
    rows = []
    ref = out["fig2_edge_only"]
    rows.append(("fig2_edge_only", dt, f"E={ref['energy_mj']:.0f}mJ "
                 f"F1={ref['f1']:.3f}"))
    for k, v in out.items():
        if isinstance(v, dict) and "gain_pct" in v:
            rows.append((k, 0.0, f"E={v['energy_mj']:.0f}mJ "
                         f"gain={v['gain_pct']:.1f}% F1={v['f1']:.3f} "
                         f"loss={v['acc_loss_pct']:.1f}%"))
    return rows


def bench_kernels(quick: bool):
    """Per-kernel call latency (interpret mode on CPU; numbers are
    correctness-path timings, not TPU performance)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    rows = []
    key = jax.random.PRNGKey(0)

    q = jax.random.normal(key, (1, 4, 512, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2, 512, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2, 512, 64), jnp.float32)
    f = lambda: ops.flash_attention(q, k, v, causal=True)
    f()
    t0 = time.time()
    n = 3
    for _ in range(n):
        jax.block_until_ready(f())
    rows.append(("kernel_flash_attention_512", (time.time() - t0) / n * 1e6,
                 "interpret"))

    x = jax.random.normal(key, (1, 512, 4, 64), jnp.float32)
    dt_ = jax.nn.softplus(jax.random.normal(key, (1, 512, 4)))
    A = -jnp.exp(jax.random.normal(key, (4,)) * 0.5)
    Bm = jax.random.normal(key, (1, 512, 64)) * 0.5
    Cm = jax.random.normal(key, (1, 512, 64)) * 0.5
    f = lambda: ops.ssd_scan(x, dt_, A, Bm, Cm, chunk=128)
    f()
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f())
    rows.append(("kernel_ssd_scan_512", (time.time() - t0) / n * 1e6,
                 "interpret"))

    a = jax.nn.sigmoid(jax.random.normal(key, (1, 512, 128)))
    b = jax.random.normal(key, (1, 512, 128)) * 0.5
    f = lambda: ops.rglru_scan(a, b)
    f()
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f())
    rows.append(("kernel_rglru_scan_512", (time.time() - t0) / n * 1e6,
                 "interpret"))
    return rows


def bench_greedytl(quick: bool):
    """GreedyTL source-selection microbenchmark: us/call vs candidate-pool
    size M (the factorized-LOO hot path; track this in results/)."""
    import jax
    import jax.numpy as jnp
    from repro.core.greedytl import greedytl

    rng = np.random.default_rng(0)
    F, C, cap = 54, 7, 160
    x = jnp.asarray(rng.normal(size=(cap, F)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, C, cap).astype(np.int32))
    m = jnp.asarray(np.ones(cap, np.float32))
    rows = []
    n = 10 if quick else 30
    for M in (8, 16, 32):
        src = jnp.asarray(rng.normal(0, 0.5, (M, F + 1, C))
                          .astype(np.float32))
        sm = jnp.asarray(np.ones(M, np.float32))
        f = lambda: greedytl(x, y, m, src, sm, num_classes=C)[0]
        jax.block_until_ready(f())
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(f())
        rows.append((f"greedytl_M{M}", (time.time() - t0) / n * 1e6,
                     f"cap={cap} factorized-LOO"))
    return rows


def _deep_greedy_problem(cap=160, n_src=12, seed=0):
    """Deep-accepting GreedyTL problem at the production shape: n_src
    sources each explain a disjoint feature block of the true boundary, so
    greedy selection keeps accepting (depth == n_src at k_max=16)."""
    import jax.numpy as jnp
    F, C, M = 54, 7, 16
    r = np.random.default_rng(seed)
    src = np.zeros((M, F + 1, C), np.float32)
    sm = np.zeros(M, np.float32)
    w_total = np.zeros((F + 1, C), np.float32)
    for i, blk in enumerate(np.array_split(np.arange(F), n_src)):
        w = np.zeros((F + 1, C), np.float32)
        w[blk] = r.normal(0, 1.0, (len(blk), C))
        src[i] = w
        sm[i] = 1.0
        w_total += w
    x = r.normal(size=(cap, F)).astype(np.float32)
    y = np.argmax(x @ w_total[:-1] + w_total[-1], axis=1).astype(np.int32)
    return tuple(jnp.asarray(v) for v in
                 (x, y, np.ones(cap, np.float32), src, sm))


def bench_greedytl_incremental(quick: bool):
    """Incremental Cholesky carry vs the refactorize-per-step PR-2 path
    (``incremental=False``): warm wall-clock at greedy depths 4/8/16 on a
    deep-accepting production-shape problem (cap=160 -> R=1120, D=23,
    M=16), per-refine jitted dispatch counts, and the ``loo_trials``
    autotuner table. Updates results/benchmarks/greedytl_incremental.json
    and the repo-level BENCH_greedytl.json trajectory (quick runs refresh
    the refine/dispatch numbers; the paper_tables cold/warm subprocess
    timings only re-measure on a full run)."""
    import jax
    import jax.numpy as jnp
    from benchmarks.paper_tables import RESULTS_DIR
    from repro.core.dispatch import dispatch_scope
    from repro.core.greedytl import (greedytl, greedytl_fleet,
                                     greedytl_fleet_stacked)
    from repro.kernels import ops as kernel_ops

    C, M, cap = 7, 16, 160
    x, y, m, src, sm = _deep_greedy_problem(cap=cap)
    n = 10 if quick else 30
    rows, refine = [], {}
    for k_max in (4, 8, 16):
        per, depth = {}, 0
        for label, inc in (("incremental", True), ("refactor", False)):
            f = lambda: greedytl(x, y, m, src, sm, num_classes=C,
                                 k_max=k_max, incremental=inc)
            w_, sel = f()
            jax.block_until_ready(w_)
            depth = int(np.asarray(sel).sum())
            t0 = time.time()
            for _ in range(n):
                jax.block_until_ready(f()[0])
            per[label] = (time.time() - t0) / n * 1e6
        speedup = per["refactor"] / per["incremental"]
        refine[f"k_max_{k_max}"] = {
            "incremental_us": round(per["incremental"]),
            "refactor_us": round(per["refactor"]),
            "depth": depth, "speedup": round(speedup, 2)}
        rows.append((f"greedytl_inc_k{k_max}", per["incremental"],
                     f"depth={depth} speedup={speedup:.2f}x vs refactor"))

    # accepting k candidates must still be ONE dispatch per entry point
    with dispatch_scope() as d1:
        jax.block_until_ready(greedytl(x, y, m, src, sm, num_classes=C)[0])
    L = 2
    xf, yf, mf = (jnp.stack([v] * L) for v in (x, y, m))
    with dispatch_scope() as d2:
        jax.block_until_ready(
            greedytl_fleet(xf, yf, mf, src, sm, num_classes=C)[0])
    srcs, sms = (jnp.stack([v] * L) for v in (src, sm))
    with dispatch_scope() as d3:
        jax.block_until_ready(greedytl_fleet_stacked(
            xf, yf, mf, srcs, sms, num_classes=C)[0])
    dispatches = {**d1, **d2, **d3}

    # persist the kernel-selection table for the production trial shape
    entry = kernel_ops.autotune_loo_trials(cap * C, M + C, M, persist=True)
    rows.append(("loo_trials_autotune",
                 min(entry["timings_us"].values()),
                 f"{kernel_ops.autotune_key(cap * C, M + C, M)} -> "
                 f"{entry['impl']}"))

    tables = None
    if not quick:
        import subprocess
        import tempfile
        code = ("import time; t0 = time.time(); "
                "from benchmarks.paper_tables import run_all; "
                "run_all(quick=True); print('WALL_S', time.time() - t0)")
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        tables_json = os.path.join(RESULTS_DIR, "paper_tables.json")
        keep = open(tables_json).read() if os.path.exists(tables_json) \
            else None

        def run_once(cache_dir):
            env = dict(os.environ,
                       PYTHONPATH="src" + os.pathsep
                       + os.environ.get("PYTHONPATH", ""),
                       JAX_COMPILATION_CACHE_DIR=cache_dir)
            out = subprocess.run([sys.executable, "-c", code], cwd=root,
                                 env=env, capture_output=True, text=True,
                                 check=True)
            return float(out.stdout.strip().split()[-1])

        try:
            with tempfile.TemporaryDirectory() as cd:
                cold = run_once(cd)
                warm = run_once(cd)
        finally:
            if keep is not None:        # quick subprocess must not clobber
                with open(tables_json, "w") as f:
                    f.write(keep)
        tables = {"cold_s": round(cold, 1), "warm_jit_cache_s":
                  round(warm, 1)}
        rows.append(("paper_tables_quick_cold", cold * 1e6,
                     "subprocess, fresh jit cache"))
        rows.append(("paper_tables_quick_warm", warm * 1e6,
                     "subprocess, persistent jit cache"))

    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, "greedytl_incremental.json")
    payload = {}
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
    payload["description"] = (
        "Before/after record for the incremental-factor GreedyTL PR: the "
        "greedy while_loop carries the active-set Cholesky factor across "
        "accepted steps (border update) instead of refactorizing; "
        "'refactor' is the in-tree incremental=False oracle (the PR-2 "
        "path). Deep-accepting problem, cap=160, M=16, warm jit, CI-class "
        "container.")
    payload["refine_us_per_call"] = refine
    payload["dispatches_per_deep_refine"] = dispatches
    payload["autotune"] = {"backend": jax.default_backend(),
                           "key": kernel_ops.autotune_key(cap * C, M + C,
                                                          M),
                           "entry": entry}
    if tables is not None:
        payload["paper_tables_quick_wall_s"] = tables
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")

    # repo-level trajectory (pr1/pr2 history seeded from
    # results/benchmarks/greedytl_factorized.json)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    bench_path = os.path.join(root, "BENCH_greedytl.json")
    traj = {"description": (
        "paper_tables --quick wall-clock and deep-refine latency across "
        "PRs; updated by benchmarks/run.py bench_greedytl_incremental "
        "(bench-smoke CI refreshes the refine numbers; table timings come "
        "from full local runs)."), "trajectory": []}
    if os.path.exists(bench_path):
        with open(bench_path) as f:
            traj = json.load(f)
    deep = refine["k_max_16"]
    entry_row = {"label": "pr7_incremental_carry",
                 "deep_refine_us": deep["incremental_us"],
                 "deep_refine_speedup_vs_refactor": deep["speedup"],
                 "deep_refine_depth": deep["depth"]}
    if tables is not None:
        entry_row["paper_tables_quick_cold_s"] = tables["cold_s"]
        entry_row["paper_tables_quick_warm_s"] = tables["warm_jit_cache_s"]
    else:
        prev = {r["label"]: r for r in traj["trajectory"]}
        old = prev.get("pr7_incremental_carry", {})
        for k in ("paper_tables_quick_cold_s", "paper_tables_quick_warm_s"):
            if k in old:
                entry_row[k] = old[k]
    traj["trajectory"] = [r for r in traj["trajectory"]
                          if r["label"] != entry_row["label"]]
    traj["trajectory"].append(entry_row)
    with open(bench_path, "w") as f:
        json.dump(traj, f, indent=1)
        f.write("\n")
    return rows


def bench_fleet_engine(quick: bool):
    """Fleet vs loop engine: warm per-scenario wall-clock and per-window
    jitted dispatch counts (the fleet engine is O(1) per window)."""
    import dataclasses

    from repro.core import fleet, htl
    from repro.core.scenario import ScenarioConfig, run_sweep
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    windows = 6 if quick else 20
    rows = []
    for algo in ("star", "a2a"):
        base = ScenarioConfig(windows=windows, eval_every=windows, algo=algo,
                              tech="wifi")
        times = {}
        for engine in ("loop", "fleet"):
            cfgs = [dataclasses.replace(base, engine=engine, seed=s)
                    for s in (1, 2)]
            run_sweep(cfgs, data)       # warm the jit cache on these seeds
            t0 = time.time()
            run_sweep(cfgs, data)
            times[engine] = (time.time() - t0) / 2 * 1e6
        # dispatch count per window: loop pays one train + (a2a) one refine
        # per DC; fleet pays one of each per window regardless of fleet size
        counts = {"loop": 0, "fleet": 0}
        orig_train, orig_fleet = htl.train_svm, fleet.train_svm_fleet

        def count_loop(*a, **k):
            counts["loop"] += 1
            return orig_train(*a, **k)

        def count_fleet(*a, **k):
            counts["fleet"] += 1
            return orig_fleet(*a, **k)

        try:
            htl.train_svm, fleet.train_svm_fleet = count_loop, count_fleet
            run_sweep([dataclasses.replace(base, engine="loop", seed=3),
                       dataclasses.replace(base, engine="fleet", seed=3)],
                      data)
        finally:
            htl.train_svm, fleet.train_svm_fleet = orig_train, orig_fleet
        rows.append((f"scenario_{algo}_fleet", times["fleet"],
                     f"loop_us={times['loop']:.0f} "
                     f"speedup={times['loop'] / times['fleet']:.2f}x "
                     f"train_dispatches_loop={counts['loop']} "
                     f"fleet={counts['fleet']} ({windows} windows)"))
    return rows


def bench_stacked_sweep(quick: bool):
    """Replica-stacked sweep vs sequential per-seed runs (ROADMAP: batched
    multi-seed rounds) — same configs, same results, fewer dispatches."""
    import dataclasses

    from repro.core.dispatch import dispatch_counts, reset_dispatch_counts
    from repro.core.scenario import ScenarioConfig, run_sweep
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    windows = 6 if quick else 20
    base = ScenarioConfig(windows=windows, eval_every=windows, algo="a2a",
                          tech="wifi")
    cfgs = [dataclasses.replace(base, seed=s) for s in range(4)]
    rows = []
    run_sweep(cfgs, data, stack_seeds=True)        # warm the jit cache
    times, counts = {}, {}
    for label, stack in (("sequential", False), ("stacked", True)):
        reset_dispatch_counts()
        t0 = time.time()
        run_sweep(cfgs, data, stack_seeds=stack)
        times[label] = (time.time() - t0) * 1e6
        c = dispatch_counts()
        counts[label] = sum(v for k, v in c.items() if "fleet" in k)
    rows.append(("sweep_stacked_4seeds", times["stacked"],
                 f"sequential_us={times['sequential']:.0f} "
                 f"speedup={times['sequential'] / times['stacked']:.2f}x "
                 f"dispatches={counts['stacked']} "
                 f"vs {counts['sequential']} ({windows} windows)"))
    return rows


def bench_fleet_scaling(quick: bool):
    """Million-DC fleet engine (DESIGN.md §10): wall-clock and bytes/DC
    across fleet sizes, scan engine vs per-window execution. Two
    per-window comparators: the PR-1 fleet engine driven one window at a
    time (per-DC Python objects + O(L^2) pairwise ledger events — measured
    up to 10^3 DCs, quadratically extrapolated above, where a single
    window already costs minutes) and the host-driven city round
    (run_city_perwindow: host draw/pack/upload + one dispatch + one sync
    per window). Writes results/benchmarks/fleet_scaling.json."""
    import resource

    from benchmarks.paper_tables import RESULTS_DIR
    from repro.core.cityscan import (city_fleet_pad, run_city,
                                     run_city_perwindow)
    from repro.core.energy import Ledger
    from repro.core.fleet import run_window_star
    from repro.core.htl import DC
    from repro.core.scenario import ScenarioConfig
    from repro.data.synthetic_covtype import NUM_CLASSES, make_covtype_like

    data = make_covtype_like(seed=0)
    W = 3 if quick else 6
    sizes = (100, 1000, 10_000) if quick else (100, 1000, 10_000, 100_000)
    fleet_measure_max = 1000
    K, iters = 4, 6
    x = data.x_train.astype(np.float32)
    y = data.y_train.astype(np.int32)
    F = x.shape[1]

    def fleet_engine_window_s(L):
        rng = np.random.default_rng(0)
        idx = rng.integers(0, len(y), size=(L, K))
        dcs = [DC(f"SM{i + 1}", x[idx[i]], y[idx[i]]) for i in range(L)]

        def once(prev):
            return run_window_star(dcs, prev, Ledger(), "wifi", cap=160,
                                   num_classes=NUM_CLASSES,
                                   n_subsample=None,
                                   rng=np.random.default_rng(1))
        prev = once(None)                  # warm the jit at this shape
        t0 = time.time()
        once(prev)
        return time.time() - t0

    fleet_window_s = {}
    for L in sizes:
        if L <= fleet_measure_max:
            fleet_window_s[L] = (fleet_engine_window_s(L), True)
        else:
            # O(L^2) pairwise ledger events dominate: scale the largest
            # measured size quadratically (documented as extrapolated)
            base_L = max(k for k in fleet_window_s)
            base_s = fleet_window_s[base_L][0]
            fleet_window_s[L] = (base_s * (L / base_L) ** 2, False)

    rows = []
    per_size = {}
    for L in sizes:
        cfg = ScenarioConfig(windows=W, eval_every=1, algo="star",
                             engine="scan", tech="wifi", fleet_size=L,
                             obs_per_dc=K, train_iters=iters)
        run_city(cfg, data)                # warm (compile at this shape)
        t0 = time.time()
        r_scan = run_city(cfg, data)
        scan_s = time.time() - t0
        run_city_perwindow(cfg, data)
        t0 = time.time()
        run_city_perwindow(cfg, data)
        pw_s = time.time() - t0
        fw_s, measured = fleet_window_s[L]
        speedup_fleet = fw_s * W / scan_s
        per_size[str(L)] = {
            "padded_dcs": city_fleet_pad(L),
            "scan_wall_s": round(scan_s, 4),
            "scan_per_window_s": round(scan_s / W, 4),
            "perwindow_city_wall_s": round(pw_s, 4),
            "fleet_engine_window_s": round(fw_s, 4),
            "fleet_engine_measured": measured,
            "speedup_scan_vs_fleet_engine": round(speedup_fleet, 1),
            "speedup_scan_vs_perwindow_city": round(pw_s / scan_s, 2),
            "peak_rss_mb": round(
                resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0,
                1),
            "final_f1": round(r_scan.f1_curve[-1], 4),
        }
        tag = "" if measured else "(extrap)"
        rows.append((f"fleet_scaling_L{L}", scan_s * 1e6,
                     f"perwindow_s={pw_s:.2f} "
                     f"fleet_window_s={fw_s:.1f}{tag} "
                     f"speedup_vs_fleet={speedup_fleet:.0f}x "
                     f"({W} windows)"))

    payload = {
        "windows": W,
        "obs_per_dc": K,
        "train_iters": iters,
        "sizes": list(sizes),
        "per_size": per_size,
        # device-resident footprint per DC inside the scan (window block
        # x/y/m + base model) — constant across fleet sizes AND windows
        "scan_device_bytes_per_dc": 4 * (K * F + 2 * K
                                         + (F + 1) * NUM_CLASSES),
        # the per-window pattern re-uploads every DC's x/y/m each window
        "perwindow_upload_bytes_per_dc_per_window": 4 * (K * F + 2 * K),
        "note": "fleet_engine_window_s beyond 1000 DCs is extrapolated "
                "quadratically from the largest measured size (pairwise "
                "ledger events are O(L^2)); peak_rss_mb is the process "
                "high-water mark, sizes run in increasing order",
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "fleet_scaling.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return rows


def bench_sweep_api(quick: bool):
    """Experiment-API smoke + timing: a tiny ``SweepSpec`` preset end to
    end through ``SweepSpec.run``, asserting the ``SweepResult`` JSON
    round-trip and parity with the legacy ``run_sweep`` shim, then writing
    a timing row to results/benchmarks/sweep_api.json so the bench
    trajectory starts populating."""
    import numpy as np
    from benchmarks.paper_tables import RESULTS_DIR
    from repro.core.experiment import SweepResult, get_preset
    from repro.core.scenario import run_sweep
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    spec = get_preset("smoke", windows=4 if quick else 10)
    spec.run(data, stack="auto")                 # warm both jit paths
    spec.run(data, stack="off")
    t0 = time.time()
    result = spec.run(data, stack="auto")
    stacked_us = (time.time() - t0) * 1e6
    t0 = time.time()
    spec.run(data, stack="off")
    off_us = (time.time() - t0) * 1e6

    roundtrip = SweepResult.from_json(result.to_json())
    assert roundtrip == result, "SweepResult JSON round-trip drifted"

    # deprecation-shim parity: the same run list through legacy run_sweep
    legacy = run_sweep([c for _, c in spec.configs()], data,
                       stack_seeds=True)
    for rec, ref in zip(result.records, legacy):
        assert rec.f1_curve == list(ref.f1_curve)
        assert np.isclose(sum(e["mj"] for e in rec.events),
                          ref.energy_total)

    payload = {
        "preset": "smoke",
        "rows": len(spec.rows()),
        "runs": len(result.records),
        "windows": spec.configs()[0][1].windows,
        "stacked_us": round(stacked_us, 1),
        "sequential_us": round(off_us, 1),
        "labels": result.labels(),
        "converged_f1": {lbl: round(result.summary(lbl)["f1"], 4)
                         for lbl in result.labels()},
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "sweep_api.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return [("sweep_api_smoke", stacked_us,
             f"runs={payload['runs']} sequential_us={off_us:.0f} "
             f"json_roundtrip=ok shim_parity=ok")]


def bench_parallel_sweep(quick: bool):
    """Sharded sweep executor (DESIGN.md §7): partitioner balance on the
    full paper grid, bitwise parity of the devices backend, and the
    process backend's wall-clock speedup. n=1 vs n=2 worker pools share
    the same spawn/import/compile overhead structure, so their ratio is
    the genuine parallel speedup; the warm in-process sequential time is
    reported alongside for the overhead context."""
    from benchmarks.paper_tables import RESULTS_DIR
    from repro.core.experiment import get_preset
    from repro.core.parallel import partition_runs, run_cost
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    spec = get_preset("smoke", windows=4 if quick else 12)
    cfgs = [c for _, c in spec.configs()]

    ref = spec.run(data)                           # warm + parity reference
    t0 = time.time()
    seq_us = ((spec.run(data), time.time() - t0)[1]) * 1e6
    t0 = time.time()
    r_dev = spec.run(data, parallel="devices:n=8")
    dev_us = (time.time() - t0) * 1e6
    assert r_dev.to_json() == ref.to_json(), "devices backend parity drifted"

    t0 = time.time()
    r1 = spec.run(data, parallel="processes:n=1")
    p1_us = (time.time() - t0) * 1e6
    t0 = time.time()
    r2 = spec.run(data, parallel="processes:n=2")
    p2_us = (time.time() - t0) * 1e6
    assert r1.to_json() == ref.to_json(), "processes n=1 parity drifted"
    assert r2.to_json() == ref.to_json(), "processes n=2 parity drifted"
    speedup = p1_us / p2_us

    # partitioner balance on the full paper grid, 8 shards: max shard
    # cost over the achievable ideal max(total/n, largest atomic group) —
    # the same ideal the partitioner property test bounds against
    from repro.core.scenario import stack_groups
    grid = [c for _, c in get_preset("paper_tables").configs()]
    shards = partition_runs(grid, 8)
    costs = [sum(run_cost(grid[i]) for i in s) for s in shards]
    max_group = max(sum(run_cost(grid[i]) for i in g)
                    for g in stack_groups(grid))
    ideal = max(sum(costs) / len(shards), max_group)
    imbalance = max(costs) / ideal

    payload = {
        "preset": "smoke",
        "windows": cfgs[0].windows,
        "runs": len(cfgs),
        "sequential_warm_us": round(seq_us, 1),
        "devices_n8_us": round(dev_us, 1),
        "processes_n1_us": round(p1_us, 1),
        "processes_n2_us": round(p2_us, 1),
        "processes_speedup_n2_vs_n1": round(speedup, 3),
        "parity": "bitwise (JSON-identical across all backends)",
        "note": "speedup is compile/compute-bound by the host: tiny "
                "quick grids are dominated by per-worker jit compile, and "
                "XLA intra-op threading already spreads a sequential run "
                "over the cores, so small/low-core hosts sit near 1x; "
                "the backends target multi-device / many-core hosts",
        "paper_grid_shards8": {
            "groups": len(stack_groups(grid)),
            "nonempty_shards": len([s for s in shards if s]),
            "shard_costs": costs,
            "ideal_max_shard_cost": ideal,
            "balance_max_over_ideal": round(imbalance, 3),
        },
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "parallel_sweep.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return [
        ("parallel_sweep_processes2", p2_us,
         f"n1_us={p1_us:.0f} speedup={speedup:.2f}x "
         f"seq_warm_us={seq_us:.0f} parity=bitwise"),
        ("parallel_sweep_devices8", dev_us, "parity=bitwise (1 host dev "
         "unless XLA_FLAGS forces more)"),
        ("parallel_sweep_partition_paper8", 0.0,
         f"balance={imbalance:.3f}x_ideal "
         f"shard_costs={[int(c) for c in costs]}"),
    ]


def bench_hosts_launcher(quick: bool):
    """Multi-host launcher (DESIGN.md §8): local-channel dispatch timing
    (n=1 vs n=2 worker hosts share the same spawn/import/compile overhead
    structure, so their ratio is the genuine multi-host speedup), bitwise
    parity, and the wall-clock cost of surviving one SIGKILLed worker
    (retry overhead = fault run vs clean run at the same width)."""
    from benchmarks.paper_tables import RESULTS_DIR
    from repro.core.experiment import get_preset
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    spec = get_preset("smoke", windows=3 if quick else 8)
    ref = spec.run(data).to_json()                 # warm + parity reference

    timings = {}
    runs = {}
    grids = (("hosts_n1", "hosts:channel=local,n=1"),
             ("hosts_n2", "hosts:channel=local,n=2"),
             ("hosts_n2_fault",
              "hosts:channel=local,n=2,retries=1,backoff=0.01,"
              "inject_kill=0"))
    for label, backend in grids:
        t0 = time.time()
        runs[label] = spec.run(data, parallel=backend)
        timings[label] = (time.time() - t0) * 1e6
        assert runs[label].to_json() == ref, f"{label} parity drifted"
    fault_log = runs["hosts_n2_fault"].meta["launcher"]
    assert any(a["status"] == "crash"
               for s in fault_log["shards"] for a in s["attempts"]), \
        "fault run recorded no crash attempt"

    payload = {
        "preset": "smoke",
        "windows": spec.configs()[0][1].windows,
        "hosts_n1_us": round(timings["hosts_n1"], 1),
        "hosts_n2_us": round(timings["hosts_n2"], 1),
        "hosts_speedup_n2_vs_n1":
            round(timings["hosts_n1"] / timings["hosts_n2"], 3),
        "hosts_n2_fault_us": round(timings["hosts_n2_fault"], 1),
        "fault_overhead_vs_clean":
            round(timings["hosts_n2_fault"] / timings["hosts_n2"], 3),
        "fault_attempts": fault_log["attempts_total"],
        "parity": "bitwise (JSON-identical to sequential, clean and "
                  "under one injected worker SIGKILL)",
        "note": "local channel spawns a fresh interpreter per shard "
                "attempt, so quick grids are dominated by per-worker "
                "import+jit compile; the channel abstraction targets "
                "real multi-machine fleets (ssh/slurm)",
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "hosts_launcher.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return [
        ("hosts_launcher_n2", timings["hosts_n2"],
         f"n1_us={timings['hosts_n1']:.0f} "
         f"speedup={payload['hosts_speedup_n2_vs_n1']:.2f}x "
         f"parity=bitwise"),
        ("hosts_launcher_fault_retry", timings["hosts_n2_fault"],
         f"overhead={payload['fault_overhead_vs_clean']:.2f}x_clean "
         f"attempts={fault_log['attempts_total']} parity=bitwise"),
    ]


def bench_sweep_service(quick: bool):
    """Sweep service (DESIGN.md §12): what streaming, caching and the
    metrics plumbing actually buy/cost. Three headline numbers —
    time-to-first-shard over the stream vs the all-shards barrier of the
    launcher path (the latency the NDJSON stream removes), cold submit
    vs exact-cache-hit wall time, and the per-call overhead of the statsd
    counters the dispatch path now emits. Inline backend: shards run
    in-process, so the numbers measure the control plane, not worker
    spawn. Writes results/benchmarks/sweep_service.json."""
    import threading

    from benchmarks.paper_tables import RESULTS_DIR
    from repro.core.experiment import get_preset
    from repro.data.synthetic_covtype import make_covtype_like
    from repro.service.client import ServiceClient
    from repro.service.server import make_server
    from repro.service.statsd import Statsd

    data = make_covtype_like(seed=0)
    spec = get_preset("smoke", windows=3 if quick else 8)
    ref = spec.run(data).to_json()                 # warm + parity reference
    backend = "hosts:channel=inline,n=2"

    # barrier baseline (PR-5 path): nothing usable until every shard lands
    t0 = time.time()
    barrier = spec.run(data, parallel=backend)
    barrier_us = (time.time() - t0) * 1e6
    assert barrier.to_json() == ref, "barrier parity drifted"

    httpd, _service = make_server(backend=backend)
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    client = ServiceClient(httpd.server_address[:2])

    # cold streamed pass: time-to-first-shard and total, over real HTTP
    t0 = time.time()
    sub = client.submit(spec, data)
    first_shard_us = None
    for event in client.stream_events(sub["job"]):
        if event["event"] == "shard" and first_shard_us is None:
            first_shard_us = (time.time() - t0) * 1e6
    cold_us = (time.time() - t0) * 1e6
    assert client.result_text(sub["job"]) == ref, "service parity drifted"

    # exact-cache hit: same spec again, served bytes — no recompute
    t0 = time.time()
    hit = client.run(spec, data)
    hit_us = (time.time() - t0) * 1e6
    assert hit.meta["service"]["cached"], "second submit missed the cache"
    assert hit.to_json() == ref, "cache-hit parity drifted"
    httpd.shutdown()

    # statsd counter overhead (the per-attempt cost added to dispatch)
    sink = Statsd()
    n = 20_000
    t0 = time.time()
    for _ in range(n):
        sink.increment("bench.counter", tags={"kind": "ok"})
    statsd_us = (time.time() - t0) * 1e6 / n

    payload = {
        "preset": "smoke",
        "windows": spec.configs()[0][1].windows,
        "backend": backend,
        "barrier_total_us": round(barrier_us, 1),
        "stream_first_shard_us": round(first_shard_us, 1),
        "stream_total_us": round(cold_us, 1),
        "first_result_speedup_vs_barrier":
            round(barrier_us / first_shard_us, 3),
        "cache_hit_us": round(hit_us, 1),
        "cache_hit_speedup_vs_cold": round(cold_us / hit_us, 3),
        "statsd_increment_us": round(statsd_us, 3),
        "parity": "bitwise (streamed merge, cache hit and barrier all "
                  "JSON-identical to sequential)",
        "note": "inline backend isolates control-plane cost; "
                "time-to-first-shard is measured client-side over real "
                "HTTP from submit to the first NDJSON shard event",
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "sweep_service.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return [
        ("sweep_service_first_shard", first_shard_us,
         f"barrier_us={barrier_us:.0f} "
         f"speedup={payload['first_result_speedup_vs_barrier']:.2f}x "
         f"parity=bitwise"),
        ("sweep_service_cache_hit", hit_us,
         f"cold_us={cold_us:.0f} "
         f"speedup={payload['cache_hit_speedup_vs_cold']:.2f}x "
         f"parity=bitwise"),
        ("statsd_increment", statsd_us, f"n={n} tagged_counter"),
    ]


def bench_pareto(quick: bool):
    """Cost-accuracy Pareto auto-tuner (DESIGN.md §14): what halving
    pruning buys over the exhaustive grid. Runs the ``pareto`` preset
    through the exhaustive search (every candidate at full budget) and
    successive halving, reporting wall-clock and window-evaluation cost,
    recovered-frontier completeness (halving's frontier vs the
    exhaustive one), and the frontier itself (energy mJ vs F1 — the
    paper's 94%-for-2% story as a searched curve). Writes
    results/benchmarks/pareto.json."""
    from benchmarks.paper_tables import RESULTS_DIR
    from repro.core.experiment import get_preset
    from repro.core.pareto import get_search
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    spec = get_preset("pareto", windows=8 if quick else 24,
                      n_seeds=1 if quick else 2)
    searches = {"exhaustive": "exhaustive",
                "halving": "halving:rungs=3,keep=0.5"}
    results, walls = {}, {}
    for label, s in searches.items():
        search = get_search(s)
        search.run(spec, data)             # warm the jit at rung shapes
        t0 = time.time()
        results[label] = search.run(spec, data)
        walls[label] = (time.time() - t0) * 1e6

    ex, hv = results["exhaustive"], results["halving"]
    ex_front = ex.frontier_labels()
    recovered = [lbl for lbl in hv.frontier_labels() if lbl in ex_front]
    completeness = len(recovered) / len(ex_front)
    payload = {
        "preset": "pareto",
        "rows": len(spec.rows()),
        "windows": spec.rows()[0][1].windows,
        "seeds": max(1, len(spec.seeds)),
        "searches": searches,
        "exhaustive_wall_us": round(walls["exhaustive"], 1),
        "halving_wall_us": round(walls["halving"], 1),
        "halving_speedup": round(walls["exhaustive"] / walls["halving"],
                                 3),
        "halving_cost": hv.cost,
        "exhaustive_cost": ex.cost,
        "frontier_completeness": completeness,
        "frontier": [p.as_dict() for p in ex.frontier],
        "halving_frontier": [p.as_dict() for p in hv.frontier],
        "halving_ledger_counts": hv.dominated_counts(),
        "schedule": hv.schedule,
        "note": "completeness = |halving frontier ∩ exhaustive frontier|"
                " / |exhaustive frontier| (pareto-smoke gates it at 1.0 "
                "on the smoke budget); costs are window-evaluations "
                "including the final bitwise frontier rerun",
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "pareto.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return [
        ("pareto_halving", walls["halving"],
         f"exhaustive_us={walls['exhaustive']:.0f} "
         f"speedup={payload['halving_speedup']:.2f}x "
         f"completeness={completeness:.2f} "
         f"frontier={len(ex_front)}/{len(spec.rows())}"),
        ("pareto_frontier_cost", float(hv.cost["evals_windows"]),
         f"exhaustive_windows={hv.cost['exhaustive_windows']} "
         f"savings={hv.cost['savings_pct']}%"),
    ]


def bench_realism(quick: bool):
    """Realism axis (DESIGN.md §13): what churn, drift and byzantine
    collectors cost. Runs the fleet engine once per knob against a shared
    clean baseline and reports the F1/energy deltas plus the wall-clock
    overhead of each realism path (drift rewrites the stream host-side;
    churn adds a ledger sweep per window; trim swaps the combine). Writes
    results/benchmarks/realism.json."""
    import dataclasses

    from benchmarks.paper_tables import RESULTS_DIR
    from repro.core.scenario import ScenarioConfig, run_scenario
    from repro.data.mobility import generate_trace
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    W = 4 if quick else 10
    base_cfg = ScenarioConfig(windows=W, eval_every=1, algo="a2a",
                              tech="wifi", engine="fleet", seed=0)
    trace = generate_trace(os.path.join("results", "traces"), windows=W,
                           mules=6, sensors=36, seed=0)
    knobs = [
        ("baseline", {}),
        ("churn_batt12", {"battery_mj": 12.0}),
        ("drift_rotate_prior", {"drift": "rotate_prior"}),
        ("byz30_mean", {"byz_frac": 0.3}),
        ("byz30_trim25", {"byz_frac": 0.3,
                          "robust_agg": "trim:frac=0.25"}),
        ("mobility_trace", {"collection": f"trace_file:path={trace}"}),
    ]
    rows, per_knob = [], {}
    results = {}
    for name, kw in knobs:
        cfg = dataclasses.replace(base_cfg, **kw)
        run_scenario(cfg, data)            # warm the jit at this shape
        t0 = time.time()
        results[name] = run_scenario(cfg, data)
        per_knob[name] = {"wall_us": round((time.time() - t0) * 1e6, 1)}
    base = results["baseline"]
    for name, kw in knobs:
        r = results[name]
        churned = sum(1 for e in r.ledger.events
                      if e["purpose"] == "churn")
        per_knob[name].update({
            "final_f1": round(r.f1_curve[-1], 4),
            "f1_delta_vs_baseline": round(r.f1_curve[-1]
                                          - base.f1_curve[-1], 4),
            "energy_mj": round(r.energy_total, 1),
            "energy_delta_vs_baseline": round(r.energy_total
                                              - base.energy_total, 1),
            "churn_events": churned,
        })
        overhead = (per_knob[name]["wall_us"]
                    / per_knob["baseline"]["wall_us"])
        rows.append((f"realism_{name}", per_knob[name]["wall_us"],
                     f"f1={r.f1_curve[-1]:.3f} "
                     f"dE={per_knob[name]['energy_delta_vs_baseline']:+.1f}mJ "
                     f"churn={churned} overhead={overhead:.2f}x"))

    payload = {
        "windows": W,
        "base": {"algo": base_cfg.algo, "tech": base_cfg.tech,
                 "engine": base_cfg.engine, "seed": base_cfg.seed},
        "trace_file": trace,
        "per_knob": per_knob,
        "note": "wall_us is one warm run_scenario call; deltas are "
                "against the clean baseline row at the same windows/seed "
                "(negative churn energy delta = depleted mules stopped "
                "spending; trim vs mean shows the robust-combine recovery "
                "under 30% mislabelled collection)",
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "realism.json"), "w") as f:
        json.dump(payload, f, indent=1)
    return rows


def bench_htl_trainer(quick: bool):
    """Paper's technique at LM scale: DCN traffic vs sync baseline."""
    import dataclasses

    import jax
    from repro.configs import get_config
    from repro.configs.base import HTLConfig, OptimizerConfig
    from repro.core.htl_trainer import HTLTrainer
    from repro.models import build_model

    cfg = get_config("llama3.2-3b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=64, num_heads=2,
                              num_kv_heads=2, head_dim=32, d_ff=128,
                              vocab_size=256)
    model = build_model(cfg)
    rows = []
    for mode in ("a2a", "star"):
        for H in (8, 32):
            htl = HTLConfig(mode=mode, num_collectors=4, local_steps=H)
            tr = HTLTrainer(model, OptimizerConfig(), htl)
            t = tr.round_traffic_bytes()
            rows.append((f"htl_traffic_{mode}_H{H}", 0.0,
                         f"ratio_vs_sync={t['traffic_ratio_vs_sync']:.3f}"))
    return rows


def bench_dryrun_summary(quick: bool):
    """Roofline headline numbers from the cached dry-run records."""
    from repro.roofline.report import analyze, load_records
    d = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
    rows = []
    if not os.path.isdir(d):
        return [("dryrun_summary", 0.0, "no dry-run cache; run "
                 "python -m repro.launch.dryrun --all")]
    recs = [r for r in load_records(d) if r["status"] == "ok"]
    doms = {}
    for r in recs:
        a = analyze(r)
        doms[a["dominant"]] = doms.get(a["dominant"], 0) + 1
    rows.append(("dryrun_combos_ok", 0.0, f"n={len(recs)} dominant={doms}"))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-tables", action="store_true")
    ap.add_argument("--engine", default="fleet", choices=("fleet", "loop"),
                    help="scenario learning-round engine for the tables")
    args, _ = ap.parse_known_args()

    print("name,us_per_call,derived")
    sections = [bench_sweep_api, bench_parallel_sweep,
                bench_hosts_launcher, bench_sweep_service, bench_greedytl,
                bench_greedytl_incremental,
                bench_fleet_engine, bench_stacked_sweep,
                bench_fleet_scaling, bench_realism, bench_pareto,
                bench_kernels,
                bench_htl_trainer, bench_dryrun_summary]
    if not args.skip_tables:
        sections.insert(
            0, functools.partial(bench_paper_tables, engine=args.engine))
    for fn in sections:
        try:
            for name, us, derived in fn(args.quick):
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:              # noqa: BLE001
            print(f"{getattr(fn, '__name__', 'bench_paper_tables')},0,"
                  f"ERROR:{e}")
            raise


if __name__ == "__main__":
    main()
