import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

# NOTE: deliberately NOT setting xla_force_host_platform_device_count here —
# smoke tests and benchmarks must see the real single CPU device; only
# repro/launch/dryrun.py (its own process) forces 512 placeholder devices.
