"""Optimizer: AdamW convergence, gradient clipping properties, schedule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.configs.base import OptimizerConfig
from repro.optim.adamw import (adamw_init, adamw_update, clip_by_global_norm,
                               global_norm)
from repro.optim.schedule import cosine_warmup_schedule


def test_adamw_minimizes_quadratic():
    cfg = OptimizerConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0,
                          warmup_steps=0, total_steps=100, min_lr_ratio=1.0)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for i in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, 0.1, cfg)
    assert float(loss(params)) < 1e-3


@given(scale=st.floats(min_value=0.01, max_value=1e4))
@settings(max_examples=30, deadline=None)
def test_clip_bounds_norm(scale):
    g = {"a": jnp.ones((4, 4)) * scale, "b": jnp.ones(7) * scale}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-4
    if float(norm) <= 1.0:       # no-op when already under the bound
        for x, y in zip(jax.tree.leaves(g), jax.tree.leaves(clipped)):
            np.testing.assert_allclose(x, y, rtol=1e-5)


def test_weight_decay_skips_vectors():
    cfg = OptimizerConfig(lr=0.1, weight_decay=1.0, grad_clip=0.0)
    params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones(2)}
    opt = adamw_init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = adamw_update(zero_g, opt, params, 0.1, cfg)
    assert float(jnp.max(jnp.abs(new["vec"] - 1.0))) < 1e-6   # no decay
    assert float(jnp.max(new["mat"])) < 1.0                    # decayed


def test_cosine_schedule_shape():
    cfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lr = cosine_warmup_schedule(cfg)
    assert float(lr(0)) == pytest.approx(0.0)
    assert float(lr(10)) == pytest.approx(1e-3, rel=0.02)
    assert float(lr(5)) == pytest.approx(5e-4, rel=0.02)
    assert float(lr(100)) == pytest.approx(1e-4, rel=0.05)
    # monotone decay after warmup
    vals = [float(lr(s)) for s in range(10, 101, 10)]
    assert all(a >= b - 1e-12 for a, b in zip(vals, vals[1:]))


def test_moments_are_float32():
    params = {"w": jnp.ones((2, 2), jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt.mu["w"].dtype == jnp.float32
    assert opt.nu["w"].dtype == jnp.float32
    cfg = OptimizerConfig()
    g = {"w": jnp.ones((2, 2), jnp.bfloat16)}
    new, opt2, _ = adamw_update(g, opt, params, 1e-3, cfg)
    assert new["w"].dtype == jnp.bfloat16       # params keep their dtype
    assert opt2.mu["w"].dtype == jnp.float32
