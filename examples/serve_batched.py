"""Batched serving example: prefill a batch of prompts and decode new tokens
with the fixed-buffer KV/state caches, on any assigned architecture.

    PYTHONPATH=src python examples/serve_batched.py --arch mamba2-1.3b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_config
from repro.data.pipeline import make_lm_batch
from repro.models import build_model
from repro.serving import ServeEngine, cache_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b", choices=ALL_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"arch={args.arch} (reduced: {cfg.num_layers}L x {cfg.d_model}) "
          f"family={cfg.family}")
    full = get_config(args.arch)
    print(f"full-config serve cache at 32k ctx, batch 128: "
          f"{cache_bytes(build_model(full), 128, 32768) / 2**30:.1f} GiB")

    batch = make_lm_batch(
        cfg.vocab_size, args.batch, args.prompt_len, d_model=cfg.d_model,
        frontend_tokens=(cfg.frontend.num_tokens if cfg.family == "vlm"
                         else 0),
        encoder_len=(cfg.encoder_seq_len if cfg.family == "audio" else 0))
    eng = ServeEngine(model, params, max_new_tokens=args.new_tokens)

    t0 = time.time()
    out = eng.generate(batch, temperature=args.temperature,
                       key=jax.random.PRNGKey(1))
    dt = time.time() - t0
    toks = np.asarray(out)
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    for i in range(min(2, args.batch)):
        print(f"  seq {i}: {toks[i].tolist()}")


if __name__ == "__main__":
    main()
