"""End-to-end driver (deliverable b): train a ~100M-parameter llama-style LM
for a few hundred steps, comparing synchronous data-parallel training with
the paper's hypothesis-transfer (A2AHTL/StarHTL) schedule, and report the
inter-collector traffic each spends.

    PYTHONPATH=src python examples/train_htl_lm.py --steps 200 [--small]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import HTLConfig, OptimizerConfig
from repro.core.htl_trainer import HTLTrainer
from repro.data.pipeline import TokenStream
from repro.models import build_model


def make_cfg(small: bool):
    cfg = get_config("llama3.2-3b")
    if small:
        return dataclasses.replace(
            cfg, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
            head_dim=32, d_ff=256, vocab_size=2048, remat="none",
            dtype="float32")
    # ~100M params: 12L x 768
    return dataclasses.replace(
        cfg, num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
        head_dim=64, d_ff=2048, vocab_size=32768, remat="none",
        dtype="float32")


def run(mode: str, cfg, steps: int, L: int, H: int, batch: int, seq: int,
        seed: int = 0):
    model = build_model(cfg)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=steps)
    htl = HTLConfig(mode=mode, num_collectors=L, local_steps=H,
                    mixing_steps=4)
    tr = HTLTrainer(model, opt, htl)
    state = tr.init(jax.random.PRNGKey(seed))
    stream = TokenStream(cfg.vocab_size, seed=seed)
    local = jax.jit(tr.local_phase)
    transfer = jax.jit(tr.transfer_phase)

    def batches(h, b):
        if mode == "sync":
            toks = np.stack([stream.tokens(b * (seq + 1)).reshape(b, seq + 1)
                             for _ in range(h)])
            return {"tokens": jnp.asarray(toks[..., :-1]),
                    "targets": jnp.asarray(toks[..., 1:])}
        toks = np.stack([stream.tokens(L * b * (seq + 1))
                         .reshape(L, b, seq + 1) for _ in range(h)])
        return {"tokens": jnp.asarray(toks[..., :-1]),
                "targets": jnp.asarray(toks[..., 1:])}

    per_dc = batch if mode == "sync" else max(1, batch // L)
    rounds = steps // H
    losses = []
    t0 = time.time()
    for r in range(rounds):
        state, ls = local(state, batches(H, per_dc))
        if mode != "sync":
            state = transfer(state, jax.tree.map(lambda x: x[0],
                                                 batches(1, per_dc)))
        losses.append(float(np.asarray(ls).mean()))
        if (r + 1) % max(1, rounds // 10) == 0:
            print(f"  [{mode:4s}] round {r + 1:3d}/{rounds} "
                  f"loss {losses[-1]:.4f} "
                  f"({(time.time() - t0) / (r + 1):.1f}s/round)", flush=True)
    traffic = tr.round_traffic_bytes()
    total_dcn = traffic["htl_round_bytes"] * rounds if mode != "sync" \
        else traffic["sync_bytes_same_steps"] * rounds
    return losses, total_dcn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--collectors", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true",
                    help="tiny model for a fast demo")
    args = ap.parse_args()

    cfg = make_cfg(args.small)
    from repro.sharding.partitioning import template_bytes
    from repro.models import build_model as _bm
    nparams = template_bytes(_bm(cfg).template(), jnp.dtype("float32")) // 4
    print(f"model: {nparams / 1e6:.1f}M params "
          f"({cfg.num_layers}L x {cfg.d_model})")

    results = {}
    for mode in ("sync", "star", "a2a"):
        print(f"-- mode={mode}")
        losses, dcn = run(mode, cfg, args.steps, args.collectors,
                          args.local_steps, args.batch, args.seq)
        results[mode] = (losses[-1], dcn)

    print("\nmode   final-loss   inter-collector-bytes")
    sync_dcn = results["sync"][1]
    for mode, (loss, dcn) in results.items():
        print(f"{mode:5s}  {loss:10.4f}   {dcn:12.3e}  "
              f"({dcn / sync_dcn:5.2f}x of sync)")


if __name__ == "__main__":
    main()
