from repro.serving.cache_utils import pad_cache, cache_bytes  # noqa: F401
from repro.serving.engine import ServeEngine  # noqa: F401
