#!/usr/bin/env python
"""Regenerate tests/golden/smoke_golden.json (the golden-value fixture).

Only run this to bless an INTENTIONAL numeric change — the whole point of
the fixture is that accidental drift fails tests/test_golden_tables.py.

    PYTHONPATH=src python tests/golden/regen_smoke_golden.py
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

WINDOWS, N_SEEDS, DATA_SEED = 4, 2, 0


def main() -> None:
    from repro.core.experiment import get_preset
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=DATA_SEED)
    spec = get_preset("smoke", windows=WINDOWS, n_seeds=N_SEEDS)
    res = spec.run(data)
    payload = {
        "preset": "smoke",
        "windows": WINDOWS,
        "n_seeds": N_SEEDS,
        "data_seed": DATA_SEED,
        "n_runs": len(res.records),
        "per_label": {
            lbl: {k: res.summary(lbl)[k]
                  for k in ("f1", "f1_curve", "energy_mj",
                            "collection_mj", "learning_mj")}
            for lbl in res.labels()
        },
        "per_run_final_f1": [
            {"label": r.label, "seed": r.cfg.seed,
             "final_f1": float(r.f1_curve[-1])}
            for r in res.records
        ],
    }
    out = os.path.join(os.path.dirname(__file__), "smoke_golden.json")
    with open(out, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"wrote {out}: {len(res.records)} runs, "
          f"labels={res.labels()}")


if __name__ == "__main__":
    main()
