"""Training step factory + CPU-scale training driver.

``make_train_step`` builds the jit-able update used both by the multi-pod
dry-run (AOT lower+compile) and the runnable examples. The HTL trainer
(`repro.core.htl_trainer`) wraps the same step with hypothesis-transfer
rounds.
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig, get_config
from repro.data.pipeline import TokenStream
from repro.models.model import Model, build_model
from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.schedule import cosine_warmup_schedule


def make_train_step(model: Model, opt_cfg: OptimizerConfig):
    sched = cosine_warmup_schedule(opt_cfg)

    def train_step(params, opt_state, batch, step):
        (_, metrics), grads = jax.value_and_grad(
            model.loss_fn, has_aux=True)(params, batch)
        lr = sched(step)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params, lr,
                                                opt_cfg)
        metrics = dict(metrics)
        metrics["gnorm"] = gnorm
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step


def train_loop(arch: str, *, steps: int = 100, batch: int = 8,
               seq_len: int = 256, reduced: bool = True, seed: int = 0,
               log_every: int = 10, opt_cfg: OptimizerConfig = None,
               ckpt_dir: str = None, ckpt_every: int = 0):
    """Runnable single-host training loop (examples / integration tests).

    With ``ckpt_dir`` set, saves params+opt periodically and resumes from the
    latest checkpoint on restart.
    """
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    opt_cfg = opt_cfg or OptimizerConfig(lr=1e-3, warmup_steps=20,
                                         total_steps=steps)
    params = model.init(jax.random.PRNGKey(seed))
    opt_state = adamw_init(params)
    start = 0
    if ckpt_dir:
        from repro.checkpoint import load_checkpoint
        from repro.checkpoint.checkpointer import checkpoint_step
        prev = checkpoint_step(ckpt_dir)
        if prev is not None:
            state = load_checkpoint(ckpt_dir,
                                    {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = prev
            print(f"resumed from step {start}")
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))

    stream = TokenStream(cfg.vocab_size, seed=seed + start)
    it = stream.batches(batch, seq_len)
    history = []
    t0 = time.time()
    for i in range(start, steps):
        b = next(it)
        if cfg.family == "vlm":
            b["frontend_embeds"] = jnp.zeros(
                (batch, cfg.frontend.num_tokens, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            b["encoder_embeds"] = jnp.zeros(
                (batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
        params, opt_state, m = step_fn(params, opt_state, b,
                                       jnp.asarray(i, jnp.int32))
        if (i + 1) % log_every == 0 or i == start:
            loss = float(m["loss"])
            history.append(loss)
            print(f"step {i + 1:5d} loss {loss:.4f} "
                  f"({(time.time() - t0) / (i - start + 1) * 1e3:.0f} "
                  f"ms/step)")
        if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
            from repro.checkpoint import save_checkpoint
            save_checkpoint(ckpt_dir, {"params": params, "opt": opt_state},
                            step=i + 1)
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config")
    args = ap.parse_args()
    train_loop(args.arch, steps=args.steps, batch=args.batch,
               seq_len=args.seq_len, reduced=not args.full)


if __name__ == "__main__":
    main()
