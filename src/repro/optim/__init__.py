from repro.optim.adamw import (  # noqa: F401
    AdamWState,
    adamw_init,
    adamw_update,
    global_norm,
    sgd_update,
)
from repro.optim.schedule import cosine_warmup_schedule  # noqa: F401
