"""Synthetic CovType-like dataset (the real UCI dataset is a data gate —
this container is offline; see DESIGN.md §2 "Data gate").

Mimics the paper's preprocessed dataset: 54 features = 10 continuous
(cartographic) + 4 one-hot wilderness-area + 40 one-hot soil-type; 7 classes,
class-balanced (paper: 19 229 pts, ~2 700/class, 80/20 train/test split).

Class structure is calibrated so that a *linear* model saturates around
F1 ~ 0.6-0.65, matching the paper's reported centralised ceiling of 0.63:
continuous features are class-conditional Gaussians with heavy overlap, and
categorical features carry class-skewed (but noisy) distributions.
"""
from __future__ import annotations

from typing import Callable, Dict, NamedTuple, Tuple

import numpy as np

NUM_FEATURES = 54
NUM_CLASSES = 7
NUM_CONTINUOUS = 10
NUM_WILDERNESS = 4
NUM_SOIL = 40


class Dataset(NamedTuple):
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray


def make_covtype_like(n_total: int = 19229, seed: int = 0,
                      test_frac: float = 0.2,
                      class_sep: float = 1.05) -> Dataset:
    rng = np.random.default_rng(seed)
    per_class = n_total // NUM_CLASSES
    n_total = per_class * NUM_CLASSES

    # class means for continuous features; overlap controlled by class_sep
    means = rng.normal(0.0, class_sep, size=(NUM_CLASSES, NUM_CONTINUOUS))
    # shared anisotropic covariance (elevation-like dominant directions)
    scales = rng.uniform(0.6, 1.8, size=NUM_CONTINUOUS)

    # class-conditional categorical distributions, mixed with uniform noise so
    # a linear model cannot fully separate classes
    wild_p = rng.dirichlet(np.ones(NUM_WILDERNESS) * 0.6, size=NUM_CLASSES)
    wild_p = 0.6 * wild_p + 0.4 / NUM_WILDERNESS
    soil_p = rng.dirichlet(np.ones(NUM_SOIL) * 0.3, size=NUM_CLASSES)
    soil_p = 0.55 * soil_p + 0.45 / NUM_SOIL

    xs, ys = [], []
    for c in range(NUM_CLASSES):
        cont = means[c] + rng.normal(0, 1, (per_class, NUM_CONTINUOUS)) * scales
        wa = rng.choice(NUM_WILDERNESS, size=per_class, p=wild_p[c])
        st = rng.choice(NUM_SOIL, size=per_class, p=soil_p[c])
        wa_oh = np.eye(NUM_WILDERNESS, dtype=np.float64)[wa]
        st_oh = np.eye(NUM_SOIL, dtype=np.float64)[st]
        xs.append(np.concatenate([cont, wa_oh, st_oh], axis=1))
        ys.append(np.full(per_class, c, dtype=np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)

    perm = rng.permutation(n_total)
    x, y = x[perm], y[perm]
    # standardize continuous block (paper preprocesses cartographic features)
    mu = x[:, :NUM_CONTINUOUS].mean(0)
    sd = x[:, :NUM_CONTINUOUS].std(0) + 1e-9
    x[:, :NUM_CONTINUOUS] = (x[:, :NUM_CONTINUOUS] - mu) / sd

    n_test = int(n_total * test_frac)
    return Dataset(x[n_test:], y[n_test:], x[:n_test], y[:n_test])


# ---------------------------------------------------------------------------
# Concept drift (DESIGN.md §13). A drift transform rewrites the *stream* a
# scenario draws — never the train/test pools — as a pure function of
# (stream, windows, obs_per_window, seed), so every engine that builds the
# same stream sees the same drifted stream (fleet/scan/city parity by
# construction). Drift randomness comes from its own `default_rng([seed,
# const])` streams: the scenario's main rng is never consumed, so
# `drift="none"` configs remain bitwise identical to pre-drift builds.
#
# Two paper-motivated schedules, addressable by spec string (grammar in
# repro.core.registry, like transports/collection policies):
#
# * ``rotate[:rate=R]`` — gradual covariate drift: the standardized
#   continuous block rotates in a fixed random 2-plane by angle ``R * t`` at
#   window ``t`` (norms preserved; the one-hot blocks are untouched, keeping
#   them valid one-hots).
# * ``prior[:at=A,gamma=G]`` — abrupt label-prior shift: from window
#   ``floor(A * windows)`` on, the stream is resampled (with replacement,
#   from the same drawn stream segment) under class weights ``G ** y`` —
#   G < 1 tilts the prior towards low class ids.
# * ``rotate_prior[:rate=,at=,gamma=]`` — both, rotation applied first.
# ---------------------------------------------------------------------------

DriftFn = Callable[[np.ndarray, np.ndarray, int, int, int],
                   Tuple[np.ndarray, np.ndarray]]


def _rotate_drift(rate: float = 0.05) -> DriftFn:
    if not 0.0 <= rate <= np.pi:
        raise ValueError(f"rotation rate must be in [0, pi] rad/window, "
                         f"got {rate}")

    def drift(x, y, windows, obs_per_window, seed):
        drng = np.random.default_rng([int(seed), 0xD21F7])
        u = drng.normal(size=NUM_CONTINUOUS)
        u /= np.linalg.norm(u)
        v = drng.normal(size=NUM_CONTINUOUS)
        v -= u * (u @ v)
        v /= np.linalg.norm(v)
        x = np.array(x, np.float64, copy=True)
        block = x[:, :NUM_CONTINUOUS]
        a, b = block @ u, block @ v
        t = np.repeat(np.arange(windows, dtype=np.float64),
                      obs_per_window)[:len(x)]
        cos, sin = np.cos(rate * t), np.sin(rate * t)
        block += ((a * (cos - 1.0) - b * sin)[:, None] * u
                  + (a * sin + b * (cos - 1.0))[:, None] * v)
        x[:, :NUM_CONTINUOUS] = block
        return x, y
    return drift


def _prior_drift(at: float = 0.5, gamma: float = 0.5) -> DriftFn:
    if not 0.0 <= at <= 1.0:
        raise ValueError(f"prior-shift onset must be in [0, 1], got {at}")
    if not gamma > 0.0:
        raise ValueError(f"prior-shift gamma must be positive, got {gamma}")

    def drift(x, y, windows, obs_per_window, seed):
        cut = int(at * windows) * obs_per_window
        if cut >= len(x) or gamma == 1.0:
            return x, y
        drng = np.random.default_rng([int(seed), 0xD21F8])
        w = gamma ** np.asarray(y[cut:], np.float64)
        idx = cut + drng.choice(len(x) - cut, size=len(x) - cut,
                                replace=True, p=w / w.sum())
        x = np.concatenate([x[:cut], x[idx]])
        y = np.concatenate([y[:cut], y[idx]])
        return x, y
    return drift


def _rotate_prior_drift(rate: float = 0.05, at: float = 0.5,
                        gamma: float = 0.5) -> DriftFn:
    rot, pri = _rotate_drift(rate), _prior_drift(at, gamma)

    def drift(x, y, windows, obs_per_window, seed):
        x, y = rot(x, y, windows, obs_per_window, seed)
        return pri(x, y, windows, obs_per_window, seed)
    return drift


def _no_drift() -> DriftFn:
    return lambda x, y, windows, obs_per_window, seed: (x, y)


DRIFT_FACTORIES: Dict[str, Callable[..., DriftFn]] = {
    "none": _no_drift,
    "rotate": _rotate_drift,
    "prior": _prior_drift,
    "rotate_prior": _rotate_prior_drift,
}

_DRIFT_CACHE: Dict[str, DriftFn] = {}


def register_drift(name: str, factory: Callable[..., DriftFn]) -> None:
    """Register a drift-schedule factory under a spec name."""
    # lazy import: repro.core.__init__ imports back into this module
    from repro.core.registry import register_factory
    register_factory(DRIFT_FACTORIES, name, factory, "drift schedule")


def get_drift(spec: str) -> DriftFn:
    """Resolve a drift spec string to a (cached) drift transform.
    Raises :class:`KeyError` on unknown names/parameters, so
    ``validate_config`` keeps its fail-fast contract."""
    from repro.core.registry import resolve_spec
    return resolve_spec(spec, DRIFT_FACTORIES, _DRIFT_CACHE,
                        "drift schedule")


def observation_bytes(label_bytes: int = 1, feature_bytes: int = 8) -> int:
    """Wire size of one observation: 54 float64 features + 1-byte label.

    Calibrated against the paper's Edge-Only benchmark (34 477 mJ over
    10 000 observations via NB-IoT) and mule-collection cost (1 728 mJ via
    802.15.4); see DESIGN.md §2.
    """
    return NUM_FEATURES * feature_bytes + label_bytes
