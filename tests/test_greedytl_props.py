"""Hypothesis property tests for GreedyTL (the paper's core learner)."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.greedytl import greedytl
from repro.core.svm import svm_scores

F, C, M_CAP = 54, 7, 16


def _run(x, y, n_src, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    n = len(y)
    cap = max(32, n)
    xp = np.zeros((cap, F), np.float32)
    xp[:n] = x
    yp = np.zeros(cap, np.int32)
    yp[:n] = y
    mp = np.zeros(cap, np.float32)
    mp[:n] = 1
    src = np.zeros((M_CAP, F + 1, C), np.float32)
    sm = np.zeros(M_CAP, np.float32)
    for i in range(n_src):
        src[i] = rng.normal(0, scale, (F + 1, C))
        sm[i] = 1
    w, sel = greedytl(jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mp),
                      jnp.asarray(src), jnp.asarray(sm), num_classes=C)
    return np.asarray(w), np.asarray(sel), src, sm


@given(n=st.integers(min_value=4, max_value=60),
       n_src=st.integers(min_value=0, max_value=8),
       seed=st.integers(min_value=0, max_value=100))
@settings(max_examples=15, deadline=None)
def test_output_always_finite(n, n_src, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, F)).astype(np.float32)
    y = rng.integers(0, C, n)
    w, sel, _, _ = _run(x, y, n_src, seed)
    assert np.isfinite(w).all()
    assert w.shape == (F + 1, C)
    # selection respects the validity mask
    assert (sel[n_src:] == 0).all()


@given(seed=st.integers(min_value=0, max_value=50))
@settings(max_examples=10, deadline=None)
def test_scale_invariance_of_sources(seed):
    """Source normalisation: scaling a source hypothesis by a constant must
    not change the collapsed model materially (alpha absorbs 1/s)."""
    rng = np.random.default_rng(seed)
    n = 40
    x = rng.normal(0, 1, (n, F)).astype(np.float32)
    y = rng.integers(0, C, n)
    w1, _, src, sm = _run(x, y, 1, seed, scale=1.0)
    # same source, scaled 100x
    cap = max(32, n)
    xp = np.zeros((cap, F), np.float32)
    xp[:n] = x
    yp = np.zeros(cap, np.int32)
    yp[:n] = y
    mp = np.zeros(cap, np.float32)
    mp[:n] = 1
    src2 = src.copy()
    src2[0] *= 100.0
    w2, _ = greedytl(jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mp),
                     jnp.asarray(src2), jnp.asarray(sm), num_classes=C)
    w2 = np.asarray(w2)
    # predictions on the training data agree
    p1 = np.asarray(svm_scores(jnp.asarray(w1), jnp.asarray(x)))
    p2 = np.asarray(svm_scores(jnp.asarray(w2), jnp.asarray(x)))
    assert np.allclose(p1, p2, atol=0.2, rtol=0.1)


def test_perfect_source_dominates():
    """If a source already classifies the local data perfectly, GreedyTL
    must produce a model at least as accurate on that data."""
    rng = np.random.default_rng(3)
    n = 60
    w_true = rng.normal(0, 1, (F + 1, C)).astype(np.float32)
    x = rng.normal(0, 1, (n, F)).astype(np.float32)
    y = np.asarray(jnp.argmax(svm_scores(jnp.asarray(w_true),
                                         jnp.asarray(x)), -1))
    cap = 64
    xp = np.zeros((cap, F), np.float32)
    xp[:n] = x
    yp = np.zeros(cap, np.int32)
    yp[:n] = y
    mp = np.zeros(cap, np.float32)
    mp[:n] = 1
    src = np.zeros((M_CAP, F + 1, C), np.float32)
    sm = np.zeros(M_CAP, np.float32)
    src[0] = w_true
    sm[0] = 1
    w, sel = greedytl(jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mp),
                      jnp.asarray(src), jnp.asarray(sm), num_classes=C,
                      lam_bias=50.0)
    assert bool(np.asarray(sel)[0])
    pred = np.asarray(jnp.argmax(svm_scores(w, jnp.asarray(x)), -1))
    # scalar-alpha + gated correction recovers most (not all) of a perfect
    # source's boundary on 60 random-label points
    assert (pred == y).mean() > 0.85
