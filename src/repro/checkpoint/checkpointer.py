"""Minimal sharding-aware checkpointer (msgpack index + npz payloads).

No orbax in this environment. Layout:
    <dir>/index.msgpack   — treedef paths, shapes, dtypes, step metadata
    <dir>/arrays.npz      — flat arrays keyed by joined path

Arrays are gathered to host before saving (single-host container); the index
records the PartitionSpec string so a multi-host restore knows the intended
sharding.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import msgpack
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(ckpt_dir: str, tree: Any, step: int = 0,
                    pspecs: Any = None) -> None:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    index = {"step": step, "leaves": []}
    spec_leaves = None
    if pspecs is not None:
        spec_leaves = [s for _, s in jax.tree_util.tree_flatten_with_path(
            pspecs, is_leaf=lambda x: x is None or not isinstance(x, (dict, list, tuple))
        )[0]]
    for i, (path, leaf) in enumerate(leaves):
        key = _path_str(path)
        arr = np.asarray(jax.device_get(leaf))
        arrays[key] = arr
        index["leaves"].append({
            "path": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "pspec": str(spec_leaves[i]) if spec_leaves else "",
        })
    np.savez(os.path.join(ckpt_dir, "arrays.npz"), **arrays)
    with open(os.path.join(ckpt_dir, "index.msgpack"), "wb") as f:
        f.write(msgpack.packb(index))


def load_checkpoint(ckpt_dir: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (a pytree of arrays)."""
    with open(os.path.join(ckpt_dir, "index.msgpack"), "rb") as f:
        index = msgpack.unpackb(f.read())
    npz = np.load(os.path.join(ckpt_dir, "arrays.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in paths:
        key = _path_str(path)
        arr = npz[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch at {key}: ckpt {arr.shape} vs "
                             f"target {leaf.shape}")
        out.append(arr.astype(leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def checkpoint_step(ckpt_dir: str) -> Optional[int]:
    try:
        with open(os.path.join(ckpt_dir, "index.msgpack"), "rb") as f:
            return msgpack.unpackb(f.read())["step"]
    except FileNotFoundError:
        return None
