"""Deterministic synthetic token pipeline for LM training/serving.

Produces reproducible pseudo-text token streams (mixture of Zipf-distributed
unigrams with short-range Markov structure so the loss actually decreases),
plus batch sharding helpers used by the launcher.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class TokenStream:
    """Infinite reproducible token stream with learnable structure."""

    def __init__(self, vocab_size: int, seed: int = 0, order: int = 1,
                 zipf_a: float = 1.2, effective_vocab: int = 2048):
        self.vocab_size = vocab_size
        self.eff = min(effective_vocab, vocab_size)
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, self.eff + 1, dtype=np.float64)
        self.unigram = ranks ** (-zipf_a)
        self.unigram /= self.unigram.sum()
        # sparse bigram structure: each token has a few preferred successors
        self.succ = self.rng.integers(0, self.eff, size=(self.eff, 4))

    def tokens(self, n: int) -> np.ndarray:
        out = np.empty(n, dtype=np.int32)
        prev = int(self.rng.choice(self.eff, p=self.unigram))
        for i in range(n):
            if self.rng.random() < 0.5:
                prev = int(self.succ[prev, self.rng.integers(0, 4)])
            else:
                prev = int(self.rng.choice(self.eff, p=self.unigram))
            out[i] = prev
        return out

    def batches(self, batch: int, seq_len: int) -> Iterator[dict]:
        while True:
            toks = self.tokens(batch * (seq_len + 1)).reshape(batch, seq_len + 1)
            yield {"tokens": jnp.asarray(toks[:, :-1]),
                   "targets": jnp.asarray(toks[:, 1:])}


def make_lm_batch(vocab_size: int, batch: int, seq_len: int, seed: int = 0,
                  frontend_tokens: int = 0, d_model: int = 0,
                  encoder_len: int = 0) -> dict:
    """One concrete batch (used by smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, vocab_size, size=(batch, seq_len + 1), dtype=np.int32)
    out = {"tokens": jnp.asarray(toks[:, :-1]), "targets": jnp.asarray(toks[:, 1:])}
    if frontend_tokens and encoder_len == 0:
        out["frontend_embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, frontend_tokens, d_model)), jnp.float32)
    if encoder_len:
        out["encoder_embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, encoder_len, d_model)), jnp.float32)
    return out
