"""Canonical config hashing + the exact result cache (DESIGN.md §12).

The cache is only *exact* if the key is: ``SweepSpec.canonical_hash()``
must be stable across dict key order, process restarts (fresh
``PYTHONHASHSEED``) and wire round-trips, invariant to spec refactorings
that expand to the same physical run list — and distinct for any
axis-value, seed, variant or base-field change (the hypothesis property
here). ``ResultCache`` itself must return stored bytes verbatim, spill
to disk atomically, warm a restarted service from that directory, and
count hits/misses into statsd.
"""
import dataclasses
import json
import subprocess
import sys

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.experiment import SweepSpec, get_preset
from repro.core.launcher import encode_dataset
from repro.core.scenario import ScenarioConfig
from repro.data.synthetic_covtype import make_covtype_like
from repro.service.cache import ResultCache, cache_key, dataset_digest
from repro.service.statsd import statsd

TECHS = ("4g", "wifi", "ble", "mesh:hops=2")
ALGOS = ("star", "a2a")
P_EDGE = (0.0, 0.03, 0.15, 0.5)


def _spec(windows, algo_i, n_techs, p_i, n_seeds, aggregate):
    base = ScenarioConfig(windows=windows, eval_every=1,
                          algo=ALGOS[algo_i % len(ALGOS)],
                          p_edge=P_EDGE[p_i % len(P_EDGE)],
                          aggregate=bool(aggregate))
    return SweepSpec("prop", base=base,
                     axes={"tech": TECHS[:1 + n_techs % len(TECHS)]},
                     label="t_{tech}").with_seeds(1 + n_seeds % 3)


SPEC_ARGS = dict(windows=st.integers(min_value=2, max_value=6),
                 algo_i=st.integers(min_value=0, max_value=1),
                 n_techs=st.integers(min_value=0, max_value=3),
                 p_i=st.integers(min_value=0, max_value=3),
                 n_seeds=st.integers(min_value=0, max_value=2),
                 aggregate=st.integers(min_value=0, max_value=1))


# ---------------------------------------------------------------------------
# canonical hash: stability
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(**SPEC_ARGS)
def test_hash_stable_across_wire_roundtrip_and_key_order(
        windows, algo_i, n_techs, p_i, n_seeds, aggregate):
    """to_wire -> JSON text -> from_wire must preserve the hash; so must
    reconstructing every config dict with reversed key order (canonical
    JSON sorts keys, so dict order can never leak into the digest)."""
    spec = _spec(windows, algo_i, n_techs, p_i, n_seeds, aggregate)
    wire = json.loads(json.dumps(spec.to_wire()))
    assert SweepSpec.from_wire(wire).canonical_hash() == \
        spec.canonical_hash()
    scrambled = dict(wire, base=dict(reversed(list(wire["base"].items()))))
    assert SweepSpec.from_wire(scrambled).canonical_hash() == \
        spec.canonical_hash()


@settings(max_examples=10, deadline=None)
@given(**SPEC_ARGS)
def test_hash_invariant_to_equivalent_spec_refactoring(
        windows, algo_i, n_techs, p_i, n_seeds, aggregate):
    """One axis-spec vs a union of single-row specs that expands to the
    identical (label, config) list: same physical runs, same hash."""
    spec = _spec(windows, algo_i, n_techs, p_i, n_seeds, aggregate)
    parts = [SweepSpec("part", base=dataclasses.replace(spec.base, tech=t),
                       label=f"t_{t}")
             for t in TECHS[:1 + n_techs % len(TECHS)]]
    union = SweepSpec.union("prop", *parts, seeds=spec.seeds)
    assert union.configs() == spec.configs()
    assert union.canonical_hash() == spec.canonical_hash()


def test_hash_stable_across_process_restarts():
    """Two fresh interpreters with different hash seeds must agree with
    the in-process digest — nothing address- or hashseed-dependent may
    enter the canonical JSON."""
    import os

    prog = ("from repro.core.experiment import get_preset;"
            "print(get_preset('smoke', windows=3).canonical_hash())")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    outs = {
        subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True,
            check=True,
            env=dict(os.environ, PYTHONPATH=os.path.abspath(src),
                     PYTHONHASHSEED=seed)).stdout.strip()
        for seed in ("1", "4242")}
    assert outs == {get_preset("smoke", windows=3).canonical_hash()}


# ---------------------------------------------------------------------------
# canonical hash: sensitivity
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(**SPEC_ARGS)
def test_hash_distinct_for_any_axis_value_change(
        windows, algo_i, n_techs, p_i, n_seeds, aggregate):
    spec = _spec(windows, algo_i, n_techs, p_i, n_seeds, aggregate)
    h = spec.canonical_hash()
    # every single-knob perturbation must move the digest
    perturbed = [
        _spec(windows + 1, algo_i, n_techs, p_i, n_seeds, aggregate),
        _spec(windows, algo_i + 1, n_techs, p_i, n_seeds, aggregate),
        _spec(windows, algo_i, n_techs + 1, p_i, n_seeds, aggregate),
        _spec(windows, algo_i, n_techs, p_i + 1, n_seeds, aggregate),
        _spec(windows, algo_i, n_techs, p_i, n_seeds + 1, aggregate),
        _spec(windows, algo_i, n_techs, p_i, n_seeds, 1 - aggregate),
    ]
    assert all(p.canonical_hash() != h for p in perturbed)


def test_hash_sees_variants_and_labels():
    base = SweepSpec("v", axes={"tech": ("4g",)}, label="row_{tech}")
    relabeled = SweepSpec("v", axes={"tech": ("4g",)}, label="other_{tech}")
    with_variant = SweepSpec("v", axes={"tech": ("4g",)},
                             variants=(("row_{tech}", {}),
                                       ("row_{tech}_agg",
                                        {"aggregate": True})))
    hashes = {base.canonical_hash(), relabeled.canonical_hash(),
              with_variant.canonical_hash()}
    assert len(hashes) == 3


# ---------------------------------------------------------------------------
# dataset digest + composite key
# ---------------------------------------------------------------------------

def test_dataset_digest_tracks_the_bits():
    data = make_covtype_like(n_total=300, seed=0)
    enc = encode_dataset(data)
    assert dataset_digest(enc) == dataset_digest(
        encode_dataset(make_covtype_like(n_total=300, seed=0)))
    assert dataset_digest(enc) != dataset_digest(
        encode_dataset(make_covtype_like(n_total=300, seed=1)))


def test_cache_key_separates_every_component():
    keys = {cache_key("s1", "d1", "auto"), cache_key("s2", "d1", "auto"),
            cache_key("s1", "d2", "auto"), cache_key("s1", "d1", "off")}
    assert len(keys) == 4
    assert cache_key("s1", "d1", "auto") == cache_key("s1", "d1", "auto")


# ---------------------------------------------------------------------------
# ResultCache behavior
# ---------------------------------------------------------------------------

def test_cache_returns_stored_bytes_verbatim_and_counts():
    cache = ResultCache()
    text = '{"schema": 1, "name": "x", "records": []}\n  '
    hits0 = statsd.counter("service.cache.hit")
    misses0 = statsd.counter("service.cache.miss")
    assert cache.get("k") is None
    cache.put("k", text)
    assert cache.get("k") == text               # verbatim, whitespace too
    assert statsd.counter("service.cache.hit") == hits0 + 1
    assert statsd.counter("service.cache.miss") == misses0 + 1


def test_cache_spills_to_disk_and_warms_a_restart(tmp_path):
    d = str(tmp_path / "cache")
    first = ResultCache(directory=d)
    first.put("deadbeef", "payload-bytes")
    assert (tmp_path / "cache" / "deadbeef.json").read_text() == \
        "payload-bytes"
    # a "restarted service": fresh instance, same directory
    second = ResultCache(directory=d)
    assert len(second) == 0
    assert second.get("deadbeef") == "payload-bytes"
    assert len(second) == 1                     # re-cached in memory
    assert second.get("unknown") is None


def test_cache_evicts_true_lru_not_insertion_order():
    """A hit refreshes recency: the hottest key must survive eviction
    even when it was inserted first."""
    cache = ResultCache(max_entries=2)
    cache.put("k0", "v0")
    cache.put("k1", "v1")
    assert cache.get("k0") == "v0"              # k0 is now most-recent
    cache.put("k2", "v2")                       # evicts k1, NOT k0
    assert cache.get("k1") is None
    assert cache.get("k0") == "v0"
    assert cache.get("k2") == "v2"
    assert cache.stats()["entries"] == 2
    # a re-put of an existing key also refreshes recency
    cache.put("k0", "v0b")
    cache.put("k3", "v3")                       # evicts k2
    assert cache.get("k2") is None
    assert cache.get("k0") == "v0b"
    with pytest.raises(ValueError):
        ResultCache(max_entries=0)


def test_cache_disk_hit_counts_separately(tmp_path):
    cache = ResultCache(directory=str(tmp_path / "c"))
    cache.put("k", "v")
    warm = ResultCache(directory=str(tmp_path / "c"))
    hit0 = statsd.counter("service.cache.hit")
    disk0 = statsd.counter("service.cache.hit_disk")
    assert warm.get("k") == "v"                 # served from disk
    assert statsd.counter("service.cache.hit_disk") == disk0 + 1
    assert statsd.counter("service.cache.hit") == hit0
    assert warm.get("k") == "v"                 # now memory-resident
    assert statsd.counter("service.cache.hit") == hit0 + 1
    assert statsd.counter("service.cache.hit_disk") == disk0 + 1


def test_cache_concurrent_same_key_puts_never_serve_partials(tmp_path):
    """16 threads hammer put/get on the SAME key: every get (memory or
    disk path) must observe one of the exact written payloads, never a
    torn/partial file — the mkstemp-per-writer atomicity satellite."""
    import threading

    d = str(tmp_path / "c")
    cache = ResultCache(directory=d)
    payloads = [f"payload-{i:02d}-" + "x" * 4096 for i in range(16)]
    valid = set(payloads)
    errors = []
    barrier = threading.Barrier(16)

    def hammer(i):
        barrier.wait()
        try:
            for _ in range(25):
                cache.put("hot", payloads[i])
                got = cache.get("hot")
                if got not in valid:
                    errors.append(f"thread {i} read a torn value "
                                  f"({len(got or '')} bytes)")
                # fresh instance: forces the disk read path
                got = ResultCache(directory=d).get("hot")
                if got is not None and got not in valid:
                    errors.append(f"thread {i} read a torn FILE "
                                  f"({len(got)} bytes)")
        except Exception as e:                         # noqa: BLE001
            errors.append(f"thread {i}: {type(e).__name__}: {e}")

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(16)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    # no temp-file litter: every mkstemp file was renamed or unlinked
    import os
    leftovers = [f for f in os.listdir(d) if f.endswith(".tmp")]
    assert leftovers == []
    assert cache.get("hot") in valid
