"""Scan-over-windows engine (repro.core.cityscan): fleet-engine parity,
city-mode smoke + determinism, shard-count invariance (subprocess, 8 fake
devices), EvalCache keying isolation, and the exact equivalence of the
confusion-count metric forms used by the streamed eval."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.metrics import (confusion_counts, f_measure,
                                f_measure_from_confusion, precision,
                                precision_from_confusion, recall,
                                recall_from_confusion)
from repro.core.scenario import (EvalCache, ScenarioConfig, run_scenario,
                                 _eval_cache)
from repro.data.synthetic_covtype import make_covtype_like

REPO = os.path.join(os.path.dirname(__file__), "..")
DATA = make_covtype_like(seed=0)
W = 5


# ---------------------------------------------------------------------------
# scan engine == fleet engine (the PR-1 parity oracle): the ledger is
# host-replayed so it must be *exactly* equal, and the streamed confusion
# eval reproduces the fleet engine's F1 values exactly on these configs
# ---------------------------------------------------------------------------

PARITY_CFGS = [
    ScenarioConfig(windows=W, eval_every=1, algo="a2a", tech="wifi", seed=1),
    ScenarioConfig(windows=W, eval_every=1, algo="star", tech="wifi", seed=1),
    ScenarioConfig(windows=W, eval_every=1, algo="star", tech="mesh:hops=2",
                   seed=2, aggregate=True),
    ScenarioConfig(windows=W, eval_every=2, algo="a2a", tech="4g", seed=3,
                   n_subsample=5),
]


@pytest.mark.parametrize("cfg", PARITY_CFGS,
                         ids=lambda c: f"{c.algo}_{c.tech}_s{c.seed}")
def test_scan_matches_fleet_engine(cfg):
    ref = run_scenario(dataclasses.replace(cfg, engine="fleet"), DATA)
    got = run_scenario(dataclasses.replace(cfg, engine="scan"), DATA)
    assert got.ledger.events == ref.ledger.events
    assert got.f1_curve == ref.f1_curve


# ---------------------------------------------------------------------------
# city engine: smoke, determinism, O(1) ledger events per window
# ---------------------------------------------------------------------------

CITY = ScenarioConfig(windows=3, eval_every=1, algo="star", engine="scan",
                      tech="wifi", fleet_size=40, obs_per_dc=4,
                      train_iters=5)


def test_city_engine_smoke():
    r = run_scenario(CITY, DATA)
    assert len(r.f1_curve) == CITY.windows
    assert all(0.0 < v <= 1.0 for v in r.f1_curve)
    assert r.f1_curve[-1] > 0.25          # it actually learns
    # analytic energy: exactly 4 ledger events per window (collection +
    # entropy index + center id + model gather), never O(L^2)
    assert len(r.ledger.events) == 4 * CITY.windows
    assert r.energy_collection > 0 and r.energy_learning > 0


def test_city_engine_deterministic():
    a = run_scenario(CITY, DATA)
    b = run_scenario(CITY, DATA)
    assert a.f1_curve == b.f1_curve
    assert a.ledger.events == b.ledger.events


def test_city_perwindow_reference_runs():
    from repro.core.cityscan import run_city_perwindow
    r = run_city_perwindow(CITY, DATA)
    assert len(r.f1_curve) == CITY.windows
    assert all(0.0 < v <= 1.0 for v in r.f1_curve)
    assert len(r.ledger.events) == 4 * CITY.windows


def test_city_mode_config_validation():
    with pytest.raises(ValueError, match="engine='scan'"):
        run_scenario(dataclasses.replace(CITY, engine="fleet",
                                         train_iters=200), DATA)
    with pytest.raises(ValueError, match="host-side collection"):
        run_scenario(dataclasses.replace(CITY, p_edge=0.5), DATA)
    with pytest.raises(ValueError, match=">= 2 DCs"):
        run_scenario(dataclasses.replace(CITY, fleet_size=1), DATA)


# ---------------------------------------------------------------------------
# shard-count invariance: sharded fleet rounds must match unsharded bitwise
# (one-hot psum + lexicographic election — DESIGN.md §10). The XLA fake-
# device flag must precede jax init, so the sweep owns its own process.
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax
    from repro.core.cityscan import run_city
    from repro.core.scenario import ScenarioConfig
    from repro.data.synthetic_covtype import make_covtype_like

    assert len(jax.devices()) == 8, jax.devices()
    data = make_covtype_like(seed=0)
    base = ScenarioConfig(windows=3, eval_every=1, algo="star",
                          engine="scan", tech="wifi", obs_per_dc=4,
                          train_iters=5)
    # padded caps 64 / 128 / 224: shard counts 2,4,8 all divide them
    for fleet_size, seed in ((40, 0), (100, 1), (200, 2)):
        cfg = dataclasses.replace(base, fleet_size=fleet_size, seed=seed)
        ref = run_city(cfg, data, max_shards=1)
        for shards in (2, 4, 8):
            got = run_city(cfg, data, max_shards=shards)
            assert got.f1_curve == ref.f1_curve, (fleet_size, shards)
            assert got.ledger.events == ref.ledger.events, \\
                (fleet_size, shards)
    print("SHARD-INVARIANCE-OK")
""")


@pytest.mark.slow
def test_city_sharded_bitwise_matches_unsharded_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "SHARD-INVARIANCE-OK" in proc.stdout


# ---------------------------------------------------------------------------
# EvalCache keying: (dataset, kind) entries must isolate — the scan
# engine's extra derivatives may never evict or shadow the fleet engine's
# test matrix, and re-running any engine must hit, not thrash
# ---------------------------------------------------------------------------

def test_evalcache_kind_keying_isolates_entries():
    import jax.numpy as jnp
    cache = EvalCache(maxsize=8)
    d1 = make_covtype_like(seed=11)
    d2 = make_covtype_like(seed=12)
    built = {}
    for i, data in enumerate((d1, d2)):
        for j, kind in enumerate(("test", "test_onehot", "train_x",
                                  "train_y")):
            built[(i, kind)] = cache.array(
                data, kind, lambda d, v=(i * 10 + j): jnp.full((3,), v))
    assert cache.misses == 8 and cache.hits == 0
    # second pass: every (dataset, kind) hits and returns the same buffer
    for i, data in enumerate((d1, d2)):
        for kind in ("test", "test_onehot", "train_x", "train_y"):
            again = cache.array(data, kind,
                                lambda d: pytest.fail("rebuilt on hit"))
            assert again is built[(i, kind)]
    assert cache.misses == 8 and cache.hits == 8


def test_evalcache_lru_bound_still_applies():
    import jax.numpy as jnp
    cache = EvalCache(maxsize=2)
    d = make_covtype_like(seed=13)
    for kind in ("a", "b", "c"):
        cache.array(d, kind, lambda _: jnp.zeros(1))
    assert len(cache) == 2                 # oldest kind evicted
    cache.array(d, "a", lambda _: jnp.zeros(1))
    assert cache.misses == 4               # 'a' was the evicted one


def test_scan_engine_reuses_fleet_test_matrix():
    """Cross-engine no-thrash regression: after a fleet run uploaded the
    test matrix, a scan run on the same dataset must only miss on its NEW
    kinds (the one-hot labels), hitting the shared 'test' entry."""
    data = make_covtype_like(seed=14)
    cfg = ScenarioConfig(windows=2, eval_every=1, algo="star", tech="wifi")
    run_scenario(cfg, data)                              # uploads 'test'
    h0, m0 = _eval_cache.hits, _eval_cache.misses
    run_scenario(dataclasses.replace(cfg, engine="scan"), data)
    assert _eval_cache.misses - m0 == 1                  # 'test_onehot' only
    assert _eval_cache.hits - h0 >= 1                    # 'test' reused


# ---------------------------------------------------------------------------
# streamed-eval metric forms: confusion-count forms are bitwise equal to
# the paper's label-array forms (integer/integer f64 divisions are exact)
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(pairs=st.lists(st.tuples(st.integers(min_value=0, max_value=6),
                                st.integers(min_value=0, max_value=6)),
                      min_size=1, max_size=200))
def test_confusion_forms_match_label_forms_bitwise(pairs):
    y_true = np.array([a for a, _ in pairs], np.int64)
    y_pred = np.array([b for _, b in pairs], np.int64)
    cm = confusion_counts(y_true, y_pred, 7)
    assert cm.sum() == len(pairs)
    assert precision_from_confusion(cm) == precision(y_true, y_pred)
    assert recall_from_confusion(cm) == recall(y_true, y_pred, 7)
    assert f_measure_from_confusion(cm) == f_measure(y_true, y_pred, 7)
