from repro.checkpoint.checkpointer import save_checkpoint, load_checkpoint  # noqa: F401
