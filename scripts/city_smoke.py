#!/usr/bin/env python
"""City-smoke CI gate: the 10^5-DC city preset must complete on 8 fake CPU
devices with peak memory independent of the window count.

The scan engine keeps per-window buffers scan-local, so doubling or
tripling ``windows`` must not grow peak RSS: the gate runs the preset at a
baseline window count first, then at the full window count, and asserts
the cumulative peak-RSS high-water mark barely moves (``ru_maxrss`` only
ever grows, so ordering baseline-first makes the ratio meaningful). A
per-window execution pattern — materializing ``(W, L, K, F)`` host blocks
or keeping per-window device buffers alive — fails the ratio.

    XLA_FLAGS="--xla_force_host_platform_device_count=8" \\
        python scripts/city_smoke.py --fleet-size 100000 --windows 6 \\
        --baseline-windows 2 --expect-devices 8

Wired into scripts/verify.sh and the CI ``city-smoke`` step.
"""
from __future__ import annotations

import argparse
import os
import resource
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fleet-size", type=int, default=100_000)
    ap.add_argument("--windows", type=int, default=6)
    ap.add_argument("--baseline-windows", type=int, default=2)
    ap.add_argument("--max-ratio", type=float, default=1.15,
                    help="allowed peak-RSS growth from baseline to full "
                         "window count")
    ap.add_argument("--expect-devices", type=int, default=0,
                    help="fail unless jax sees exactly this many devices "
                         "(guards the XLA_FLAGS fake-device recipe)")
    args = ap.parse_args()

    import jax

    from repro.core.dispatch import dispatch_counts, reset_dispatch_counts
    from repro.core.experiment import get_preset
    from repro.data.synthetic_covtype import make_covtype_like

    n_dev = len(jax.devices())
    print(f"devices={n_dev} backend={jax.default_backend()}")
    if args.expect_devices and n_dev != args.expect_devices:
        print(f"FAIL: expected {args.expect_devices} devices (did "
              f"XLA_FLAGS=--xla_force_host_platform_device_count get set "
              f"before jax initialized?)")
        return 1

    data = make_covtype_like(seed=0)
    curves = {}
    peaks = {}
    for w in (args.baseline_windows, args.windows):
        spec = get_preset("city", fleet_size=args.fleet_size, windows=w)
        reset_dispatch_counts()
        t0 = time.time()
        result = spec.run(data)
        dt = time.time() - t0
        counts = dispatch_counts()
        peaks[w] = peak_rss_mb()
        curves[w] = result.records[0].f1_curve
        print(f"windows={w}: {dt:.1f}s peak_rss={peaks[w]:.0f}MB "
              f"dispatches={counts} f1={[round(v, 3) for v in curves[w]]}")
        if counts.get("city_scan", 0) != 1:
            print(f"FAIL: expected exactly 1 city_scan dispatch, "
                  f"got {counts}")
            return 1

    rc = 0
    ratio = peaks[args.windows] / peaks[args.baseline_windows]
    if ratio > args.max_ratio:
        print(f"FAIL: peak RSS grew {ratio:.3f}x from "
              f"{args.baseline_windows} to {args.windows} windows "
              f"(allowed {args.max_ratio}x) — memory is not flat in the "
              f"window count")
        rc = 1
    full = curves[args.windows]
    if len(full) != args.windows or not all(0.0 < v <= 1.0 for v in full):
        print(f"FAIL: malformed F1 curve {full}")
        rc = 1
    if full[-1] < 0.15:
        print(f"FAIL: final F1 {full[-1]:.3f} below sanity floor — the "
              f"city fleet did not learn")
        rc = 1
    if rc == 0:
        print(f"city smoke: OK ({args.fleet_size} DCs, flat memory "
              f"{ratio:.3f}x <= {args.max_ratio}x, final F1 "
              f"{full[-1]:.3f})")
    return rc


if __name__ == "__main__":
    sys.exit(main())
