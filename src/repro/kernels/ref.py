"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
used by the shape/dtype sweep tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def mha_reference(q, k, v, *, causal=True, window=0, q_offset=0):
    """q: (B,H,Sq,d); k,v: (B,KV,Skv,d) -> (B,H,Sq,d). Exact softmax."""
    B, H, Sq, d = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def ssd_reference(x, dt, A, Bm, Cm):
    """Sequential SSD recurrence (the literal state-space form).

    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm, Cm: (B,S,N).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp                       # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(dtt * A)                    # (B,H)
        h = h * decay[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", xt * dtt[..., None], bt)
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2))
    hN, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), hN.astype(x.dtype)


def loo_trials_inv_reference(AtA, Aty, A_rm, y, rmask, cmask, lam_d, M):
    """Inverse-based greedy-trial scorer — the O(M·D³) formulation the
    Cholesky-bordering kernel replaces. For each candidate column j < M it
    solves the column-masked ridge over active ∪ {j} via ``jnp.linalg.inv``
    and returns the closed-form LOO SSE (M,). Ground truth for
    ``loo_trials`` / ``loo_trials_ref`` parity tests.
    """
    def one(j):
        cm = jnp.where(jnp.arange(cmask.shape[0]) == j, 1.0, cmask)
        cm2 = cm[:, None] * cm[None, :]
        G = AtA * cm2 + jnp.diag(lam_d)
        Ginv = jnp.linalg.inv(G)
        v = (Ginv @ (Aty * cm)) * cm
        resid = (A_rm @ v - y) * rmask
        h = jnp.sum((A_rm @ (Ginv * cm2)) * A_rm, axis=-1)
        loo = resid / jnp.maximum(1.0 - h, 0.1)
        return jnp.sum(loo ** 2)

    return jax.vmap(one)(jnp.arange(M))


def greedy_select_refactor_reference(AtA, Aty, A_rm, y, rmask, src_mask,
                                     lam_d, M, k_max=16):
    """Full-refactorization greedy source selection — the per-step O(M·D³)
    host loop the incremental factor carry replaces. Every step re-solves
    the column-masked ridge of active ∪ {j} for all candidates j via
    ``jnp.linalg.inv`` in float64 and accepts the best iff it improves the
    LOO SSE. Ground truth for the incremental-carry property suite.

    Returns (sel (M,) 0/1 numpy, objective trajectory [bias-only LOO,
    then the accepted objective after each greedy step]).
    """
    AtA, Aty, A_rm = (np.asarray(v, np.float64) for v in (AtA, Aty, A_rm))
    y, rmask, lam_d = (np.asarray(v, np.float64) for v in (y, rmask, lam_d))
    src_mask = np.asarray(src_mask, np.float64)
    D = AtA.shape[0]
    C = D - M

    def loo_full(cm):
        cm2 = cm[:, None] * cm[None, :]
        Ginv = np.linalg.inv(AtA * cm2 + np.diag(lam_d))
        v = (Ginv @ (Aty * cm)) * cm
        resid = (A_rm @ v - y) * rmask
        h = np.sum(((A_rm * cm) @ Ginv) * (A_rm * cm), axis=-1)
        loo = resid / np.maximum(1.0 - h, 0.1)
        return float(np.sum(loo ** 2))

    sel = np.zeros(M)
    best = loo_full(np.concatenate([np.zeros(M), np.ones(C)]))
    traj = [best]
    for _ in range(min(k_max, M)):
        objs = np.full(M, np.inf)
        for j in range(M):
            if sel[j] or not src_mask[j]:
                continue
            cm = np.concatenate([sel * src_mask, np.ones(C)])
            cm[j] = 1.0
            objs[j] = loo_full(cm)
        j = int(np.argmin(objs))
        if not np.isfinite(objs[j]) or objs[j] >= best:
            break
        sel[j] = 1.0
        best = objs[j]
        traj.append(best)
    return sel, traj


def rglru_reference(a, b, h0=None):
    """Sequential linear recurrence h_t = a_t h_{t-1} + b_t.

    a, b: (B,S,W) float32; h0: (B,W) or None.
    """
    B, S, W = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (a.astype(jnp.float32).transpose(1, 0, 2),
                          b.astype(jnp.float32).transpose(1, 0, 2)))
    return hs.transpose(1, 0, 2)
