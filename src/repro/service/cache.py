"""Exact sweep-result cache keyed by canonical config hash (DESIGN.md §12).

The repo's bitwise-determinism contract — a sweep's ``SweepResult`` JSON
is a pure function of (physical run list, dataset bytes, stack mode),
identical across backends, shard counts, retries and worker crashes — is
exactly the property that makes result caching *exact* rather than
approximate: serving the stored bytes IS re-running the sweep. The
service gate (scripts/service_parity.py) enforces this by diffing a
cache hit byte-for-byte against a fresh recomputation.

The key is a sha256 over the three inputs of that pure function:

* ``SweepSpec.canonical_hash()`` — the expanded run list as canonical
  JSON (sorted keys; invariant to dict key order, process restarts and
  spec refactorings that expand identically; distinct for any
  axis/seed/base change — property-tested in tests/test_service_cache.py);
* the dataset digest — sha256 over the base64 buffer payloads of the
  launcher wire codec (:func:`repro.core.launcher.encode_dataset`), i.e.
  over the exact float bits every worker decodes;
* the stack mode and the result-schema version (a schema bump must never
  serve bytes written by an older reader's layout).

Storage is an in-memory dict with an optional spill directory: entries
written as ``<key>.json`` (atomic rename), re-read on miss — so a
restarted service warms from disk, and two services sharing a directory
share a cache. Hit/miss/store counters feed ``service.cache.*`` in
:mod:`repro.service.statsd`.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import threading
from collections import OrderedDict
from typing import Any, Dict, Mapping, Optional

from repro.service.statsd import statsd

CACHE_SCHEMA = 1


def dataset_digest(encoded: Mapping[str, Any]) -> str:
    """sha256 of an encoded-dataset payload (wire codec of
    :mod:`repro.core.launcher`): hashes dtype/shape/base64 buffers in
    field order, so two datasets digest equal iff their bits are equal."""
    h = hashlib.sha256()
    for name in sorted(encoded["fields"]):
        f = encoded["fields"][name]
        h.update(name.encode())
        h.update(str(f["dtype"]).encode())
        h.update(str(f["shape"]).encode())
        h.update(f["b64"].encode())
    return h.hexdigest()


def cache_key(spec_hash: str, data_digest: str, stack: str, *,
              search: str = "") -> str:
    """The exact-result cache key: all inputs of the deterministic sweep
    function, plus the schema version. ``search`` is the *canonical*
    search spec for Pareto-search jobs (DESIGN.md §14) — a search's
    ``ParetoResult`` is a different pure function of the same grid, so
    it must never collide with the plain sweep's bytes. It only enters
    the hashed blob when non-empty, so every pre-search key is
    unchanged."""
    blob: Dict[str, Any] = {"schema": CACHE_SCHEMA, "spec": spec_hash,
                            "data": data_digest, "stack": stack}
    if search:
        blob["search"] = search
    text = json.dumps(blob, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(text.encode()).hexdigest()


class ResultCache:
    """Byte-exact result store: ``put`` the merged ``SweepResult`` JSON
    text, ``get`` it back verbatim. Thread-safe (the service's job threads
    store while request handlers look up). Memory entries are true-LRU
    (a hit refreshes recency, so the hottest key is the last evicted);
    hit telemetry distinguishes memory hits (``service.cache.hit``) from
    disk-warmed hits (``service.cache.hit_disk``)."""

    def __init__(self, directory: Optional[str] = None,
                 max_entries: int = 256):
        if max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.directory = directory
        self.max_entries = max_entries
        self._mem: "OrderedDict[str, str]" = OrderedDict()
        self._lock = threading.Lock()
        if directory:
            os.makedirs(directory, exist_ok=True)

    def __len__(self) -> int:
        with self._lock:
            return len(self._mem)

    def _path(self, key: str) -> str:
        return os.path.join(self.directory, f"{key}.json")

    def get(self, key: str) -> Optional[str]:
        with self._lock:
            text = self._mem.get(key)
            if text is not None:
                self._mem.move_to_end(key)      # true LRU: hits refresh
        if text is not None:
            statsd.increment("service.cache.hit")
            return text
        if self.directory:
            try:
                with open(self._path(key)) as f:
                    text = f.read()
            except OSError:
                text = None
            if text is not None:
                with self._lock:
                    self._remember(key, text)
                statsd.increment("service.cache.hit_disk")
                return text
        statsd.increment("service.cache.miss")
        return None

    def put(self, key: str, text: str) -> None:
        with self._lock:
            self._remember(key, text)
        if self.directory:
            # unique temp per writer: concurrent puts of the SAME key must
            # not share a temp path, or interleaved truncate/write/rename
            # can publish a partially-written file — each writer stages its
            # own file and the atomic rename decides the winner
            fd, tmp = tempfile.mkstemp(dir=self.directory,
                                       prefix=f".{key}.", suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(text)
                os.replace(tmp, self._path(key))  # readers never see partials
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
        statsd.increment("service.cache.store")

    def _remember(self, key: str, text: str) -> None:
        self._mem[key] = text
        self._mem.move_to_end(key)
        while len(self._mem) > self.max_entries:
            self._mem.popitem(last=False)

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"entries": len(self._mem),
                    "max_entries": self.max_entries,
                    "directory": self.directory}
