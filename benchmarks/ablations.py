"""Beyond-paper ablations on the faithful HTL layer.

1. Global-model update rate (our EMA interpretation of the paper's
   "update the model elaborated until the previous time slot").
2. Center-election policy for StarHTL (paper: max label entropy) vs
   max-data and random election.
3. Source-pool ablation: does including the previous global model as a
   GreedyTL source (the incremental mechanism) actually matter?
4. Collection-policy ablation: the paper's Poisson+Zipf arrivals vs the
   registry's uniform / trace-replay / bursty policies at fixed energy
   budget (same windows, same technologies).
5. Engine timing: the batched ``fleet`` engine (which ablations 1-2 run
   on — policies resolve through repro.core.htl at call time, so the
   monkey-patches apply to both engines) vs the per-DC ``loop`` reference,
   seeds replica-stacked vs sequential. Timings land in ablations.json.

Each sweep-shaped ablation is a declarative ``SweepSpec`` axis
(:mod:`repro.core.experiment`); the monkey-patched ones wrap a spec run
per policy variant.

    PYTHONPATH=src python -m benchmarks.ablations [--windows 40]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.paper_tables import RESULTS_DIR
from repro.core.experiment import SweepSpec
from repro.core.scenario import ScenarioConfig
from repro.data.synthetic_covtype import make_covtype_like


def _base(windows: int, **kw) -> ScenarioConfig:
    return ScenarioConfig(algo="star", tech="wifi", windows=windows,
                          eval_every=max(1, windows // 10), **kw)


def ema_ablation(data, windows, seeds=2):
    spec = SweepSpec("ema", base=_base(windows),
                     axes={"global_update_rate": (1.0, 0.5, 0.3, 0.15)},
                     label="eta={global_update_rate}").with_seeds(seeds)
    res = spec.run(data, stack="auto")
    return {lbl: round(res.summary(lbl)["f1"], 4) for lbl in res.labels()}


def collection_ablation(data, windows, seeds=2):
    """Arrival-process ablation over the collection-policy registry: the
    same scenario under Zipf, uniform, deterministic trace replay, and
    bursty arrivals."""
    spec = SweepSpec(
        "collection", base=_base(windows),
        axes={"collection": ("poisson_zipf", "uniform",
                             "trace:loads=60-25-15", "bursty:burst=8")},
        label="{collection}").with_seeds(seeds)
    res = spec.run(data, stack="auto")
    return {lbl: {"f1": round(res.summary(lbl)["f1"], 4),
                  "energy_mj": round(res.summary(lbl)["energy_mj"], 1)}
            for lbl in res.labels()}


def election_ablation(data, windows, seeds=2):
    """Entropy election vs alternatives (monkey-patched policy)."""
    import repro.core.htl as htl_mod
    orig = htl_mod.label_entropy
    out = {}

    policies = {
        "entropy (paper)": orig,
        "max-data": lambda y, k: float(len(y)),
        "random": lambda y, k: float(np.random.default_rng(len(y))
                                     .random()),
    }
    spec = SweepSpec("election", base=_base(windows)).with_seeds(seeds)
    try:
        for name, fn in policies.items():
            htl_mod.label_entropy = fn
            res = spec.run(data, stack="auto")
            out[name] = round(res.summary("election")["f1"], 4)
    finally:
        htl_mod.label_entropy = orig
    return out


def prev_model_source_ablation(data, windows, seeds=2):
    """Drop the previous global model from the GreedyTL source pool."""
    import repro.core.htl as htl_mod
    out = {}
    orig_refine = htl_mod._greedy_refine
    # _greedy_refine is a loop-engine internal; pin that engine
    spec = SweepSpec("prev_src",
                     base=_base(windows, engine="loop")).with_seeds(seeds)

    for label, drop in (("with prev-global source (ours)", False),
                        ("without prev-global source", True)):
        if drop:
            def patched(dc, sources, cap, num_classes):
                return orig_refine(dc, sources[:-1] if len(sources) > 1
                                   else sources, cap, num_classes)
            htl_mod._greedy_refine = patched
        try:
            res = spec.run(data, stack="off")
            out[label] = round(res.summary("prev_src")["f1"], 4)
        finally:
            htl_mod._greedy_refine = orig_refine
    return out


def engine_timing(data, windows, seeds=3):
    """Fleet vs loop engine wall-clock on the ablation workload (ROADMAP:
    drive the fleet path through the ablations too), and replica-stacked vs
    sequential seed handling for the fleet engine. Warm timings (the jit
    cache is shared across variants), F1 parity asserted as a side effect.
    """
    out = {}
    f1 = {}
    for engine, stack in (("fleet", "auto"), ("fleet", "off"),
                          ("loop", "off")):
        spec = SweepSpec(f"timing_{engine}",
                         base=_base(windows, engine=engine)
                         ).with_seeds(seeds)
        spec.run(data, stack=stack)               # warm the jit cache
        t0 = time.time()
        res = spec.run(data, stack=stack)
        label = f"{engine}_stacked" if stack == "auto" else engine
        out[f"{label}_s"] = round(time.time() - t0, 3)
        f1[label] = round(res.summary(f"timing_{engine}")["f1"], 4)
    out["fleet_speedup_vs_loop"] = round(out["loop_s"] / out["fleet_s"], 2)
    out["stacking_speedup"] = round(out["fleet_s"] / out["fleet_stacked_s"],
                                    2)
    assert abs(f1["fleet"] - f1["loop"]) < 1e-3, f1
    assert abs(f1["fleet"] - f1["fleet_stacked"]) < 1e-3, f1
    out["converged_f1"] = f1["fleet"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=40)
    args = ap.parse_args()
    data = make_covtype_like(seed=0)
    out = {
        "ema_rate": ema_ablation(data, args.windows),
        "collection_policy": collection_ablation(data, args.windows),
        "election": election_ablation(data, args.windows),
        "prev_model_source": prev_model_source_ablation(data, args.windows),
        "engine_timing": engine_timing(data, args.windows),
    }
    print(json.dumps(out, indent=1))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "ablations.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
