"""Spec-string grammar shared by the experiment-facing registries.

Transports, radio technologies and collection policies are all addressed by
*spec strings* of the form

    name
    name:key=value
    name:key=value,key2=value2

(DESIGN.md §5) so a whole experiment variant fits in one `ScenarioConfig`
string field and sweeps stay declarative — ``"mesh:hops=3"``,
``"lora:sf=12"``, ``"bursty:burst=8"``. This module owns the grammar:
:func:`parse_spec` splits a spec into ``(name, params)`` with numeric/bool
coercion, and :func:`format_spec` renders the canonical form back
(sorted keys), so ``format_spec(*parse_spec(s))`` is a stable round-trip
for any valid spec.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple


def _coerce(raw: str) -> Any:
    """int | float | bool | str, in that order of preference."""
    low = raw.strip().lower()
    if low in ("true", "false"):
        return low == "true"
    try:
        return int(raw)
    except ValueError:
        pass
    try:
        return float(raw)
    except ValueError:
        pass
    return raw.strip()


def parse_spec(spec: str) -> Tuple[str, Dict[str, Any]]:
    """``"mesh:hops=3,paywall=false"`` -> ``("mesh", {"hops": 3, ...})``.

    The bare form ``"mesh"`` parses to ``("mesh", {})``. Raises
    :class:`ValueError` on malformed parameter segments (missing ``=``,
    empty key), so registries can surface the offending spec verbatim.
    """
    if not isinstance(spec, str) or not spec.strip():
        raise ValueError(f"empty transport/policy spec: {spec!r}")
    name, sep, tail = spec.partition(":")
    name = name.strip()
    params: Dict[str, Any] = {}
    if sep and not tail.strip():
        raise ValueError(f"spec {spec!r} has a ':' but no parameters")
    if tail.strip():
        for part in tail.split(","):
            key, eq, val = part.partition("=")
            if not eq or not key.strip() or not val.strip():
                raise ValueError(
                    f"malformed parameter {part!r} in spec {spec!r} "
                    f"(expected key=value)")
            params[key.strip()] = _coerce(val)
    return name, params


def format_spec(name: str, params: Dict[str, Any] | None = None) -> str:
    """Canonical spec string: params sorted by key, bools lowercase."""
    if not params:
        return name
    def render(v: Any) -> str:
        if isinstance(v, bool):
            return "true" if v else "false"
        return str(v)
    body = ",".join(f"{k}={render(params[k])}" for k in sorted(params))
    return f"{name}:{body}"


def register_factory(registry: Dict[str, Any], name: str, factory: Any,
                     kind: str) -> None:
    """Shared registration rule: idempotent for the same factory object,
    :class:`ValueError` on a conflicting re-registration."""
    prev = registry.get(name)
    if prev is not None and prev is not factory:
        raise ValueError(f"{kind} {name!r} already registered")
    registry[name] = factory


def resolve_spec(spec: str, factories: Dict[str, Any],
                 cache: Dict[str, Any], kind: str) -> Any:
    """Shared spec-string resolution: parse → look up factory → construct
    with the params as kwargs → cache under both the given and the
    canonical spelling. Unknown names, malformed specs and unknown
    parameter *names* raise :class:`KeyError` (fail-fast registries);
    invalid parameter *values* propagate as the factory's
    :class:`ValueError`."""
    obj = cache.get(spec)
    if obj is not None:
        return obj
    try:
        name, params = parse_spec(spec)
    except ValueError as e:
        raise KeyError(str(e)) from e
    factory = factories.get(name)
    if factory is None:
        raise KeyError(f"no {kind} registered for {spec!r}; known: "
                       f"{sorted(factories)}")
    try:
        obj = factory(**params)
    except TypeError as e:
        raise KeyError(f"bad parameters for {kind} {spec!r}: {e}") from e
    cache[spec] = obj
    cache.setdefault(format_spec(name, params), obj)
    return obj
