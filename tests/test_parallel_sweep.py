"""Parallel sweep executor: backend parity (the bitwise contract),
partitioner properties, per-shard dispatch accounting, and the
process-pool isolation guards (DESIGN.md §7).

The hard promise under test: ``parallel="devices:n=K"`` and
``parallel="processes:n=K"`` may never change a published number — their
``SweepResult`` JSON must be byte-identical to the sequential run's.
"""
import os
import pickle
import subprocess
import sys

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import scenario
from repro.core.dispatch import dispatch_counts, reset_dispatch_counts
from repro.core.experiment import get_preset
from repro.core.parallel import (EXECUTORS, assert_host_only, get_executor,
                                 partition_runs, run_cost)
from repro.core.scenario import ScenarioConfig, stack_groups, stack_key
from repro.data.synthetic_covtype import make_covtype_like

REPO = os.path.join(os.path.dirname(__file__), "..")
DATA = make_covtype_like(seed=0)

FLEET_ENTRIES = ("train_svm", "train_svm_fleet", "greedytl",
                 "greedytl_fleet", "greedytl_fleet_stacked")


def _fleet_counts():
    c = dispatch_counts()
    return {k: c.get(k, 0) for k in FLEET_ENTRIES}


# ---------------------------------------------------------------------------
# backend parity: serialized results must be byte-identical
# ---------------------------------------------------------------------------

def test_smoke_parity_devices_backend_both_stack_modes():
    spec = get_preset("smoke", windows=4)
    for stack in ("auto", "off"):
        ref = spec.run(DATA, stack=stack).to_json()
        got = spec.run(DATA, stack=stack, parallel="devices:n=8").to_json()
        assert got == ref, f"devices backend drifted (stack={stack})"


def test_smoke_parity_processes_backend_and_dispatch_merge():
    """One process-pool run checks three contracts at once: JSON parity
    with the sequential run, worker dispatch counts merged back equal to
    the sequential counts (same groups -> same jitted calls, so the
    per-shard dispatch gate holds), and the parent's EvalCache untouched
    (workers evaluate in their own processes)."""
    spec = get_preset("smoke", windows=3)
    reset_dispatch_counts()
    ref = spec.run(DATA)
    seq_counts = _fleet_counts()
    cache = scenario._eval_cache
    hits, misses = cache.hits, cache.misses

    reset_dispatch_counts()
    got = spec.run(DATA, parallel="processes:n=2")
    assert got.to_json() == ref.to_json()
    assert _fleet_counts() == seq_counts
    assert (cache.hits, cache.misses) == (hits, misses)


def test_transport_grid_parity_devices_backend():
    spec = get_preset("transport_grid", windows=3)
    ref = spec.run(DATA).to_json()
    assert spec.run(DATA, parallel="devices:n=8").to_json() == ref


@pytest.mark.slow
def test_transport_grid_parity_processes_backend():
    spec = get_preset("transport_grid", windows=3)
    ref = spec.run(DATA).to_json()
    assert spec.run(DATA, parallel="processes:n=2").to_json() == ref


@pytest.mark.slow
def test_fake_devices_parity_subprocess():
    """The real multi-device path: 8 fake CPU devices (the XLA flag must
    be set before jax initializes, so this needs its own process — same
    recipe as scripts/verify.sh's parity gate)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "parallel_parity.py"),
         "--preset", "smoke", "--windows", "3", "--expect-devices", "8",
         "--backends", "devices:n=8"],
        env=env, capture_output=True, text=True, timeout=540)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    assert "devices=8" in proc.stdout


# ---------------------------------------------------------------------------
# partitioner properties
# ---------------------------------------------------------------------------

ALGOS = ("a2a", "star")
TECHS = ("4g", "wifi", "ble")


def _mk_cfg(row):
    windows, algo_i, tech_i, seed = row
    return ScenarioConfig(windows=windows, algo=ALGOS[algo_i % 2],
                          tech=TECHS[tech_i % 3], seed=seed)


ROWS = st.lists(st.tuples(st.integers(min_value=1, max_value=40),
                          st.integers(min_value=0, max_value=1),
                          st.integers(min_value=0, max_value=2),
                          st.integers(min_value=0, max_value=3)),
                min_size=1, max_size=24)


@settings(max_examples=40, deadline=None)
@given(rows=ROWS, n_shards=st.integers(min_value=1, max_value=8))
def test_partitioner_assigns_every_row_once_and_keeps_groups_whole(
        rows, n_shards):
    cfgs = [_mk_cfg(r) for r in rows]
    shards = partition_runs(cfgs, n_shards)
    assert len(shards) == n_shards
    flat = sorted(i for s in shards for i in s)
    assert flat == list(range(len(cfgs)))          # exactly-once
    owner = {i: k for k, s in enumerate(shards) for i in s}
    for group in stack_groups(cfgs):
        assert len({owner[i] for i in group}) == 1  # stack-key atomicity
    for s in shards:
        assert s == sorted(s)                       # order-stable shards


@settings(max_examples=40, deadline=None)
@given(rows=ROWS, n_shards=st.integers(min_value=1, max_value=8))
def test_partitioner_balance_within_2x_ideal(rows, n_shards):
    cfgs = [_mk_cfg(r) for r in rows]
    shards = partition_runs(cfgs, n_shards)
    shard_costs = [sum(run_cost(cfgs[i]) for i in s) for s in shards]
    group_costs = [sum(run_cost(cfgs[i]) for i in g)
                   for g in stack_groups(cfgs)]
    ideal = max(sum(shard_costs) / n_shards, max(group_costs))
    assert max(shard_costs) <= 2.0 * ideal + 1e-9


@settings(max_examples=40, deadline=None)
@given(rows=ROWS, n_shards=st.integers(min_value=1, max_value=8),
       rot=st.integers(min_value=0, max_value=23))
def test_partitioner_invariant_to_row_order(rows, n_shards, rot):
    """Shard k must receive the same multiset of configs however the input
    rows are permuted (rotations and reversal stand in for arbitrary
    permutations)."""
    cfgs = [_mk_cfg(r) for r in rows]

    def shard_contents(cs):
        return [sorted(repr(cs[i]) for i in s)
                for s in partition_runs(cs, n_shards)]

    ref = shard_contents(cfgs)
    k = rot % len(cfgs)
    assert shard_contents(cfgs[k:] + cfgs[:k]) == ref
    assert shard_contents(list(reversed(cfgs))) == ref


def test_partitioner_rejects_bad_shard_count():
    with pytest.raises(ValueError):
        partition_runs([ScenarioConfig()], 0)


def test_partitioner_smoke_grid_layout():
    """The smoke preset's two stack groups land whole on the two
    least-loaded shards, larger group first."""
    cfgs = [c for _, c in get_preset("smoke", windows=4).configs()]
    shards = partition_runs(cfgs, 8)
    assert shards[0] == [0, 1, 2, 3]       # star 4g/mesh x 2 seeds
    assert shards[1] == [4, 5]             # a2a_wifi x 2 seeds
    assert all(not s for s in shards[2:])


# ---------------------------------------------------------------------------
# executor registry + process-pool isolation guards
# ---------------------------------------------------------------------------

def test_executor_registry_spec_grammar():
    assert get_executor("none") is get_executor("none")
    assert get_executor("devices:n=8") is get_executor("devices:n=8")
    assert sorted(EXECUTORS) == ["devices", "hosts", "none", "processes"]
    with pytest.raises(KeyError):
        get_executor("warpdrive")
    with pytest.raises(KeyError):          # unknown parameter name
        get_executor("devices:bogus=1")
    with pytest.raises(ValueError):        # invalid parameter value
        get_executor("processes:n=0")


def test_assert_host_only_rejects_device_buffers():
    import jax.numpy as jnp

    assert_host_only((["a"], {"x": np.zeros(3)}, DATA,
                      ScenarioConfig()))    # numpy + plain data pass
    with pytest.raises(TypeError, match="device buffer"):
        assert_host_only({"w": jnp.zeros(3)})
    with pytest.raises(TypeError, match="device buffer"):
        assert_host_only([("nested", [jnp.ones(2)])])


def test_eval_cache_never_crosses_the_pool_boundary():
    """The EvalCache holds jax device buffers; pickling it (the only way
    it could ride a worker queue) must refuse."""
    cache = scenario.EvalCache()
    cache.test_array(DATA)
    with pytest.raises(TypeError, match="process-local"):
        pickle.dumps(cache)
