"""Energy model (paper Section 5.2, Table 1).

``E = P * t`` with ``t = S / B`` — power (mW) times transfer duration. Every
logical transfer is recorded in an event ledger, split by purpose
(collection vs learning), so the per-table breakdowns (paper Tables 2-6) come
straight out of the ledger.

Accounting conventions (the paper leaves these implicit; see DESIGN.md §2 —
the per-technology relay/mains-power rules are implemented once, in
:mod:`repro.core.topology`):

* Only battery-powered endpoints are counted. The edge server is mains
  powered: transfers to it count the device's tx only; transfers *from* it
  count the device's rx only.
* 4G/NB-IoT go through infrastructure: one tx + one rx per unicast.
* 802.11g uses a WiFi-Direct-style star topology: one mule is the Access
  Point. A unicast between two non-AP mules is relayed: 2 tx + 2 rx, all on
  battery. If the AP is an endpoint: 1 tx + 1 rx.
* Observations on the wire are 54 float64 features + 1-byte label (433 B,
  calibrated to the paper's 34 477 mJ Edge-Only benchmark); models are
  float32 (7 x 55 x 4 = 1 540 B).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List

from repro.core.registry import resolve_spec


@dataclass(frozen=True)
class Tech:
    name: str
    tx_mw: float
    up_mbps: float
    rx_mw: float
    down_mbps: float

    def tx_mj(self, nbytes: float) -> float:
        return self.tx_mw * (nbytes * 8.0 / (self.up_mbps * 1e6))

    def rx_mj(self, nbytes: float) -> float:
        return self.rx_mw * (nbytes * 8.0 / (self.down_mbps * 1e6))


def lora_bitrate_mbps(sf: int, bw_khz: float = 125.0,
                      code_rate: float = 0.8) -> float:
    """LoRa PHY bitrate for spreading factor ``sf`` (EU868 defaults:
    125 kHz bandwidth, CR 4/5): ``sf * BW / 2**sf * CR`` — SF7 ~= 5.5 kbps,
    SF12 ~= 0.29 kbps. Higher SF buys range at a steep energy-per-byte
    cost, which is exactly the trade-off ``"lora:sf=N"`` sweeps expose."""
    if sf != int(sf) or not 7 <= int(sf) <= 12:
        raise ValueError(f"LoRa spreading factor must be an integer in "
                         f"7..12, got {sf}")
    sf = int(sf)
    return float(sf) * (bw_khz * 1e3) / (2.0 ** sf) * code_rate / 1e6


def _lora_tech(sf: int = 7) -> Tech:
    # SX127x-class transceiver at +14 dBm / 3.3 V: ~44 mA tx, ~12 mA rx
    rate = lora_bitrate_mbps(sf)
    return Tech(f"lora:sf={int(sf)}" if int(sf) != 7 else "lora",
                145.2, rate, 39.6, rate)


def _mesh_tech(hops: int = 1) -> Tech:
    """Per-event energy of a ``"mesh:hops=N"`` spec: hop count multiplies
    *event counts* (:class:`repro.core.topology.MeshTransport`), never the
    per-event energy, so every mesh depth shares the 802.15.4 entry. The
    hop count is validated here too so the direct ``Ledger.add`` path
    fails as fast as the transport registry."""
    if isinstance(hops, bool) or hops != int(hops) or int(hops) < 1:
        raise ValueError(f"mesh hop count must be a positive integer, "
                         f"got {hops!r}")
    return TECHS["802.15.4"]


# Table 1 of the paper, plus the BLE/LoRa additions (DESIGN.md §5):
# BLE 4.x connection events ~= 0.27 Mbps application throughput at
# ~10 mA tx / 9 mA rx on 3.6 V coin-cell class radios.
TECHS: Dict[str, Tech] = {
    "4g": Tech("4g", 2100.0, 75.0, 2100.0, 35.0),
    "nbiot": Tech("nbiot", 199.0, 0.2, 199.52, 0.2),
    "802.15.4": Tech("802.15.4", 3.0, 0.12, 3.0, 0.12),
    "wifi": Tech("wifi", 1080.0, 48.0, 740.0, 48.0),
    "ble": Tech("ble", 36.0, 0.27, 32.4, 0.27),
    "lora": _lora_tech(),
}


# Parameterized technologies: factories keyed by spec name, resolved (and
# cached, outside the static paper-constant TECHS table) through the same
# registry machinery as transports and collection policies.
TECH_FACTORIES: Dict[str, object] = {
    "mesh": _mesh_tech,
    "lora": _lora_tech,
}

_TECH_CACHE: Dict[str, Tech] = {}


def resolve_tech(spec: str) -> Tech:
    """Per-event energy model for a technology *spec string*.

    Flat names resolve straight from :data:`TECHS`. Parameterized specs
    resolve through :data:`TECH_FACTORIES` and the shared spec grammar
    (:mod:`repro.core.registry`): ``"lora:sf=12"`` builds (and caches)
    the SF-dependent LoRa entry, ``"mesh:hops=N"`` reuses the 802.15.4
    per-event energies — hop count multiplies *event counts*, not the
    per-event energy, and lives in
    :class:`repro.core.topology.MeshTransport`. Raises :class:`KeyError`
    for unknown technologies/parameters (matching the transport registry)
    and :class:`ValueError` for invalid parameter values."""
    tech = TECHS.get(spec)
    if tech is not None:
        return tech
    return resolve_spec(spec, TECH_FACTORIES, _TECH_CACHE, "technology")

OBS_BYTES = 54 * 8 + 1        # 433 B (calibrated, DESIGN.md §2)
MODEL_BYTES = 55 * 7 * 4      # 1 540 B linear model, float32
INDEX_BYTES = 8               # entropy index / center id messages


@dataclass
class Ledger:
    events: List[dict] = field(default_factory=list)
    # Per-node battery meter (mJ drained so far), keyed by DC name. This is
    # runtime-only feedback state for the churn model (DESIGN.md §13): it is
    # excluded from equality and never serialized — the event list stays the
    # only parity surface.
    node_mj: Dict[str, float] = field(default_factory=dict, compare=False,
                                      repr=False)

    def add(self, tech: str, nbytes: float, *, purpose: str,
            n_tx: int = 1, n_rx: int = 1, what: str = "",
            src: str = None, dst: str = None) -> float:
        """Record one transfer event. ``src``/``dst`` optionally name the
        battery-powered endpoints: the tx side of the event is attributed
        to ``src``'s battery meter and the rx side to ``dst``'s (relay
        events — AP forwarding, mesh hops — are folded into the endpoints'
        meters; the churn model cares about fleet membership, not per-hop
        physics). Attribution never changes the event itself."""
        t = resolve_tech(tech)
        tx_mj = n_tx * t.tx_mj(nbytes)
        rx_mj = n_rx * t.rx_mj(nbytes)
        mj = tx_mj + rx_mj
        self.events.append({"tech": tech, "bytes": nbytes, "purpose": purpose,
                            "n_tx": n_tx, "n_rx": n_rx, "mj": mj,
                            "what": what})
        if src is not None and tx_mj:
            self.node_mj[src] = self.node_mj.get(src, 0.0) + tx_mj
        if dst is not None and rx_mj:
            self.node_mj[dst] = self.node_mj.get(dst, 0.0) + rx_mj
        return mj

    # -- high-level events ---------------------------------------------------
    def collect_to_edge(self, n_obs: int) -> float:
        """Sensor -> edge server over NB-IoT (tx only; ES is mains powered)."""
        return self.add("nbiot", n_obs * OBS_BYTES, purpose="collection",
                        n_tx=1, n_rx=0, what="sensor->ES")

    def collect_to_mule(self, n_obs: int, name: str = "SM") -> float:
        """Sensor -> SmartMule over 802.15.4 (both endpoints on battery).
        ``name`` identifies the receiving mule so the rx side lands on its
        battery meter (the tx side is the sensor's, not a DC's)."""
        return self.add("802.15.4", n_obs * OBS_BYTES, purpose="collection",
                        n_tx=1, n_rx=1, what=f"sensor->{name}", dst=name)

    def churn(self, name: str, window: int) -> None:
        """Record a battery depletion: zero-energy bookkeeping event (the
        node's radio goes silent — nothing is transferred), so churn shows
        up in the serialized event stream exactly where it happened."""
        self.events.append({"tech": "none", "bytes": 0.0, "purpose": "churn",
                            "n_tx": 0, "n_rx": 0, "mj": 0.0,
                            "what": f"{name} depleted@w{window}"})

    def unicast(self, tech: str, nbytes: float, *, src_is_es=False,
                dst_is_es=False, src_is_ap=False, dst_is_ap=False,
                purpose="learning", what="model") -> float:
        """One unicast between Data Collectors.

        Flag-based convenience wrapper: the per-technology relay/mains-power
        rules live in :mod:`repro.core.topology` (the single source of
        truth); algorithm code should charge against a
        :class:`~repro.core.topology.Topology` directly.
        """
        from repro.core.topology import Node, transfer_counts
        n_tx, n_rx = transfer_counts(
            tech, Node("src", is_es=src_is_es, is_ap=src_is_ap),
            Node("dst", is_es=dst_is_es, is_ap=dst_is_ap))
        return self.add(tech, nbytes, purpose=purpose, n_tx=n_tx, n_rx=n_rx,
                        what=what)

    # -- summaries -----------------------------------------------------------
    def total(self, purpose: str = None) -> float:
        return sum(e["mj"] for e in self.events
                   if purpose is None or e["purpose"] == purpose)

    def by_purpose(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.events:
            out[e["purpose"]] = out.get(e["purpose"], 0.0) + e["mj"]
        return out

    def by_tech(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for e in self.events:
            out[e["tech"]] = out.get(e["tech"], 0.0) + e["mj"]
        return out
