"""RG-LRU linear-recurrence Pallas TPU kernel.

Evaluates h_t = a_t * h_{t-1} + b_t with per-timestep diagonal gates. The
time axis is chunked; chunks run sequentially on the last grid dimension
with the (width-block,) hidden state carried in VMEM scratch. Within a chunk
the recurrence uses a log-depth Blelloch-style prefix combine over VREG
tiles — O(log Q) vector ops instead of Q sequential steps, which is how the
recurrence maps to the TPU's 8x128 vector units (no MXU work in this op).

Validated in interpret mode against ``ref.rglru_reference``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rglru_kernel(a_ref, b_ref, y_ref, h_scr, *, chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0, 0].astype(jnp.float32)         # (Q, Wb)
    b = b_ref[0, 0].astype(jnp.float32)         # (Q, Wb)

    # inclusive parallel prefix of the affine maps (a, b):
    # (a2,b2) o (a1,b1) = (a1*a2, a2*b1 + b2), combined at stride 1,2,4,...
    Q = chunk
    stride = 1
    while stride < Q:
        a_shift = jnp.concatenate(
            [jnp.ones((stride, a.shape[1]), jnp.float32), a[:-stride]], 0)
        b_shift = jnp.concatenate(
            [jnp.zeros((stride, b.shape[1]), jnp.float32), b[:-stride]], 0)
        b = a * b_shift + b
        a = a * a_shift
        stride *= 2

    h0 = h_scr[...]                             # (1, Wb) carried state
    h = a * h0 + b                              # prefix applied to h0
    y_ref[0, 0] = h.astype(y_ref.dtype)
    h_scr[...] = h[-1:, :]


@functools.partial(jax.jit, static_argnames=("chunk", "block_w", "interpret"))
def rglru_scan(a, b, *, chunk: int = 128, block_w: int = 128,
               interpret: bool = False):
    """a, b: (B, S, W) -> h: (B, S, W), the inclusive linear recurrence."""
    B, S, W = a.shape
    Q = min(chunk, S)
    assert S % Q == 0, "seq len must divide the chunk size"
    Wb = min(block_w, W)
    assert W % Wb == 0, "width must divide the width block"
    nc = S // Q
    nw = W // Wb

    kernel = functools.partial(_rglru_kernel, chunk=Q)
    # grid: (batch*width-blocks) parallel, chunks sequential
    af = a.reshape(B, nc, Q, nw, Wb).transpose(0, 3, 1, 2, 4) \
        .reshape(B * nw, nc, Q, Wb)
    bf = b.reshape(B, nc, Q, nw, Wb).transpose(0, 3, 1, 2, 4) \
        .reshape(B * nw, nc, Q, Wb)

    h = pl.pallas_call(
        kernel,
        grid=(B * nw, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, Wb), lambda i, c: (i, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, Wb), lambda i, c: (i, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, Wb), lambda i, c: (i, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nw, nc, Q, Wb), a.dtype),
        scratch_shapes=_scratch(Wb),
        interpret=interpret,
    )(af, bf)

    return h.reshape(B, nw, nc, Q, Wb).transpose(0, 2, 3, 1, 4) \
        .reshape(B, S, W)


def _scratch(Wb):
    from jax.experimental.pallas import tpu as pltpu
    return [pltpu.VMEM((1, Wb), jnp.float32)]
