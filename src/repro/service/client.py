"""Streaming client for the sweep service (DESIGN.md §12).

Stdlib-only (``http.client`` + JSON) counterpart of
:mod:`repro.service.server`: submit a :class:`SweepSpec`, then *stream*
per-shard events and fold each into an incremental, order-stable merge
(:class:`repro.core.parallel.ShardMerger`) — the client-side replacement
for the launcher's all-shards barrier. Because shards write to disjoint
run-index slots, any arrival order (and any replay after a reconnect)
merges to the same run list, so :meth:`ServiceClient.run` returns a
``SweepResult`` whose JSON is byte-identical to the sequential
in-process ``spec.run(data)`` — the property scripts/service_parity.py
gates.

Stream resumption: the server persists every job event with a sequence
number, so when a stream connection drops mid-job (server restarts a
worker, an LB idles the connection, or the server bounds the response
via ``max_events``), the client transparently reconnects with
``cursor=<next seq>`` and continues; the merger's idempotent ``add``
makes overlap harmless. Submit payloads are checked by
:func:`repro.core.parallel.assert_host_only` before they leave the
process — the no-device-buffers-on-the-wire contract holds on both ends.
"""
from __future__ import annotations

import json
import socket
from http.client import HTTPConnection, HTTPException
from typing import Any, Dict, Iterator, Optional, Tuple, Union

from repro.core.experiment import SweepResult, SweepSpec, records_from
from repro.core.launcher import encode_dataset
from repro.core.parallel import ShardMerger, assert_host_only
from repro.service.server import SERVICE_SCHEMA

_RECONNECT_ERRORS = (ConnectionError, HTTPException, socket.timeout,
                     OSError)


class ClientError(RuntimeError):
    """A request the service rejected (``status`` carries the HTTP code,
    0 for transport-level failures)."""

    def __init__(self, status: int, detail: str):
        super().__init__(f"[{status}] {detail}")
        self.status = status
        self.detail = detail


class ServiceClient:
    """One service endpoint. ``address`` is ``"host:port"`` or a
    ``(host, port)`` pair; ``timeout`` is the per-connection socket
    timeout (streams block up to this long waiting for the next event,
    then the read fails and the client reconnects with its cursor)."""

    def __init__(self, address: Union[str, Tuple[str, int]],
                 timeout: float = 60.0, max_reconnects: int = 100):
        if isinstance(address, str):
            host, _, port = address.rpartition(":")
            self.host, self.port = host or "127.0.0.1", int(port)
        else:
            self.host, self.port = address[0], int(address[1])
        self.timeout = timeout
        self.max_reconnects = max_reconnects

    # -- plain JSON round-trips ----------------------------------------------
    def _request(self, method: str, path: str,
                 body: Optional[Dict[str, Any]] = None) -> Any:
        status, raw = self._request_raw(method, path, body)
        try:
            payload = json.loads(raw)
        except json.JSONDecodeError as e:
            raise ClientError(status, f"non-JSON response for {method} "
                                      f"{path}: {e}")
        if status >= 400:
            raise ClientError(status, str((payload or {}).get(
                "error", raw[:400])))
        return payload

    def _request_raw(self, method: str, path: str,
                     body: Optional[Dict[str, Any]] = None
                     ) -> Tuple[int, str]:
        conn = HTTPConnection(self.host, self.port, timeout=self.timeout)
        try:
            data = json.dumps(body) if body is not None else None
            headers = ({"Content-Type": "application/json"}
                       if data is not None else {})
            conn.request(method, path, body=data, headers=headers)
            resp = conn.getresponse()
            return resp.status, resp.read().decode("utf-8")
        except _RECONNECT_ERRORS as e:
            raise ClientError(0, f"{method} {path} failed: {e}")
        finally:
            conn.close()

    # -- the service API -----------------------------------------------------
    def submit(self, spec: SweepSpec, data: Any, *, stack: str = "auto",
               backend: Optional[str] = None, cache: str = "use",
               search: str = "") -> Dict[str, Any]:
        """POST the sweep; returns the submit reply (job id, shard
        partition, cache key, ``cached`` flag). ``data`` is a
        :class:`Dataset` or an already-encoded wire payload. A non-empty
        ``search`` spec (``"halving:rungs=3,keep=0.5"``) makes the job a
        Pareto search over the grid (DESIGN.md §14) — the reply carries
        ``kind="search"`` and no shard partition."""
        payload: Dict[str, Any] = {
            "schema": SERVICE_SCHEMA,
            "spec": spec.to_wire(),
            "data": data if isinstance(data, dict) else
            encode_dataset(data),
            "stack": stack,
            "cache": cache,
        }
        if backend is not None:
            payload["backend"] = backend
        if search:
            payload["search"] = search
        assert_host_only(payload, where="service request")
        return self._request("POST", "/v1/jobs", payload)

    def status(self, job: str) -> Dict[str, Any]:
        return self._request("GET", f"/v1/jobs/{job}")

    def cancel(self, job: str, cancel_token: str) -> Dict[str, Any]:
        """Cancel a job. ``cancel_token`` is the capability the submit
        reply returned — the server 403s any other value, so holding a
        job id alone does not grant cancellation."""
        return self._request("POST", f"/v1/jobs/{job}/cancel",
                             {"cancel_token": cancel_token})

    def metrics(self) -> Dict[str, Any]:
        return self._request("GET", "/v1/metrics")

    def health(self) -> Dict[str, Any]:
        return self._request("GET", "/v1/healthz")

    def result_text(self, job: str) -> str:
        """The merged result JSON exactly as the server stores (and
        caches) it — the verbatim parity surface."""
        status, raw = self._request_raw("GET", f"/v1/jobs/{job}/results")
        if status >= 400:
            try:
                detail = json.loads(raw).get("error", raw[:400])
            except json.JSONDecodeError:
                detail = raw[:400]
            raise ClientError(status, detail)
        return raw

    def result(self, job: str) -> SweepResult:
        return SweepResult.from_json(self.result_text(job))

    def result_page(self, job: str, page: int,
                    per_page: int) -> SweepResult:
        status, raw = self._request_raw(
            "GET", f"/v1/jobs/{job}/results?page={page}"
                   f"&per_page={per_page}")
        if status >= 400:
            raise ClientError(status, raw[:400])
        return SweepResult.from_json(raw)

    # -- streaming -----------------------------------------------------------
    def stream_events(self, job: str, cursor: int = 0, *,
                      max_events_per_conn: int = 0
                      ) -> Iterator[Dict[str, Any]]:
        """Yield the job's NDJSON events from ``cursor`` until the
        terminal event, transparently reconnecting (with the advancing
        cursor) when a connection drops or the server bounds a response.
        Replayed events after a reconnect are *not* filtered here — the
        merger's idempotent ``add`` handles them — but the cursor
        advances past everything yielded, so a reconnect never re-reads
        from zero."""
        reconnects = 0
        while True:
            path = f"/v1/jobs/{job}/stream?cursor={cursor}"
            if max_events_per_conn:
                path += f"&max_events={max_events_per_conn}"
            conn = HTTPConnection(self.host, self.port,
                                  timeout=self.timeout)
            dropped = False
            try:
                conn.request("GET", path)
                resp = conn.getresponse()
                if resp.status >= 400:
                    raise ClientError(resp.status,
                                      resp.read().decode()[:400])
                while True:
                    try:
                        line = resp.readline()
                    except _RECONNECT_ERRORS:
                        dropped = True
                        break
                    if not line:            # EOF: server closed cleanly
                        break
                    event = json.loads(line)
                    assert_host_only(event, where="service stream event")
                    cursor = event["seq"] + 1
                    yield event
                    if event["event"] in ("done", "error"):
                        return
            except _RECONNECT_ERRORS:
                dropped = True
            finally:
                conn.close()
            reconnects += 1
            if dropped and reconnects > self.max_reconnects:
                raise ClientError(0, f"stream for {job} dropped "
                                     f"{reconnects} times; giving up at "
                                     f"cursor {cursor}")

    def run(self, spec: SweepSpec, data: Any, *, stack: str = "auto",
            backend: Optional[str] = None, cache: str = "use",
            max_events_per_conn: int = 0) -> SweepResult:
        """Submit + stream + merge: the end-to-end replacement for an
        in-process ``spec.run(data)``. Returns as soon as the *last*
        shard lands (no server-side barrier in between — each shard is
        merged the moment its event arrives). The returned result's JSON
        is byte-identical to the sequential run's; service bookkeeping
        (job id, cache key, hit flag) rides the out-of-band ``meta``."""
        sub = self.submit(spec, data, stack=stack, backend=backend,
                          cache=cache)
        job = sub["job"]
        service_meta = {"job": job, "key": sub["key"],
                        "cached": sub["cached"],
                        "n_shards": sub["n_shards"]}
        if sub["cached"]:
            out = SweepResult.from_json(self.result_text(job))
            out.meta["service"] = service_meta
            return out
        labels = [lbl for lbl, _ in spec.configs()]
        merger = ShardMerger(len(labels), sub["shards"])
        for event in self.stream_events(
                job, max_events_per_conn=max_events_per_conn):
            if event["event"] == "shard":
                merger.add(event["shard"], event["result"],
                           event["dispatch_counts"])
            elif event["event"] == "error":
                raise ClientError(500, f"job {job} {event['state']}: "
                                       f"{event.get('error')}")
        out = SweepResult(name=sub["name"],
                          records=records_from(labels, merger.results()))
        out.meta["service"] = service_meta
        return out

    def search(self, spec: SweepSpec, data: Any, search: str, *,
               stack: str = "auto", backend: Optional[str] = None,
               cache: str = "use",
               on_rung: Optional[Any] = None) -> "Any":
        """Submit a Pareto search over ``spec``'s grid and stream its
        ``rung`` events until the terminal one, then fetch the stored
        :class:`~repro.core.pareto.ParetoResult` verbatim — the
        service-side equivalent of ``get_search(search).run(spec, data)``
        (bitwise, including the embedded frontier ``SweepResult``).
        ``on_rung(record)`` fires per streamed rung event."""
        from repro.core.pareto import ParetoResult

        sub = self.submit(spec, data, stack=stack, backend=backend,
                          cache=cache, search=search)
        job = sub["job"]
        if not sub["cached"]:
            for event in self.stream_events(job):
                if event["event"] == "rung" and on_rung is not None:
                    on_rung(event)
                elif event["event"] == "error":
                    raise ClientError(500, f"search job {job} "
                                           f"{event['state']}: "
                                           f"{event.get('error')}")
        out = ParetoResult.from_json(self.result_text(job))
        out.meta["service"] = {"job": job, "key": sub["key"],
                               "cached": sub["cached"],
                               "search": sub["search"]}
        return out
