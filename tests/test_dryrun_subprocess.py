"""End-to-end dry-run smoke: one cheap combo in a subprocess (the dry-run
must own its process — it forces 512 placeholder host devices before any
jax import, which cannot happen inside the pytest process)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_dryrun_one_combo_subprocess():
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "mamba2-1.3b", "--shape", "long_500k", "--mesh", "pod1",
             "--out", d],
            env=env, capture_output=True, text=True, timeout=540)
        assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
        rec = json.load(open(os.path.join(
            d, "mamba2-1.3b_long_500k_pod1.json")))
        assert rec["status"] == "ok"
        assert rec["num_devices"] == 256
        assert rec["hlo_flops"] > 0
        assert "collectives" in rec
