"""Beyond-paper ablations on the faithful HTL layer.

1. Global-model update rate (our EMA interpretation of the paper's
   "update the model elaborated until the previous time slot").
2. Center-election policy for StarHTL (paper: max label entropy) vs
   max-data and random election.
3. Source-pool ablation: does including the previous global model as a
   GreedyTL source (the incremental mechanism) actually matter?
4. Engine timing: the batched ``fleet`` engine (which ablations 1-2 run
   on — policies resolve through repro.core.htl at call time, so the
   monkey-patches apply to both engines) vs the per-DC ``loop`` reference,
   seeds replica-stacked vs sequential. Timings land in ablations.json.

    PYTHONPATH=src python -m benchmarks.ablations [--windows 40]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

from repro.core.scenario import ScenarioConfig, run_scenario, run_sweep
from repro.data.synthetic_covtype import make_covtype_like

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "benchmarks")


def ema_ablation(data, windows, seeds=2):
    out = {}
    for eta in (1.0, 0.5, 0.3, 0.15):
        f1s = []
        for s in range(seeds):
            r = run_scenario(ScenarioConfig(
                algo="star", tech="wifi", windows=windows,
                eval_every=max(1, windows // 10), global_update_rate=eta,
                seed=s), data)
            f1s.append(r.converged_f1())
        out[f"eta={eta}"] = round(float(np.mean(f1s)), 4)
    return out


def election_ablation(data, windows, seeds=2):
    """Entropy election vs alternatives (monkey-patched policy)."""
    import repro.core.htl as htl_mod
    orig = htl_mod.label_entropy
    out = {}

    policies = {
        "entropy (paper)": orig,
        "max-data": lambda y, k: float(len(y)),
        "random": lambda y, k: float(np.random.default_rng(len(y))
                                     .random()),
    }
    try:
        for name, fn in policies.items():
            htl_mod.label_entropy = fn
            f1s = []
            for s in range(seeds):
                r = run_scenario(ScenarioConfig(
                    algo="star", tech="wifi", windows=windows,
                    eval_every=max(1, windows // 10), seed=s), data)
                f1s.append(r.converged_f1())
            out[name] = round(float(np.mean(f1s)), 4)
    finally:
        htl_mod.label_entropy = orig
    return out


def prev_model_source_ablation(data, windows, seeds=2):
    """Drop the previous global model from the GreedyTL source pool."""
    import repro.core.htl as htl_mod
    out = {}
    orig_refine = htl_mod._greedy_refine

    for label, drop in (("with prev-global source (ours)", False),
                        ("without prev-global source", True)):
        if drop:
            def patched(dc, sources, cap, num_classes):
                return orig_refine(dc, sources[:-1] if len(sources) > 1
                                   else sources, cap, num_classes)
            htl_mod._greedy_refine = patched
        try:
            f1s = []
            for s in range(seeds):
                # _greedy_refine is a loop-engine internal; pin that engine
                r = run_scenario(ScenarioConfig(
                    algo="star", tech="wifi", windows=windows,
                    eval_every=max(1, windows // 10), seed=s,
                    engine="loop"), data)
                f1s.append(r.converged_f1())
            out[label] = round(float(np.mean(f1s)), 4)
        finally:
            htl_mod._greedy_refine = orig_refine
    return out


def engine_timing(data, windows, seeds=3):
    """Fleet vs loop engine wall-clock on the ablation workload (ROADMAP:
    drive the fleet path through the ablations too), and replica-stacked vs
    sequential seed handling for the fleet engine. Warm timings (the jit
    cache is shared across variants), F1 parity asserted as a side effect.
    """
    out = {}
    f1 = {}
    for engine, stack in (("fleet", True), ("fleet", False),
                          ("loop", False)):
        cfgs = [ScenarioConfig(algo="star", tech="wifi", windows=windows,
                               eval_every=max(1, windows // 10), seed=s,
                               engine=engine) for s in range(seeds)]
        run_sweep(cfgs, data, stack_seeds=stack)       # warm the jit cache
        t0 = time.time()
        rs = run_sweep(cfgs, data, stack_seeds=stack)
        label = f"{engine}_stacked" if stack else engine
        out[f"{label}_s"] = round(time.time() - t0, 3)
        f1[label] = round(float(np.mean([r.converged_f1() for r in rs])), 4)
    out["fleet_speedup_vs_loop"] = round(out["loop_s"] / out["fleet_s"], 2)
    out["stacking_speedup"] = round(out["fleet_s"] / out["fleet_stacked_s"],
                                    2)
    assert abs(f1["fleet"] - f1["loop"]) < 1e-3, f1
    assert abs(f1["fleet"] - f1["fleet_stacked"]) < 1e-3, f1
    out["converged_f1"] = f1["fleet"]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=40)
    args = ap.parse_args()
    data = make_covtype_like(seed=0)
    out = {
        "ema_rate": ema_ablation(data, args.windows),
        "election": election_ablation(data, args.windows),
        "prev_model_source": prev_model_source_ablation(data, args.windows),
        "engine_timing": engine_timing(data, args.windows),
    }
    print(json.dumps(out, indent=1))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "ablations.json"), "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":
    main()
