"""Multi-host sweep launcher with shard-level fault tolerance (DESIGN §8).

PR 4 sharded `SweepSpec.run` across devices and local processes; this
module is the next scale step the ROADMAP seeded — dispatching the same
deterministic shard payloads to *independent host processes*, on this
machine or others, while keeping the repo's non-negotiable contract: a
launched run merges **bitwise identical** (JSON-identical `SweepResult`)
to the sequential run, clean or under worker loss.

Three layers:

* **Wire format.** A shard request is pure JSON: the shard's labels,
  `ScenarioConfig` dicts, the dataset (numpy buffers base64-encoded, so
  float64 bits survive any transport exactly) and the stack flag. A shard
  response is the shard's `SweepResult` JSON plus its jitted-dispatch
  counts — produced by the same shared shard runner
  (:func:`repro.core.parallel.run_shard_payload`) the spawn pool uses, so
  the payload schema cannot drift between transports. Responses on a
  stream are framed by a sentinel line (:data:`RESULT_SENTINEL`), making
  the protocol robust to stray library prints on stdout.

* **Channels** (`HostChannel`): pluggable shard transports, addressed by
  the nested spec grammar of :mod:`repro.core.registry` (`";"`-separated
  params, unkeyed segments continue the previous value — so
  ``ssh:hosts=a;b;c`` is both well-formed and readable):

  - ``local`` — one fresh ``python -m repro.core.launcher --worker``
    subprocess per shard attempt; `n` interchangeable slots. The
    CI-testable reference channel.
  - ``ssh:hosts=a;b;c`` — the same worker over ``ssh host ...`` with
    stdin/stdout JSON framing; one slot per remote host.
  - ``slurm:array=N`` — batch mode: stages per-shard request files +
    an ``#SBATCH --array`` job script whose tasks run the file-mode
    worker (``--input``/``--output``), then collects result files.
    ``submit=bash`` simulates the array locally (the CI path),
    ``submit=sbatch`` really submits, ``submit=none`` only stages.

* **Fault tolerance** (`HostsExecutor`): worker loss is a first-class
  event, not an abort. Each shard gets up to ``retries + 1`` attempts
  with exponential backoff; a failed/crashed/timed-out attempt
  re-dispatches to a *different surviving slot* when one exists (slots
  with fewer failures are preferred). Because a shard is a deterministic
  function of its partition — same configs, same within-group order, same
  seeds — a retried shard reproduces exactly the bytes the first attempt
  would have produced, which is the whole determinism argument for
  bitwise parity under re-dispatch. Every attempt (slot, status, error,
  elapsed) is logged into ``SweepResult.meta["launcher"]`` — a
  side-channel field excluded from serialization and equality, so the
  parity contract is untouched.

Gated by ``scripts/hosts_parity.py`` (clean + one injected SIGKILL) in
scripts/verify.sh and a named CI step; property/crash suites in
tests/test_launcher.py.
"""
from __future__ import annotations

import base64
import dataclasses
import json
import os
import sys
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.parallel import (SweepExecutor, merge_shard_payloads,
                                 partition_runs, run_shard_payload)
from repro.core.registry import format_spec, parse_spec, register_factory
from repro.core.scenario import ScenarioConfig
from repro.data.synthetic_covtype import Dataset

PAYLOAD_SCHEMA = 1
RESULT_SENTINEL = "==REPRO_SHARD_RESULT=="
# set on a worker's environment by the fault-injection path: the worker
# SIGKILLs itself mid-shard (request parsed, dataset decoded, no result
# written) — the hardest failure shape a channel can see
INJECT_ENV = "REPRO_LAUNCHER_INJECT"


# ---------------------------------------------------------------------------
# wire format: dataset codec, requests, framing
# ---------------------------------------------------------------------------

def encode_dataset(data: Dataset) -> Dict[str, Any]:
    """Dataset -> JSON-safe dict. Buffers go as base64 of the raw bytes,
    so the decoded arrays are bit-for-bit the originals on any host with
    the same endianness (dtype strings pin byte order explicitly)."""
    out: Dict[str, Any] = {"kind": "arrays", "fields": {}}
    for name, arr in zip(Dataset._fields, data):
        a = np.ascontiguousarray(arr)
        out["fields"][name] = {
            "dtype": a.dtype.str,          # includes byte order, e.g. '<f8'
            "shape": list(a.shape),
            "b64": base64.b64encode(a.tobytes()).decode("ascii"),
        }
    return out


def decode_dataset(payload: Dict[str, Any]) -> Dataset:
    if payload.get("kind") != "arrays":
        raise ValueError(f"unknown dataset payload kind "
                         f"{payload.get('kind')!r}")
    fields = []
    for name in Dataset._fields:
        f = payload["fields"][name]
        a = np.frombuffer(base64.b64decode(f["b64"]),
                          dtype=np.dtype(f["dtype"]))
        fields.append(a.reshape(f["shape"]).copy())   # writable, owned
    return Dataset(*fields)


def build_request(shard: int, labels: Sequence[str],
                  cfgs: Sequence[ScenarioConfig], data: Any,
                  stack: bool) -> Dict[str, Any]:
    """One shard's worker request: pure JSON, transport-agnostic.
    ``data`` may be a :class:`Dataset` or an already-encoded payload dict
    — the executor encodes once and shares it across all shards."""
    return {
        "schema": PAYLOAD_SCHEMA,
        "shard": int(shard),
        "labels": list(labels),
        "cfgs": [dataclasses.asdict(c) for c in cfgs],
        "stack": bool(stack),
        "data": data if isinstance(data, dict) else encode_dataset(data),
    }


def run_request(request: Dict[str, Any]) -> Dict[str, Any]:
    """Worker side: execute one shard request through the shared shard
    runner and return the response payload."""
    if request.get("schema") != PAYLOAD_SCHEMA:
        raise ValueError(f"unsupported shard-request schema "
                         f"{request.get('schema')!r} (this worker speaks "
                         f"{PAYLOAD_SCHEMA})")
    cfgs = [ScenarioConfig(**c) for c in request["cfgs"]]
    data = decode_dataset(request["data"])
    if os.environ.get(INJECT_ENV) == "sigkill":
        # fault-injection hook (scripts/hosts_parity.py --inject-failures,
        # tests/test_launcher.py): die mid-shard with no exit handlers and
        # no response — exactly what a powered-off edge node looks like
        import signal
        sys.stderr.write("launcher worker: injected SIGKILL\n")
        sys.stderr.flush()
        os.kill(os.getpid(), signal.SIGKILL)
    payload, counts = run_shard_payload(request["labels"], cfgs, data,
                                        request["stack"])
    return {"schema": PAYLOAD_SCHEMA, "shard": request["shard"],
            "result": payload, "dispatch_counts": counts}


def frame_response(response: Dict[str, Any]) -> str:
    """Stream framing: sentinel line, then the response JSON on one line.
    Anything a library printed to stdout before the sentinel is ignored
    by :func:`parse_response`."""
    return f"\n{RESULT_SENTINEL}\n{json.dumps(response)}\n"


def parse_response(stream_text: str) -> Dict[str, Any]:
    lines = stream_text.splitlines()
    for i in range(len(lines) - 1, -1, -1):
        if lines[i].strip() == RESULT_SENTINEL:
            body = "\n".join(lines[i + 1:]).strip()
            try:
                response = json.loads(body)
            except json.JSONDecodeError as e:
                raise ChannelError("frame", f"unparseable response after "
                                   f"sentinel: {e}") from e
            if response.get("schema") != PAYLOAD_SCHEMA:
                raise ChannelError("frame", f"response schema "
                                   f"{response.get('schema')!r} != "
                                   f"{PAYLOAD_SCHEMA}")
            return response
    raise ChannelError("frame", f"no result sentinel in worker output "
                       f"({len(stream_text)} bytes)")


def _worker_env(extra_env: Optional[Dict[str, str]] = None) -> Dict[str, str]:
    """Subprocess environment: inherit, ensure src/ is importable (the
    worker runs ``-m repro.core.launcher`` from an arbitrary cwd)."""
    import repro
    # repro is a namespace package (no __init__.py): locate src/ via
    # __path__, not __file__ (which is None)
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    env = dict(os.environ)
    prev = env.get("PYTHONPATH", "")
    if src not in prev.split(os.pathsep):
        env["PYTHONPATH"] = f"{src}{os.pathsep}{prev}" if prev else src
    env.update(extra_env or {})
    return env


def _stats():
    """The process-wide statsd client (repro.service.statsd), resolved
    lazily: the metrics module is stdlib-only and imports nothing from
    repro.core, so the retry path can emit fleet-health counters/timers
    (shard attempts, failures by kind, retries, attempt latency) without
    the core layer depending on the service layer at import time."""
    from repro.service.statsd import statsd
    return statsd


# ---------------------------------------------------------------------------
# channels
# ---------------------------------------------------------------------------

class ChannelError(RuntimeError):
    """One failed shard attempt. ``kind`` classifies it for the attempt
    log: 'crash' (nonzero exit / vanished worker), 'timeout', 'frame'
    (unparseable response), 'submit' (batch submission failed)."""

    def __init__(self, kind: str, detail: str):
        super().__init__(f"[{kind}] {detail}")
        self.kind = kind
        self.detail = detail


class HostChannel:
    """One way to run shard payloads on some set of hosts.

    Interactive channels (``batch = False``) expose ``slots()`` —
    identifiers of independent workers — and a synchronous
    :meth:`run` per attempt. Batch channels (``batch = True``,
    slurm) take whole request batches via :meth:`run_batch` and return
    per-request responses or :class:`ChannelError`\\ s.
    """

    batch = False

    def slots(self) -> List[str]:
        raise NotImplementedError

    def run(self, slot: str, request: Dict[str, Any], *,
            timeout: Optional[float] = None,
            extra_env: Optional[Dict[str, str]] = None) -> Dict[str, Any]:
        raise NotImplementedError

    def run_batch(self, requests: Sequence[Dict[str, Any]], *,
                  timeout: Optional[float] = None
                  ) -> List[Any]:       # Dict | ChannelError per request
        raise NotImplementedError

    def describe(self) -> str:
        return type(self).__name__


def _communicate(cmd: List[str], request: Dict[str, Any], *,
                 timeout: Optional[float], extra_env: Optional[Dict[str, str]],
                 where: str) -> Dict[str, Any]:
    """Shared subprocess attempt: request JSON on stdin, framed response
    on stdout; crash/timeout/frame failures become :class:`ChannelError`."""
    import subprocess

    try:
        proc = subprocess.run(cmd, input=json.dumps(request),
                              capture_output=True, text=True,
                              timeout=timeout, env=_worker_env(extra_env))
    except subprocess.TimeoutExpired:
        raise ChannelError("timeout",
                           f"worker on {where} exceeded {timeout}s")
    except OSError as e:
        raise ChannelError("crash", f"could not spawn worker on {where}: "
                           f"{e}")
    if proc.returncode != 0:
        raise ChannelError(
            "crash", f"worker on {where} exited {proc.returncode}; stderr "
            f"tail: {proc.stderr[-800:]!r}")
    return parse_response(proc.stdout)


class InlineChannel(HostChannel):
    """``inline`` / ``inline:n=K``: run shard requests *in this process*
    through the same :func:`run_request` a worker would run — no spawn, no
    import, no fresh jit cache. The sweep service's default backend
    (DESIGN.md §12): a long-running server already paid import+compile
    once, so per-shard subprocess cost would dominate every small job.

    Attempts are serialized by a module-wide lock: the shared shard runner
    snapshots the *global* dispatch counter per shard
    (:func:`repro.core.parallel.run_shard_payload` resets then reads it),
    so two in-process shards may never interleave. Streaming still works —
    shards complete one by one and stream as they land; the slots only
    bound how many jobs queue on the lock. Fault injection is *simulated*
    (a scripted :class:`ChannelError`, never a real SIGKILL — that would
    kill the server): retry-path tests run cheaply, while the real-kill
    gate keeps using the ``local`` channel."""

    _RUN_LOCK = threading.Lock()

    def __init__(self, n: int = 1):
        if n < 1:
            raise ValueError(f"inline channel needs n >= 1, got {n}")
        self.n = n

    def slots(self) -> List[str]:
        return [f"inline/{i}" for i in range(self.n)]

    def run(self, slot, request, *, timeout=None, extra_env=None):
        if (extra_env or {}).get(INJECT_ENV):
            raise ChannelError("crash", f"inline worker on {slot}: "
                               f"injected fault (simulated; inline never "
                               f"SIGKILLs its own process)")
        with InlineChannel._RUN_LOCK:
            return run_request(request)

    def describe(self) -> str:
        return format_spec("inline", {"n": self.n}, sep=";")


class LocalChannel(HostChannel):
    """``local`` / ``local:n=K``: one fresh subprocess per shard attempt
    on this machine — K interchangeable slots bound the concurrency. The
    CI-testable reference channel: every attempt is a brand-new
    interpreter, so jit caches, EvalCache and dispatch counters are
    worker-local by construction (same isolation as the spawn pool)."""

    def __init__(self, n: int = 2):
        if n < 1:
            raise ValueError(f"local channel needs n >= 1, got {n}")
        self.n = n

    def slots(self) -> List[str]:
        return [f"local/{i}" for i in range(self.n)]

    def run(self, slot, request, *, timeout=None, extra_env=None):
        cmd = [sys.executable, "-m", "repro.core.launcher", "--worker"]
        return _communicate(cmd, request, timeout=timeout,
                            extra_env=extra_env, where=slot)

    def describe(self) -> str:
        return format_spec("local", {"n": self.n}, sep=";")


class SSHChannel(HostChannel):
    """``ssh:hosts=a;b;c``: the stdin/stdout worker over ssh, one slot
    per remote host. Assumes the repo is importable on the remote (same
    checkout path or an installed package); ``python`` and ``opts``
    parameterize the remote interpreter and extra ssh options."""

    def __init__(self, hosts: str = "", python: str = "python3",
                 opts: str = ""):
        self.hosts = [h.strip() for h in str(hosts).split(";") if h.strip()]
        if not self.hosts:
            raise ValueError("ssh channel needs hosts=a;b;c")
        self.python = python
        self.opts = [o for o in str(opts).split() if o]

    def slots(self) -> List[str]:
        return [f"ssh/{h}" for h in self.hosts]

    def command(self, slot: str,
                extra_env: Optional[Dict[str, str]] = None) -> List[str]:
        """The exact argv for one attempt (unit-testable without a
        cluster). Injection env rides the remote command line — the local
        environment does not cross ssh."""
        host = slot.split("/", 1)[1]
        remote_env = "".join(f"{k}={v} " for k, v in
                             (extra_env or {}).items())
        return (["ssh", "-o", "BatchMode=yes", *self.opts, host,
                 f"{remote_env}{self.python} -m repro.core.launcher "
                 f"--worker"])

    def run(self, slot, request, *, timeout=None, extra_env=None):
        # extra_env is encoded into the remote command; the local
        # subprocess env is untouched
        return _communicate(self.command(slot, extra_env), request,
                            timeout=timeout, extra_env=None, where=slot)

    def describe(self) -> str:
        return format_spec("ssh", {"hosts": ";".join(self.hosts)}, sep=";")


class SlurmChannel(HostChannel):
    """``slurm:array=N``: batch dispatch through a SLURM array job.

    :meth:`run_batch` *stages* the batch — per-shard request files plus an
    ``#SBATCH --array=0-(S-1)%N`` script whose task i runs the file-mode
    worker (``--input shard_i.json --output result_i.json``) — then
    submits per ``submit=``:

    - ``sbatch``: really submit, poll for result files until ``timeout``;
    - ``bash``: simulate the array locally by running the script once per
      task id with ``SLURM_ARRAY_TASK_ID`` set (the CI path — identical
      script, identical file flow, no scheduler);
    - ``none``: stage only and report every shard as pending (the
      operator submits by hand and re-collects).

    Missing/unreadable results surface as per-shard 'crash'
    :class:`ChannelError`\\ s, so the executor's retry loop re-stages just
    the failed shards as a follow-up array.
    """

    batch = True

    def __init__(self, array: int = 0, dir: str = "results/slurm_shards",
                 submit: str = "sbatch", python: str = "python3",
                 poll_s: float = 5.0, max_wait: float = 3600.0):
        if submit not in ("sbatch", "bash", "none"):
            raise ValueError(f"slurm submit must be sbatch|bash|none, "
                             f"got {submit!r}")
        self.array = int(array)          # max simultaneous tasks; 0 = all
        self.dir = dir
        self.submit = submit
        self.python = python
        self.poll_s = float(poll_s)
        # poll budget when the executor passes no timeout: a task that
        # dies without writing its result file must become a 'crash'
        # ChannelError (and a retry), never an infinite poll loop
        self.max_wait = float(max_wait)
        self._batch_no = 0

    def _fresh_batch_dir(self) -> str:
        """A directory no previous batch has used — result files are
        collected from here, so a stale ``result_*.json`` left by an
        earlier run (this channel instance or a prior one pointing at the
        same ``dir``) must never be readable as a fresh response."""
        while True:
            self._batch_no += 1
            batch_dir = os.path.join(self.dir,
                                     f"batch_{self._batch_no:03d}")
            try:
                os.makedirs(batch_dir, exist_ok=False)
                return batch_dir
            except FileExistsError:
                continue

    def slots(self) -> List[str]:
        return ["slurm/array"]

    def stage(self, requests: Sequence[Dict[str, Any]], batch_dir: str
              ) -> str:
        """Write request files + the array-job script; returns the script
        path."""
        os.makedirs(batch_dir, exist_ok=True)
        for i, req in enumerate(requests):
            with open(os.path.join(batch_dir, f"shard_{i:04d}.json"),
                      "w") as f:
                json.dump(req, f)
        n = len(requests)
        throttle = f"%{self.array}" if 0 < self.array < n else ""
        py = self.python if self.submit != "bash" else sys.executable
        script = os.path.join(batch_dir, "launch_array.sh")
        with open(script, "w") as f:
            f.write(
                "#!/usr/bin/env bash\n"
                "#SBATCH --job-name=repro-sweep-shards\n"
                f"#SBATCH --array=0-{n - 1}{throttle}\n"
                f"#SBATCH --output={batch_dir}/slurm_%a.log\n"
                "set -euo pipefail\n"
                f"i=$(printf '%04d' \"$SLURM_ARRAY_TASK_ID\")\n"
                f"{py} -m repro.core.launcher "
                f"--input {batch_dir}/shard_$i.json "
                f"--output {batch_dir}/result_$i.json\n")
        os.chmod(script, 0o755)
        return script

    def run_batch(self, requests, *, timeout=None):
        import subprocess

        batch_dir = self._fresh_batch_dir()
        script = self.stage(requests, batch_dir)
        n = len(requests)
        if self.submit == "bash":
            for i in range(n):
                subprocess.run(["bash", script], timeout=timeout,
                               env=_worker_env(
                                   {"SLURM_ARRAY_TASK_ID": str(i)}),
                               capture_output=True)
        elif self.submit == "sbatch":
            sub = subprocess.run(["sbatch", script], capture_output=True,
                                 text=True)
            if sub.returncode != 0:
                err = ChannelError("submit", f"sbatch failed: "
                                   f"{sub.stderr[-400:]!r}")
                return [err] * n
            deadline = time.monotonic() + (timeout if timeout
                                           else self.max_wait)
            while any(not os.path.exists(
                    os.path.join(batch_dir, f"result_{i:04d}.json"))
                    for i in range(n)):
                if time.monotonic() > deadline:
                    break
                time.sleep(self.poll_s)
        # submit == "none": stage only — collection below reports pending
        outs: List[Any] = []
        for i in range(n):
            path = os.path.join(batch_dir, f"result_{i:04d}.json")
            if not os.path.exists(path):
                outs.append(ChannelError(
                    "crash", f"no result file {path} (task missing, "
                    f"killed, or not yet submitted)"))
                continue
            try:
                with open(path) as f:
                    response = json.load(f)
                if response.get("schema") != PAYLOAD_SCHEMA:
                    raise ValueError(f"schema {response.get('schema')!r}")
                outs.append(response)
            except (ValueError, OSError) as e:
                outs.append(ChannelError("frame", f"bad result file "
                                         f"{path}: {e}"))
        return outs

    def describe(self) -> str:
        return format_spec("slurm", {"array": self.array,
                                     "submit": self.submit}, sep=";")


CHANNELS: Dict[str, Any] = {
    "inline": InlineChannel,
    "local": LocalChannel,
    "ssh": SSHChannel,
    "slurm": SlurmChannel,
}


def register_channel(name: str, factory: Any) -> None:
    register_factory(CHANNELS, name, factory, "host channel")


def get_channel(spec: str, *, default_slots: Optional[int] = None
                ) -> HostChannel:
    """Resolve a channel spec (nested grammar: ``";"``-separated params,
    list continuation — ``"local"``, ``"local:n=4"``,
    ``"ssh:hosts=a;b;c"``, ``"slurm:array=8;submit=bash"``). A trailing
    ``":"`` on a bare name is tolerated (``"local:"``). ``default_slots``
    seeds the local channel's slot count when the spec doesn't."""
    name, params = parse_spec(str(spec).rstrip(":"), sep=";",
                              merge_unkeyed=True)
    factory = CHANNELS.get(name)
    if factory is None:
        raise KeyError(f"no host channel registered for {spec!r}; known: "
                       f"{sorted(CHANNELS)}")
    if name == "local" and "n" not in params and default_slots:
        params["n"] = default_slots
    try:
        return factory(**params)
    except TypeError as e:
        raise KeyError(f"bad parameters for host channel {spec!r}: {e}") \
            from e


# ---------------------------------------------------------------------------
# slot pool: prefer surviving slots, avoid a shard's own failed slots
# ---------------------------------------------------------------------------

class _SlotPool:
    def __init__(self, slots: Sequence[str]):
        self._order = {s: i for i, s in enumerate(slots)}
        self._free = list(slots)
        self._failures = {s: 0 for s in slots}
        self._cv = threading.Condition()

    def acquire(self, avoid: Sequence[str] = ()) -> str:
        """Block for a free slot. Preference order: slots this shard has
        not failed on, then fewest recorded failures (surviving slots
        first), then stable index — so a retry lands on a different,
        healthier worker whenever one is free."""
        with self._cv:
            while not self._free:
                self._cv.wait()
            s = min(self._free, key=lambda x: (x in avoid,
                                               self._failures[x],
                                               self._order[x]))
            self._free.remove(s)
            return s

    def release(self, slot: str, *, failed: bool) -> None:
        with self._cv:
            if failed:
                self._failures[slot] += 1
            self._free.append(slot)
            self._cv.notify()


# ---------------------------------------------------------------------------
# the hosts executor
# ---------------------------------------------------------------------------

class LauncherError(RuntimeError):
    """A shard exhausted its retry budget. Carries the full attempt log
    so the operator sees every slot/failure that was tried."""

    def __init__(self, msg: str, attempts: List[dict]):
        super().__init__(msg)
        self.attempts = attempts


class HostsExecutor(SweepExecutor):
    """``parallel="hosts:channel=...,n=K,retries=R"``: partition with the
    shared stack-key partitioner, dispatch each shard to an independent
    host process through the channel, retry failures on surviving slots,
    merge order-stably — bitwise parity with ``parallel="none"`` by the
    same argument as the spawn pool, because shards are deterministic
    functions of the partition and retries re-run the identical payload.

    Parameters (spec grammar): ``channel`` — a nested channel spec or a
    ready :class:`HostChannel` instance (tests inject fakes this way);
    ``n`` — shard count, defaulting to the channel's slot count;
    ``retries`` — extra attempts per shard; ``backoff`` — base seconds
    for exponential backoff (``backoff * 2**(attempt-1)``); ``timeout`` —
    per-attempt seconds; ``inject_kill`` — fault injection: the shard
    index whose *first* attempt gets ``REPRO_LAUNCHER_INJECT=sigkill``
    (the CI fault gate's hook).
    """

    def __init__(self, channel: Any = "local", n: Optional[int] = None,
                 retries: int = 2, backoff: float = 0.05,
                 timeout: Optional[float] = None,
                 inject_kill: Optional[int] = None):
        if n is not None and n < 1:
            raise ValueError(f"hosts executor needs n >= 1, got {n}")
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        if backoff < 0:
            raise ValueError(f"backoff must be >= 0, got {backoff}")
        self.channel = channel
        self.n = n
        self.retries = retries
        self.backoff = backoff
        self.timeout = timeout
        self.inject_kill = inject_kill

    def _resolve_channel(self) -> HostChannel:
        if isinstance(self.channel, HostChannel):
            return self.channel
        return get_channel(str(self.channel), default_slots=self.n)

    def execute(self, labels, cfgs, data, *, stack):
        return self.execute_with_meta(labels, cfgs, data, stack=stack)[0]

    def execute_with_meta(self, labels, cfgs, data, *, stack,
                          on_shard=None, stop=None):
        """``on_shard(shard_index, response_dict)`` — when given — fires as
        each shard's response lands (from the dispatching thread), which is
        what the sweep service streams to its clients: the merge becomes
        incremental instead of barriered. ``stop`` is an optional
        :class:`threading.Event`; once set, no *new* shard attempt starts
        and the run fails fast with a ``cancelled`` attempt log (job
        cancellation, DESIGN.md §12). Neither affects the merged values —
        both are pure control-plane hooks."""
        channel = self._resolve_channel()
        n = self.n if self.n is not None else max(1, len(channel.slots()))
        shards = [s for s in partition_runs(cfgs, n) if s]
        encoded = encode_dataset(data)      # once; identical for all shards
        requests = [build_request(k, [labels[i] for i in idxs],
                                  [cfgs[i] for i in idxs], encoded, stack)
                    for k, idxs in enumerate(shards)]
        if not shards:
            return [], {"launcher": {"channel": channel.describe(),
                                     "n_shards": 0, "shards": []}}
        logs: List[Dict[str, Any]] = [
            {"shard": k, "runs": list(idxs), "attempts": []}
            for k, idxs in enumerate(shards)]
        if channel.batch:
            outs = self._dispatch_batch(channel, requests, logs,
                                        on_shard=on_shard, stop=stop)
        else:
            outs = self._dispatch_slots(channel, requests, logs,
                                        on_shard=on_shard, stop=stop)
        results = merge_shard_payloads(
            len(cfgs), shards,
            [(r["result"], r["dispatch_counts"]) for r in outs])
        meta = {"launcher": {
            "channel": channel.describe(),
            "n_shards": len(shards),
            "retries": self.retries,
            "attempts_total": sum(len(l["attempts"]) for l in logs),
            "shards": logs,
        }}
        return results, meta

    # -- interactive channels (inline / local / ssh) ------------------------
    def _dispatch_slots(self, channel, requests, logs, *,
                        on_shard=None, stop=None):
        pool = _SlotPool(channel.slots())
        stats = _stats()

        def run_one(k: int) -> Dict[str, Any]:
            failed_on: List[str] = []
            for attempt in range(1, self.retries + 2):
                if stop is not None and stop.is_set():
                    logs[k]["attempts"].append(
                        {"attempt": attempt, "slot": None,
                         "status": "cancelled"})
                    raise LauncherError(f"shard {k} cancelled before "
                                        f"attempt {attempt}",
                                        logs[k]["attempts"])
                slot = pool.acquire(avoid=failed_on)
                extra_env = ({INJECT_ENV: "sigkill"}
                             if (self.inject_kill == k and attempt == 1)
                             else None)
                t0 = time.monotonic()
                try:
                    response = channel.run(slot, requests[k],
                                           timeout=self.timeout,
                                           extra_env=extra_env)
                    self._check(response, k)
                except ChannelError as e:
                    pool.release(slot, failed=True)
                    failed_on.append(slot)
                    elapsed = time.monotonic() - t0
                    stats.increment("launcher.shard.attempts")
                    stats.increment("launcher.shard.failures",
                                    tags={"kind": e.kind})
                    stats.timing("launcher.shard.attempt_ms",
                                 elapsed * 1e3)
                    logs[k]["attempts"].append({
                        "attempt": attempt, "slot": slot,
                        "status": e.kind, "error": e.detail,
                        "elapsed_s": round(elapsed, 3)})
                    if attempt > self.retries:
                        raise LauncherError(
                            f"shard {k} failed {attempt} attempt(s), "
                            f"retry budget {self.retries} exhausted; "
                            f"last: {e}", logs[k]["attempts"]) from e
                    stats.increment("launcher.shard.retries")
                    time.sleep(self.backoff * (2 ** (attempt - 1)))
                    continue
                pool.release(slot, failed=False)
                elapsed = time.monotonic() - t0
                stats.increment("launcher.shard.attempts")
                stats.increment("launcher.shard.ok")
                stats.timing("launcher.shard.attempt_ms", elapsed * 1e3)
                logs[k]["attempts"].append({
                    "attempt": attempt, "slot": slot, "status": "ok",
                    "elapsed_s": round(elapsed, 3)})
                if on_shard is not None:
                    on_shard(k, response)
                return response
            raise AssertionError("unreachable")

        with ThreadPoolExecutor(
                max_workers=min(len(requests),
                                len(channel.slots()))) as tpool:
            return list(tpool.map(run_one, range(len(requests))))

    # -- batch channels (slurm) ---------------------------------------------
    def _dispatch_batch(self, channel, requests, logs, *,
                        on_shard=None, stop=None):
        stats = _stats()
        outs: List[Any] = [None] * len(requests)
        pending = list(range(len(requests)))
        for attempt in range(1, self.retries + 2):
            if stop is not None and stop.is_set():
                for k in pending:
                    logs[k]["attempts"].append(
                        {"attempt": attempt, "slot": None,
                         "status": "cancelled"})
                raise LauncherError(
                    f"shard(s) {pending} cancelled before batch attempt "
                    f"{attempt}",
                    [a for k in pending for a in logs[k]["attempts"]])
            batch = channel.run_batch([requests[k] for k in pending],
                                      timeout=self.timeout)
            still: List[int] = []
            for k, result in zip(pending, batch):
                entry = {"attempt": attempt, "slot": "slurm/array"}
                stats.increment("launcher.shard.attempts")
                if isinstance(result, ChannelError):
                    entry.update(status=result.kind, error=result.detail)
                    stats.increment("launcher.shard.failures",
                                    tags={"kind": result.kind})
                    still.append(k)
                else:
                    try:
                        self._check(result, k)
                        outs[k] = result
                        entry.update(status="ok")
                        stats.increment("launcher.shard.ok")
                        if on_shard is not None:
                            on_shard(k, result)
                    except ChannelError as e:
                        entry.update(status=e.kind, error=e.detail)
                        stats.increment("launcher.shard.failures",
                                        tags={"kind": e.kind})
                        still.append(k)
                logs[k]["attempts"].append(entry)
            pending = still
            if not pending:
                return outs
            if attempt <= self.retries:
                stats.increment("launcher.shard.retries", len(pending))
                time.sleep(self.backoff * (2 ** (attempt - 1)))
        raise LauncherError(
            f"shard(s) {pending} failed after {self.retries + 1} batch "
            f"attempt(s)",
            [a for k in pending for a in logs[k]["attempts"]])

    @staticmethod
    def _check(response: Dict[str, Any], shard: int) -> None:
        if response.get("shard") != shard:
            raise ChannelError("frame", f"response for shard "
                               f"{response.get('shard')!r}, expected "
                               f"{shard}")
        if "result" not in response or "dispatch_counts" not in response:
            raise ChannelError("frame", "response missing result/"
                               "dispatch_counts")


# ---------------------------------------------------------------------------
# worker entry points: `python -m repro.core.launcher --worker` (stream)
# and `--input/--output` (file mode, SLURM array tasks)
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro.core.launcher",
        description="Shard worker for the multi-host sweep launcher "
                    "(DESIGN.md §8)")
    ap.add_argument("--worker", action="store_true",
                    help="stream mode: shard request JSON on stdin, "
                         "framed response on stdout")
    ap.add_argument("--input", help="file mode: read the shard request "
                                    "from this JSON file")
    ap.add_argument("--output", help="file mode: write the response here")
    args = ap.parse_args(argv)

    if args.input or args.output:
        if not (args.input and args.output):
            ap.error("file mode needs both --input and --output")
        with open(args.input) as f:
            request = json.load(f)
        response = run_request(request)
        tmp = args.output + ".tmp"
        with open(tmp, "w") as f:
            json.dump(response, f)
        os.replace(tmp, args.output)     # atomic: collectors never see
        return 0                         # a half-written result
    if args.worker:
        request = json.loads(sys.stdin.read())
        response = run_request(request)
        sys.stdout.write(frame_response(response))
        sys.stdout.flush()
        return 0
    ap.error("pick a mode: --worker or --input/--output")
    return 2


if __name__ == "__main__":
    sys.exit(main())
