"""HLO cost-walker unit tests (synthetic HLO) + dry-run record analysis."""
import glob
import json
import os

import pytest

from repro.roofline.analysis import HW, model_flops_for, roofline_from_record
from repro.roofline.hlo import analyze_hlo

SYNTH = """HloModule test

%cond (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(10)
  ROOT %cmp = pred[] compare(%iv, %c), direction=LT
}

%body (p: (s32[])) -> (s32[]) {
  %p = (s32[]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %a = f32[128,256]{1,0} parameter(1)
  %b = f32[256,64]{1,0} parameter(2)
  %d = f32[128,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,64]{1,0} all-reduce(%d), replica_groups={{0,1},{2,3}}
  ROOT %t = (s32[]) tuple(%iv)
}

ENTRY %main (x: f32[128,256]) -> f32[128,64] {
  %x = f32[128,256]{1,0} parameter(0)
  %w = f32[256,64]{1,0} parameter(1)
  %d0 = f32[128,64]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %t0 = (s32[]) tuple()
  %wh = (s32[]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[128,64]{1,0} all-gather(%d0), replica_groups={{0,256}}, dimensions={0}
}
"""


def test_analyze_synthetic_hlo():
    a = analyze_hlo(SYNTH)
    # entry dot: 2*128*64*256 ; body dot x10 trips
    dot_flops = 2 * 128 * 64 * 256
    assert a["flops"] == pytest.approx(dot_flops * 11)
    coll = a["collectives"]
    # all-reduce in body (10x) + all-gather in entry
    ar_bytes = 128 * 64 * 4
    assert coll["by_op"]["all-reduce"] == pytest.approx(ar_bytes * 10)
    assert coll["by_op"]["all-gather"] == pytest.approx(ar_bytes)
    # the all-gather replica group {0,256} crosses the pod boundary
    assert coll["dcn_bytes"] == pytest.approx(ar_bytes)


def test_roofline_terms():
    rec = {"num_devices": 256, "flops": 197e12, "bytes_accessed": 819e9,
           "analytic_bytes": 819e9,
           "collectives": {"total_bytes": 50e9, "dcn_bytes": 0.0},
           "model_flops": 197e12 * 256 * 0.5}
    out = roofline_from_record(rec)
    assert out["compute_s"] == pytest.approx(1.0)
    assert out["memory_s"] == pytest.approx(1.0)
    assert out["collective_s"] == pytest.approx(1.0)
    assert out["useful_fraction"] == pytest.approx(0.5)


def test_model_flops():
    from repro.configs import INPUT_SHAPES, get_config
    cfg = get_config("llama3.2-3b")
    f = model_flops_for(cfg, INPUT_SHAPES["train_4k"])
    assert f == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=0.01)
    fd = model_flops_for(cfg, INPUT_SHAPES["decode_32k"])
    assert fd == pytest.approx(2 * cfg.param_count() * 128, rel=0.01)


DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


@pytest.mark.skipif(not glob.glob(os.path.join(DRYRUN_DIR, "*.json")),
                    reason="dry-run cache not present")
def test_dryrun_records_complete():
    """Every (arch x shape x mesh) combo either compiled OK or is one of the
    documented long_500k full-attention skips. This asserts deliverable (e).
    """
    recs = [json.load(open(f)) for f in
            glob.glob(os.path.join(DRYRUN_DIR, "*.json"))]
    by_status = {}
    for r in recs:
        by_status.setdefault(r["status"], []).append(r)
    assert not by_status.get("error"), [
        (r["arch"], r["shape"]) for r in by_status.get("error", [])]
    for r in by_status.get("skipped", []):
        assert r["shape"] == "long_500k"
    for r in by_status.get("ok", []):
        assert r.get("hlo_flops", 0) > 0
        assert "collectives" in r
