"""CI environment guards.

The property suites (partitioner, transports, launcher retry/merge) fall
back to the deterministic shim in tests/_hypothesis_fallback.py when
``hypothesis`` is not installed — fine for offline dev boxes, but CI must
never silently run them degraded. The CI workflow installs real
hypothesis (requirements-ci.txt); these tests fail red if that install
regresses. GitHub Actions always sets ``CI=true``, so the guards
self-activate there and skip locally.
"""
import os

import pytest

IN_CI = os.environ.get("CI", "").lower() == "true"

pytestmark = pytest.mark.skipif(
    not IN_CI, reason="guards the CI environment only (CI=true)")


def test_real_hypothesis_is_installed_in_ci():
    import hypothesis  # noqa: F401 — ImportError = degraded CI

    assert hypothesis.__version__


@pytest.mark.parametrize("module", ["test_parallel_sweep", "test_launcher",
                                    "test_transports", "test_sweep_service",
                                    "test_service_cache"])
def test_property_suites_bind_real_hypothesis_not_the_shim(module):
    """The try/except import in each property suite must have resolved to
    the real library: the shim's ``given`` lives in
    ``_hypothesis_fallback``, the real one in ``hypothesis.core``."""
    import importlib

    m = importlib.import_module(module)
    bound_in = m.given.__module__
    assert not bound_in.startswith("_hypothesis_fallback"), \
        f"{module} is running on the fallback shim in CI"
    assert bound_in.startswith("hypothesis"), bound_in
