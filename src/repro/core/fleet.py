"""Batched fleet-round engine: one window = O(1) jitted dispatches.

The loop engine in :mod:`repro.core.htl` issues one ``train_svm`` and (for
A2AHTL) one ``greedytl`` dispatch *per Data Collector*, so a sweep over many
scenario configurations (paper Tables 2-6) pays thousands of tiny dispatches
and host syncs. This engine groups the per-window DC fleet by bucketed
sample capacity (:func:`repro.core.svm.sample_cap` — masked padding rows
are dead compute, and under Zipf allocation most mules hold <16 of a
window's 100 observations), pads each group's DC count to a bucketed fleet
capacity, and runs

* base training as one :func:`~repro.core.svm.train_svm_fleet` per sample
  bucket (``vmap`` over the DC axis), and
* the A2AHTL refine step as one
  :func:`~repro.core.greedytl.greedytl_fleet_stacked` per sample bucket,

so dispatch count per window is bounded by the (tiny, fixed) bucket set and
shapes are stable across windows — Poisson-varying fleet sizes land on the
same handful of executables, no recompiles. Energy is charged through the
same :class:`~repro.core.topology.Topology` patterns as the loop engine, so
ledger totals match exactly; model updates match numerically — the refine
step maps the exact per-call computation graph over the fleet (bitwise),
base training is vmapped (equal to low-order bits) — so F1 curves agree
within 1e-4 (tests/test_fleet_engine.py).

The ``*_stacked`` runners extend the same trick across scenario replicas
(ROADMAP: batched multi-seed rounds): every replica's fleet concatenates
into the flat DC axis — with per-DC source pools, since each replica
exchanged its own base models — so one dispatch per bucket serves a whole
seed/config group of a sweep, while per-replica ledgers, rng streams and
host-side control flow stay exactly as in the unstacked runners.

Election/subsampling policies are resolved through the :mod:`~repro.core.
htl` module at call time, so policy ablations that monkey-patch the loop
engine (benchmarks/ablations.py) apply to this engine too.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import htl
from repro.core.energy import INDEX_BYTES, Ledger, MODEL_BYTES
from repro.core.greedytl import greedytl_fleet_stacked
from repro.core.htl import DC, build_source_pool
from repro.core.metrics import trimmed_mean
from repro.core.svm import pad_fleet, sample_cap, train_svm_fleet
from repro.core.topology import Topology, fleet_nodes

FLEET_BUCKETS = (1, 2, 4, 8, 16)   # padded DC-axis caps (cover Poisson(7))


def fleet_cap(n_dcs: int) -> int:
    """Bucketed DC-axis capacity: Poisson-varying fleet sizes land on a
    handful of stable shapes (multiples of 32 beyond the largest bucket, so
    stacked multi-replica fleets stay near-dense), keeping the jit cache
    tiny and padding waste low."""
    for b in FLEET_BUCKETS:
        if n_dcs <= b:
            return b
    return -(-n_dcs // 32) * 32


def _sample_groups(dcs: Sequence[DC], cap: int) -> dict:
    """{bucketed sample capacity: [index into dcs]} — the dispatch plan."""
    groups: dict = {}
    for i, d in enumerate(dcs):
        groups.setdefault(sample_cap(d.n, cap), []).append(i)
    return groups


def train_base_bucketed(dcs: Sequence[DC], cap: int, num_classes: int
                        ) -> List[np.ndarray]:
    """Base SVMs for an arbitrary DC list (one fleet or several stacked
    replicas) in O(1) dispatches: one ``train_svm_fleet`` per sample
    bucket, DC counts padded to bucketed fleet capacities. Masked rows and
    padding DCs contribute nothing, so each model equals its individually
    trained counterpart to float roundoff. Returns one (F+1, C) per DC."""
    out: List[Optional[np.ndarray]] = [None] * len(dcs)
    for b, idxs in sorted(_sample_groups(dcs, cap).items()):
        sel = [dcs[i] for i in idxs]
        x, y, m, _ = pad_fleet([d.x for d in sel], [d.y for d in sel],
                               b, fleet_cap(len(sel)))
        w = train_svm_fleet(jnp.asarray(x), jnp.asarray(y), jnp.asarray(m),
                            num_classes=num_classes)
        w = np.asarray(w)
        for j, i in enumerate(idxs):
            out[i] = w[j]
    return out


def refine_bucketed(dcs: Sequence[DC], srcs: Sequence[np.ndarray],
                    src_masks: Sequence[np.ndarray], cap: int,
                    num_classes: int) -> List[np.ndarray]:
    """GreedyTL for an arbitrary DC list, each against ITS OWN source pool,
    in O(1) dispatches (one ``greedytl_fleet_stacked`` per sample bucket).
    Padding DCs carry all-zero masks and leave the greedy loop after one
    step, so they are nearly free. Returns one (F+1, C) per DC. The greedy
    loop inside runs the incremental factor carry (DESIGN.md §11) by
    default — accepting k sources never adds dispatches or recompiles."""
    out: List[Optional[np.ndarray]] = [None] * len(dcs)
    for b, idxs in sorted(_sample_groups(dcs, cap).items()):
        sel = [dcs[i] for i in idxs]
        lcap = fleet_cap(len(sel))
        x, y, m, _ = pad_fleet([d.x for d in sel], [d.y for d in sel],
                               b, lcap)
        src = np.zeros((lcap,) + srcs[idxs[0]].shape, np.float32)
        sm = np.zeros((lcap,) + src_masks[idxs[0]].shape, np.float32)
        for j, i in enumerate(idxs):
            src[j] = srcs[i]
            sm[j] = src_masks[i]
        w, _ = greedytl_fleet_stacked(jnp.asarray(x), jnp.asarray(y),
                                      jnp.asarray(m), jnp.asarray(src),
                                      jnp.asarray(sm),
                                      num_classes=num_classes)
        w = np.asarray(w)
        for j, i in enumerate(idxs):
            out[i] = w[j]
    return out


def run_window_a2a(dcs: List[DC], prev_global: Optional[np.ndarray],
                   ledger: Ledger, tech: str, *, cap: int, num_classes: int,
                   n_subsample: Optional[int] = None,
                   rng: Optional[np.random.Generator] = None,
                   robust: float = 0.0) -> np.ndarray:
    """One A2AHTL round (Algorithm 1), batched. Returns the new global
    model. Drop-in replacement for :func:`repro.core.htl.run_window_a2a`
    (``robust`` = the combine's trim fraction, 0.0 = plain mean)."""
    out = run_window_a2a_stacked([dcs], [prev_global], [ledger], [tech],
                                 cap=cap, num_classes=num_classes,
                                 n_subsamples=[n_subsample],
                                 rngs=None if rng is None else [rng],
                                 robusts=[robust])
    return out[0]


def run_window_star(dcs: List[DC], prev_global: Optional[np.ndarray],
                    ledger: Ledger, tech: str, *, cap: int, num_classes: int,
                    n_subsample: Optional[int] = None,
                    rng: Optional[np.random.Generator] = None,
                    robust: float = 0.0) -> np.ndarray:
    """One StarHTL round (Algorithm 2), batched base training. Drop-in
    replacement for :func:`repro.core.htl.run_window_star` (``robust``
    accepted for interchangeability; StarHTL has no combine)."""
    out = run_window_star_stacked([dcs], [prev_global], [ledger], [tech],
                                  cap=cap, num_classes=num_classes,
                                  n_subsamples=[n_subsample],
                                  rngs=None if rng is None else [rng],
                                  robusts=[robust])
    return out[0]


# ---------------------------------------------------------------------------
# replica-stacked rounds: one dispatch set serves every replica of a sweep
# group (seed replicas, or configs differing only in collection/energy
# parameters) — per-replica ledgers and control flow stay separate
# ---------------------------------------------------------------------------

def _split_live(fleets):
    """(replica, non-empty DCs) pairs for the replicas that reach a
    learning round; a replica whose window collected nothing keeps its
    previous global model."""
    live = [(s, [d for d in dcs if d.n > 0]) for s, dcs in enumerate(fleets)]
    return [(s, dcs) for s, dcs in live if dcs]


def _base_and_singles(fleets, prev_globals, cap, num_classes, out):
    """Shared head of both stacked rounds: flat-stacked base training for
    every live replica, then the single-DC early exit (that DC's base model,
    averaged with the previous global model if any) resolved host-side.
    Returns [(replica, dcs, base models)] for replicas with >= 2 DCs."""
    live = _split_live(fleets)
    if not live:
        return []
    flat = [d for _, dcs in live for d in dcs]
    base = train_base_bucketed(flat, cap, num_classes)
    multi, ofs = [], 0
    for s, dcs in live:
        b = base[ofs:ofs + len(dcs)]
        ofs += len(dcs)
        if len(dcs) == 1:
            only = b[0]
            out[s] = (only if prev_globals[s] is None
                      else 0.5 * (only + prev_globals[s]))
        else:
            multi.append((s, dcs, b))
    return multi


def run_window_a2a_stacked(fleets: List[List[DC]],
                           prev_globals: List[Optional[np.ndarray]],
                           ledgers: List[Ledger], techs: List[str], *,
                           cap: int, num_classes: int,
                           n_subsamples: Optional[List[Optional[int]]] = None,
                           rngs: Optional[List[np.random.Generator]] = None,
                           robusts: Optional[List[float]] = None
                           ) -> List[Optional[np.ndarray]]:
    """One A2AHTL round for every replica — O(1) dispatches TOTAL.

    ``fleets[s]``/``ledgers[s]``/``techs[s]``/... belong to replica s; all
    host-side control flow (AP election, topology charging, early exits,
    subsampling rng, combine trim fraction ``robusts[s]``) is per replica,
    exactly as in the unstacked round, so each replica's ledger and model
    trajectory match a sequential run. Returns the new global model per
    replica.
    """
    S = len(fleets)
    rngs = rngs or [np.random.default_rng(0) for _ in range(S)]
    n_subsamples = n_subsamples or [None] * S
    robusts = robusts or [0.0] * S
    out: List[Optional[np.ndarray]] = list(prev_globals)
    multi = _base_and_singles(fleets, prev_globals, cap, num_classes, out)
    if not multi:
        return out

    # host side per replica: m0 exchange charge, source pool, subsample
    topos, subs, srcs, smasks, counts = [], [], [], [], []
    for s, dcs, b in multi:
        topo = Topology(ledgers[s], techs[s],
                        fleet_nodes(dcs, htl._ap_name(dcs)))
        topo.exchange_all(MODEL_BYTES, what="m0 exchange")
        topos.append(topo)
        src, src_mask = build_source_pool(list(b), prev_globals[s])
        for d in dcs:
            subs.append(htl._subsample(d, n_subsamples[s], num_classes,
                                       rngs[s]))
            srcs.append(src)
            smasks.append(src_mask)
        counts.append(len(dcs))

    # refine every replica's fleet against its own pool — O(buckets) calls
    refined = refine_bucketed(subs, srcs, smasks, cap, num_classes)

    ofs = 0
    for i, (s, dcs, _) in enumerate(multi):
        r = np.stack(refined[ofs:ofs + counts[i]])
        ofs += counts[i]
        ap = htl._ap_name(dcs)
        center = next((d for d in dcs if d.name == ap), dcs[0])
        topos[i].gather(topos[i].node(center.name), MODEL_BYTES,
                        what="m1 gather")
        out[s] = trimmed_mean(r, robusts[s])
    return out


def run_window_star_stacked(fleets: List[List[DC]],
                            prev_globals: List[Optional[np.ndarray]],
                            ledgers: List[Ledger], techs: List[str], *,
                            cap: int, num_classes: int,
                            n_subsamples: Optional[List[Optional[int]]]
                            = None,
                            rngs: Optional[List[np.random.Generator]] = None,
                            robusts: Optional[List[float]] = None
                            ) -> List[Optional[np.ndarray]]:
    """One StarHTL round for every replica — O(1) dispatches TOTAL.

    Center election and all message charging stay per replica; the
    per-replica GreedyTL "batch of one" calls stack into the flat DC axis
    with per-replica source pools. ``robusts`` is accepted for signature
    interchangeability with the A2A runner (StarHTL has no combine).
    """
    S = len(fleets)
    rngs = rngs or [np.random.default_rng(0) for _ in range(S)]
    n_subsamples = n_subsamples or [None] * S
    out: List[Optional[np.ndarray]] = list(prev_globals)
    multi = _base_and_singles(fleets, prev_globals, cap, num_classes, out)
    if not multi:
        return out

    sids, subs, srcs, smasks = [], [], [], []
    for s, dcs, b in multi:
        topo = Topology(ledgers[s], techs[s],
                        fleet_nodes(dcs, htl._ap_name(dcs)))
        topo.exchange_all(INDEX_BYTES, what="entropy index")
        c_idx = int(np.argmax([htl.label_entropy(d.y, num_classes)
                               for d in dcs]))
        center = dcs[c_idx]
        topo.broadcast(topo.node(center.name), INDEX_BYTES, what="center id")
        topo.gather(topo.node(center.name), MODEL_BYTES, what="m0 to center")
        src, src_mask = build_source_pool(list(b), prev_globals[s])
        subs.append(htl._subsample(center, n_subsamples[s], num_classes,
                                   rngs[s]))
        srcs.append(src)
        smasks.append(src_mask)
        sids.append(s)

    refined = refine_bucketed(subs, srcs, smasks, cap, num_classes)
    for i, s in enumerate(sids):
        out[s] = refined[i]
    return out
