"""Scenario simulation (paper Sections 3, 5, 6).

A slotted data-collection process: ``windows`` collection windows of
``obs_per_window`` observations each. Observations are either collected by
SmartMules (802.15.4) or shipped to the Edge Server (NB-IoT). The number of
mules per window is Poisson(lambda); the per-mule allocation follows a Zipf
ranking (or uniform, Scenario 3). After each window a learning round runs
(centralised on the ES, or A2AHTL/StarHTL among the Data Collectors) and the
global model is evaluated on the held-out test set.

The per-window pipeline is decomposed into composable phases —

    collection policy -> learning round -> global EMA update -> eval

— each a module-level function, so alternative policies (engines,
topologies, collection schemes) compose without touching the driver.
Collection policies are a spec-string registry
(:data:`COLLECTION_POLICIES`, mirroring the transport registry in
:mod:`repro.core.topology`): builtin ``poisson_zipf`` (the paper's
process), ``uniform`` (Scenario 3), ``trace`` (deterministic replay of a
recorded per-mule allocation) and ``bursty`` (contiguous arrival runs).
The learning round runs on one of two engines: ``"fleet"`` (default,
O(1) jitted dispatches per window, :mod:`repro.core.fleet`) or ``"loop"``
(the per-DC reference, :mod:`repro.core.htl`); they are numerically
interchangeable (tests/test_fleet_engine.py).

:func:`run_sweep` evaluates many configurations while sharing the jitted
fleet trainers across them — the core workload of the paper's Tables 2-6.
With ``stack_seeds=True`` it additionally runs all stack-compatible
replicas of a configuration in lockstep, stacking them into the fleet DC
axis so one jitted dispatch per window serves every seed (per-seed energy
ledgers and rng streams stay separate — :func:`run_scenarios_stacked`).
Stack compatibility is *derived from field metadata*: every
:class:`ScenarioConfig` field tagged ``host_side`` steers only host-side
work (collection rng, energy charging, GreedyTL subsampling inputs, EMA
rate), never the shapes or semantics of the jitted calls, so
:func:`_stack_key` normalizes exactly those fields — new fields declare
their stacking behavior where they are defined.

This module is the scenario *engine room*; the declarative experiment
surface (``SweepSpec`` axes / presets / ``SweepResult``) lives in
:mod:`repro.core.experiment`, and :func:`run_scenario` / :func:`run_sweep`
remain as its thin compatibility layer.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fleet as fleet_engine
from repro.core import htl as loop_engine
from repro.core.energy import Ledger
from repro.core.htl import DC, apply_aggregation_heuristic
from repro.core.metrics import f_measure
from repro.core.registry import register_factory, resolve_spec
from repro.core.svm import pad_local, svm_predict, train_svm
from repro.data.synthetic_covtype import Dataset, NUM_CLASSES

ENGINES = {
    "fleet": {"a2a": fleet_engine.run_window_a2a,
              "star": fleet_engine.run_window_star},
    "loop": {"a2a": loop_engine.run_window_a2a,
             "star": loop_engine.run_window_star},
}

# Whole-scenario engines dispatch above the per-window ENGINES table:
# "scan" (repro.core.cityscan, imported lazily — it is heavier) rolls the
# per-window loop into one jitted lax.scan; with ``fleet_size`` set it runs
# the shard_map'd city engine instead of the collection stream.
SCENARIO_ENGINES = ("scan",)


def _host(doc: str = "") -> dict:
    """Field metadata marking a config field as *host-side*: it steers
    collection rng, energy charging or other host work but never the
    shapes/semantics of the jitted calls, so replicas differing only in
    host-side fields may run replica-stacked (see :func:`_stack_key`)."""
    return {"host_side": True, "doc": doc}


@dataclass(frozen=True)
class ScenarioConfig:
    windows: int = 100
    obs_per_window: int = 100
    lam_poisson: float = field(default=7.0, metadata=_host())
    zipf_alpha: float = field(default=1.5, metadata=_host())
    # fraction of each window shipped to the ES
    p_edge: float = field(default=0.0, metadata=_host())
    algo: str = "star"            # 'star' | 'a2a' | 'edge_only'
    # DC<->DC technology: any transport spec string registered in
    # repro.core.topology ('4g', 'wifi', 'ble', 'mesh:hops=3', 'lora:sf=12')
    tech: str = field(default="4g", metadata=_host())
    # Scenario 3: uniform allocation over mules (legacy switch; equivalent
    # to collection="uniform", kept so existing grids keep working)
    uniform: bool = field(default=False, metadata=_host())
    # data-aggregation heuristic (Section 6.3)
    aggregate: bool = field(default=False, metadata=_host())
    # GreedyTL points per class (Sec. 7)
    n_subsample: Optional[int] = field(default=None, metadata=_host())
    include_es_in_learning: bool = field(default=True, metadata=_host())
    cap: int = 160                # padded local-dataset capacity
    eval_every: int = 1
    seed: int = field(default=0, metadata=_host())
    engine: str = "fleet"         # 'fleet' (batched) | 'loop' (reference)
    # collection-policy spec string (COLLECTION_POLICIES): 'poisson_zipf',
    # 'uniform', 'trace:loads=60-25-15', 'bursty:burst=8'
    collection: str = field(default="poisson_zipf", metadata=_host())
    # "This model is used to update the model elaborated until the previous
    # time slot" (paper Section 3): the window model updates the global model
    # incrementally. We use an exponential moving average with this rate.
    global_update_rate: float = field(default=0.3, metadata=_host())
    # City mode (engine="scan" only): a fixed fleet of ``fleet_size`` DCs,
    # each drawing ``obs_per_dc`` observations per window ON DEVICE — the
    # 10^5-DC scaling axis (repro.core.cityscan.run_city). None = the
    # paper's host-side collection stream.
    fleet_size: Optional[int] = None
    obs_per_dc: int = 4
    # Base-SVM GD iterations, honored by the scan engine only (the
    # loop/fleet engines pin the paper's 200 — parity oracle); the city
    # preset trims it so 10^5-DC rounds fit the CI budget.
    train_iters: int = 200
    # --- realism axis (DESIGN.md §13) ---
    # Per-mule battery budget (mJ). When set, each mule's attributed drain
    # (Ledger.node_mj) is swept at the top of every window and a depleted
    # mule leaves the fleet for good (DC churn); None = infinite batteries.
    # Host-side: churn only changes which DCs the host hands the engines.
    battery_mj: Optional[float] = field(default=None, metadata=_host())
    # Concept-drift schedule applied to the observation stream: a spec
    # string over repro.data.synthetic_covtype.DRIFT_FACTORIES ("none",
    # "rotate:rate=0.05", "prior:at=0.5,gamma=0.5", "rotate_prior").
    drift: str = field(default="none", metadata=_host())
    # Per-live-mule-per-window probability of a faulty (byzantine) upload:
    # the mule's window labels arrive cyclically shifted by one class.
    byz_frac: float = field(default=0.0, metadata=_host())
    # Combine rule of the A2A refine step: "mean" (the paper's average)
    # or "trim:frac=F" (coordinate-wise F-trimmed mean, byzantine-robust).
    robust_agg: str = field(default="mean", metadata=_host())


@dataclass
class ScenarioResult:
    f1_curve: List[float]
    ledger: Ledger
    cfg: ScenarioConfig

    @property
    def final_f1(self) -> float:
        return self.f1_curve[-1]

    def converged_f1(self, start_frac: float = 0.5) -> float:
        """Paper: mean F1 over the converged interval (50th-100th window)."""
        k = int(len(self.f1_curve) * start_frac)
        return float(np.mean(self.f1_curve[k:]))

    @property
    def energy_total(self) -> float:
        return self.ledger.total()

    @property
    def energy_collection(self) -> float:
        return self.ledger.total("collection")

    @property
    def energy_learning(self) -> float:
        return self.ledger.total("learning")


def _zipf_probs(n: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return p / p.sum()


# ---------------------------------------------------------------------------
# collection-policy registry (mirrors the transport registry)
# ---------------------------------------------------------------------------

# A policy maps (cfg, rng, n_mule_obs[, window]) -> (L mules,
# per-observation mule assignment in [0, L)); factories take the
# spec-string parameters. ``window`` is the 0-based window index — the
# builtin stochastic policies ignore it (their dynamics live in the rng
# stream), the trace-file policy uses it as its cursor.
CollectionPolicy = Callable[["ScenarioConfig", np.random.Generator, int, int],
                            Tuple[int, np.ndarray]]


def _poisson_zipf_policy() -> CollectionPolicy:
    """The paper's process: Poisson(lambda) mules, Zipf(alpha) allocation."""
    def policy(cfg, rng, n, window=0):
        L = max(1, rng.poisson(cfg.lam_poisson))
        return L, rng.choice(L, size=n, p=_zipf_probs(L, cfg.zipf_alpha))
    return policy


def _uniform_policy() -> CollectionPolicy:
    """Scenario 3: Poisson(lambda) mules, uniform allocation."""
    def policy(cfg, rng, n, window=0):
        L = max(1, rng.poisson(cfg.lam_poisson))
        return L, rng.integers(0, L, size=n)
    return policy


def _apportion(shares: np.ndarray, n: int) -> Tuple[int, np.ndarray]:
    """Largest-remainder apportionment of ``n`` observations over per-mule
    ``shares`` — the deterministic allocation core shared by the ``trace``
    and ``trace_file`` policies."""
    L = len(shares)
    quota = shares / shares.sum() * n
    counts = np.floor(quota).astype(np.int64)
    order = np.argsort(-(quota - counts))
    counts[order[:n - counts.sum()]] += 1
    return L, np.repeat(np.arange(L), counts)


def _trace_policy(loads: str = "60-25-15") -> CollectionPolicy:
    """Deterministic replay of a recorded allocation: ``loads`` is a
    dash-separated per-mule load trace (relative shares), apportioned to
    each window's observations by largest remainder — same mule fleet,
    same split, every window, every seed."""
    shares = np.array([int(s) for s in str(loads).split("-")], np.float64)
    if len(shares) == 0 or (shares < 0).any() or shares.sum() <= 0:
        raise ValueError(f"trace loads must be non-negative with a positive "
                         f"sum, got {loads!r}")

    def policy(cfg, rng, n, window=0):
        return _apportion(shares, n)
    return policy


def _trace_file_policy(path: str = "") -> CollectionPolicy:
    """Windowed cursor over a mobility-trace *file*
    (:mod:`repro.data.mobility`): window ``t`` apportions the mule share of
    the window's observations over row ``t % windows`` of the trace's
    ``(windows, mules)`` load matrix — the fleet moves window to window,
    and a scenario longer than the trace wraps around. Mules with zero
    load in a window simply collect nothing. Entirely rng-independent, so
    every seed replica sees the same fleet trajectory."""
    if not path:
        raise ValueError(
            "trace_file needs path=<trace json>; generate one with "
            "repro.data.mobility.generate_trace")
    from repro.data.mobility import load_trace
    loads = load_trace(str(path))

    def policy(cfg, rng, n, window=0):
        return _apportion(loads[window % loads.shape[0]], n)
    return policy


def _bursty_policy(burst: float = 8.0) -> CollectionPolicy:
    """Bursty arrivals: observations reach mules in contiguous runs of
    geometric mean length ``burst`` (a mule meets a sensor and drains it),
    run owners drawn from the Zipf(alpha) ranking — heavier short-term
    skew than i.i.d. Zipf at the same marginal allocation."""
    if burst < 1.0:
        raise ValueError(f"burst length must be >= 1, got {burst}")

    def policy(cfg, rng, n, window=0):
        L = max(1, rng.poisson(cfg.lam_poisson))
        p = _zipf_probs(L, cfg.zipf_alpha)
        assign = np.empty(n, np.int64)
        i = 0
        while i < n:
            run = int(rng.geometric(1.0 / burst))
            assign[i:i + run] = rng.choice(L, p=p)
            i += run
        return L, assign
    return policy


COLLECTION_POLICIES: Dict[str, Callable[..., CollectionPolicy]] = {
    "poisson_zipf": _poisson_zipf_policy,
    "uniform": _uniform_policy,
    "trace": _trace_policy,
    "trace_file": _trace_file_policy,
    "bursty": _bursty_policy,
}

_POLICY_CACHE: Dict[str, CollectionPolicy] = {}


def register_collection_policy(name: str,
                               factory: Callable[..., CollectionPolicy]
                               ) -> None:
    """Register a collection-policy factory under a spec name."""
    register_factory(COLLECTION_POLICIES, name, factory,
                     "collection policy")


def get_collection_policy(spec: str) -> CollectionPolicy:
    """Resolve a policy spec string (``"bursty:burst=8"``) to a cached
    policy callable; :class:`KeyError` on unknown names/malformed specs."""
    return resolve_spec(spec, COLLECTION_POLICIES, _POLICY_CACHE,
                        "collection policy")


def _effective_collection(cfg: ScenarioConfig) -> str:
    """The legacy ``uniform`` switch is sugar for ``collection="uniform"``
    (only when the policy was left at its default, so explicit policies
    always win)."""
    if cfg.uniform and cfg.collection == "poisson_zipf":
        return "uniform"
    return cfg.collection


# ---------------------------------------------------------------------------
# realism axis: battery-driven churn, robust aggregation, drifted streams
# (DESIGN.md §13)
# ---------------------------------------------------------------------------

class ChurnBook:
    """Per-replica churn state: one battery budget, and which mules have
    already depleted it (name -> window of death). Depletion is swept at
    the top of every window against the ledger's attributed per-node drain
    (:attr:`~repro.core.energy.Ledger.node_mj`) in sorted-name order, so
    every driver that replays the same windows (fleet engine, scan
    planner, stacked replicas) kills the same mules at the same windows —
    churn parity is by construction, not by coincidence. The ES is mains
    powered and never churns."""

    def __init__(self, battery_mj: float):
        self.battery_mj = float(battery_mj)
        self.dead: Dict[str, int] = {}

    def sweep(self, ledger: Ledger, window: int) -> None:
        """Retire every node whose attributed drain crossed the budget."""
        for name in sorted(ledger.node_mj):
            if name == "ES" or name in self.dead:
                continue
            if ledger.node_mj[name] >= self.battery_mj:
                self.dead[name] = window
                ledger.churn(name, window)


def resolve_robust(spec: str) -> float:
    """Trim fraction of a robust-aggregation spec: ``"mean"`` -> 0.0 (the
    paper's plain average), ``"trim[:frac=F]"`` -> F (coordinate-wise
    trimmed mean, default 0.2). Same fail-fast contract as the spec
    registries: unknown names/parameters raise :class:`KeyError`, invalid
    fractions :class:`ValueError`."""
    from repro.core.registry import parse_spec
    try:
        name, params = parse_spec(spec)
    except ValueError as e:
        raise KeyError(str(e)) from e
    if name == "mean":
        if params:
            raise KeyError(f"robust_agg 'mean' takes no parameters, "
                           f"got {spec!r}")
        return 0.0
    if name == "trim":
        frac = params.pop("frac", 0.2)
        if params:
            raise KeyError(f"unknown robust_agg parameters "
                           f"{sorted(params)} in {spec!r}")
        if isinstance(frac, bool) or not isinstance(frac, (int, float)) \
                or not 0.0 <= float(frac) < 0.5:
            raise ValueError(f"trim fraction must be in [0, 0.5), "
                             f"got {frac!r}")
        return float(frac)
    raise KeyError(f"no robust aggregation registered for {spec!r}; "
                   f"known: ['mean', 'trim']")


def build_stream(cfg: ScenarioConfig, data: Dataset,
                 rng: np.random.Generator
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """The scenario's observation stream: a seeded draw from the train
    pool, then the configured concept-drift transform. Shared by every
    driver (sequential, stacked, scan planner), so drifted streams are
    identical across engines by construction. Consumes exactly one
    ``rng.permutation`` — drift randomness lives in its own seeded
    streams, so ``drift="none"`` configs replay bitwise as before."""
    n_total = cfg.windows * cfg.obs_per_window
    order = rng.permutation(len(data.y_train))[:n_total]
    sx, sy = data.x_train[order], data.y_train[order]
    if cfg.drift != "none":
        from repro.data.synthetic_covtype import get_drift
        sx, sy = get_drift(cfg.drift)(sx, sy, cfg.windows,
                                      cfg.obs_per_window, cfg.seed)
    return sx.astype(np.float32), sy.astype(np.int32)


# ---------------------------------------------------------------------------
# per-window phases
# ---------------------------------------------------------------------------

def collect_window(cfg: ScenarioConfig, rng: np.random.Generator,
                   wx: np.ndarray, wy: np.ndarray, ledger: Ledger, *,
                   window: int = 0, churn: Optional[ChurnBook] = None
                   ) -> List[DC]:
    """Collection phase: split the window's observations between the Edge
    Server (NB-IoT, fraction ``p_edge``) and a SmartMule fleet (802.15.4)
    whose size/allocation comes from the configured collection policy,
    charging every transfer. This is a pure dispatch point: the arrival
    process itself lives in :data:`COLLECTION_POLICIES`.

    The realism hooks are applied here, identically for every driver:
    ``churn`` retires depleted mules *before* they collect (their
    observations are lost — the radio is dark, nothing is charged), and a
    ``byz_frac`` coin per live mule corrupts that mule's window labels
    (cyclic class shift). Both consume host rng/state only when enabled,
    so baseline configs replay bitwise."""
    if churn is not None:
        churn.sweep(ledger, window)
    n_edge = int(round(cfg.p_edge * cfg.obs_per_window))
    idx = rng.permutation(cfg.obs_per_window)
    edge_idx, mule_idx = idx[:n_edge], idx[n_edge:]

    policy = get_collection_policy(_effective_collection(cfg))
    L, assign = policy(cfg, rng, len(mule_idx), window)

    dcs: List[DC] = []
    for m in range(L):
        sel = mule_idx[assign == m]
        if len(sel) == 0:
            continue
        name = f"SM{m + 1}"
        if churn is not None and name in churn.dead:
            continue
        wy_m = wy[sel]
        if cfg.byz_frac > 0.0 and rng.random() < cfg.byz_frac:
            wy_m = (wy_m + 1) % NUM_CLASSES
        ledger.collect_to_mule(len(sel), name)
        dcs.append(DC(name, wx[sel], wy_m))
    if n_edge > 0:
        ledger.collect_to_edge(n_edge)
        if cfg.include_es_in_learning:
            dcs.append(DC("ES", wx[edge_idx], wy[edge_idx], is_es=True))
    return dcs


def learning_round(cfg: ScenarioConfig, dcs: List[DC],
                   prev_global: Optional[np.ndarray], ledger: Ledger,
                   rng: np.random.Generator) -> Optional[np.ndarray]:
    """One HTL round on the configured engine (after the optional
    data-aggregation heuristic, paper Section 6.3). A window whose fleet
    churned away entirely runs no round (``None``: the global model is
    kept as-is — matching the scan engine's ``learn`` mask bitwise)."""
    if cfg.aggregate:
        dcs = apply_aggregation_heuristic(dcs, ledger, cfg.tech)
    if not dcs:
        return None
    run = ENGINES[cfg.engine][cfg.algo]
    return run(dcs, prev_global, ledger, cfg.tech, cap=cfg.cap,
               num_classes=NUM_CLASSES, n_subsample=cfg.n_subsample, rng=rng,
               robust=resolve_robust(cfg.robust_agg))


def update_global(cfg: ScenarioConfig, prev: Optional[np.ndarray],
                  new: Optional[np.ndarray]) -> Optional[np.ndarray]:
    """Paper Section 3: the window model updates the global model via EMA."""
    if prev is None or new is None:
        return new if new is not None else prev
    eta = cfg.global_update_rate
    return (1.0 - eta) * prev + eta * new


_predict = jax.jit(svm_predict)


class EvalCache:
    """Keyed device-side dataset-derivative cache.

    Entries are keyed by ``(dataset identity, kind)`` — the dataset ref is
    pinned inside the entry so ids stay valid — and LRU-bounded, so
    interleaved sweeps over several datasets (sequential, stacked,
    alternating, or the scan engine's streamed eval, which derives several
    device arrays per dataset) all hit without re-uploading per window.
    Keying on the *kind* as well keeps the scan engine's extra derivatives
    (one-hot test labels, device train stream) from evicting the fleet
    engine's test matrix mid-sweep — cross-engine isolation is regression
    tested (tests/test_cityscan.py).

    Mutation is locked: the ``devices`` sweep backend evaluates shards
    from several threads against this one cache, and its entries hold
    device buffers — which is also why the cache must never be shipped to
    ``processes``-backend workers (each worker process builds its own;
    tests/test_parallel_sweep.py pins both properties)."""

    def __init__(self, maxsize: int = 16):
        self.maxsize = maxsize
        self._entries: "OrderedDict[tuple, tuple]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def array(self, data: Dataset, kind: str,
              build: Callable[[Dataset], jnp.ndarray]) -> jnp.ndarray:
        """The device array ``build(data)``, cached under
        ``(id(data), kind)``."""
        key = (id(data), kind)
        with self._lock:
            hit = self._entries.get(key)
            if hit is not None and hit[0] is data:
                self.hits += 1
                self._entries.move_to_end(key)
                return hit[1]
        # build outside the lock (device transfer can be slow); a racing
        # miss on the same key costs one redundant upload, nothing else
        arr = build(data)
        with self._lock:
            self.misses += 1
            self._entries[key] = (data, arr)
            self._entries.move_to_end(key)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
        return arr

    def test_array(self, data: Dataset) -> jnp.ndarray:
        return self.array(
            data, "test", lambda d: jnp.asarray(d.x_test.astype(np.float32)))

    def __len__(self) -> int:
        return len(self._entries)

    def __reduce__(self):
        raise TypeError(
            "EvalCache holds jax device buffers and is process-local; "
            "workers of the 'processes' sweep backend must build their "
            "own (never pickle it across the pool boundary)")


_eval_cache = EvalCache()


def _eval(w: np.ndarray, data: Dataset) -> float:
    pred = np.asarray(_predict(jnp.asarray(w), _eval_cache.test_array(data)))
    return f_measure(data.y_test, pred, NUM_CLASSES)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def _acc_cap(n_seen: int, n_total: int) -> int:
    """Bucketed capacity for the ES's growing accumulated dataset (doubling
    from 128): masked tail rows are dead compute for the trainer, so early
    windows need not pay for the full-stream allocation."""
    b = 128
    while b < n_seen:
        b *= 2
    return min(b, n_total)


def _run_edge_only(cfg: ScenarioConfig, data: Dataset, ledger: Ledger,
                   stream_x: np.ndarray, stream_y: np.ndarray
                   ) -> ScenarioResult:
    """Edge-only benchmark: the ES accumulates everything and retrains."""
    n_total = cfg.windows * cfg.obs_per_window
    f1_curve: List[float] = []
    xacc = np.zeros((n_total, stream_x.shape[1]), np.float32)
    yacc = np.zeros((n_total,), np.int32)
    macc = np.zeros((n_total,), np.float32)
    w = None
    for t in range(cfg.windows):
        s = slice(t * cfg.obs_per_window, (t + 1) * cfg.obs_per_window)
        ledger.collect_to_edge(cfg.obs_per_window)
        xacc[s] = stream_x[s]
        yacc[s] = stream_y[s]
        macc[s] = 1.0
        b = _acc_cap((t + 1) * cfg.obs_per_window, n_total)
        w = train_svm(jnp.asarray(xacc[:b]), jnp.asarray(yacc[:b]),
                      jnp.asarray(macc[:b]), num_classes=NUM_CLASSES,
                      iters=300,
                      w0=None if w is None else jnp.asarray(w))
        w = np.asarray(w)
        if (t + 1) % cfg.eval_every == 0:
            f1_curve.append(_eval(w, data))
    return ScenarioResult(f1_curve, ledger, cfg)


def validate_config(cfg: ScenarioConfig) -> None:
    """Fail fast on configs that cannot run: unknown engine / transport /
    collection specs (KeyError, before any window runs) and the
    empty-fleet trap — ``p_edge`` rounding to the whole window with the ES
    excluded from learning leaves every round with ``dcs == []``, so the
    global model would stay ``None`` forever and the first eval would
    crash deep in the engines."""
    if cfg.engine not in ENGINES and cfg.engine not in SCENARIO_ENGINES:
        raise KeyError(f"unknown engine {cfg.engine!r}; pick one of "
                       f"{sorted(ENGINES) + sorted(SCENARIO_ENGINES)}")
    if cfg.engine != "scan" and cfg.train_iters != 200:
        raise ValueError(
            f"train_iters={cfg.train_iters} is honored by the scan engine "
            f"only; the loop/fleet engines pin the paper's 200 iterations "
            f"(they are the parity oracle)")
    if cfg.train_iters < 1:
        raise ValueError(f"train_iters must be >= 1, got {cfg.train_iters}")
    if cfg.fleet_size is not None:
        if cfg.engine != "scan" or cfg.algo != "star":
            raise ValueError(
                "city mode (fleet_size set) needs engine='scan' and "
                "algo='star' — the device-resident fleet round is StarHTL")
        if cfg.fleet_size < 2:
            raise ValueError(f"city fleets need >= 2 DCs, got "
                             f"{cfg.fleet_size}")
        if cfg.obs_per_dc < 1:
            raise ValueError(f"obs_per_dc must be >= 1, got "
                             f"{cfg.obs_per_dc}")
        if (cfg.p_edge != 0.0 or cfg.aggregate or cfg.uniform
                or cfg.n_subsample is not None
                or cfg.collection != "poisson_zipf"):
            raise ValueError(
                "city mode draws observations on device per DC; the "
                "host-side collection knobs (p_edge, aggregate, uniform, "
                "n_subsample, collection policy) must stay at defaults")
    if cfg.engine == "scan" and cfg.algo == "edge_only":
        raise ValueError("the scan engine covers the HTL algorithms "
                         "('a2a'/'star'); use engine='fleet' for "
                         "algo='edge_only'")
    if cfg.algo != "edge_only":
        from repro.core.energy import resolve_tech
        from repro.core.topology import get_transport
        get_transport(cfg.tech)      # relay structure ...
        resolve_tech(cfg.tech)       # ... and per-event energy, both layers
        get_collection_policy(_effective_collection(cfg))
    # realism axis (DESIGN.md §13)
    if cfg.battery_mj is not None and cfg.battery_mj <= 0:
        raise ValueError(f"battery_mj must be positive (or None for "
                         f"infinite batteries), got {cfg.battery_mj}")
    if not 0.0 <= cfg.byz_frac <= 1.0:
        raise ValueError(f"byz_frac must be in [0, 1], got {cfg.byz_frac}")
    if cfg.algo == "edge_only" and (cfg.battery_mj is not None
                                    or cfg.byz_frac > 0.0):
        raise ValueError("churn/byzantine knobs model the mule fleet; "
                         "algo='edge_only' has no mules")
    if cfg.drift != "none":
        from repro.data.synthetic_covtype import get_drift
        get_drift(cfg.drift)         # KeyError/ValueError before any window
    resolve_robust(cfg.robust_agg)
    if cfg.fleet_size is not None and (cfg.drift != "none"
                                       or cfg.byz_frac > 0.0
                                       or cfg.robust_agg != "mean"):
        raise ValueError(
            "city mode draws observations on device and runs StarHTL "
            "(no A2A combine): of the realism axis only battery churn "
            "applies; drift/byz_frac/robust_agg must stay at defaults")
    n_edge = int(round(cfg.p_edge * cfg.obs_per_window))
    if (cfg.algo != "edge_only" and not cfg.include_es_in_learning
            and n_edge >= cfg.obs_per_window):
        raise ValueError(
            f"empty fleet: p_edge={cfg.p_edge} sends all "
            f"{cfg.obs_per_window} observations of every window to the ES "
            f"while include_es_in_learning=False, so no Data Collector "
            f"ever joins a learning round; lower p_edge, set "
            f"include_es_in_learning=True, or use algo='edge_only'")


def run_scenario(cfg: ScenarioConfig, data: Dataset) -> ScenarioResult:
    validate_config(cfg)
    if cfg.engine == "scan":
        from repro.core import cityscan
        if cfg.fleet_size is not None:
            return cityscan.run_city(cfg, data)
        return cityscan.run_scenario_scan(cfg, data)
    rng = np.random.default_rng(cfg.seed)
    ledger = Ledger()
    stream_x, stream_y = build_stream(cfg, data, rng)

    if cfg.algo == "edge_only":
        return _run_edge_only(cfg, data, ledger, stream_x, stream_y)

    churn = None if cfg.battery_mj is None else ChurnBook(cfg.battery_mj)
    f1_curve: List[float] = []
    prev_global: Optional[np.ndarray] = None
    for t in range(cfg.windows):
        s = slice(t * cfg.obs_per_window, (t + 1) * cfg.obs_per_window)
        dcs = collect_window(cfg, rng, stream_x[s], stream_y[s], ledger,
                             window=t, churn=churn)
        new_global = learning_round(cfg, dcs, prev_global, ledger, rng)
        prev_global = update_global(cfg, prev_global, new_global)
        if (t + 1) % cfg.eval_every == 0:
            f1_curve.append(_eval(prev_global, data))

    return ScenarioResult(f1_curve, ledger, cfg)


# {field: default} for every ScenarioConfig field tagged host_side — the
# stack key normalizes exactly these, so adding a field with
# ``metadata=_host()`` automatically opts it into replica stacking (and
# omitting the tag automatically keeps it a group splitter).
_HOST_SIDE_DEFAULTS: Dict[str, object] = {
    f.name: f.default for f in dataclasses.fields(ScenarioConfig)
    if f.metadata.get("host_side")
}


def host_side_fields() -> Tuple[str, ...]:
    """Names of the config fields that may vary within a stacked group."""
    return tuple(_HOST_SIDE_DEFAULTS)


def stack_key(cfg: ScenarioConfig) -> ScenarioConfig:
    """Configs with equal keys may run replica-stacked: the normalized
    fields only steer host-side work (collection rng, energy charging,
    GreedyTL subsampling inputs, EMA rate), never the shapes or semantics
    of the jitted calls, so stacking them changes nothing per replica.
    Which fields those are is declared as ``host_side`` field metadata on
    :class:`ScenarioConfig` — this function is purely derived.

    The key is also the sharding atom of the parallel sweep executor
    (:mod:`repro.core.parallel`): a partition that never splits equal-key
    rows across shards preserves exactly the stacking groups — and
    therefore exactly the computation — of a sequential run.
    """
    return dataclasses.replace(cfg, **_HOST_SIDE_DEFAULTS)


# compatibility alias (pre-parallel-executor internal name)
_stack_key = stack_key


def stack_groups(configs: Sequence[ScenarioConfig],
                 key_fn: Callable[[ScenarioConfig], object] = stack_key
                 ) -> List[List[int]]:
    """Indices of ``configs`` grouped by ``key_fn`` (default
    :func:`stack_key`), groups in first-appearance order, indices
    ascending — the shared grouping entry for the stacked sweep driver
    below and the shard partitioner in :mod:`repro.core.parallel`, so
    grouping semantics cannot diverge between the two."""
    groups: "OrderedDict[object, List[int]]" = OrderedDict()
    for i, cfg in enumerate(configs):
        groups.setdefault(key_fn(cfg), []).append(i)
    return list(groups.values())


def run_scenarios_stacked(cfgs: Sequence[ScenarioConfig], data: Dataset
                          ) -> List[ScenarioResult]:
    """Run several scenario replicas in lockstep — one dispatch set per
    window for the whole group.

    The replicas may differ in seed and in any host-side field (tech,
    p_edge, uniform, aggregate, n_subsample, Zipf/Poisson parameters, EMA
    rate — see :func:`_stack_key`). Each window, every replica collects its
    own data (own rng stream, own energy ledger) and the learning rounds
    stack into the flat fleet DC axis
    (:func:`repro.core.fleet.run_window_a2a_stacked` / ``_star_stacked``),
    so the group costs O(sample buckets) dispatches per window instead of
    O(replicas). Results match sequential :func:`run_scenario` runs
    replica-for-replica (ledgers exactly, F1 curves to the engine-parity
    tolerance; tests/test_fleet_engine.py).
    """
    cfg0 = cfgs[0]
    for c in cfgs:
        validate_config(c)
    if any(_stack_key(c) != _stack_key(cfg0) for c in cfgs):
        raise ValueError("run_scenarios_stacked needs configs that agree "
                         "on every non-host-side field (see _stack_key)")
    if cfg0.engine != "fleet" or cfg0.algo not in ("a2a", "star"):
        return [run_scenario(c, data) for c in cfgs]
    run_stacked = {"a2a": fleet_engine.run_window_a2a_stacked,
                   "star": fleet_engine.run_window_star_stacked}[cfg0.algo]

    S = len(cfgs)
    rngs = [np.random.default_rng(c.seed) for c in cfgs]
    ledgers = [Ledger() for _ in cfgs]
    techs = [c.tech for c in cfgs]
    n_subsamples = [c.n_subsample for c in cfgs]
    robusts = [resolve_robust(c.robust_agg) for c in cfgs]
    churns = [None if c.battery_mj is None else ChurnBook(c.battery_mj)
              for c in cfgs]
    streams = [build_stream(c, data, rng) for c, rng in zip(cfgs, rngs)]

    curves: List[List[float]] = [[] for _ in cfgs]
    prevs: List[Optional[np.ndarray]] = [None] * S
    for t in range(cfg0.windows):
        sl = slice(t * cfg0.obs_per_window, (t + 1) * cfg0.obs_per_window)
        fleets = []
        for s in range(S):
            dcs = collect_window(cfgs[s], rngs[s], streams[s][0][sl],
                                 streams[s][1][sl], ledgers[s],
                                 window=t, churn=churns[s])
            if cfgs[s].aggregate:
                dcs = apply_aggregation_heuristic(dcs, ledgers[s], techs[s])
            fleets.append(dcs)
        news = run_stacked(fleets, prevs, ledgers, techs, cap=cfg0.cap,
                           num_classes=NUM_CLASSES,
                           n_subsamples=n_subsamples, rngs=rngs,
                           robusts=robusts)
        # a replica whose fleet churned away keeps its model as-is (the
        # sequential driver skips the round; EMA-ing prev with itself is
        # NOT a bitwise no-op, so the skip must match exactly)
        prevs = [prevs[s] if not fleets[s]
                 else update_global(cfgs[s], prevs[s], news[s])
                 for s in range(S)]
        if (t + 1) % cfg0.eval_every == 0:
            for s in range(S):
                curves[s].append(_eval(prevs[s], data))
    return [ScenarioResult(curves[s], ledgers[s], cfgs[s]) for s in range(S)]


def run_sweep(configs: Sequence[ScenarioConfig], data: Dataset, *,
              stack_seeds: bool = False) -> List[ScenarioResult]:
    """Evaluate many scenario configurations over the same dataset.

    .. deprecated:: compatibility shim — new code should build a
       declarative :class:`repro.core.experiment.SweepSpec` and call
       ``spec.run(data, stack="auto")``, which routes through this
       function and therefore emits identical results
       (tests/test_experiment.py asserts the parity).

    The batched fleet trainers are shape-stable (bucketed sample capacity,
    bucketed DC capacity), so every configuration after the first reuses the
    same jitted executables — the sweep pays compilation once, which is what
    makes the paper's algorithm x technology x p_edge x aggregation grids
    (Tables 2-6) cheap to extend.

    ``stack_seeds=True`` groups stack-compatible configs (equal
    :func:`_stack_key`: same algo/engine/windows/cap, any mix of seeds and
    host-side fields) and runs each group through
    :func:`run_scenarios_stacked` — O(sample buckets) dispatches per window
    for the whole group; other configs — and the default — run
    sequentially. Result order always matches ``configs``.
    """
    if not stack_seeds:
        return [run_scenario(cfg, data) for cfg in configs]
    results: List[Optional[ScenarioResult]] = [None] * len(configs)
    for idxs in stack_groups(configs):
        grp = [configs[i] for i in idxs]
        key = stack_key(grp[0])
        if (len(grp) == 1 or key.engine != "fleet"
                or key.algo not in ("a2a", "star")):
            rs = [run_scenario(c, data) for c in grp]
        else:
            rs = run_scenarios_stacked(grp, data)
        for i, r in zip(idxs, rs):
            results[i] = r
    return results
