"""minicpm3-4b — dense decoder with Multi-head Latent Attention (MLA)
[hf:openbmb/MiniCPM3-4B].

62L, d_model=2560, 40H (kv=40 logical; MLA caches a 256-dim latent + 32-dim
rope key), d_ff=6400, vocab=73448.
"""
from repro.configs.base import MLAConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    head_dim=64,
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64),
    tie_embeddings=True,
    supports_long_context=False,
    source="hf:openbmb/MiniCPM3-4B",
))
