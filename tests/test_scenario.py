"""Scenario simulation: end-to-end windows, energy decomposition, Zipf
allocation, and the paper's qualitative orderings at reduced scale."""
import dataclasses

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.scenario import (EvalCache, ScenarioConfig, _eval,
                                 _zipf_probs, get_collection_policy,
                                 run_scenario, validate_config)
from repro.data.synthetic_covtype import make_covtype_like

DATA = make_covtype_like(seed=0)
BASE = ScenarioConfig(windows=12, eval_every=4)


def test_edge_only():
    r = run_scenario(dataclasses.replace(BASE, algo="edge_only"), DATA)
    assert len(r.f1_curve) == 3
    assert r.f1_curve[-1] > 0.55
    assert r.energy_learning == 0.0
    # NB-IoT collection: 12 windows x 100 obs x 433B
    assert r.energy_collection == pytest.approx(34477 * 12 / 100, rel=0.01)


@pytest.mark.parametrize("algo", ["star", "a2a"])
def test_htl_scenarios_run(algo):
    r = run_scenario(dataclasses.replace(BASE, algo=algo), DATA)
    assert np.isfinite(r.f1_curve).all()
    assert r.f1_curve[-1] > 0.3
    assert r.energy_collection > 0 and r.energy_learning > 0
    assert r.energy_total == pytest.approx(
        r.energy_collection + r.energy_learning)


def test_htl_saves_energy_vs_edge_only():
    edge = run_scenario(dataclasses.replace(BASE, algo="edge_only"), DATA)
    star = run_scenario(dataclasses.replace(BASE, algo="star", tech="wifi"),
                        DATA)
    saving = 1 - star.energy_total / edge.energy_total
    assert saving > 0.9          # paper headline: up to 94%


def test_partial_edge_energy_ordering():
    """More data shipped to the edge -> more collection energy (Table 2)."""
    energies = []
    for frac in (0.5, 0.15, 0.03):
        r = run_scenario(dataclasses.replace(BASE, algo="star",
                                             p_edge=frac), DATA)
        energies.append(r.energy_collection)
    assert energies[0] > energies[1] > energies[2]


def test_aggregation_reduces_participants_not_data():
    r = run_scenario(dataclasses.replace(BASE, algo="star", aggregate=True),
                     DATA)
    assert np.isfinite(r.f1_curve).all()


def test_subsample_runs():
    r = run_scenario(dataclasses.replace(BASE, algo="star", n_subsample=2),
                     DATA)
    assert np.isfinite(r.f1_curve).all()


def test_uniform_distribution_runs():
    r = run_scenario(dataclasses.replace(BASE, algo="a2a", uniform=True),
                     DATA)
    assert np.isfinite(r.f1_curve).all()


def test_deterministic_given_seed():
    r1 = run_scenario(dataclasses.replace(BASE, algo="star", seed=3), DATA)
    r2 = run_scenario(dataclasses.replace(BASE, algo="star", seed=3), DATA)
    assert r1.f1_curve == r2.f1_curve
    assert r1.energy_total == pytest.approx(r2.energy_total)


# ---------------------------------------------------------------------------
@given(n=st.integers(min_value=1, max_value=50),
       alpha=st.floats(min_value=0.1, max_value=3.0))
@settings(max_examples=50, deadline=None)
def test_zipf_probs(n, alpha):
    p = _zipf_probs(n, alpha)
    assert p.shape == (n,)
    assert p.sum() == pytest.approx(1.0)
    assert (np.diff(p) <= 1e-12).all()         # decreasing in rank


def test_zipf_unbalance_matches_paper():
    """alpha=1.5, N=7: top mule holds ~53-55%% of the data (paper Sec. 6.3)."""
    p = _zipf_probs(7, 1.5)
    assert 0.5 < p[0] < 0.58


# ---------------------------------------------------------------------------
# empty-fleet guard
# ---------------------------------------------------------------------------

def test_empty_fleet_raises_clear_error():
    """p_edge=1.0 with the ES excluded from learning leaves every window
    with dcs == []; this must fail fast with a clear ValueError instead of
    falling through into the engines with a forever-None global model."""
    bad = dataclasses.replace(BASE, p_edge=1.0,
                              include_es_in_learning=False)
    with pytest.raises(ValueError, match="empty fleet"):
        run_scenario(bad, DATA)
    with pytest.raises(ValueError, match="empty fleet"):
        validate_config(bad)
    # ... including when rounding (not the literal 1.0) empties the fleet
    with pytest.raises(ValueError, match="empty fleet"):
        validate_config(dataclasses.replace(
            BASE, p_edge=0.999, include_es_in_learning=False))


def test_empty_fleet_guard_leaves_valid_configs_alone():
    # all-edge collection is fine when the ES joins the learning round...
    r = run_scenario(dataclasses.replace(BASE, windows=4, eval_every=2,
                                         p_edge=1.0), DATA)
    assert np.isfinite(r.f1_curve).all()
    # ... and edge_only never builds a fleet at all
    validate_config(dataclasses.replace(
        BASE, algo="edge_only", p_edge=1.0, include_es_in_learning=False))
    # high-but-not-total offload keeps some mule data
    validate_config(dataclasses.replace(
        BASE, p_edge=0.5, include_es_in_learning=False))


# ---------------------------------------------------------------------------
# collection-policy registry
# ---------------------------------------------------------------------------

def test_uniform_flag_equals_uniform_policy():
    """The legacy uniform=True switch and collection="uniform" must be the
    same process, rng draw for rng draw."""
    a = run_scenario(dataclasses.replace(BASE, uniform=True, seed=2), DATA)
    b = run_scenario(dataclasses.replace(BASE, collection="uniform",
                                         seed=2), DATA)
    assert a.f1_curve == b.f1_curve
    assert a.energy_total == pytest.approx(b.energy_total)


def test_trace_policy_is_deterministic_replay():
    pol = get_collection_policy("trace:loads=50-30-20")
    cfg = BASE
    L1, a1 = pol(cfg, np.random.default_rng(0), 100)
    L2, a2 = pol(cfg, np.random.default_rng(9), 100)
    assert L1 == L2 == 3
    assert (a1 == a2).all()                    # rng-independent replay
    counts = np.bincount(a1, minlength=3)
    assert list(counts) == [50, 30, 20]


def test_bursty_policy_produces_contiguous_runs():
    pol = get_collection_policy("bursty:burst=8")
    L, assign = pol(BASE, np.random.default_rng(0), 200)
    assert len(assign) == 200 and 0 <= assign.min() and assign.max() < L
    switches = int((np.diff(assign) != 0).sum())
    # i.i.d. assignment over ~7 mules switches ~85% of steps; bursts of
    # mean length 8 switch at most ~1/4 of them
    assert switches < 60


def test_scenarios_run_under_every_builtin_policy():
    for policy in ("poisson_zipf", "uniform", "trace:loads=60-25-15",
                   "bursty:burst=4"):
        r = run_scenario(dataclasses.replace(
            BASE, windows=4, eval_every=2, collection=policy), DATA)
        assert np.isfinite(r.f1_curve).all(), policy


def test_unknown_or_malformed_policy_rejected():
    with pytest.raises(KeyError):
        run_scenario(dataclasses.replace(BASE, collection="tarot"), DATA)
    with pytest.raises(KeyError):
        get_collection_policy("bursty:burst")
    with pytest.raises(KeyError):          # unknown parameter name
        get_collection_policy("bursty:size=3")
    with pytest.raises(ValueError):        # bad parameter value
        get_collection_policy("bursty:burst=0.5")
    with pytest.raises(ValueError):
        get_collection_policy("trace:loads=0-0")


# ---------------------------------------------------------------------------
# keyed eval cache
# ---------------------------------------------------------------------------

def test_eval_cache_identity_and_eviction():
    cache = EvalCache(maxsize=2)
    d1 = make_covtype_like(seed=1)
    d2 = make_covtype_like(seed=2)
    a1 = cache.test_array(d1)
    assert cache.test_array(d1) is a1          # hit: same device array
    assert cache.hits == 1 and cache.misses == 1
    a2 = cache.test_array(d2)
    assert a2 is not a1
    assert cache.test_array(d1) is a1          # both live under maxsize=2
    d3 = make_covtype_like(seed=3)
    cache.test_array(d3)                       # evicts LRU (d2)
    assert len(cache) == 2
    assert cache.test_array(d1) is a1          # d1 survived (recently used)
    before = cache.misses
    cache.test_array(d2)                       # d2 was evicted: a miss
    assert cache.misses == before + 1


def test_eval_serves_interleaved_datasets():
    """The keyed cache must keep interleaved sweeps over several datasets
    correct — each eval scores against its own test set."""
    d_other = make_covtype_like(seed=7)
    w = np.zeros((DATA.x_train.shape[1] + 1, 7), np.float32)
    f_a1 = _eval(w, DATA)
    f_b1 = _eval(w, d_other)
    assert _eval(w, DATA) == f_a1
    assert _eval(w, d_other) == f_b1
