"""Sweep the paper's central trade-off: energy vs accuracy as a function of
how much data reaches the edge server, which radio links the mules use, and
the HTL variant. Prints a small ASCII table (the analogue of paper Fig. 3 +
Tables 2-4).

The grid is the ``"energy_tradeoff"`` preset of the declarative experiment
API (:mod:`repro.core.experiment`) evaluated by one
``SweepSpec.run(stack="auto")`` call: stack-compatible configurations
(same algorithm, any mix of technologies / p_edge / aggregation — derived
from ``host_side`` config-field metadata) run in lockstep on a shared
fleet axis, O(sample buckets) jitted dispatches per window per group.
``--transports`` swaps in the mesh/BLE/LoRa technology grid over the
parameterized transport registry instead.

    PYTHONPATH=src python examples/energy_tradeoff.py --windows 30
"""
import argparse

from repro.core.experiment import get_preset
from repro.data.synthetic_covtype import make_covtype_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=30)
    ap.add_argument("--engine", default="fleet", choices=("fleet", "loop"))
    ap.add_argument("--transports", action="store_true",
                    help="sweep the mesh/BLE/LoRa transport grid instead")
    args = ap.parse_args()
    data = make_covtype_like(seed=0)

    preset = "transport_grid" if args.transports else "energy_tradeoff"
    spec = get_preset(preset, windows=args.windows, engine=args.engine)
    result = spec.run(data, stack="auto")

    labels = result.labels()
    if args.transports:
        # reference for savings: the costliest technology in the grid
        ref_label = max(labels,
                        key=lambda l: result.summary(l)["energy_mj"])
    else:
        ref_label = labels[0]                      # edge-only row
    ref = result.summary(ref_label)
    e0, f0 = ref["energy_mj"], ref["f1"]

    print(f"{'configuration':28s} {'energy mJ':>10s} {'saving':>7s} "
          f"{'F1':>6s} {'loss':>6s}")
    for label in labels:
        r = result.summary(label)
        sav = 100 * (1 - r["energy_mj"] / e0)
        loss = 100 * (f0 - r["f1"]) / max(f0, 1e-9)
        bar = "#" * int(max(0.0, sav) // 4)
        print(f"{label:28s} {r['energy_mj']:10.0f} {sav:6.1f}% "
              f"{r['f1']:6.3f} {loss:5.1f}%  {bar}")


if __name__ == "__main__":
    main()
