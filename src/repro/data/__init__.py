from repro.data.synthetic_covtype import make_covtype_like  # noqa: F401
from repro.data.pipeline import TokenStream, make_lm_batch  # noqa: F401
