"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles in ref.py
(interpret mode on CPU — kernel bodies execute in Python)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.loo_trials import loo_trials, loo_trials_ref
from repro.kernels.ref import (loo_trials_inv_reference, mha_reference,
                               rglru_reference, ssd_reference)
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.ssd_scan import ssd_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,Sq,Skv,d,causal,window",
    [
        (2, 4, 2, 256, 256, 64, True, 0),     # GQA causal
        (1, 8, 8, 128, 384, 64, True, 0),     # MHA, kv longer (decode-ish)
        (2, 4, 1, 256, 256, 128, True, 64),   # MQA + sliding window
        (1, 2, 2, 192, 192, 64, False, 0),    # bidirectional, ragged blocks
        (1, 4, 4, 64, 64, 32, True, 0),       # small head dim
    ])
def test_flash_attention_sweep(B, H, KV, Sq, Skv, d, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, d), dtype)
    k = jax.random.normal(ks[1], (B, KV, Skv, d), dtype)
    v = jax.random.normal(ks[2], (B, KV, Skv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = mha_reference(q, k, v, causal=causal, window=window)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    assert err < TOL[dtype], f"err={err}"


def test_flash_attention_q_offset_decode():
    """Decode semantics: 1 query at position T attends to all T+1 keys."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, H, d, T = 2, 4, 64, 128
    q = jax.random.normal(ks[0], (B, H, 1, d))
    k = jax.random.normal(ks[1], (B, H, T, d))
    v = jax.random.normal(ks[2], (B, H, T, d))
    out = flash_attention(q, k, v, causal=True, q_offset=T - 1,
                          interpret=True)
    ref = mha_reference(q, k, v, causal=True, q_offset=T - 1)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 256, 4, 64, 32, 64),
    (1, 128, 2, 32, 64, 128),
    (2, 512, 8, 64, 128, 128),
    (1, 256, 1, 128, 16, 32),
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = (jax.random.normal(ks[3], (B, S, N)) * 0.5).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, S, N)) * 0.5).astype(dtype)
    y, st = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, sr = ssd_reference(x, dt, A, Bm, Cm)
    scale = float(jnp.max(jnp.abs(yr.astype(jnp.float32)))) + 1e-9
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - yr.astype(jnp.float32)))) / scale
    tol = 3e-5 if dtype == jnp.float32 else 5e-2
    assert err < tol, f"err={err}"
    sscale = float(jnp.max(jnp.abs(sr.astype(jnp.float32)))) + 1e-9
    serr = float(jnp.max(jnp.abs(st.astype(jnp.float32)
                                 - sr.astype(jnp.float32)))) / sscale
    assert serr < tol, f"state err={serr}"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,W,chunk,bw", [
    (2, 256, 256, 64, 128),
    (1, 128, 128, 128, 128),
    (3, 512, 384, 128, 128),
    (1, 64, 512, 32, 256),
])
def test_rglru_scan_sweep(B, S, W, chunk, bw, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))).astype(dtype)
    b = (jax.random.normal(ks[1], (B, S, W)) * 0.5).astype(dtype)
    h = rglru_scan(a, b, chunk=chunk, block_w=bw, interpret=True)
    hr = rglru_reference(a, b)
    err = float(jnp.max(jnp.abs(h.astype(jnp.float32)
                                - hr.astype(jnp.float32))))
    assert err < (1e-4 if dtype == jnp.float32 else 5e-2), f"err={err}"


def _bordering_inputs(R, M, C, seed):
    """Shared-factor quantities for a random masked ridge system, prepared
    exactly as greedytl._score_trials does (Cholesky of the active set,
    whitened rows, candidate borderings)."""
    from jax.scipy.linalg import solve_triangular
    rng = np.random.default_rng(seed)
    D = M + C
    A = rng.normal(size=(R, D)).astype(np.float32)
    y = rng.normal(size=R).astype(np.float32)
    rmask = (rng.random(R) < 0.8).astype(np.float32)
    sel = (rng.random(M) < 0.3).astype(np.float32)
    cmask = np.concatenate([sel, np.ones(C, np.float32)])
    lam_d = (np.abs(rng.normal(0.5, 0.2, D)) + 1e-3).astype(np.float32)
    A_rm = A * rmask[:, None]
    AtA = A_rm.T @ A_rm
    Aty = A_rm.T @ (y * rmask)

    L = jnp.linalg.cholesky(AtA * (cmask[:, None] * cmask[None, :])
                            + jnp.diag(lam_d))
    Am = A_rm * cmask[None, :]
    Ut = solve_triangular(L, Am.T, lower=True).T
    z = solve_triangular(L, jnp.asarray(Aty * cmask), lower=True)
    Cc = solve_triangular(L, jnp.asarray(AtA[:, :M] * cmask[:, None]),
                          lower=True)
    dsq = np.diag(AtA)[:M] + lam_d[:M] - jnp.sum(Cc ** 2, axis=0)
    dinv = jax.lax.rsqrt(jnp.maximum(dsq, 1e-8))
    zj = (Aty[:M] - Cc.T @ z) * dinv
    shared = (Ut, Cc, jnp.asarray(A_rm[:, :M]), Ut @ z,
              jnp.sum(Ut ** 2, -1), jnp.asarray(y), jnp.asarray(rmask),
              zj, dinv)
    system = (AtA, Aty, A_rm, y, rmask, cmask, lam_d)
    valid = sel == 0
    return shared, system, valid


@pytest.mark.parametrize("R,M,C,block_r", [
    (1120, 16, 7, 256),     # production shape (cap=160)
    (224, 16, 7, 256),      # small cap, single padded tile
    (448, 8, 7, 64),        # multi-tile, narrow candidate set
    (1120, 32, 7, 128),     # wide candidate set (bench shape)
    (200, 16, 4, 128),      # ragged rows (R % 8 != 0)
])
def test_loo_trials_kernel_vs_ref(R, M, C, block_r):
    """Pallas interpret path == pure-jnp oracle on random systems."""
    shared, _, _ = _bordering_inputs(R, M, C, seed=R + M)
    out = loo_trials(*shared, block_r=block_r, interpret=True)
    ref = loo_trials_ref(*shared)
    err = float(jnp.max(jnp.abs(out - ref))) / (float(jnp.max(ref)) + 1e-9)
    assert err < 2e-6, f"rel err={err}"


@pytest.mark.parametrize("R", [1, 3, 5, 7, 9, 20])
@pytest.mark.parametrize("block_r", [4, 8, 100, 256])
def test_loo_trials_small_R_and_odd_tiles(R, block_r):
    """Regression: R < 8, R not a multiple of 8, and tuned/odd block_r
    values must all snap the row tile to a sublane multiple and pad the
    tail with rmask=0 rows — not crash or mis-reduce. (The autotuner can
    hand the kernel any block_r, and tiny fleets produce tiny R.)"""
    shared, _, _ = _bordering_inputs(R, 16, 7, seed=R * 31 + block_r)
    out = loo_trials(*shared, block_r=block_r, interpret=True)
    ref = loo_trials_ref(*shared)
    err = float(jnp.max(jnp.abs(out - ref))) / (float(jnp.max(ref)) + 1e-9)
    assert err < 2e-6, f"rel err={err}"


def test_loo_trials_rejects_nonpositive_block_r():
    shared, _, _ = _bordering_inputs(64, 16, 7, seed=0)
    with pytest.raises(ValueError):
        loo_trials(*shared, block_r=0, interpret=True)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_loo_trials_matches_inverse_formulation(seed):
    """Cholesky-bordering objectives == the O(M D^3) inverse-based LOO the
    kernel replaced, for every valid (not-yet-selected) candidate."""
    shared, system, valid = _bordering_inputs(1120, 16, 7, seed)
    AtA, Aty, A_rm, y, rmask, cmask, lam_d = system
    ref = np.asarray(loo_trials_inv_reference(
        jnp.asarray(AtA), jnp.asarray(Aty), jnp.asarray(A_rm),
        jnp.asarray(y), jnp.asarray(rmask), jnp.asarray(cmask),
        jnp.asarray(lam_d), 16))
    fac = np.asarray(loo_trials_ref(*shared))
    rel = np.abs(fac - ref)[valid] / np.maximum(np.abs(ref[valid]), 1e-6)
    assert rel.max() < 1e-5, rel.max()


def test_models_agree_xla_vs_pallas():
    """End-to-end: loss with attention_impl='pallas' == 'xla' reference."""
    import dataclasses

    from repro.configs import get_config
    from repro.data.pipeline import make_lm_batch
    from repro.models import build_model

    for arch in ["llama3.2-3b", "mamba2-1.3b", "recurrentgemma-9b"]:
        cfg = get_config(arch).reduced()
        m_x = build_model(cfg)
        m_p = build_model(dataclasses.replace(cfg, attention_impl="pallas"))
        params = m_x.init(jax.random.PRNGKey(0))
        batch = make_lm_batch(cfg.vocab_size, 2, 128, d_model=cfg.d_model)
        lx, _ = jax.jit(m_x.loss_fn)(params, batch)
        lp, _ = jax.jit(m_p.loss_fn)(params, batch)
        assert abs(float(lx) - float(lp)) < 1e-3, arch
