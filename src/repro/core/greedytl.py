"""GreedyTL — transfer learning through greedy source selection
(Kuzborskij, Orabona, Caputo, ICIAP 2015 [28] / CVIU 2017 [37]).

The paper (Section 4, Step 2) describes it as solving "an optimisation
problem to find the linear combination of models m(0) which maximises the
prediction accuracy with respect to the local dataset". We implement exactly
that, in two regularized-least-squares stages, both gated by the closed-form
leave-one-out (LOO) error — the selection criterion of [28]:

* **Stage 1 — greedy source combination.** Candidate pool = source
  hypotheses; each source j enters with a single scalar coefficient alpha_j
  shared across classes (this preserves the source's cross-class calibration
  — the multiclass adaptation of the binary algorithm in [28]). Exact greedy
  forward selection: at every step each remaining source is trial-added and
  the LOO error of the joint ridge recomputed; the best is kept only if it
  improves.
* **Stage 2 — local correction.** A per-class ridge over the original
  features fits the residual; it is kept only if it improves the stacked LOO
  error (with few local samples it usually is not — which is exactly why
  GreedyTL works with 2-10 points per class, paper Section 7).

Because the base hypotheses are linear (paper: linear SVM), the result
collapses EXACTLY into one linear model:

    w_eff = sum_j (alpha_j / s_j) W_src_j + W_correction (+ biases)

so the deployed model is identical to the fitted one, the on-wire model size
stays constant, and the paper's Step-4 averaging is well-posed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.svm import svm_scores


def _loo_ridge(A, y, rmask, cmask, lam):
    """Ridge with LOO error. A: (R,D); y: (R,); rmask: (R,); cmask: (D,).

    ``lam`` may be a scalar or a per-column vector (D,) — the per-class bias
    columns get a stronger penalty so that a few samples per class cannot
    shift a good source's decision boundaries.
    Returns (loo_sse, coeffs (D,)).
    """
    Am = A * cmask[None, :] * rmask[:, None]
    D = A.shape[1]
    G = Am.T @ Am + jnp.diag(jnp.broadcast_to(lam, (D,)) + 1e-4)
    Ginv = jnp.linalg.inv(G)
    v = (Ginv @ (Am.T @ (y * rmask))) * cmask
    resid = (Am @ v - y) * rmask
    h = jnp.sum((Am @ Ginv) * Am, axis=-1)
    loo = resid / jnp.maximum(1.0 - h, 0.1)
    return jnp.sum(loo ** 2), v


def _loo_ridge_gram(AtA, Aty, A_rm, y, rmask, cmask, lam_d):
    """Column-masked ridge + LOO error from a PRECOMPUTED Gram system.

    Mathematically identical to :func:`_loo_ridge` (the column mask is 0/1,
    so masking the Gram matrix equals the Gram of the masked matrix), but
    the O(R D^2) products ``A^T A`` and ``A^T y`` are shared across the
    hundreds of greedy-selection trials instead of rebuilt per trial.
    """
    cm2 = cmask[:, None] * cmask[None, :]
    G = AtA * cm2 + jnp.diag(lam_d)
    Ginv = jnp.linalg.inv(G)
    v = (Ginv @ (Aty * cmask)) * cmask
    resid = (A_rm @ v - y) * rmask
    h = jnp.sum((A_rm @ (Ginv * cm2)) * A_rm, axis=-1)
    loo = resid / jnp.maximum(1.0 - h, 0.1)
    return jnp.sum(loo ** 2), v


def _greedytl(x, y, mask, src_w, src_mask, *, num_classes: int,
              lam_src: float = 0.1, lam_x: float = 10.0,
              lam_bias: float = 2.0, k_max: int = 16):
    """Unjitted GreedyTL core — also the map target of the fleet refiner."""
    n, F = x.shape
    M, _, C = src_w.shape
    xm = x * mask[:, None]
    Yoh = (2.0 * jax.nn.one_hot(y, num_classes) - 1.0) * mask[:, None]  # (n,C)

    # source predictions H (M, n, C), normalised per source to unit RMS
    H = jax.vmap(lambda w: svm_scores(w, xm))(src_w) * mask[None, :, None]
    denom = jnp.maximum(1.0, jnp.sum(mask)) * C
    s = jnp.sqrt(jnp.sum(H ** 2, axis=(1, 2)) / denom) + 1e-6    # (M,)
    Hn = H / s[:, None, None]

    # ---- Stage 1: stacked system over (n*C) rows, unknowns = alpha + bias_c
    R = n * C
    A_src = Hn.transpose(1, 2, 0).reshape(R, M)          # (R, M)
    A_bias = jnp.tile(jnp.eye(C), (n, 1))                # (R, C)
    A = jnp.concatenate([A_src, A_bias], axis=1)         # (R, M+C)
    yr = Yoh.reshape(R)
    rmask = jnp.repeat(mask, C)
    bias_cols = jnp.concatenate([jnp.zeros(M), jnp.ones(C)])
    lam_vec = jnp.concatenate([jnp.full((M,), lam_src),
                               jnp.full((C,), lam_bias)])

    # Gram system shared by every trial of every greedy step
    A_rm = A * rmask[:, None]
    AtA = A_rm.T @ A_rm
    Aty = A_rm.T @ (yr * rmask)
    lam_d = jnp.broadcast_to(lam_vec, (A.shape[1],)) + 1e-4

    def _loo(cm):
        return _loo_ridge_gram(AtA, Aty, A_rm, yr, rmask, cm, lam_d)

    def cond(state):
        k, sel, best, done = state
        return (~done) & (k < min(k_max, M))

    def body(state):
        k, sel, best, done = state

        def trial(j):
            cand = jnp.where(jnp.arange(M) == j, 1.0, sel) * src_mask
            cm = jnp.concatenate([cand, jnp.ones(C)])
            obj, _ = _loo(cm)
            invalid = (sel[j] > 0) | (src_mask[j] == 0)
            return jnp.where(invalid, jnp.inf, obj)

        objs = jax.vmap(trial)(jnp.arange(M))
        j = jnp.argmin(objs)
        improved = (objs[j] < best) & ~done
        sel = jnp.where(improved, jnp.where(jnp.arange(M) == j, 1.0, sel),
                        sel)
        return (k + 1, sel, jnp.where(improved, objs[j], best),
                done | ~improved)

    obj0, _ = _loo(bias_cols)
    # Early-exit greedy selection: once no trial improves, further steps are
    # provable no-ops, so a while_loop saves the (typically ~4x) dead steps
    # a fixed-length scan would still execute.
    _, sel, _, _ = jax.lax.while_loop(
        cond, body, (0, jnp.zeros(M), obj0, jnp.asarray(False)))

    cm = jnp.concatenate([sel * src_mask, jnp.ones(C)])
    _, v1 = _loo(cm)
    alpha = v1[:M] / s                                   # undo normalisation
    bias1 = v1[M:]                                       # (C,)

    w_src_part = jnp.einsum("m,mfc->fc", alpha, src_w)   # (F+1, C)
    w_src_part = w_src_part.at[F].add(bias1)

    # ---- Stage 2: per-class local correction on the residual, LOO-gated
    fitted = jnp.einsum("m,mnc->nc", v1[:M], Hn) + bias1[None, :]
    resid = (Yoh - fitted) * mask[:, None]               # (n, C)

    def fit_class(rc):
        return _loo_ridge(xm, rc, mask, jnp.ones(F), lam_x)

    loo_x, Vx = jax.vmap(fit_class, in_axes=1, out_axes=(0, 0))(resid)
    # gate: correction kept only if summed LOO improves over zero correction
    loo_zero = jnp.sum(resid ** 2)
    keep = jnp.sum(loo_x) < loo_zero
    Vx = jnp.where(keep, Vx.T, 0.0)                      # (F, C)

    w_eff = w_src_part.at[:F].add(Vx)
    return w_eff, sel


@partial(jax.jit, static_argnames=("num_classes", "k_max"))
def greedytl(x, y, mask, src_w, src_mask, *, num_classes: int,
             lam_src: float = 0.1, lam_x: float = 10.0,
             lam_bias: float = 2.0, k_max: int = 16, lam: float = None):
    """Greedy source combination + gated local correction (see module doc).

    x: (n, F) padded local data; y: (n,); mask: (n,) row validity.
    src_w: (M, F+1, C) stacked source hypotheses; src_mask: (M,).
    Returns (w_eff (F+1, C), selected (M,) 0/1 source-selection mask).
    """
    if lam is not None:           # backwards-compatible alias
        lam_src = lam
    return _greedytl(x, y, mask, src_w, src_mask, num_classes=num_classes,
                     lam_src=lam_src, lam_x=lam_x, lam_bias=lam_bias,
                     k_max=k_max)


@partial(jax.jit, static_argnames=("num_classes", "k_max"))
def greedytl_fleet(x, y, mask, src_w, src_mask, *, num_classes: int,
                   lam_src: float = 0.1, lam_x: float = 10.0,
                   lam_bias: float = 2.0, k_max: int = 16):
    """GreedyTL at every DC of a padded fleet — ONE dispatch per window.

    x: (L, cap, F); y: (L, cap); mask: (L, cap). The source pool
    src_w (M, F+1, C) / src_mask (M,) is SHARED across the fleet (paper
    Algorithm 1: every DC refines against the same m(0) exchange).
    Returns (w_eff (L, F+1, C), selected (L, M)).

    Uses ``lax.map`` rather than ``vmap``: each DC's slice then runs the
    exact per-call computation graph, so the result is bitwise identical to
    L separate :func:`greedytl` calls (the loop engine) — vmap's batched
    linalg is not — while still costing a single dispatch. Padding DCs
    (all-zero masks) leave the greedy while_loop after one step, so they
    are nearly free.
    """
    return jax.lax.map(
        lambda t: _greedytl(t[0], t[1], t[2], src_w, src_mask,
                            num_classes=num_classes, lam_src=lam_src,
                            lam_x=lam_x, lam_bias=lam_bias, k_max=k_max),
        (x, y, mask))
