"""Transformer building blocks: norms, RoPE, chunked attention (GQA / MLA),
gated MLP, and scatter-based MoE with capacity dropping.

All forwards are pure functions over parameter dicts built from
:class:`~repro.sharding.partitioning.ParamSpec` templates. Attention is
q-chunked (exact softmax, memory O(chunk x kv_len)) so 32k-token prefill
lowers without materialising S x S score matrices; the Pallas flash kernel
(`repro.kernels.flash_attention`) is the TPU fast path selected via
``ModelConfig.attention_impl``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig
from repro.sharding.partitioning import ParamSpec, hint

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (half-rotation / llama style)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                 # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (...,S,d/2)
    cos = jnp.cos(angles)[..., None, :]                          # (...,S,1,d/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core: q-chunked exact attention, GQA aware
# ---------------------------------------------------------------------------

def _attend_chunk(q, k, v, q_pos, k_pos, causal, window):
    """q: (B,Cq,KV,G,hd)  k,v: (B,T,KV,hd)  -> (B,Cq,KV,G,hd).

    q_pos: (Cq,) shared positions, or (B,Cq) per-sequence positions
    (continuous batching decodes sequences at different depths).
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqkgd,btkd->bqkgt", q, k,
                        preferred_element_type=jnp.float32) * scale
    qp = q_pos[..., :, None]                   # (Cq,1) or (B,Cq,1)
    kp = k_pos[None, :]
    mask = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        mask &= qp >= kp
    if window and window > 0:
        mask &= (qp - kp) < window
    if mask.ndim == 2:
        mask = mask[None, :, None, None, :]
    else:                                      # batched positions
        mask = mask[:, :, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqkgt,btkd->bqkgd", probs.astype(v.dtype), v)
    return out


def chunked_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                      chunk=512):
    """q: (B,S,H,hd), k/v: (B,T,KV,hd). Exact attention, scanned over q chunks.

    q_offset: absolute position of q[0] relative to k[0] (decode: T_cache).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    vd = v.shape[-1]                      # v head dim may differ (MLA)
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    k_pos = jnp.arange(T)
    offset_arr = jnp.asarray(q_offset)
    if S <= chunk or S % chunk != 0:
        q_pos = offset_arr[..., None] + jnp.arange(S)  # (S,) or (B,S)
        out = _attend_chunk(qg, k, v, q_pos, k_pos, causal, window)
        return out.reshape(B, S, H, vd)

    n_chunks = S // chunk
    qg = qg.reshape(B, n_chunks, chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    def body(_, inputs):
        qc, start = inputs
        q_pos = q_offset + start + jnp.arange(chunk)
        return None, _attend_chunk(qc, k, v, q_pos, k_pos, causal, window)

    starts = jnp.arange(n_chunks) * chunk
    _, out = lax.scan(body, None, (qg, starts))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, vd)
    return out


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_template(cfg: ModelConfig, cross=False) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    t = {
        "wq": ParamSpec((D, H, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((D, KV, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((H, hd, D), ("heads", "head_dim", "embed"),
                        "scaled_normal"),
    }
    if cfg.qkv_bias:
        t["bq"] = ParamSpec((H, hd), ("heads", "head_dim"), "zeros")
        t["bk"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), "zeros")
        t["bv"] = ParamSpec((KV, hd), ("kv_heads", "head_dim"), "zeros")
    return t


def gqa_project_qkv(p, x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def gqa_attention(p, x, cfg: ModelConfig, *, positions=None, causal=None,
                  window=None, rope=True):
    """Full-sequence (train / prefill) GQA self-attention."""
    B, S, D = x.shape
    q, k, v = gqa_project_qkv(p, x, cfg)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    causal = cfg.causal if causal is None else causal
    window = cfg.sliding_window if window is None else window
    if cfg.context_parallel_attention:
        # shard query positions over the model axis; K/V replicated there
        q = hint(q, ("batch", "qseq", None, None))
        k = hint(k, ("batch", None, None, None))
        v = hint(v, ("batch", None, None, None))
    if cfg.attention_impl == "pallas":
        from repro.kernels.ops import flash_attention_bshd
        out = flash_attention_bshd(q, k, v, causal=causal, window=window)
    else:
        out = chunked_attention(q, k, v, causal=causal, window=window)
    if cfg.context_parallel_attention:
        out = hint(out, ("batch", "qseq", None, None))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k, v)


def gqa_decode(p, x, cache_k, cache_v, cfg: ModelConfig, *, t_cache: int,
               window=None, rope=True):
    """One-token decode against a full KV cache of length t_cache."""
    q, k_new, v_new = gqa_project_qkv(p, x, cfg)       # (B,1,?,hd)
    pos = jnp.full((x.shape[0], 1), t_cache)
    if rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    k = jnp.concatenate([cache_k, k_new], axis=1)
    v = jnp.concatenate([cache_v, v_new], axis=1)
    window = cfg.sliding_window if window is None else window
    out = chunked_attention(q, k, v, causal=True, window=window,
                            q_offset=t_cache)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k_new, v_new)


def cross_attention(p, x, enc_kv, cfg: ModelConfig):
    """Decoder cross-attention over precomputed encoder K/V."""
    k, v = enc_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    out = chunked_attention(q, k, v, causal=False, window=0)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention) — MiniCPM3 / DeepSeek-V3
# ---------------------------------------------------------------------------

def mla_template(cfg: ModelConfig) -> dict:
    D, H = cfg.d_model, cfg.num_heads
    m = cfg.mla
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    t = {}
    if m.q_lora_rank:
        t["wq_a"] = ParamSpec((D, m.q_lora_rank), ("embed", "latent"))
        t["q_norm"] = ParamSpec((m.q_lora_rank,), (None,), "ones")
        t["wq_b"] = ParamSpec((m.q_lora_rank, H, qk), ("latent", "heads", None))
    else:
        t["wq"] = ParamSpec((D, H, qk), ("embed", "heads", None))
    t["wkv_a"] = ParamSpec((D, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("embed", "latent"))
    t["kv_norm"] = ParamSpec((m.kv_lora_rank,), (None,), "ones")
    t["wkv_b"] = ParamSpec((m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
                           ("latent", "heads", None))
    t["wo"] = ParamSpec((H, m.v_head_dim, D), ("heads", None, "embed"),
                        "scaled_normal")
    return t


def _mla_q(p, x, m: MLAConfig, cfg, positions):
    if m.q_lora_rank:
        qa = rmsnorm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", qa, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(p, x, cfg: ModelConfig, *, positions=None):
    """Expanded (train / prefill) MLA. Returns output and latent cache entry."""
    B, S, D = x.shape
    m = cfg.mla
    H = cfg.num_heads
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q_nope, q_rope = _mla_q(p, x, m, cfg, positions)

    kv_a = x @ p["wkv_a"]                                   # (B,S,latent+rope)
    c_kv = rmsnorm(kv_a[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(kv_a[..., None, m.kv_lora_rank:], positions,
                        cfg.rope_theta)                     # (B,S,1,rope)
    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b"])
    k_nope = kv[..., :m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim:]

    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (B, S, H, m.qk_rope_head_dim))], axis=-1)
    out = chunked_attention(q, k, v, causal=cfg.causal)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    cache = jnp.concatenate([c_kv, kv_a[..., m.kv_lora_rank:]], axis=-1)
    return y, cache


def mla_decode(p, x, cache, cfg: ModelConfig, *, t_cache: int):
    """Absorbed one-token MLA decode against a latent cache.

    cache: (B, T, kv_lora + rope_dim) — the whole point of MLA: the per-token
    cache is the low-rank latent + shared rope key, not per-head K/V.
    """
    B = x.shape[0]
    m = cfg.mla
    H = cfg.num_heads
    pos = jnp.full((B, 1), t_cache)
    q_nope, q_rope = _mla_q(p, x, m, cfg, pos)              # (B,1,H,*)

    kv_a = x @ p["wkv_a"]
    c_new = rmsnorm(kv_a[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(kv_a[..., None, m.kv_lora_rank:], pos, cfg.rope_theta)
    new_entry = jnp.concatenate([c_new, kr_new[:, :, 0, :]], axis=-1)
    cache = jnp.concatenate([cache, new_entry], axis=1)     # (B,T+1,...)

    c = cache[..., :m.kv_lora_rank]                         # (B,T+1,r)
    k_rope = cache[..., m.kv_lora_rank:]                    # (B,T+1,rope)

    wk = p["wkv_b"][..., :m.qk_nope_head_dim]               # (r,H,nope)
    wv = p["wkv_b"][..., m.qk_nope_head_dim:]               # (r,H,v)
    # absorb k up-projection into q: q_lat (B,1,H,r)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, wk)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (jnp.einsum("bshr,btr->bsht", q_lat, c,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,btk->bsht", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bsht,btr->bshr", probs.astype(c.dtype), c)
    o = jnp.einsum("bshr,rhk->bshk", o_lat, wv)             # (B,1,H,v)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return y, new_entry


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------

def mlp_template(d_model: int, d_ff: int) -> dict:
    return {
        "wi": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "wg": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "wo": ParamSpec((d_ff, d_model), ("mlp", "embed"), "scaled_normal"),
    }


def mlp(p, x):
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# MoE: top-k routing with capacity dropping, scatter-based dispatch
# ---------------------------------------------------------------------------

def moe_template(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    m = cfg.moe
    t = {
        "router": ParamSpec((D, m.num_experts), ("embed", None)),
        "wi": ParamSpec((m.num_experts, D, m.d_expert),
                        ("experts", "embed", None)),
        "wg": ParamSpec((m.num_experts, D, m.d_expert),
                        ("experts", "embed", None)),
        "wo": ParamSpec((m.num_experts, m.d_expert, D),
                        ("experts", None, "embed"), "scaled_normal"),
    }
    if m.num_shared_experts:
        t["shared"] = mlp_template(D, m.d_expert * m.num_shared_experts)
    return t


def _capacity(tokens: int, m: MoEConfig) -> int:
    c = math.ceil(tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)   # round up to a multiple of 8


def moe_ffn(p, x, cfg: ModelConfig):
    """x: (B,S,D) -> (y, aux_loss). Scatter-based dispatch: no (T,E,C) one-hot
    is ever materialised (critical at T ~ 1M tokens for deepseek-v3)."""
    B, S, D = x.shape
    m = cfg.moe
    T = B * S
    E, K = m.num_experts, m.top_k
    C = _capacity(T, m)
    xt = x.reshape(T, D)

    logits = (xt @ p["router"]).astype(jnp.float32)         # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = lax.top_k(probs, K)                         # (T,K)
    gate = gate / (jnp.sum(gate, axis=-1, keepdims=True) + 1e-9)

    # position of each assignment within its expert (stable sort by expert id)
    flat_e = idx.reshape(-1)                                # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)                 # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * K) - starts[sorted_e]
    keep = pos_in_e < C                                     # capacity dropping
    src_tok = order // K

    dest = jnp.where(keep, sorted_e * C + pos_in_e, E * C)  # E*C = drop slot
    buf = jnp.zeros((E * C + 1, D), xt.dtype).at[dest].set(xt[src_tok])
    # expert-parallel layout: dispatch buffer sharding must agree with the
    # expert weights' (workload-dependent, §Perf 1b/1c)
    e_ax = "experts_both" if cfg.expert_parallel == "both" else "experts"
    h = hint(buf[:-1].reshape(E, C, D), (e_ax, None, None))

    hh = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["wg"])) * \
        jnp.einsum("ecd,edf->ecf", h, p["wi"])
    y_e = hint(jnp.einsum("ecf,efd->ecd", hh, p["wo"]),
               (e_ax, None, None)).reshape(E * C, D)

    gath = jnp.where(keep[:, None], y_e[jnp.clip(dest, 0, E * C - 1)], 0.0)
    w = gate.reshape(-1)[order][:, None].astype(xt.dtype)
    y = jnp.zeros((T, D), xt.dtype).at[src_tok].add(gath * w)

    # load-balance aux loss (Switch/GShard form): E * sum_e f_e * P_e
    f = jnp.bincount(flat_e, length=E).astype(jnp.float32) / (T * K)
    pmean = jnp.mean(probs, axis=0)
    aux = m.router_aux_coef * E * jnp.sum(f * pmean)

    y = y.reshape(B, S, D)
    if m.num_shared_experts:
        y = y + mlp(p["shared"], x)
    return y, aux


def moe_ffn_shard_map(p, x, cfg: ModelConfig):
    """MoE FFN with a hand-written expert-parallel schedule (§Perf follow-up).

    shard_map manual over the 'model' axis: every shard owns E/n_shards
    experts, tokens are replicated across that axis, so dispatch is a purely
    LOCAL scatter (each shard picks the assignments routed to its experts)
    and the only collective is one activation-sized psum of the combined
    output — instead of the weight/buffer gathers GSPMD lowers the auto
    version to. Falls back to :func:`moe_ffn` off-mesh or when the expert
    count does not divide the axis.
    """
    from repro.sharding.partitioning import current_mesh
    mesh = current_mesh()
    m = cfg.moe
    E = m.num_experts
    if (mesh is None or "model" not in mesh.shape
            or E % mesh.shape["model"] != 0):
        return moe_ffn(p, x, cfg)
    n_sh = mesh.shape["model"]
    E_loc = E // n_sh
    B, S, D = x.shape
    T = B * S
    K = m.top_k
    C = _capacity(T, m)
    from jax.sharding import PartitionSpec as P_

    def local(wi, wg, wo, router, xt):
        # wi/wg/wo: (E_loc, ...) this shard's experts; xt replicated (T, D)
        sh = lax.axis_index("model")
        logits = (xt @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = lax.top_k(probs, K)
        gate = gate / (jnp.sum(gate, axis=-1, keepdims=True) + 1e-9)

        flat_e = idx.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=E)
        starts = jnp.cumsum(counts) - counts
        pos_in_e = jnp.arange(T * K) - starts[sorted_e]
        mine = (sorted_e >= sh * E_loc) & (sorted_e < (sh + 1) * E_loc)
        keep = (pos_in_e < C) & mine
        src_tok = order // K
        local_e = sorted_e - sh * E_loc
        dest = jnp.where(keep, local_e * C + pos_in_e, E_loc * C)
        buf = jnp.zeros((E_loc * C + 1, D), xt.dtype).at[dest].set(
            xt[src_tok])
        h = buf[:-1].reshape(E_loc, C, D)
        hh = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, wg)) * \
            jnp.einsum("ecd,edf->ecf", h, wi)
        y_e = jnp.einsum("ecf,efd->ecd", hh, wo).reshape(E_loc * C, D)
        gath = jnp.where(keep[:, None],
                         y_e[jnp.clip(dest, 0, E_loc * C - 1)], 0.0)
        w = gate.reshape(-1)[order][:, None].astype(xt.dtype)
        y = jnp.zeros((T, D), xt.dtype).at[src_tok].add(gath * w)
        y = lax.psum(y, "model")          # the only collective
        f = counts.astype(jnp.float32) / (T * K)
        aux = m.router_aux_coef * E * jnp.sum(f * jnp.mean(probs, axis=0))
        return y, aux

    from jax.experimental.shard_map import shard_map
    fn = shard_map(
        local, mesh=mesh, check_rep=False,
        in_specs=(P_("model"), P_("model"), P_("model"), P_(), P_()),
        out_specs=(P_(), P_()))
    y, aux = fn(p["wi"], p["wg"], p["wo"], p["router"],
                x.reshape(T, D))
    y = y.reshape(B, S, D)
    if m.num_shared_experts:
        y = y + mlp(p["shared"], x)
    return y, aux
