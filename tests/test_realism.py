"""Realism axis (DESIGN.md §13): DC churn from energy-ledger battery
feedback, concept drift in the covtype stream, mobility-trace collection,
and byzantine collectors with robust aggregation.

The hard promises under test:

* every realism knob is **engine-invariant**: fleet vs scan produce
  bitwise-identical F1 curves AND ledgers for churn/drift/trace-file/
  byzantine configs (the scan engine host-replays collection + churn, so
  nothing may diverge);
* realism configs stack and shard like any other config (all new fields
  are ``host_side``), bitwise across stack modes and shard counts, and
  through the streaming sweep service;
* baselines stay baselines: ``drift="none"``, ``robust_agg="mean"``,
  ``battery_mj=None`` and ``byz_frac=0.0`` are bitwise no-ops (the
  golden suite pins this globally; here we pin the mechanisms).
"""
import dataclasses
import json
import threading

import numpy as np
import pytest

from repro.core.energy import Ledger
from repro.core.experiment import SweepSpec, get_preset
from repro.core.metrics import trimmed_mean
from repro.core.scenario import (ChurnBook, ScenarioConfig,
                                 get_collection_policy, host_side_fields,
                                 resolve_robust, run_scenario,
                                 validate_config)
from repro.data.mobility import (generate_trace, load_trace,
                                 make_trace_loads)
from repro.data.synthetic_covtype import get_drift, make_covtype_like

DATA = make_covtype_like(n_total=1400, seed=0)
W = 4


def _run(engine, **kw):
    cfg = ScenarioConfig(windows=W, eval_every=1, engine=engine, **kw)
    validate_config(cfg)
    return run_scenario(cfg, DATA)


# ---------------------------------------------------------------------------
# trimmed mean (the robust combine primitive)
# ---------------------------------------------------------------------------

def test_trimmed_mean_zero_frac_is_plain_mean_bitwise():
    rng = np.random.default_rng(0)
    stack = rng.normal(size=(6, 5, 3)).astype(np.float32)
    assert np.array_equal(trimmed_mean(stack, 0.0), np.mean(stack, axis=0))


def test_trimmed_mean_drops_the_tails():
    stack = np.array([[0.0], [1.0], [2.0], [3.0], [100.0]])
    assert trimmed_mean(stack, 0.2)[0] == 2.0       # drops 0 and 100
    assert trimmed_mean(stack, 0.1)[0] == np.mean(stack)  # k=0: plain mean
    for bad in (-0.1, 0.5, 0.9):
        with pytest.raises(ValueError):
            trimmed_mean(stack, bad)


def test_resolve_robust_spec_grammar():
    assert resolve_robust("mean") == 0.0
    assert resolve_robust("trim") == 0.2
    assert resolve_robust("trim:frac=0.25") == 0.25
    with pytest.raises(KeyError):
        resolve_robust("median")
    with pytest.raises(ValueError):
        resolve_robust("trim:frac=0.5")


# ---------------------------------------------------------------------------
# mobility traces: generator, loader, trace_file policy
# ---------------------------------------------------------------------------

def test_trace_generator_deterministic_and_idempotent(tmp_path):
    loads = make_trace_loads(windows=5, mules=3, sensors=20, seed=7)
    assert loads.shape == (5, 3)
    assert np.array_equal(loads,
                          make_trace_loads(windows=5, mules=3,
                                           sensors=20, seed=7))
    assert (loads.sum(axis=1) == 20).all()     # every sensor lands somewhere
    p1 = generate_trace(str(tmp_path), windows=5, mules=3, sensors=20,
                        seed=7)
    p2 = generate_trace(str(tmp_path), windows=5, mules=3, sensors=20,
                        seed=7)
    assert p1 == p2                            # digest-named, idempotent
    assert np.array_equal(load_trace(p1), loads.astype(np.float64))
    p3 = generate_trace(str(tmp_path), windows=5, mules=3, sensors=20,
                        seed=8)
    assert p3 != p1                            # seed lands in the digest


def test_load_trace_validates(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": 1, "windows": 2, "mules": 2,
                               "loads": [[0, 0], [1, 1]]}))
    with pytest.raises(ValueError, match="zero total load"):
        load_trace(str(bad))                   # a window with zero load
    bad.write_text(json.dumps({"schema": 99}))
    with pytest.raises(ValueError):
        load_trace(str(bad))


def test_trace_file_policy_windowed_cursor_wraps(tmp_path):
    path = generate_trace(str(tmp_path), windows=3, mules=3, sensors=30,
                          seed=1)
    policy = get_collection_policy(f"trace_file:path={path}")
    cfg = ScenarioConfig(windows=6)
    rng = np.random.default_rng(0)
    ref = [policy(cfg, rng, 50, w) for w in range(3)]
    for w in range(3):
        # cursor wraps: window w+3 replays window w's loads exactly
        L, assign = policy(cfg, rng, 50, w + 3)
        assert L == ref[w][0]
        assert np.array_equal(assign, ref[w][1])
        assert len(assign) == 50               # every observation assigned
        assert set(assign) <= set(range(L))
    with pytest.raises(ValueError, match="path"):
        get_collection_policy("trace_file")


# ---------------------------------------------------------------------------
# churn: battery feedback, graceful degradation
# ---------------------------------------------------------------------------

def test_churn_depletes_mules_and_degrades_gracefully():
    base = _run("fleet", algo="star", tech="4g", seed=0)
    churned = _run("fleet", algo="star", tech="4g", seed=0,
                   battery_mj=25.0)
    churn_events = [e for e in churned.ledger.events
                    if e["purpose"] == "churn"]
    assert churn_events, "battery 25 mJ over 4 windows must deplete mules"
    assert all(e["mj"] == 0.0 for e in churn_events)
    # a depleted mule stops accruing collection energy from its window on
    first = churn_events[0]
    name = first["what"].split()[0]
    died_at = int(first["what"].rsplit("@w", 1)[1])
    windows_seen = 0
    for e in churned.ledger.events:
        if e["what"] == f"sensor->{name}":
            windows_seen += 1
    assert windows_seen <= died_at
    # graceful: finite F1, strictly cheaper than the un-churned baseline
    assert all(np.isfinite(v) for v in churned.f1_curve)
    assert churned.energy_total < base.energy_total
    # no battery => bitwise baseline
    again = _run("fleet", algo="star", tech="4g", seed=0)
    assert again.f1_curve == base.f1_curve
    assert again.ledger.events == base.ledger.events


def test_churnbook_sweeps_deterministically_and_spares_the_es():
    led = Ledger()
    led.node_mj.update({"SM2": 9.0, "SM1": 11.0, "ES": 999.0})
    book = ChurnBook(10.0)
    book.sweep(led, 3)
    assert book.dead == {"SM1": 3}             # ES never churns
    assert led.events[-1]["purpose"] == "churn"
    book.sweep(led, 4)                         # already dead: no re-churn
    assert [e for e in led.events if e["purpose"] == "churn"] \
        == [led.events[-1]]


# ---------------------------------------------------------------------------
# drift: schedule semantics
# ---------------------------------------------------------------------------

def test_drift_transforms_are_deterministic_and_scoped():
    x = np.random.default_rng(0).normal(size=(60, 54))
    y = np.random.default_rng(1).integers(0, 7, size=60).astype(np.int32)
    rot = get_drift("rotate:rate=0.3")
    x1, y1 = rot(x, y, 6, 10, seed=0)
    x2, y2 = rot(x, y, 6, 10, seed=0)
    assert np.array_equal(x1, x2) and np.array_equal(y1, y2)
    # rotation touches only the continuous block, labels untouched
    assert np.array_equal(x1[:, 10:], x[:, 10:])
    assert np.array_equal(y1, y)
    # window 0 is undrifted (angle 0); later windows move
    assert np.allclose(x1[:10], x[:10])
    assert not np.allclose(x1[-10:], x[-10:])
    # norms preserved (it IS a rotation)
    assert np.allclose(np.linalg.norm(x1[:, :10], axis=1),
                       np.linalg.norm(x[:, :10], axis=1))

    pri = get_drift("prior:at=0.5,gamma=0.2")
    _, yp = pri(x, y, 6, 10, seed=0)
    assert np.array_equal(yp[:30], y[:30])     # pre-onset untouched
    # gamma < 1 tilts the post-onset prior towards low class ids
    assert yp[30:].mean() < y[30:].mean() + 1e-9
    with pytest.raises(KeyError):
        get_drift("melt")
    with pytest.raises(ValueError):
        get_drift("rotate:rate=9.9")


def test_drift_none_is_bitwise_baseline():
    a = _run("fleet", algo="star", tech="4g", seed=1)
    b = _run("fleet", algo="star", tech="4g", seed=1, drift="none")
    assert a.f1_curve == b.f1_curve and a.ledger.events == b.ledger.events
    c = _run("fleet", algo="star", tech="4g", seed=1, drift="rotate:rate=0.4")
    assert c.f1_curve != a.f1_curve            # drift actually bites
    assert c.ledger.events == a.ledger.events  # ...but costs no energy


# ---------------------------------------------------------------------------
# engine parity: fleet == scan, bitwise, for every realism knob
# ---------------------------------------------------------------------------

REALISM_CFGS = [
    dict(algo="star", tech="4g", seed=0, battery_mj=25.0),
    dict(algo="a2a", tech="wifi", seed=1, battery_mj=30.0),
    dict(algo="star", tech="4g", seed=2, drift="rotate_prior"),
    dict(algo="a2a", tech="wifi", seed=3, byz_frac=0.3,
         robust_agg="trim:frac=0.25"),
]


@pytest.mark.parametrize("kw", REALISM_CFGS,
                         ids=lambda k: "_".join(f"{a}" for a in k.values()))
def test_scan_matches_fleet_on_realism_configs(kw):
    ref = _run("fleet", **kw)
    got = _run("scan", **kw)
    assert got.ledger.events == ref.ledger.events
    assert got.f1_curve == ref.f1_curve


def test_scan_matches_fleet_on_trace_file(tmp_path):
    path = generate_trace(str(tmp_path), windows=W, mules=4, sensors=30,
                          seed=0)
    kw = dict(algo="star", tech="4g", seed=0,
              collection=f"trace_file:path={path}")
    ref = _run("fleet", **kw)
    got = _run("scan", **kw)
    assert got.ledger.events == ref.ledger.events
    assert got.f1_curve == ref.f1_curve


# ---------------------------------------------------------------------------
# stacking / sharding / service: realism rows behave like any other row
# ---------------------------------------------------------------------------

def _realism_spec():
    base = ScenarioConfig(windows=W, eval_every=1, algo="star", tech="4g")
    return SweepSpec(
        "realism_mini", base=base,
        axes={"battery_mj": (None, 25.0), "drift": ("none", "rotate")},
        label="b{battery_mj}_d{drift}").with_seeds(2)


def test_realism_fields_are_host_side_and_stack_bitwise():
    hs = set(host_side_fields())
    assert {"battery_mj", "drift", "byz_frac", "robust_agg"} <= hs
    spec = _realism_spec()
    ref = spec.run(DATA, stack="off").to_json()
    assert spec.run(DATA, stack="auto").to_json() == ref
    assert spec.run(
        DATA, parallel="hosts:channel=inline,n=2").to_json() == ref


def test_service_streamed_realism_matches_sequential_bitwise():
    from repro.service.client import ServiceClient
    from repro.service.server import make_server

    spec = _realism_spec()
    ref = spec.run(DATA).to_json()
    httpd, _ = make_server(backend="hosts:channel=inline,n=2")
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    try:
        client = ServiceClient(httpd.server_address[:2])
        out = client.run(spec, DATA, cache="off")
        assert out.to_json() == ref
    finally:
        httpd.shutdown()


# ---------------------------------------------------------------------------
# validation: fail fast, and city-mode restrictions
# ---------------------------------------------------------------------------

def test_validate_config_rejects_bad_realism_knobs():
    for kw in (dict(battery_mj=0.0), dict(battery_mj=-3.0),
               dict(byz_frac=-0.1), dict(byz_frac=1.5),
               dict(drift="melt"), dict(robust_agg="median"),
               dict(algo="edge_only", battery_mj=5.0),
               dict(algo="edge_only", byz_frac=0.1)):
        with pytest.raises((ValueError, KeyError)):
            validate_config(ScenarioConfig(windows=2, **kw))
    # city mode: battery churn is supported, the host-loop knobs are not
    city = ScenarioConfig(windows=2, algo="star", engine="scan",
                          fleet_size=16, obs_per_dc=4, train_iters=3)
    validate_config(dataclasses.replace(city, battery_mj=3.0))
    for kw in (dict(drift="rotate"), dict(byz_frac=0.2),
               dict(robust_agg="trim")):
        with pytest.raises(ValueError, match="city"):
            validate_config(dataclasses.replace(city, **kw))


def test_realism_presets_expand_and_validate():
    for name in ("churn", "drift", "byzantine", "realism"):
        spec = get_preset(name, windows=3)
        runs = spec.configs()
        assert runs
        for _, cfg in runs:
            validate_config(cfg)
