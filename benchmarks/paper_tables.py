"""Reproduction of the paper's tables/figures (one function per table).

All energies in mJ, F-measures on the held-out test set, losses relative to
our own Edge-Only run (exactly how the paper computes its losses). Results
are cached under results/benchmarks/ as JSON; ``--quick`` runs fewer windows
and seeds for CI-speed smoke validation.

The whole grid is built up front and evaluated by ONE
:func:`~repro.core.scenario.run_sweep` call with ``stack_seeds=True``: every
stack-compatible row x seed replica (same algorithm, any mix of seeds,
technologies, p_edge, allocation and aggregation settings) runs in lockstep
on a shared fleet axis, so the sweep pays O(sample buckets) jitted
dispatches per window for a whole table column group instead of O(rows x
seeds).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import numpy as np

from repro.core.scenario import ScenarioConfig, run_sweep
from repro.data.synthetic_covtype import make_covtype_like

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "benchmarks")


def _stats(results):
    """Aggregate one row's seed replicas: converged F1 and energies."""
    curves = [r.f1_curve for r in results]
    return {
        "f1": float(np.mean([r.converged_f1() for r in results])),
        "f1_std": float(np.std([r.converged_f1() for r in results])),
        "energy_mj": float(np.mean([r.energy_total for r in results])),
        "collection_mj": float(np.mean([r.energy_collection
                                        for r in results])),
        "learning_mj": float(np.mean([r.energy_learning for r in results])),
        "f1_curve": list(np.mean(np.array(curves), axis=0)),
    }


def _grid(base: ScenarioConfig):
    """(label, config) pairs for every table row of the paper."""
    rows = [("fig2_edge_only", dataclasses.replace(base, algo="edge_only"))]

    # -- Table 2: partial data on the edge (StarHTL, 4G between DCs) --------
    for frac, lbl in [(0.5, "50"), (0.15, "15"), (0.03, "3")]:
        rows.append((f"table2_edge{lbl}pct",
                     dataclasses.replace(base, algo="star", p_edge=frac,
                                         tech="4g")))

    # -- Table 3: no data on edge, Zipf, A2A/Star x 4G/WiFi ------------------
    for algo in ("a2a", "star"):
        for tech in ("4g", "wifi"):
            rows.append((f"table3_{algo}_{tech}",
                         dataclasses.replace(base, algo=algo, tech=tech)))

    # -- Table 4: + data-aggregation heuristic (Zipf) ------------------------
    for algo in ("a2a", "star"):
        for tech in ("4g", "wifi"):
            rows.append((f"table4_{algo}_{tech}_agg",
                         dataclasses.replace(base, algo=algo, tech=tech,
                                             aggregate=True)))

    # -- Tables 5/6: uniform initial distribution ----------------------------
    for algo in ("a2a", "star"):
        for tech in ("4g", "wifi"):
            rows.append((f"table5_{algo}_{tech}_uniform",
                         dataclasses.replace(base, algo=algo, tech=tech,
                                             uniform=True)))
            rows.append((f"table6_{algo}_{tech}_uniform_agg",
                         dataclasses.replace(base, algo=algo, tech=tech,
                                             uniform=True, aggregate=True)))

    # -- Tables 8/9: GreedyTL sub-sampling (computational complexity) --------
    for n_sub in (2, 5, 10):
        for algo in ("a2a", "star"):
            rows.append((f"table8_{algo}_n{n_sub}",
                         dataclasses.replace(base, algo=algo, tech="wifi",
                                             n_subsample=n_sub)))
            rows.append((f"table9_{algo}_n{n_sub}_uniform",
                         dataclasses.replace(base, algo=algo, tech="wifi",
                                             uniform=True,
                                             n_subsample=n_sub)))
    return rows


def run_all(windows: int = 100, n_seeds: int = 3, quick: bool = False,
            engine: str = "fleet"):
    if quick:
        windows, n_seeds = 30, 1
    data = make_covtype_like(seed=0)
    out = {"windows": windows, "n_seeds": n_seeds, "engine": engine}

    base = ScenarioConfig(windows=windows, eval_every=max(1, windows // 20),
                          engine=engine)
    rows = _grid(base)

    t0 = time.time()
    configs = [dataclasses.replace(cfg, seed=s)
               for _, cfg in rows for s in range(n_seeds)]
    print(f"sweeping {len(rows)} rows x {n_seeds} seed(s), {windows} "
          f"windows, replica-stacked (rows print when the sweep returns)",
          flush=True)
    results = run_sweep(configs, data, stack_seeds=True)
    out["sweep_seconds"] = round(time.time() - t0, 1)
    print(f"sweep done in {out['sweep_seconds']}s", flush=True)

    ref = None
    for i, (label, _) in enumerate(rows):
        r = _stats(results[i * n_seeds:(i + 1) * n_seeds])
        if label == "fig2_edge_only":
            ref = r
        else:
            r["gain_pct"] = 100.0 * (1 - r["energy_mj"] / ref["energy_mj"])
            r["acc_loss_pct"] = (100.0 * (ref["f1"] - r["f1"])
                                 / max(ref["f1"], 1e-9))
            print(f"{label:34s} E={r['energy_mj']:8.0f} mJ "
                  f"gain={r['gain_pct']:5.1f}% "
                  f"F1={r['f1']:.3f} loss={r['acc_loss_pct']:4.1f}%",
                  flush=True)
        out[label] = r

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "paper_tables.json"), "w") as f:
        json.dump(out, f, indent=1)
    return out
