"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Temporal mixing = gated linear recurrence:
    i_t = sigmoid(W_i u_t)          (input gate, block-diagonal)
    r_t = sigmoid(W_r u_t)          (recurrence gate, block-diagonal)
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Train/prefill evaluate the recurrence with `lax.associative_scan` (parallel
prefix over time); decode is the O(1) step. The Pallas kernel
(`repro.kernels.rglru_scan`) is the TPU fast path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding.partitioning import ParamSpec

C_FACTOR = 8.0
N_GATE_BLOCKS = 16


def rglru_width(cfg: ModelConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def rglru_template(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    W = rglru_width(cfg)
    r = cfg.rglru
    nb = N_GATE_BLOCKS
    bs = W // nb
    return {
        "w_y": ParamSpec((D, W), ("embed", "lru")),
        "w_x": ParamSpec((D, W), ("embed", "lru")),
        "conv_w": ParamSpec((r.conv_width, W), ("conv", "lru"), "conv"),
        "conv_b": ParamSpec((W,), ("lru",), "zeros"),
        "gate_i": ParamSpec((nb, bs, bs), (None, None, None), "fan_in"),
        "gate_r": ParamSpec((nb, bs, bs), (None, None, None), "fan_in"),
        "lam": ParamSpec((W,), ("lru",), "dt_bias"),
        "w_out": ParamSpec((W, D), ("lru", "embed"), "scaled_normal"),
    }


def _causal_conv(u, w, b):
    cw = w.shape[0]
    C = u.shape[-1]
    out = lax.conv_general_dilated(
        u, w[:, None, :], window_strides=(1,), padding=[(cw - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=C)
    return out + b


def _block_diag(u, w):
    """u: (...,W), w: (nb,bs,bs) -> (...,W) block-diagonal matmul."""
    nb, bs, _ = w.shape
    ur = u.reshape(u.shape[:-1] + (nb, bs))
    out = jnp.einsum("...nb,nbc->...nc", ur, w)
    return out.reshape(u.shape)


def _gates(u, p):
    i = jax.nn.sigmoid(_block_diag(u, p["gate_i"]).astype(jnp.float32))
    r = jax.nn.sigmoid(_block_diag(u, p["gate_r"]).astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * \
        u.astype(jnp.float32)
    return a, gated_in


def rglru_scan_ref(a, b):
    """Linear recurrence h_t = a_t h_{t-1} + b_t over axis 1 (time).

    a, b: (B,S,W) float32. Parallel prefix: (a2,b2)o(a1,b1)=(a1*a2, a2*b1+b2).
    """
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_forward(p, x, cfg: ModelConfig):
    """x: (B,S,D) -> (y, (h_final, conv_tail))."""
    B, S, D = x.shape
    y_branch = jax.nn.gelu(x @ p["w_y"])
    u = x @ p["w_x"]
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    a, gated_in = _gates(u, p)
    if cfg.attention_impl == "pallas":
        from repro.kernels.ops import rglru_scan as rglru_scan_kernel
        h = rglru_scan_kernel(a, gated_in)
    else:
        h = rglru_scan_ref(a, gated_in)                 # (B,S,W) f32
    h = h.astype(x.dtype)
    out = (h * y_branch) @ p["w_out"]
    conv_tail = (x @ p["w_x"])[:, S - (cfg.rglru.conv_width - 1):, :]
    return out, (h[:, -1, :], conv_tail)


def rglru_decode(p, x, h_state, conv_state, cfg: ModelConfig):
    """One-token step. x: (B,1,D); h_state: (B,W); conv_state: (B,cw-1,W)."""
    y_branch = jax.nn.gelu(x @ p["w_y"])                # (B,1,W)
    u_new = x @ p["w_x"]                                # (B,1,W)
    window = jnp.concatenate([conv_state, u_new], axis=1)
    u = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    a, gated_in = _gates(u[:, None, :], p)              # (B,1,W)
    h = a[:, 0] * h_state.astype(jnp.float32) + gated_in[:, 0]
    h = h.astype(x.dtype)
    out = (h[:, None, :] * y_branch) @ p["w_out"]
    return out, (h, window[:, 1:, :])
