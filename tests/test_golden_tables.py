"""Golden-value regression gate: silent numeric drift anywhere in the
collection / learning / energy pipeline turns into a red test.

tests/golden/smoke_golden.json pins known-good smoke-preset values
(per-label converged F1, mean F1 curves, energy totals by purpose — the
quantities behind the paper tables and results/benchmarks/sweep_api.json).
A failure here means the published numbers changed: either fix the
regression, or — for an *intentional* numeric change — regenerate the
fixture and say so in the PR:

    PYTHONPATH=src python tests/golden/regen_smoke_golden.py
"""
import json
import os

import numpy as np
import pytest

from repro.core.experiment import get_preset
from repro.data.synthetic_covtype import make_covtype_like

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "smoke_golden.json")
ATOL = 1e-6


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def result(golden):
    data = make_covtype_like(seed=golden["data_seed"])
    spec = get_preset("smoke", windows=golden["windows"],
                      n_seeds=golden["n_seeds"])
    return spec.run(data)


def test_labels_and_run_count_pinned(golden, result):
    assert result.labels() == list(golden["per_label"])
    assert len(result.records) == golden["n_runs"]


def test_f1_matches_golden(golden, result):
    for lbl, want in golden["per_label"].items():
        s = result.summary(lbl)
        np.testing.assert_allclose(
            s["f1"], want["f1"], rtol=0, atol=ATOL,
            err_msg=f"converged F1 drifted for {lbl!r}")
        np.testing.assert_allclose(
            s["f1_curve"], want["f1_curve"], rtol=0, atol=ATOL,
            err_msg=f"F1 curve drifted for {lbl!r}")


def test_energy_matches_golden(golden, result):
    """Energies are host-side float64 sums over the event ledger —
    deterministic, so they must match to full precision (gated at the
    same 1e-6, relative, since totals are ~1e4 mJ)."""
    for lbl, want in golden["per_label"].items():
        s = result.summary(lbl)
        for k in ("energy_mj", "collection_mj", "learning_mj"):
            np.testing.assert_allclose(
                s[k], want[k], rtol=1e-6, atol=0,
                err_msg=f"{k} drifted for {lbl!r}")


def test_per_run_final_f1_matches_golden(golden, result):
    """Per-(label, seed) resolution — a mean can hide two cancelling
    regressions."""
    finals = [(r.label, r.cfg.seed, r.f1_curve[-1])
              for r in result.records]
    for (lbl, seed, f1), want in zip(finals, golden["per_run_final_f1"]):
        assert lbl == want["label"] and seed == want["seed"]
        np.testing.assert_allclose(
            f1, want["final_f1"], rtol=0, atol=ATOL,
            err_msg=f"final F1 drifted for {lbl!r} seed={seed}")
