"""whisper-medium — encoder-decoder ASR backbone [arXiv:2212.04356].

24 enc + 24 dec layers, d_model=1024, 16 heads (MHA, kv=16), d_ff=4096,
vocab=51865. The mel-spectrogram + conv frontend is a STUB: ``input_specs``
feeds precomputed frame embeddings of shape (batch, 1500, d_model).

Adaptation note: real Whisper uses learned absolute positions capped at 448
decoder tokens; we use RoPE in the decoder so the assigned decode shapes lower
structurally, and record the architectural cap in ``max_decode_kv``.
"""
from repro.configs.base import FrontendConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,                 # decoder layers
    num_encoder_layers=24,
    encoder_seq_len=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    qkv_bias=True,
    causal=True,
    frontend=FrontendConfig(kind="audio", num_tokens=1500, embed_dim=0),
    max_decode_kv=448,
    supports_long_context=False,
    source="arXiv:2212.04356",
))
