"""HLO cost model: FLOPs, HBM bytes, and collective traffic from the
(SPMD-partitioned) compiled HLO text.

``compiled.cost_analysis()`` does NOT multiply ``while``-loop bodies by their
trip count (layer scans report as a single iteration), so we walk the module
ourselves:

* computations are split out of the text; every op line defines
  ``%name = TYPE op(...)`` giving a per-computation symbol table of shapes;
* a call graph (while bodies/conditions, fusions, calls) propagates a
  multiplicity to every computation — a dot inside a fusion inside an
  80-trip layer scan counts 80x;
* FLOPs come from ``dot``/``convolution`` ops (2 * |out| * contracted dim);
* HBM bytes are approximated at fusion boundaries: for ops at control level
  (entry / while bodies / called computations) we count operand + result
  sizes, skipping fusion-internal ops (mirrors XLA's own bytes-accessed
  convention);
* collective bytes use the op result size — shapes in the partitioned module
  are already per-shard, which is what the roofline's per-device collective
  term needs.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# ops that touch HBM even after TPU fusion: matmuls/convs (operands stream
# from HBM), data-movement ops, reductions, and fusion call sites themselves
_HBM_OPS = frozenset({
    "dot", "convolution", "fusion", "custom-call", "copy", "gather",
    "scatter", "dynamic-update-slice", "dynamic-slice", "reduce",
    "reduce-window", "sort", "cumsum", "all-gather", "all-reduce",
    "reduce-scatter", "all-to-all", "collective-permute", "concatenate",
    "pad", "slice",
})

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "opaque": 0,
}

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_TUPLE_SHAPE_RE = re.compile(r"^\(")
_OP_RE = re.compile(r"^\s*(?:\([^)]*\)|[a-z0-9]+\[[\d,]*\][^ ]*)\s+"
                    r"([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)="
                       r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}?")
_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _parse_shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d.strip()]
        out.append((dt, dims))
    return out


def _shape_bytes(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        self.defs: Dict[str, List[Tuple[str, List[int]]]] = {}
        self.callees: List[Tuple[str, str]] = []   # (kind, callee)
        self.is_fusion_target = False


def _split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        ln = raw.rstrip()
        if not ln:
            continue
        stripped = ln.strip()
        if stripped.startswith("HloModule"):
            continue
        if stripped.endswith("{") and ("->" in stripped
                                       or stripped.startswith("ENTRY")):
            head = stripped[:-1].strip()
            is_entry = head.startswith("ENTRY")
            if is_entry:
                head = head[len("ENTRY"):].strip()
            name = head.split("(")[0].strip().lstrip("%").strip()
            if name:
                cur = Computation(name)
                comps[name] = cur
                if is_entry:
                    comps["__entry__"] = cur
                continue
        if stripped == "}":
            continue
        if cur is None:
            continue
        cur.lines.append(stripped)
        dm = _DEF_RE.match(stripped)
        if dm:
            name, rhs = dm.group(1), dm.group(2)
            # result type(s) = everything before the opcode's open paren
            cur.defs[name] = _parse_shapes(rhs[:_first_paren(rhs)])
        cm = _CALLS_RE.findall(stripped)
        for grp in cm:
            for callee in re.split(r",\s*%?", grp):
                kind = "fusion" if "fusion(" in stripped else (
                    "while" if "while(" in stripped else "call")
                cur.callees.append((kind, callee))
    return comps


def _first_paren(s: str) -> int:
    i = s.find("(")
    return i if i >= 0 else len(s)


def _opcode(line: str) -> Optional[str]:
    dm = _DEF_RE.match(line)
    rhs = dm.group(2) if dm else line
    # rhs looks like: "bf16[8,128]{1,0} opcode(%a, %b), attrs..."
    m = re.search(r"\}?\s([a-z][\w\-]*)\(", rhs)
    return m.group(1) if m else None


def _while_trip_count(cond: Computation) -> int:
    consts = []
    for ln in cond.lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def analyze_hlo(hlo: str, pod_size: int = 256) -> dict:
    comps = _split_computations(hlo)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0,
                "collectives": {"total_bytes": 0.0, "dcn_bytes": 0.0,
                                "by_op": {}, "n_ops": 0}}

    # ---- multiplicity propagation ----------------------------------------
    mult: Dict[str, float] = defaultdict(float)
    fusion_targets = set()

    def visit2(comp: Computation, m: float):
        if mult[comp.name] >= m:
            return                    # already visited at >= multiplicity
        mult[comp.name] = m
        for ln in comp.lines:
            if " while(" in ln:
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                cm = re.search(r"condition=%?([\w.\-]+)", ln)
                tm = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ln)
                if bm and bm.group(1) in comps:
                    cond = comps.get(cm.group(1)) if cm else None
                    if tm:
                        trip = int(tm.group(1))
                    else:
                        trip = _while_trip_count(cond) if cond else 1
                    visit2(comps[bm.group(1)], m * max(1, trip))
                    if cond:
                        visit2(cond, m * max(1, trip))
            else:
                for attr in ("calls", "to_apply"):
                    am = re.search(attr + r"=%?([\w.\-]+)", ln)
                    if am and am.group(1) in comps:
                        if "fusion(" in ln:
                            fusion_targets.add(am.group(1))
                        visit2(comps[am.group(1)], m)
                bm = re.search(r"branch_computations=\{([^}]*)\}", ln)
                if bm:
                    for nm in re.split(r",\s*%?", bm.group(1)):
                        nm = nm.strip().lstrip("%")
                        if nm in comps:
                            visit2(comps[nm], m)

    mult.clear()
    visit2(entry, 1.0)

    # ---- walk ops ---------------------------------------------------------
    flops = 0.0
    bytes_hbm = 0.0
    coll_total = 0.0
    coll_dcn = 0.0
    coll_by_op: Dict[str, float] = defaultdict(float)
    n_coll = 0

    for key, comp in comps.items():
        if key == "__entry__":       # alias of the entry computation
            continue
        m = mult.get(comp.name, 0.0)
        if m <= 0:
            continue
        in_fusion = comp.name in fusion_targets
        for ln in comp.lines:
            op = _opcode(ln)
            if op is None:
                continue
            dm = _DEF_RE.match(ln)
            out_shapes = comp.defs.get(dm.group(1), []) if dm else []
            out_elems = sum(_n_elems(s) for s in out_shapes)

            # FLOPs: dots and convolutions (counted even inside fusions)
            if op == "dot":
                cdims = _CONTRACT_RE.search(ln)
                lhs = _first_operand_shape(ln, comp)
                contracted = 1
                if cdims and lhs:
                    for d in cdims.group(1).split(","):
                        if d.strip():
                            contracted *= lhs[1][int(d)]
                flops += m * 2.0 * out_elems * contracted
            elif op == "convolution":
                rhs_shape = _nth_operand_shape(ln, comp, 1)
                kernel_elems = _n_elems((rhs_shape[0], rhs_shape[1])) \
                    if rhs_shape else 0
                out_ch = out_shapes[0][1][-1] if (out_shapes and
                                                  out_shapes[0][1]) else 1
                flops += m * 2.0 * out_elems * max(1, kernel_elems //
                                                   max(1, out_ch))

            # HBM bytes: control level only (fusion boundaries), and only ops
            # that resist fusion on TPU — elementwise/layout ops are assumed
            # fused into neighbours (the CPU backend fuses less than Mosaic/
            # XLA:TPU, so counting every control-level op wildly over-states
            # TPU HBM traffic).
            if not in_fusion and op in _HBM_OPS:
                opnd_bytes = _operand_bytes(ln, comp)
                bytes_hbm += m * (_shape_bytes(out_shapes) + opnd_bytes)

            # collectives
            for cop in COLLECTIVE_OPS:
                if op in (cop, cop + "-start"):
                    b = m * _shape_bytes(out_shapes)
                    coll_total += b
                    coll_by_op[cop] += b
                    n_coll += 1
                    if _line_crosses_pod(ln, pod_size):
                        coll_dcn += b
                    break

    return {"flops": flops, "bytes": bytes_hbm,
            "collectives": {"total_bytes": coll_total, "dcn_bytes": coll_dcn,
                            "by_op": dict(coll_by_op), "n_ops": n_coll}}


def _n_elems(shape: Tuple[str, List[int]]) -> int:
    n = 1
    for d in shape[1]:
        n *= d
    return n


def _operand_names(ln: str) -> List[str]:
    i = ln.find("(")
    j = ln.find(")", i)
    if i < 0 or j < 0:
        return []
    return _OPERAND_RE.findall(ln[i + 1:j])


def _first_operand_shape(ln, comp):
    names = _operand_names(ln)
    if names and names[0] in comp.defs and comp.defs[names[0]]:
        return comp.defs[names[0]][0]
    return None


def _nth_operand_shape(ln, comp, n):
    names = _operand_names(ln)
    if len(names) > n and names[n] in comp.defs and comp.defs[names[n]]:
        return comp.defs[names[n]][0]
    return None


def _operand_bytes(ln, comp) -> int:
    total = 0
    for nm in _operand_names(ln):
        shapes = comp.defs.get(nm)
        if shapes:
            total += _shape_bytes(shapes)
    return total


def _crosses_pod(groups: str, pod_size: int = 256) -> bool:
    for grp in re.finditer(r"\{([\d,\s]+)\}", "{" + groups + "}"):
        ids = [int(x) for x in grp.group(1).replace(" ", "").split(",") if x]
        if ids and (min(ids) // pod_size) != (max(ids) // pod_size):
            return True
    return False


def _line_crosses_pod(ln: str, pod_size: int = 256) -> bool:
    """Handle both explicit {{0,1},{2,3}} and iota [G,N]<=[dims]T(perm)
    replica-group encodings."""
    im = _IOTA_GROUPS_RE.search(ln)
    if im:
        import numpy as _np
        g, n = int(im.group(1)), int(im.group(2))
        dims = [int(x) for x in im.group(3).split(",")]
        total = 1
        for d in dims:
            total *= d
        ids = _np.arange(total).reshape(dims)
        if im.group(4):
            perm = [int(x) for x in im.group(4).split(",")]
            ids = ids.transpose(perm)
        ids = ids.reshape(g, n)
        pods = ids // pod_size
        return bool((pods.min(axis=1) != pods.max(axis=1)).any())
    gm = _GROUPS_RE.search(ln)
    if gm:
        return _crosses_pod(gm.group(1), pod_size)
    return False


def summarize_collectives(hlo: str) -> dict:
    return analyze_hlo(hlo)["collectives"]
