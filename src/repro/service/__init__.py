"""The sweep service layer (DESIGN.md §12): a streaming HTTP RPC control
plane over the core sweep machinery — server, client, exact result cache
and dependency-free statsd metrics. Stdlib-only on top of repro.core.

Heavy imports are deferred: ``from repro.service import statsd`` must
stay importable without pulling jax (the launcher's metrics hook relies
on it)."""
from repro.service.statsd import Statsd, statsd   # noqa: F401

__all__ = ["Statsd", "statsd"]
