"""Batched Cholesky-bordering LOO trial scorer — Pallas TPU kernel.

GreedyTL's greedy source selection scores every candidate column j of the
Gram system G = AᵀA + diag(λ) by the closed-form leave-one-out (LOO) error
of the ridge over the active set S ∪ {j}. Instead of re-inverting the
(bordered) Gram per candidate, the caller factors G_S = LLᵀ once per greedy
step and hands this kernel the *shared* triangular solves

    Ut  = (L⁻¹ A_Sᵀ)ᵀ                  (R, D)  whitened data rows
    Cc  = L⁻¹ G[:, :M]                 (D, M)  candidate borderings
    zⱼ, d⁻¹                            (M,)    bordered RHS / Schur pivots
    fitted_base, h_base                (R,)    active-set fit and leverage

so each trial reduces to a rank-1 bordering (Schur complement of the added
row/column): tᵢⱼ = (Aᵢⱼ − uᵢᵀcⱼ)·dⱼ⁻¹, hᵢⱼ = h_baseᵢ + tᵢⱼ²,
fittedᵢⱼ = fitted_baseᵢ + tᵢⱼ·zⱼ — one (R,D)x(D,M) matmul plus an
elementwise epilogue and a row reduction, fused here into a single kernel
launch over row tiles (grid is sequential; a VMEM scratch accumulates the
per-candidate objectives across tiles).

``loo_trials_ref`` is the pure-jnp oracle; on CPU backends it IS the
production path (see ``repro.kernels.ops``) — interpret mode is only for
kernel-correctness tests, Python-per-grid-cell is far too slow for the
greedy loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAX_CANDIDATES = 128      # one lane tile; M_CAP=16 in the HTL layer


def loo_trials_ref(ut, cc, a_cand, fitted_base, h_base, y, rmask, zj, dinv):
    """Pure-jnp oracle (and the CPU production path).

    ut: (R, D); cc: (D, M); a_cand: (R, M); fitted_base/h_base/y/rmask: (R,);
    zj/dinv: (M,). Returns per-candidate LOO SSE (M,).
    """
    t = (a_cand - ut @ cc) * dinv[None, :]                       # (R, M)
    fitted = fitted_base[:, None] + t * zj[None, :]
    resid = (fitted - y[:, None]) * rmask[:, None]
    h = h_base[:, None] + t * t
    loo = resid / jnp.maximum(1.0 - h, 0.1)
    return jnp.sum(loo * loo, axis=0)


def _loo_trials_kernel(ut_ref, cc_ref, ac_ref, fb_ref, hb_ref, y_ref,
                       rm_ref, zj_ref, dinv_ref, o_ref, acc_scr, *, M: int):
    ri = pl.program_id(0)
    nr = pl.num_programs(0)

    @pl.when(ri == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    u = ut_ref[...].astype(jnp.float32)                          # (bR, D)
    t = (ac_ref[...].astype(jnp.float32)
         - jax.lax.dot(u, cc_ref[...].astype(jnp.float32),
                       preferred_element_type=jnp.float32)) * dinv_ref[...]
    fitted = fb_ref[...] + t * zj_ref[...]                       # (bR, M)
    resid = (fitted - y_ref[...]) * rm_ref[...]
    h = hb_ref[...] + t * t
    loo = resid / jnp.maximum(1.0 - h, 0.1)
    acc_scr[:1, :M] += jnp.sum(loo * loo, axis=0, keepdims=True)

    @pl.when(ri == nr - 1)
    def _finalize():
        o_ref[...] = acc_scr[:1, :M]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def loo_trials(ut, cc, a_cand, fitted_base, h_base, y, rmask, zj, dinv, *,
               block_r: int = 256, interpret: bool = False):
    """Pallas trial scorer; same contract as :func:`loo_trials_ref`.

    Row-padding is handled here (padded rows carry rmask=0, so they add 0 to
    every objective); candidate masking (already-selected / invalid columns)
    is the caller's job — pass dinv=0 there and overwrite the result.
    """
    R, D = ut.shape
    M = cc.shape[1]
    assert M <= MAX_CANDIDATES, M
    if block_r < 1:
        raise ValueError(f"block_r must be >= 1, got {block_r}")
    # Clamp the tile to the padded row count, then snap it UP to the sublane
    # multiple: a tuned/odd block_r (or R < 8) must never produce a tile
    # that is not a multiple of 8, and the grid padding below must hold for
    # any (R, block_r) combination — tail rows carry rmask=0 and add 0.
    bR = _round_up(max(1, min(block_r, _round_up(R, 8))), 8)
    Rp = _round_up(R, bR)
    if Rp != R:
        pad = ((0, Rp - R),)
        ut = jnp.pad(ut, pad + ((0, 0),))
        a_cand = jnp.pad(a_cand, pad + ((0, 0),))
        fitted_base, h_base, y, rmask = (
            jnp.pad(v, pad) for v in (fitted_base, h_base, y, rmask))
    col = lambda v: v.reshape(-1, 1).astype(jnp.float32)
    row = lambda v: v.reshape(1, -1).astype(jnp.float32)

    kernel = functools.partial(_loo_trials_kernel, M=M)
    out = pl.pallas_call(
        kernel,
        grid=(Rp // bR,),
        in_specs=[
            pl.BlockSpec((bR, D), lambda i: (i, 0)),      # ut
            pl.BlockSpec((D, M), lambda i: (0, 0)),       # cc
            pl.BlockSpec((bR, M), lambda i: (i, 0)),      # a_cand
            pl.BlockSpec((bR, 1), lambda i: (i, 0)),      # fitted_base
            pl.BlockSpec((bR, 1), lambda i: (i, 0)),      # h_base
            pl.BlockSpec((bR, 1), lambda i: (i, 0)),      # y
            pl.BlockSpec((bR, 1), lambda i: (i, 0)),      # rmask
            pl.BlockSpec((1, M), lambda i: (0, 0)),       # zj
            pl.BlockSpec((1, M), lambda i: (0, 0)),       # dinv
        ],
        out_specs=pl.BlockSpec((1, M), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, M), jnp.float32),
        scratch_shapes=_scratch(),
        interpret=interpret,
    )(ut, cc, a_cand, col(fitted_base), col(h_base), col(y), col(rmask),
      row(zj), row(dinv))
    return out[0]


def _scratch():
    from jax.experimental.pallas import tpu as pltpu
    return [pltpu.VMEM((8, MAX_CANDIDATES), jnp.float32)]  # obj acc (row 0)
