"""A2AHTL and StarHTL (paper Algorithms 1 & 2) over an energy ledger.

Each window, every Data Collector (DC) holds a disjoint local dataset.
A2AHTL: local SVM -> all-to-all model exchange -> GreedyTL at every DC ->
gather refined models at one DC -> average. StarHTL: local SVM -> entropy
based center election -> models to the center only -> GreedyTL at the center.

All model transfers, index exchanges and raw-data aggregations are charged
through the :class:`~repro.core.topology.Topology` message patterns (unicast /
broadcast / gather / exchange_all), which encode the per-technology relay and
mains-power conventions once for every engine.

This module is the *loop* reference engine: one jitted dispatch per DC. The
batched O(1)-dispatch engine in :mod:`repro.core.fleet` must stay numerically
on top of it (see tests/test_fleet_engine.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.energy import INDEX_BYTES, Ledger, MODEL_BYTES, OBS_BYTES
from repro.core.greedytl import greedytl
from repro.core.metrics import trimmed_mean
from repro.core.svm import pad_local, sample_cap, train_svm
from repro.core.topology import Topology, fleet_nodes

M_CAP = 16        # max source hypotheses per GreedyTL call (padded, masked)


@dataclass
class DC:
    name: str
    x: np.ndarray
    y: np.ndarray
    is_es: bool = False

    @property
    def n(self) -> int:
        return len(self.y)


def label_entropy(y: np.ndarray, num_classes: int) -> float:
    """Information entropy with log base |K| (paper Section 4, StarHTL)."""
    if len(y) == 0:
        return 0.0
    counts = np.bincount(y, minlength=num_classes).astype(np.float64)
    p = counts / counts.sum()
    p = p[p > 0]
    return float(-(p * np.log(p) / np.log(num_classes)).sum())


def _train_base(dc: DC, cap: int, num_classes: int) -> np.ndarray:
    # bucketed sample capacity: padded rows are dead compute (masked rows
    # contribute zero gradient), and the fleet engine buckets identically
    x, y, m = pad_local(dc.x, dc.y, sample_cap(dc.n, cap))
    w = train_svm(jnp.asarray(x), jnp.asarray(y), jnp.asarray(m),
                  num_classes=num_classes)
    return np.asarray(w)


def _subsample(dc: DC, n_per_class: Optional[int], num_classes: int,
               rng: np.random.Generator) -> DC:
    """Paper Section 7: GreedyTL retrained on n points per class."""
    if n_per_class is None or dc.n == 0:
        return dc
    keep = []
    for c in range(num_classes):
        idx = np.where(dc.y == c)[0]
        if len(idx) > n_per_class:
            idx = rng.choice(idx, n_per_class, replace=False)
        keep.append(idx)
    keep = np.concatenate(keep) if keep else np.arange(0)
    return dataclasses.replace(dc, x=dc.x[keep], y=dc.y[keep])


def _greedy_refine(dc: DC, sources: List[np.ndarray], cap: int,
                   num_classes: int) -> np.ndarray:
    x, y, m = pad_local(dc.x, dc.y, sample_cap(dc.n, cap))
    src, src_mask = build_source_pool(sources, None)
    w_eff, _ = greedytl(jnp.asarray(x), jnp.asarray(y), jnp.asarray(m),
                        jnp.asarray(src), jnp.asarray(src_mask),
                        num_classes=num_classes)
    return np.asarray(w_eff)


def apply_aggregation_heuristic(dcs: List[DC], ledger: Ledger, tech: str
                                ) -> List[DC]:
    """Paper Section 6.3: DCs with local data below 2x the model size ship
    raw data to one of them (the largest under-provisioned mule), which alone
    joins the learning round."""
    thresh_obs = int(np.ceil(2 * MODEL_BYTES / OBS_BYTES))
    small = [d for d in dcs if not d.is_es and d.n < thresh_obs]
    big = [d for d in dcs if d.is_es or d.n >= thresh_obs]
    if len(small) <= 1:
        return dcs
    small.sort(key=lambda d: -d.n)
    sink = small[0]
    xs, ys = [sink.x], [sink.y]
    topo = Topology(ledger, tech, fleet_nodes(dcs, _ap_name(dcs)))
    for d in small[1:]:
        if d.n == 0:
            continue
        topo.unicast(topo.node(d.name), topo.node(sink.name),
                     d.n * OBS_BYTES, what="raw-data aggregation")
        xs.append(d.x)
        ys.append(d.y)
    merged = DC(sink.name, np.concatenate(xs), np.concatenate(ys))
    return big + [merged]


def _ap_name(dcs: List[DC]) -> Optional[str]:
    mules = [d for d in dcs if not d.is_es]
    if not mules:
        return None
    return max(mules, key=lambda d: d.n).name


def build_source_pool(base: List[np.ndarray],
                      prev_global: Optional[np.ndarray]):
    """The shared GreedyTL source pool of a window: every base model plus the
    previous global model, truncated to M_CAP. Returns padded
    (src (M_CAP, F+1, C), src_mask (M_CAP,)) — shared by both engines."""
    sources = list(base)
    if prev_global is not None:
        sources = sources + [prev_global]
    sources = sources[:M_CAP]
    F1, C = sources[0].shape
    src = np.zeros((M_CAP, F1, C), np.float32)
    src_mask = np.zeros((M_CAP,), np.float32)
    for i, w in enumerate(sources):
        src[i] = w
        src_mask[i] = 1.0
    return src, src_mask


def run_window_a2a(dcs: List[DC], prev_global: Optional[np.ndarray],
                   ledger: Ledger, tech: str, *, cap: int, num_classes: int,
                   n_subsample: Optional[int] = None,
                   rng: Optional[np.random.Generator] = None,
                   robust: float = 0.0) -> np.ndarray:
    """One A2AHTL round (Algorithm 1). Returns the new global model.
    ``robust`` is the combine step's trim fraction
    (:func:`repro.core.metrics.trimmed_mean`; 0.0 = the paper's mean)."""
    rng = rng or np.random.default_rng(0)
    dcs = [d for d in dcs if d.n > 0]
    if not dcs:
        return prev_global
    ap = _ap_name(dcs)

    base = {d.name: _train_base(d, cap, num_classes) for d in dcs}
    if len(dcs) == 1:
        only = base[dcs[0].name]
        return only if prev_global is None else 0.5 * (only + prev_global)
    topo = Topology(ledger, tech, fleet_nodes(dcs, ap))

    # Step 1: every DC sends its base model to every other DC
    topo.exchange_all(MODEL_BYTES, what="m0 exchange")

    # Step 2: GreedyTL at every DC (prev global model joins the shared pool)
    sources = [base[o.name] for o in dcs]
    if prev_global is not None:
        sources = sources + [prev_global]
    refined = [_greedy_refine(_subsample(d, n_subsample, num_classes, rng),
                              sources, cap, num_classes) for d in dcs]

    # Step 3: send refined models to one DC (the AP / largest mule)
    center = next((d for d in dcs if d.name == ap), dcs[0])
    topo.gather(topo.node(center.name), MODEL_BYTES, what="m1 gather")

    # Step 4: average (or trimmed mean, byzantine-robust combine)
    return trimmed_mean(np.stack(refined), robust)


def run_window_star(dcs: List[DC], prev_global: Optional[np.ndarray],
                    ledger: Ledger, tech: str, *, cap: int, num_classes: int,
                    n_subsample: Optional[int] = None,
                    rng: Optional[np.random.Generator] = None,
                    robust: float = 0.0) -> np.ndarray:
    """One StarHTL round (Algorithm 2). ``robust`` is accepted for engine
    interchangeability but is a no-op: StarHTL has no multi-model combine
    (the center's GreedyTL output IS the round's model)."""
    rng = rng or np.random.default_rng(0)
    dcs = [d for d in dcs if d.n > 0]
    if not dcs:
        return prev_global
    ap = _ap_name(dcs)

    base = {d.name: _train_base(d, cap, num_classes) for d in dcs}
    if len(dcs) == 1:
        only = base[dcs[0].name]
        return only if prev_global is None else 0.5 * (only + prev_global)
    topo = Topology(ledger, tech, fleet_nodes(dcs, ap))

    # Step 1: entropy index exchange + center id broadcast (tiny messages)
    topo.exchange_all(INDEX_BYTES, what="entropy index")
    center = max(dcs, key=lambda d: label_entropy(d.y, num_classes))
    topo.broadcast(topo.node(center.name), INDEX_BYTES, what="center id")

    # Step 2: base models to the center only
    topo.gather(topo.node(center.name), MODEL_BYTES, what="m0 to center")

    # Step 3: GreedyTL at the center only
    sources = [base[d.name] for d in dcs]
    if prev_global is not None:
        sources = sources + [prev_global]
    return _greedy_refine(_subsample(center, n_subsample, num_classes, rng),
                          sources, cap, num_classes)
