"""llava-next-mistral-7b — VLM, Mistral-7B backbone with anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

32L, d_model=4096, 32H GQA kv=8, d_ff=14336, vocab=32000. The SigLIP/CLIP
vision tower + projector is a STUB: ``input_specs`` feeds patch embeddings
(batch, n_img_tokens, d_model). AnyRes tiling => up to 5 tiles x 576 patches
= 2880 image tokens prepended to the text sequence.
"""
from repro.configs.base import FrontendConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    frontend=FrontendConfig(kind="vision", num_tokens=2880, embed_dim=0),
    supports_long_context=False,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
))
