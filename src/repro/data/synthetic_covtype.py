"""Synthetic CovType-like dataset (the real UCI dataset is a data gate —
this container is offline; see DESIGN.md §2 "Data gate").

Mimics the paper's preprocessed dataset: 54 features = 10 continuous
(cartographic) + 4 one-hot wilderness-area + 40 one-hot soil-type; 7 classes,
class-balanced (paper: 19 229 pts, ~2 700/class, 80/20 train/test split).

Class structure is calibrated so that a *linear* model saturates around
F1 ~ 0.6-0.65, matching the paper's reported centralised ceiling of 0.63:
continuous features are class-conditional Gaussians with heavy overlap, and
categorical features carry class-skewed (but noisy) distributions.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import numpy as np

NUM_FEATURES = 54
NUM_CLASSES = 7
NUM_CONTINUOUS = 10
NUM_WILDERNESS = 4
NUM_SOIL = 40


class Dataset(NamedTuple):
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray


def make_covtype_like(n_total: int = 19229, seed: int = 0,
                      test_frac: float = 0.2,
                      class_sep: float = 1.05) -> Dataset:
    rng = np.random.default_rng(seed)
    per_class = n_total // NUM_CLASSES
    n_total = per_class * NUM_CLASSES

    # class means for continuous features; overlap controlled by class_sep
    means = rng.normal(0.0, class_sep, size=(NUM_CLASSES, NUM_CONTINUOUS))
    # shared anisotropic covariance (elevation-like dominant directions)
    scales = rng.uniform(0.6, 1.8, size=NUM_CONTINUOUS)

    # class-conditional categorical distributions, mixed with uniform noise so
    # a linear model cannot fully separate classes
    wild_p = rng.dirichlet(np.ones(NUM_WILDERNESS) * 0.6, size=NUM_CLASSES)
    wild_p = 0.6 * wild_p + 0.4 / NUM_WILDERNESS
    soil_p = rng.dirichlet(np.ones(NUM_SOIL) * 0.3, size=NUM_CLASSES)
    soil_p = 0.55 * soil_p + 0.45 / NUM_SOIL

    xs, ys = [], []
    for c in range(NUM_CLASSES):
        cont = means[c] + rng.normal(0, 1, (per_class, NUM_CONTINUOUS)) * scales
        wa = rng.choice(NUM_WILDERNESS, size=per_class, p=wild_p[c])
        st = rng.choice(NUM_SOIL, size=per_class, p=soil_p[c])
        wa_oh = np.eye(NUM_WILDERNESS, dtype=np.float64)[wa]
        st_oh = np.eye(NUM_SOIL, dtype=np.float64)[st]
        xs.append(np.concatenate([cont, wa_oh, st_oh], axis=1))
        ys.append(np.full(per_class, c, dtype=np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)

    perm = rng.permutation(n_total)
    x, y = x[perm], y[perm]
    # standardize continuous block (paper preprocesses cartographic features)
    mu = x[:, :NUM_CONTINUOUS].mean(0)
    sd = x[:, :NUM_CONTINUOUS].std(0) + 1e-9
    x[:, :NUM_CONTINUOUS] = (x[:, :NUM_CONTINUOUS] - mu) / sd

    n_test = int(n_total * test_frac)
    return Dataset(x[n_test:], y[n_test:], x[:n_test], y[:n_test])


def observation_bytes(label_bytes: int = 1, feature_bytes: int = 8) -> int:
    """Wire size of one observation: 54 float64 features + 1-byte label.

    Calibrated against the paper's Edge-Only benchmark (34 477 mJ over
    10 000 observations via NB-IoT) and mule-collection cost (1 728 mJ via
    802.15.4); see DESIGN.md §2.
    """
    return NUM_FEATURES * feature_bytes + label_bytes
