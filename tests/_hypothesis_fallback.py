"""Deterministic fallback for ``hypothesis`` when it is not installed.

The tier-1 suite uses a handful of hypothesis property tests. This shim
implements just the surface those tests touch (``given``, ``settings`` and
the ``integers``/``floats``/``sampled_from``/``lists``/``tuples``
strategies) with a fixed-seed PRNG, so the property tests still exercise a
spread of inputs — boundary values first, then seeded random draws — and the
suite collects and passes without the dependency. When ``hypothesis`` IS
installed, the test modules import the real thing and this file is unused.
"""
from __future__ import annotations

import functools
import inspect
import random
from types import SimpleNamespace
from typing import Any, Callable, List

_DEFAULT_MAX_EXAMPLES = 20
_SEED = 0xC0FFEE


class _Strategy:
    """A strategy = (draw fn, optional boundary examples tried first)."""

    def __init__(self, draw: Callable[[random.Random], Any],
                 boundary: List[Any] = ()):  # noqa: B006 - read-only default
        self._draw = draw
        self.boundary = list(boundary)

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


def integers(min_value: int = 0, max_value: int = 2 ** 31 - 1) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value),
                     boundary=[min_value, max_value])


def floats(min_value: float = 0.0, max_value: float = 1.0,
           **_kw) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value),
                     boundary=[min_value, max_value])


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    return _Strategy(lambda r: seq[r.randrange(len(seq))],
                     boundary=seq[:1])


def lists(elem: _Strategy, min_size: int = 0, max_size: int = 10,
          **_kw) -> _Strategy:
    def draw(r: random.Random):
        n = r.randint(min_size, max_size)
        return [elem.draw(r) for _ in range(n)]
    boundary = [[elem.draw(random.Random(_SEED)) for _ in range(min_size)]]
    return _Strategy(draw, boundary=boundary)


def tuples(*elems: _Strategy) -> _Strategy:
    return _Strategy(lambda r: tuple(e.draw(r) for e in elems))


strategies = SimpleNamespace(integers=integers, floats=floats,
                             sampled_from=sampled_from, lists=lists,
                             tuples=tuples)


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_kw):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(**strats: _Strategy):
    """Run the test over boundary examples first, then seeded random draws."""
    def deco(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            n = getattr(runner, "_fallback_max_examples",
                        getattr(fn, "_fallback_max_examples",
                                _DEFAULT_MAX_EXAMPLES))
            rng = random.Random(_SEED)
            names = sorted(strats)
            n_boundary = max(len(strats[k].boundary) for k in names)
            for i in range(min(n, n_boundary)):
                drawn = {k: (strats[k].boundary[i]
                             if i < len(strats[k].boundary)
                             else strats[k].draw(rng)) for k in names}
                fn(*args, **drawn, **kwargs)
            for _ in range(max(0, n - n_boundary)):
                drawn = {k: strats[k].draw(rng) for k in names}
                fn(*args, **drawn, **kwargs)

        # pytest must not see the drawn parameters (it would treat them as
        # fixtures): hide the wrapped signature, keep only non-strategy params
        del runner.__wrapped__
        params = [p for name, p in
                  inspect.signature(fn).parameters.items()
                  if name not in strats]
        runner.__signature__ = inspect.Signature(params)
        return runner
    return deco
