"""Spec-string grammar shared by the experiment-facing registries.

Transports, radio technologies and collection policies are all addressed by
*spec strings* of the form

    name
    name:key=value
    name:key=value,key2=value2

(DESIGN.md §5) so a whole experiment variant fits in one `ScenarioConfig`
string field and sweeps stay declarative — ``"mesh:hops=3"``,
``"lora:sf=12"``, ``"bursty:burst=8"``. This module owns the grammar:
:func:`parse_spec` splits a spec into ``(name, params)`` with numeric/bool
coercion, and :func:`format_spec` renders the canonical form back
(sorted keys), so ``format_spec(*parse_spec(s))`` is a stable round-trip
for any valid spec.

**Nested (channel) specs** (DESIGN.md §8). A spec may itself appear as a
parameter *value* of an outer spec — the multi-host launcher's executor
spec embeds a whole ``HostChannel`` spec::

    hosts:channel=ssh:hosts=edge-a;edge-b;edge-c,n=3,retries=2

Two grammar rules make this nest without escaping: the outer grammar
splits parameters on ``","`` only and takes the *first* ``"="`` of a
segment as the key/value boundary, so an embedded spec may freely contain
``":"``, ``"="`` and ``";"``; and the nested channel grammar uses
``sep=";"`` with ``merge_unkeyed=True`` — a ``";"``-segment without its
own ``"="`` *continues the previous value* (``"ssh:hosts=a;b;c"`` parses
to ``{"hosts": "a;b;c"}``), which is what makes ``";"`` double as both
the channel parameter separator and the host-list separator.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple


def _coerce(raw: str) -> Any:
    """int | float | bool | str, in that order of preference."""
    low = raw.strip().lower()
    if low in ("true", "false"):
        return low == "true"
    try:
        return int(raw)
    except ValueError:
        pass
    try:
        return float(raw)
    except ValueError:
        pass
    return raw.strip()


def parse_spec(spec: str, *, sep: str = ",",
               merge_unkeyed: bool = False) -> Tuple[str, Dict[str, Any]]:
    """``"mesh:hops=3,paywall=false"`` -> ``("mesh", {"hops": 3, ...})``.

    The bare form ``"mesh"`` parses to ``("mesh", {})``. Raises
    :class:`ValueError` on malformed parameter segments (missing ``=``,
    empty key), so registries can surface the offending spec verbatim.

    ``sep``/``merge_unkeyed`` select the *nested channel grammar* (module
    docstring): parameters split on ``sep`` (``";"`` for channel specs),
    and with ``merge_unkeyed=True`` a segment without its own ``"="``
    continues the previous parameter's value — ``"ssh:hosts=a;b;c"``
    parses to ``("ssh", {"hosts": "a;b;c"})`` instead of erroring. Merged
    values stay strings (coercion happens once, on the final value).
    """
    if not isinstance(spec, str) or not spec.strip():
        raise ValueError(f"empty transport/policy spec: {spec!r}")
    name, colon, tail = spec.partition(":")
    name = name.strip()
    raw: Dict[str, str] = {}
    if colon and not tail.strip():
        raise ValueError(f"spec {spec!r} has a ':' but no parameters")
    if tail.strip():
        last_key = None
        for part in tail.split(sep):
            key, eq, val = part.partition("=")
            if not eq and merge_unkeyed and last_key is not None \
                    and part.strip():
                raw[last_key] = f"{raw[last_key]}{sep}{part.strip()}"
                continue
            if not eq or not key.strip() or not val.strip():
                raise ValueError(
                    f"malformed parameter {part!r} in spec {spec!r} "
                    f"(expected key=value)")
            last_key = key.strip()
            raw[last_key] = val.strip()
    return name, {k: _coerce(v) for k, v in raw.items()}


def format_spec(name: str, params: Dict[str, Any] | None = None, *,
                sep: str = ",") -> str:
    """Canonical spec string: params sorted by key, bools lowercase.
    ``sep=";"`` renders the nested channel grammar."""
    if not params:
        return name
    def render(v: Any) -> str:
        if isinstance(v, bool):
            return "true" if v else "false"
        return str(v)
    body = sep.join(f"{k}={render(params[k])}" for k in sorted(params))
    return f"{name}:{body}"


def register_factory(registry: Dict[str, Any], name: str, factory: Any,
                     kind: str) -> None:
    """Shared registration rule: idempotent for the same factory object,
    :class:`ValueError` on a conflicting re-registration."""
    prev = registry.get(name)
    if prev is not None and prev is not factory:
        raise ValueError(f"{kind} {name!r} already registered")
    registry[name] = factory


def resolve_spec(spec: str, factories: Dict[str, Any],
                 cache: Dict[str, Any], kind: str, *,
                 sep: str = ",", merge_unkeyed: bool = False) -> Any:
    """Shared spec-string resolution: parse → look up factory → construct
    with the params as kwargs → cache under both the given and the
    canonical spelling. Unknown names, malformed specs and unknown
    parameter *names* raise :class:`KeyError` (fail-fast registries);
    invalid parameter *values* propagate as the factory's
    :class:`ValueError`.

    ``sep``/``merge_unkeyed`` select the nested channel grammar (module
    docstring) for registries whose parameter values are themselves spec
    strings — the sweep service's config grammar
    (``serve:port=8080;backend=hosts:channel=local,n=2``) resolves with
    ``sep=";"``, ``merge_unkeyed=True`` so an embedded executor/channel
    spec nests without escaping."""
    obj = cache.get(spec)
    if obj is not None:
        return obj
    try:
        name, params = parse_spec(spec, sep=sep,
                                  merge_unkeyed=merge_unkeyed)
    except ValueError as e:
        raise KeyError(str(e)) from e
    factory = factories.get(name)
    if factory is None:
        raise KeyError(f"no {kind} registered for {spec!r}; known: "
                       f"{sorted(factories)}")
    try:
        obj = factory(**params)
    except TypeError as e:
        raise KeyError(f"bad parameters for {kind} {spec!r}: {e}") from e
    cache[spec] = obj
    cache.setdefault(format_spec(name, params, sep=sep), obj)
    return obj
