"""Flash attention Pallas TPU kernel.

Block-tiled online-softmax attention with causal and sliding-window masking,
GQA-aware (KV heads indexed via the BlockSpec index map — no KV repetition in
HBM). Targets the TPU MXU: q/k/v blocks are (block_q x head_dim) /
(block_kv x head_dim) VMEM tiles with head_dim padded to 128-lane multiples
by XLA; accumulation is f32 in VMEM scratch persisted across the sequential
kv grid dimension.

Validated on CPU via ``interpret=True`` against ``ref.mha_reference``
(tests/test_kernels.py sweeps shapes and dtypes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_kv: int, causal: bool,
                  window: int, q_offset: int, kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                 # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                 # (bkv, d)
    v = v_ref[0, 0].astype(jnp.float32)
    # rows past kv_len are padding (undefined memory); 0 * NaN = NaN would
    # poison the p @ v matmul, so zero them explicitly
    kv_valid = (ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_kv, 1), 0)) < kv_len
    v = jnp.where(kv_valid, v, 0.0)
    k = jnp.where(kv_valid, k, 0.0)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, :1]                               # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)                      # (bq, 1)

    l_scr[:, :1] = l_scr[:, :1] * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(
        p.astype(v.dtype), v).astype(jnp.float32)
    m_scr[:, :1] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_offset", "block_q",
                              "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = False):
    """q: (B, H, Sq, d); k, v: (B, KV, Skv, d). Returns (B, H, Sq, d).

    GQA: H must be a multiple of KV; kv blocks are selected via index_map.
    """
    B, H, Sq, d = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    nq = pl.cdiv(Sq, bq)
    nk = pl.cdiv(Skv, bkv)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=bq, block_kv=bkv, causal=causal,
        window=window, q_offset=q_offset, kv_len=Skv)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bkv, d), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, d), q.dtype),
        scratch_shapes=_scratch(bq, d),
        interpret=interpret,
    )(q, k, v)


def _scratch(bq, d):
    from jax.experimental.pallas import tpu as pltpu
    return [
        pltpu.VMEM((bq, 128), jnp.float32),   # running max (col 0)
        pltpu.VMEM((bq, 128), jnp.float32),   # running denom (col 0)
        pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
    ]
