"""GreedyTL — transfer learning through greedy source selection
(Kuzborskij, Orabona, Caputo, ICIAP 2015 [28] / CVIU 2017 [37]).

The paper (Section 4, Step 2) describes it as solving "an optimisation
problem to find the linear combination of models m(0) which maximises the
prediction accuracy with respect to the local dataset". We implement exactly
that, in two regularized-least-squares stages, both gated by the closed-form
leave-one-out (LOO) error — the selection criterion of [28]:

* **Stage 1 — greedy source combination.** Candidate pool = source
  hypotheses; each source j enters with a single scalar coefficient alpha_j
  shared across classes (this preserves the source's cross-class calibration
  — the multiclass adaptation of the binary algorithm in [28]). Exact greedy
  forward selection: at every step each remaining source is trial-added and
  the LOO error of the joint ridge recomputed; the best is kept only if it
  improves.
* **Stage 2 — local correction.** A per-class ridge over the original
  features fits the residual; it is kept only if it improves the stacked LOO
  error (with few local samples it usually is not — which is exactly why
  GreedyTL works with 2-10 points per class, paper Section 7).

Because the base hypotheses are linear (paper: linear SVM), the result
collapses EXACTLY into one linear model:

    w_eff = sum_j (alpha_j / s_j) W_src_j + W_correction (+ biases)

so the deployed model is identical to the fitted one, the on-wire model size
stays constant, and the paper's Step-4 averaging is well-posed.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.svm import svm_scores


def _loo_ridge(A, y, rmask, cmask, lam):
    """Ridge with LOO error. A: (R,D); y: (R,); rmask: (R,); cmask: (D,).

    ``lam`` may be a scalar or a per-column vector (D,) — the per-class bias
    columns get a stronger penalty so that a few samples per class cannot
    shift a good source's decision boundaries.
    Returns (loo_sse, coeffs (D,)).
    """
    Am = A * cmask[None, :] * rmask[:, None]
    D = A.shape[1]
    G = Am.T @ Am + jnp.diag(jnp.broadcast_to(lam, (D,)) + 1e-4)
    Ginv = jnp.linalg.inv(G)
    v = (Ginv @ (Am.T @ (y * rmask))) * cmask
    resid = (Am @ v - y) * rmask
    h = jnp.sum((Am @ Ginv) * Am, axis=-1)
    loo = resid / jnp.maximum(1.0 - h, 0.1)
    return jnp.sum(loo ** 2), v


@partial(jax.jit, static_argnames=("num_classes", "k_max"))
def greedytl(x, y, mask, src_w, src_mask, *, num_classes: int,
             lam_src: float = 0.1, lam_x: float = 10.0,
             lam_bias: float = 2.0, k_max: int = 16, lam: float = None):
    """Greedy source combination + gated local correction (see module doc).

    x: (n, F) padded local data; y: (n,); mask: (n,) row validity.
    src_w: (M, F+1, C) stacked source hypotheses; src_mask: (M,).
    Returns (w_eff (F+1, C), selected (M,) 0/1 source-selection mask).
    """
    if lam is not None:           # backwards-compatible alias
        lam_src = lam
    n, F = x.shape
    M, _, C = src_w.shape
    xm = x * mask[:, None]
    Yoh = (2.0 * jax.nn.one_hot(y, num_classes) - 1.0) * mask[:, None]  # (n,C)

    # source predictions H (M, n, C), normalised per source to unit RMS
    H = jax.vmap(lambda w: svm_scores(w, xm))(src_w) * mask[None, :, None]
    denom = jnp.maximum(1.0, jnp.sum(mask)) * C
    s = jnp.sqrt(jnp.sum(H ** 2, axis=(1, 2)) / denom) + 1e-6    # (M,)
    Hn = H / s[:, None, None]

    # ---- Stage 1: stacked system over (n*C) rows, unknowns = alpha + bias_c
    R = n * C
    A_src = Hn.transpose(1, 2, 0).reshape(R, M)          # (R, M)
    A_bias = jnp.tile(jnp.eye(C), (n, 1))                # (R, C)
    A = jnp.concatenate([A_src, A_bias], axis=1)         # (R, M+C)
    yr = Yoh.reshape(R)
    rmask = jnp.repeat(mask, C)
    bias_cols = jnp.concatenate([jnp.zeros(M), jnp.ones(C)])
    lam_vec = jnp.concatenate([jnp.full((M,), lam_src),
                               jnp.full((C,), lam_bias)])

    def greedy_step(state, _):
        sel, best, done = state

        def trial(j):
            cand = jnp.where(jnp.arange(M) == j, 1.0, sel) * src_mask
            cm = jnp.concatenate([cand, jnp.ones(C)])
            obj, _ = _loo_ridge(A, yr, rmask, cm, lam_vec)
            invalid = (sel[j] > 0) | (src_mask[j] == 0)
            return jnp.where(invalid, jnp.inf, obj)

        objs = jax.vmap(trial)(jnp.arange(M))
        j = jnp.argmin(objs)
        improved = (objs[j] < best) & ~done
        sel = jnp.where(improved, jnp.where(jnp.arange(M) == j, 1.0, sel),
                        sel)
        return (sel, jnp.where(improved, objs[j], best),
                done | ~improved), None

    obj0, _ = _loo_ridge(A, yr, rmask, bias_cols, lam_vec)
    (sel, _, _), _ = jax.lax.scan(
        greedy_step, (jnp.zeros(M), obj0, jnp.asarray(False)), None,
        length=min(k_max, M))

    cm = jnp.concatenate([sel * src_mask, jnp.ones(C)])
    _, v1 = _loo_ridge(A, yr, rmask, cm, lam_vec)
    alpha = v1[:M] / s                                   # undo normalisation
    bias1 = v1[M:]                                       # (C,)

    w_src_part = jnp.einsum("m,mfc->fc", alpha, src_w)   # (F+1, C)
    w_src_part = w_src_part.at[F].add(bias1)

    # ---- Stage 2: per-class local correction on the residual, LOO-gated
    fitted = jnp.einsum("m,mnc->nc", v1[:M], Hn) + bias1[None, :]
    resid = (Yoh - fitted) * mask[:, None]               # (n, C)

    def fit_class(rc):
        return _loo_ridge(xm, rc, mask, jnp.ones(F), lam_x)

    loo_x, Vx = jax.vmap(fit_class, in_axes=1, out_axes=(0, 0))(resid)
    # gate: correction kept only if summed LOO improves over zero correction
    loo_zero = jnp.sum(resid ** 2)
    keep = jnp.sum(loo_x) < loo_zero
    Vx = jnp.where(keep, Vx.T, 0.0)                      # (F, C)

    w_eff = w_src_part.at[:F].add(Vx)
    return w_eff, sel
