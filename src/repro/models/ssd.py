"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Train/prefill use the chunked dual form: intra-chunk attention-like matmuls
(MXU-friendly) + inter-chunk recurrent state carry via `lax.scan`. Decode is
the O(1) recurrent step. The Pallas kernel (`repro.kernels.ssd_scan`) is the
TPU fast path for the intra-chunk part; this module is the XLA reference used
for lowering and as the kernel oracle's substrate.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.sharding.partitioning import ParamSpec


def ssd_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = s.num_heads or d_inner // s.head_dim
    return d_inner, nheads, s.head_dim, s.state_dim


def ssd_template(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    s = cfg.ssm
    d_in, nh, P, N = ssd_dims(cfg)
    conv_ch = d_in + 2 * N
    return {
        "w_z": ParamSpec((D, d_in), ("embed", "mlp")),
        "w_xbc": ParamSpec((D, conv_ch), ("embed", "mlp")),
        "w_dt": ParamSpec((D, nh), ("embed", None)),
        "dt_bias": ParamSpec((nh,), (None,), "dt_bias"),
        "A_log": ParamSpec((nh,), (None,), "ssm_a"),
        "D_skip": ParamSpec((nh,), (None,), "ones"),
        "conv_w": ParamSpec((s.conv_width, conv_ch), ("conv", "mlp"), "conv"),
        "conv_b": ParamSpec((conv_ch,), ("mlp",), "zeros"),
        "gate_norm": ParamSpec((d_in,), ("mlp",), "ones"),
        "w_out": ParamSpec((d_in, D), ("mlp", "embed"), "scaled_normal"),
    }


def _causal_conv(u, w, b):
    """Depthwise causal conv. u: (B,S,C), w: (cw,C)."""
    cw = w.shape[0]
    B, S, C = u.shape
    out = lax.conv_general_dilated(
        u, w[:, None, :],
        window_strides=(1,), padding=[(cw - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=C)
    return out + b


def _gated_rmsnorm(y, z, scale, eps):
    y = y * jax.nn.silu(z)
    y32 = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    return (y32 * lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    x:  (B,S,H,P)   dt: (B,S,H) (post-softplus)   A: (H,) (negative)
    Bm: (B,S,N)     Cm: (B,S,N)  (single group, shared across heads)
    Returns y: (B,S,H,P), final_state: (B,H,P,N).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q != 0:
        Q = S
    nc = S // Q
    xr = x.reshape(Bsz, nc, Q, H, P)
    dtr = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    Br = Bm.reshape(Bsz, nc, Q, N)
    Cr = Cm.reshape(Bsz, nc, Q, N)

    da = dtr * A                                    # (B,nc,Q,H), negative
    cs = jnp.cumsum(da, axis=2)                     # within-chunk cumsum
    seg_last = cs[:, :, -1:, :]                     # (B,nc,1,H)

    # intra-chunk: Y[i] = sum_{j<=i} exp(cs_i - cs_j) (C_i . B_j) dt_j x_j
    scores = jnp.einsum("bcin,bcjn->bcij", Cr, Br,
                        preferred_element_type=jnp.float32)
    decay = cs[:, :, :, None, :] - cs[:, :, None, :, :]      # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(decay), 0.0)
    M = scores[..., None] * L * dtr[:, :, None, :, :]        # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M.astype(x.dtype), xr)

    # chunk input states: S_c = sum_j exp(cs_last - cs_j) dt_j B_j (x) x_j
    w = jnp.exp(seg_last - cs) * dtr                         # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                             w.astype(x.dtype), Br.astype(x.dtype), xr)

    # inter-chunk recurrence over chunk axis
    seg_decay = jnp.exp(seg_last[:, :, 0, :]).astype(x.dtype)   # (B,nc,H)

    def body(h, inp):
        s_c, d_c = inp                                # (B,H,P,N), (B,H)
        h_prev = h
        h = h * d_c[:, :, None, None] + s_c
        return h, h_prev

    h0 = jnp.zeros((Bsz, H, P, N), x.dtype)
    hN, h_prevs = lax.scan(
        body, h0,
        (chunk_state.transpose(1, 0, 2, 3, 4), seg_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)        # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcin,bchpn->bcihp", Cr.astype(x.dtype), h_prevs)
    y_inter = y_inter * jnp.exp(cs)[..., None].astype(x.dtype)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, hN


def ssd_forward(p, x, cfg: ModelConfig):
    """Full-sequence SSD mixer. x: (B,S,D) -> (y, (ssm_state, conv_tail))."""
    B, S, D = x.shape
    s = cfg.ssm
    d_in, nh, P, N = ssd_dims(cfg)

    z = x @ p["w_z"]                                   # (B,S,d_in)
    xbc = _causal_conv(x @ p["w_xbc"], p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :d_in].reshape(B, S, nh, P)
    Bm = xbc[..., d_in:d_in + N]
    Cm = xbc[..., d_in + N:]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    if cfg.attention_impl == "pallas":
        from repro.kernels.ops import ssd_scan as ssd_scan_kernel
        y, h_final = ssd_scan_kernel(xs, dt, A, Bm, Cm, chunk=s.chunk_size)
    else:
        y, h_final = ssd_chunked(xs, dt, A, Bm, Cm, s.chunk_size)
    y = y + xs * p["D_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = _gated_rmsnorm(y, z, p["gate_norm"], cfg.norm_eps)
    conv_tail = (x @ p["w_xbc"])[:, S - (s.conv_width - 1):, :]
    return y @ p["w_out"], (h_final, conv_tail)


def ssd_decode(p, x, ssm_state, conv_state, cfg: ModelConfig):
    """One-token recurrent step.

    x: (B,1,D); ssm_state: (B,H,P,N); conv_state: (B,cw-1,conv_ch).
    """
    B = x.shape[0]
    s = cfg.ssm
    d_in, nh, P, N = ssd_dims(cfg)

    z = x @ p["w_z"]                                   # (B,1,d_in)
    u = x @ p["w_xbc"]                                 # (B,1,conv_ch)
    window = jnp.concatenate([conv_state, u], axis=1)  # (B,cw,conv_ch)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)[:, None, :]            # (B,1,conv_ch)

    xs = xbc[..., :d_in].reshape(B, nh, P)
    Bm = xbc[:, 0, d_in:d_in + N]                      # (B,N)
    Cm = xbc[:, 0, d_in + N:]
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)[:, 0] + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))       # (H,)

    decay = jnp.exp(dt * A).astype(x.dtype)            # (B,H)
    dx = (dt.astype(x.dtype))[..., None] * xs          # (B,H,P)
    new_state = ssm_state * decay[:, :, None, None] + \
        jnp.einsum("bhp,bn->bhpn", dx, Bm.astype(x.dtype))
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(x.dtype))
    y = y + xs * p["D_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(B, 1, d_in)
    y = _gated_rmsnorm(y, z, p["gate_norm"], cfg.norm_eps)
    new_conv = window[:, 1:, :]
    return y @ p["w_out"], (new_state, new_conv)
