"""Scenario simulation: end-to-end windows, energy decomposition, Zipf
allocation, and the paper's qualitative orderings at reduced scale."""
import dataclasses

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.scenario import (ScenarioConfig, _zipf_probs, run_scenario)
from repro.data.synthetic_covtype import make_covtype_like

DATA = make_covtype_like(seed=0)
BASE = ScenarioConfig(windows=12, eval_every=4)


def test_edge_only():
    r = run_scenario(dataclasses.replace(BASE, algo="edge_only"), DATA)
    assert len(r.f1_curve) == 3
    assert r.f1_curve[-1] > 0.55
    assert r.energy_learning == 0.0
    # NB-IoT collection: 12 windows x 100 obs x 433B
    assert r.energy_collection == pytest.approx(34477 * 12 / 100, rel=0.01)


@pytest.mark.parametrize("algo", ["star", "a2a"])
def test_htl_scenarios_run(algo):
    r = run_scenario(dataclasses.replace(BASE, algo=algo), DATA)
    assert np.isfinite(r.f1_curve).all()
    assert r.f1_curve[-1] > 0.3
    assert r.energy_collection > 0 and r.energy_learning > 0
    assert r.energy_total == pytest.approx(
        r.energy_collection + r.energy_learning)


def test_htl_saves_energy_vs_edge_only():
    edge = run_scenario(dataclasses.replace(BASE, algo="edge_only"), DATA)
    star = run_scenario(dataclasses.replace(BASE, algo="star", tech="wifi"),
                        DATA)
    saving = 1 - star.energy_total / edge.energy_total
    assert saving > 0.9          # paper headline: up to 94%


def test_partial_edge_energy_ordering():
    """More data shipped to the edge -> more collection energy (Table 2)."""
    energies = []
    for frac in (0.5, 0.15, 0.03):
        r = run_scenario(dataclasses.replace(BASE, algo="star",
                                             p_edge=frac), DATA)
        energies.append(r.energy_collection)
    assert energies[0] > energies[1] > energies[2]


def test_aggregation_reduces_participants_not_data():
    r = run_scenario(dataclasses.replace(BASE, algo="star", aggregate=True),
                     DATA)
    assert np.isfinite(r.f1_curve).all()


def test_subsample_runs():
    r = run_scenario(dataclasses.replace(BASE, algo="star", n_subsample=2),
                     DATA)
    assert np.isfinite(r.f1_curve).all()


def test_uniform_distribution_runs():
    r = run_scenario(dataclasses.replace(BASE, algo="a2a", uniform=True),
                     DATA)
    assert np.isfinite(r.f1_curve).all()


def test_deterministic_given_seed():
    r1 = run_scenario(dataclasses.replace(BASE, algo="star", seed=3), DATA)
    r2 = run_scenario(dataclasses.replace(BASE, algo="star", seed=3), DATA)
    assert r1.f1_curve == r2.f1_curve
    assert r1.energy_total == pytest.approx(r2.energy_total)


# ---------------------------------------------------------------------------
@given(n=st.integers(min_value=1, max_value=50),
       alpha=st.floats(min_value=0.1, max_value=3.0))
@settings(max_examples=50, deadline=None)
def test_zipf_probs(n, alpha):
    p = _zipf_probs(n, alpha)
    assert p.shape == (n,)
    assert p.sum() == pytest.approx(1.0)
    assert (np.diff(p) <= 1e-12).all()         # decreasing in rank


def test_zipf_unbalance_matches_paper():
    """alpha=1.5, N=7: top mule holds ~53-55%% of the data (paper Sec. 6.3)."""
    p = _zipf_probs(7, 1.5)
    assert 0.5 < p[0] < 0.58
