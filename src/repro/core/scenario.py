"""Scenario simulation (paper Sections 3, 5, 6).

A slotted data-collection process: ``windows`` collection windows of
``obs_per_window`` observations each. Observations are either collected by
SmartMules (802.15.4) or shipped to the Edge Server (NB-IoT). The number of
mules per window is Poisson(lambda); the per-mule allocation follows a Zipf
ranking (or uniform, Scenario 3). After each window a learning round runs
(centralised on the ES, or A2AHTL/StarHTL among the Data Collectors) and the
global model is evaluated on the held-out test set.

The per-window pipeline is decomposed into composable phases —

    collection policy -> learning round -> global EMA update -> eval

— each a module-level function, so alternative policies (engines,
topologies, collection schemes) compose without touching the driver. The
learning round runs on one of two engines: ``"fleet"`` (default,
O(1) jitted dispatches per window, :mod:`repro.core.fleet`) or ``"loop"``
(the per-DC reference, :mod:`repro.core.htl`); they are numerically
interchangeable (tests/test_fleet_engine.py).

:func:`run_sweep` evaluates many configurations while sharing the jitted
fleet trainers across them — the core workload of the paper's Tables 2-6.
With ``stack_seeds=True`` it additionally runs all seed replicas of a
configuration in lockstep, stacking them into the fleet DC axis so one
jitted dispatch per window serves every seed (per-seed energy ledgers and
rng streams stay separate — :func:`run_scenarios_stacked`).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fleet as fleet_engine
from repro.core import htl as loop_engine
from repro.core.energy import Ledger
from repro.core.htl import DC, apply_aggregation_heuristic
from repro.core.metrics import f_measure
from repro.core.svm import pad_local, svm_predict, train_svm
from repro.data.synthetic_covtype import Dataset, NUM_CLASSES

ENGINES = {
    "fleet": {"a2a": fleet_engine.run_window_a2a,
              "star": fleet_engine.run_window_star},
    "loop": {"a2a": loop_engine.run_window_a2a,
             "star": loop_engine.run_window_star},
}


@dataclass(frozen=True)
class ScenarioConfig:
    windows: int = 100
    obs_per_window: int = 100
    lam_poisson: float = 7.0
    zipf_alpha: float = 1.5
    p_edge: float = 0.0           # fraction of each window shipped to the ES
    algo: str = "star"            # 'star' | 'a2a' | 'edge_only'
    tech: str = "4g"              # DC<->DC technology: '4g' | 'wifi'
    uniform: bool = False         # Scenario 3: uniform allocation over mules
    aggregate: bool = False       # data-aggregation heuristic (Section 6.3)
    n_subsample: Optional[int] = None   # GreedyTL points per class (Sec. 7)
    include_es_in_learning: bool = True
    cap: int = 160                # padded local-dataset capacity
    eval_every: int = 1
    seed: int = 0
    engine: str = "fleet"         # 'fleet' (batched) | 'loop' (reference)
    # "This model is used to update the model elaborated until the previous
    # time slot" (paper Section 3): the window model updates the global model
    # incrementally. We use an exponential moving average with this rate.
    global_update_rate: float = 0.3


@dataclass
class ScenarioResult:
    f1_curve: List[float]
    ledger: Ledger
    cfg: ScenarioConfig

    @property
    def final_f1(self) -> float:
        return self.f1_curve[-1]

    def converged_f1(self, start_frac: float = 0.5) -> float:
        """Paper: mean F1 over the converged interval (50th-100th window)."""
        k = int(len(self.f1_curve) * start_frac)
        return float(np.mean(self.f1_curve[k:]))

    @property
    def energy_total(self) -> float:
        return self.ledger.total()

    @property
    def energy_collection(self) -> float:
        return self.ledger.total("collection")

    @property
    def energy_learning(self) -> float:
        return self.ledger.total("learning")


def _zipf_probs(n: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return p / p.sum()


# ---------------------------------------------------------------------------
# per-window phases
# ---------------------------------------------------------------------------

def collect_window(cfg: ScenarioConfig, rng: np.random.Generator,
                   wx: np.ndarray, wy: np.ndarray, ledger: Ledger
                   ) -> List[DC]:
    """Collection policy: split the window's observations between the Edge
    Server (NB-IoT, fraction ``p_edge``) and a Poisson fleet of SmartMules
    (802.15.4, Zipf- or uniformly-allocated), charging every transfer."""
    n_edge = int(round(cfg.p_edge * cfg.obs_per_window))
    idx = rng.permutation(cfg.obs_per_window)
    edge_idx, mule_idx = idx[:n_edge], idx[n_edge:]

    L = max(1, rng.poisson(cfg.lam_poisson))
    if cfg.uniform:
        assign = rng.integers(0, L, size=len(mule_idx))
    else:
        assign = rng.choice(L, size=len(mule_idx),
                            p=_zipf_probs(L, cfg.zipf_alpha))

    dcs: List[DC] = []
    for m in range(L):
        sel = mule_idx[assign == m]
        if len(sel) == 0:
            continue
        ledger.collect_to_mule(len(sel))
        dcs.append(DC(f"SM{m + 1}", wx[sel], wy[sel]))
    if n_edge > 0:
        ledger.collect_to_edge(n_edge)
        if cfg.include_es_in_learning:
            dcs.append(DC("ES", wx[edge_idx], wy[edge_idx], is_es=True))
    return dcs


def learning_round(cfg: ScenarioConfig, dcs: List[DC],
                   prev_global: Optional[np.ndarray], ledger: Ledger,
                   rng: np.random.Generator) -> Optional[np.ndarray]:
    """One HTL round on the configured engine (after the optional
    data-aggregation heuristic, paper Section 6.3)."""
    if cfg.aggregate:
        dcs = apply_aggregation_heuristic(dcs, ledger, cfg.tech)
    run = ENGINES[cfg.engine][cfg.algo]
    return run(dcs, prev_global, ledger, cfg.tech, cap=cfg.cap,
               num_classes=NUM_CLASSES, n_subsample=cfg.n_subsample, rng=rng)


def update_global(cfg: ScenarioConfig, prev: Optional[np.ndarray],
                  new: Optional[np.ndarray]) -> Optional[np.ndarray]:
    """Paper Section 3: the window model updates the global model via EMA."""
    if prev is None or new is None:
        return new if new is not None else prev
    eta = cfg.global_update_rate
    return (1.0 - eta) * prev + eta * new


_predict = jax.jit(svm_predict)
_EVAL_CACHE: list = []     # single entry: (data ref, device test array) —
                           # the data ref pins the id; one slot, no growth


def _eval(w: np.ndarray, data: Dataset) -> float:
    if not _EVAL_CACHE or _EVAL_CACHE[0][0] is not data:
        _EVAL_CACHE[:] = [(data, jnp.asarray(
            data.x_test.astype(np.float32)))]
    pred = np.asarray(_predict(jnp.asarray(w), _EVAL_CACHE[0][1]))
    return f_measure(data.y_test, pred, NUM_CLASSES)


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def _acc_cap(n_seen: int, n_total: int) -> int:
    """Bucketed capacity for the ES's growing accumulated dataset (doubling
    from 128): masked tail rows are dead compute for the trainer, so early
    windows need not pay for the full-stream allocation."""
    b = 128
    while b < n_seen:
        b *= 2
    return min(b, n_total)


def _run_edge_only(cfg: ScenarioConfig, data: Dataset, ledger: Ledger,
                   stream_x: np.ndarray, stream_y: np.ndarray
                   ) -> ScenarioResult:
    """Edge-only benchmark: the ES accumulates everything and retrains."""
    n_total = cfg.windows * cfg.obs_per_window
    f1_curve: List[float] = []
    xacc = np.zeros((n_total, stream_x.shape[1]), np.float32)
    yacc = np.zeros((n_total,), np.int32)
    macc = np.zeros((n_total,), np.float32)
    w = None
    for t in range(cfg.windows):
        s = slice(t * cfg.obs_per_window, (t + 1) * cfg.obs_per_window)
        ledger.collect_to_edge(cfg.obs_per_window)
        xacc[s] = stream_x[s]
        yacc[s] = stream_y[s]
        macc[s] = 1.0
        b = _acc_cap((t + 1) * cfg.obs_per_window, n_total)
        w = train_svm(jnp.asarray(xacc[:b]), jnp.asarray(yacc[:b]),
                      jnp.asarray(macc[:b]), num_classes=NUM_CLASSES,
                      iters=300,
                      w0=None if w is None else jnp.asarray(w))
        w = np.asarray(w)
        if (t + 1) % cfg.eval_every == 0:
            f1_curve.append(_eval(w, data))
    return ScenarioResult(f1_curve, ledger, cfg)


def run_scenario(cfg: ScenarioConfig, data: Dataset) -> ScenarioResult:
    if cfg.engine not in ENGINES:
        raise KeyError(f"unknown engine {cfg.engine!r}; "
                       f"pick one of {sorted(ENGINES)}")
    rng = np.random.default_rng(cfg.seed)
    ledger = Ledger()
    n_total = cfg.windows * cfg.obs_per_window
    order = rng.permutation(len(data.y_train))[:n_total]
    stream_x = data.x_train[order].astype(np.float32)
    stream_y = data.y_train[order].astype(np.int32)

    if cfg.algo == "edge_only":
        return _run_edge_only(cfg, data, ledger, stream_x, stream_y)

    f1_curve: List[float] = []
    prev_global: Optional[np.ndarray] = None
    for t in range(cfg.windows):
        s = slice(t * cfg.obs_per_window, (t + 1) * cfg.obs_per_window)
        dcs = collect_window(cfg, rng, stream_x[s], stream_y[s], ledger)
        new_global = learning_round(cfg, dcs, prev_global, ledger, rng)
        prev_global = update_global(cfg, prev_global, new_global)
        if (t + 1) % cfg.eval_every == 0:
            f1_curve.append(_eval(prev_global, data))

    return ScenarioResult(f1_curve, ledger, cfg)


def _stack_key(cfg: ScenarioConfig) -> ScenarioConfig:
    """Configs with equal keys may run replica-stacked: the normalized
    fields only steer host-side work (collection rng, energy charging,
    GreedyTL subsampling inputs, EMA rate), never the shapes or semantics
    of the jitted calls, so stacking them changes nothing per replica."""
    return dataclasses.replace(
        cfg, seed=0, tech="4g", p_edge=0.0, uniform=False, aggregate=False,
        n_subsample=None, zipf_alpha=1.5, lam_poisson=7.0,
        global_update_rate=0.3, include_es_in_learning=True)


def run_scenarios_stacked(cfgs: Sequence[ScenarioConfig], data: Dataset
                          ) -> List[ScenarioResult]:
    """Run several scenario replicas in lockstep — one dispatch set per
    window for the whole group.

    The replicas may differ in seed and in any host-side field (tech,
    p_edge, uniform, aggregate, n_subsample, Zipf/Poisson parameters, EMA
    rate — see :func:`_stack_key`). Each window, every replica collects its
    own data (own rng stream, own energy ledger) and the learning rounds
    stack into the flat fleet DC axis
    (:func:`repro.core.fleet.run_window_a2a_stacked` / ``_star_stacked``),
    so the group costs O(sample buckets) dispatches per window instead of
    O(replicas). Results match sequential :func:`run_scenario` runs
    replica-for-replica (ledgers exactly, F1 curves to the engine-parity
    tolerance; tests/test_fleet_engine.py).
    """
    cfg0 = cfgs[0]
    if any(_stack_key(c) != _stack_key(cfg0) for c in cfgs):
        raise ValueError("run_scenarios_stacked needs configs that agree "
                         "on every non-host-side field (see _stack_key)")
    if cfg0.engine != "fleet" or cfg0.algo not in ("a2a", "star"):
        return [run_scenario(c, data) for c in cfgs]
    run_stacked = {"a2a": fleet_engine.run_window_a2a_stacked,
                   "star": fleet_engine.run_window_star_stacked}[cfg0.algo]

    S = len(cfgs)
    rngs = [np.random.default_rng(c.seed) for c in cfgs]
    ledgers = [Ledger() for _ in cfgs]
    techs = [c.tech for c in cfgs]
    n_subsamples = [c.n_subsample for c in cfgs]
    n_total = cfg0.windows * cfg0.obs_per_window
    streams = []
    for rng in rngs:
        order = rng.permutation(len(data.y_train))[:n_total]
        streams.append((data.x_train[order].astype(np.float32),
                        data.y_train[order].astype(np.int32)))

    curves: List[List[float]] = [[] for _ in cfgs]
    prevs: List[Optional[np.ndarray]] = [None] * S
    for t in range(cfg0.windows):
        sl = slice(t * cfg0.obs_per_window, (t + 1) * cfg0.obs_per_window)
        fleets = []
        for s in range(S):
            dcs = collect_window(cfgs[s], rngs[s], streams[s][0][sl],
                                 streams[s][1][sl], ledgers[s])
            if cfgs[s].aggregate:
                dcs = apply_aggregation_heuristic(dcs, ledgers[s], techs[s])
            fleets.append(dcs)
        news = run_stacked(fleets, prevs, ledgers, techs, cap=cfg0.cap,
                           num_classes=NUM_CLASSES,
                           n_subsamples=n_subsamples, rngs=rngs)
        prevs = [update_global(cfgs[s], prevs[s], news[s]) for s in range(S)]
        if (t + 1) % cfg0.eval_every == 0:
            for s in range(S):
                curves[s].append(_eval(prevs[s], data))
    return [ScenarioResult(curves[s], ledgers[s], cfgs[s]) for s in range(S)]


def run_sweep(configs: Sequence[ScenarioConfig], data: Dataset, *,
              stack_seeds: bool = False) -> List[ScenarioResult]:
    """Evaluate many scenario configurations over the same dataset.

    The batched fleet trainers are shape-stable (bucketed sample capacity,
    bucketed DC capacity), so every configuration after the first reuses the
    same jitted executables — the sweep pays compilation once, which is what
    makes the paper's algorithm x technology x p_edge x aggregation grids
    (Tables 2-6) cheap to extend.

    ``stack_seeds=True`` groups stack-compatible configs (equal
    :func:`_stack_key`: same algo/engine/windows/cap, any mix of seeds and
    host-side fields) and runs each group through
    :func:`run_scenarios_stacked` — O(sample buckets) dispatches per window
    for the whole group; other configs — and the default — run
    sequentially. Result order always matches ``configs``.
    """
    if not stack_seeds:
        return [run_scenario(cfg, data) for cfg in configs]
    groups: dict = {}
    for i, cfg in enumerate(configs):
        groups.setdefault(_stack_key(cfg), []).append(i)
    results: List[Optional[ScenarioResult]] = [None] * len(configs)
    for key, idxs in groups.items():
        grp = [configs[i] for i in idxs]
        if (len(grp) == 1 or key.engine != "fleet"
                or key.algo not in ("a2a", "star")):
            rs = [run_scenario(c, data) for c in grp]
        else:
            rs = run_scenarios_stacked(grp, data)
        for i, r in zip(idxs, rs):
            results[i] = r
    return results
