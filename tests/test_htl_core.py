"""Paper-core behaviour: SVM, GreedyTL transfer, election, HTL windows,
aggregation heuristic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.energy import Ledger, MODEL_BYTES, OBS_BYTES
from repro.core.greedytl import greedytl
from repro.core.htl import (DC, apply_aggregation_heuristic, label_entropy,
                            run_window_a2a, run_window_star)
from repro.core.metrics import f_measure
from repro.core.svm import pad_local, svm_predict, train_svm
from repro.data.synthetic_covtype import make_covtype_like

DATA = make_covtype_like(seed=0)
XT = jnp.asarray(DATA.x_test.astype(np.float32))


def _f1(w):
    return f_measure(DATA.y_test, np.asarray(svm_predict(w, XT)), 7)


def _svm_on(n, start=0):
    x = DATA.x_train[start:start + n].astype(np.float32)
    y = DATA.y_train[start:start + n]
    xp, yp, mp = pad_local(x, y, max(n, 160))
    return np.asarray(train_svm(jnp.asarray(xp), jnp.asarray(yp),
                                jnp.asarray(mp), num_classes=7))


def test_svm_learns():
    w = _svm_on(4000)
    assert _f1(w) > 0.6


def test_svm_masking_equivalence():
    """Padding with masked rows must not change the solution."""
    x = DATA.x_train[:100].astype(np.float32)
    y = DATA.y_train[:100]
    x1, y1, m1 = pad_local(x, y, 100)
    x2, y2, m2 = pad_local(x, y, 200)
    w1 = train_svm(jnp.asarray(x1), jnp.asarray(y1), jnp.asarray(m1),
                   num_classes=7)
    w2 = train_svm(jnp.asarray(x2), jnp.asarray(y2), jnp.asarray(m2),
                   num_classes=7)
    assert float(jnp.max(jnp.abs(w1 - w2))) < 1e-4


def test_greedytl_transfers_from_strong_source():
    strong = _svm_on(5000)
    x = DATA.x_train[6000:6050].astype(np.float32)
    y = DATA.y_train[6000:6050]
    xp, yp, mp = pad_local(x, y, 160)
    local = np.asarray(train_svm(jnp.asarray(xp), jnp.asarray(yp),
                                 jnp.asarray(mp), num_classes=7))
    src = np.zeros((16, 55, 7), np.float32)
    sm = np.zeros(16, np.float32)
    src[0] = strong
    sm[0] = 1
    w_eff, sel = greedytl(jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mp),
                          jnp.asarray(src), jnp.asarray(sm), num_classes=7)
    assert bool(np.asarray(sel)[0]), "strong source must be selected"
    assert _f1(w_eff) > _f1(local) + 0.05, \
        "transfer must beat the local-only model"


def test_greedytl_ensemble_of_weak_sources():
    """Combining several weak sources should beat each of them."""
    weaks = [_svm_on(30, start=7000 + i * 30) for i in range(5)]
    weak_best = max(_f1(w) for w in weaks)
    x = DATA.x_train[6000:6100].astype(np.float32)
    y = DATA.y_train[6000:6100]
    xp, yp, mp = pad_local(x, y, 160)
    src = np.zeros((16, 55, 7), np.float32)
    sm = np.zeros(16, np.float32)
    for i, w in enumerate(weaks):
        src[i] = w
        sm[i] = 1
    w_eff, _ = greedytl(jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mp),
                        jnp.asarray(src), jnp.asarray(sm), num_classes=7)
    assert _f1(w_eff) > weak_best + 0.03


def test_greedytl_ignores_invalid_sources():
    """Masked-out (garbage) sources must not affect the result."""
    x = DATA.x_train[:80].astype(np.float32)
    y = DATA.y_train[:80]
    xp, yp, mp = pad_local(x, y, 160)
    strong = _svm_on(3000)
    src = np.zeros((16, 55, 7), np.float32)
    sm = np.zeros(16, np.float32)
    src[0] = strong
    sm[0] = 1
    w1, _ = greedytl(jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mp),
                     jnp.asarray(src), jnp.asarray(sm), num_classes=7)
    src2 = src.copy()
    src2[5:] = 1e3          # garbage in masked slots
    w2, _ = greedytl(jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mp),
                     jnp.asarray(src2), jnp.asarray(sm), num_classes=7)
    assert float(jnp.max(jnp.abs(w1 - w2))) < 1e-3


def test_label_entropy():
    assert label_entropy(np.array([0, 1, 2, 3, 4, 5, 6]), 7) == \
        pytest.approx(1.0)
    assert label_entropy(np.zeros(10, np.int64), 7) == pytest.approx(0.0)
    balanced = label_entropy(np.arange(70) % 7, 7)
    skewed = label_entropy(np.array([0] * 60 + [1] * 10), 7)
    assert balanced > skewed


def _window_dcs(ns, start=0):
    dcs, ofs = [], start
    for i, n in enumerate(ns):
        dcs.append(DC(f"SM{i + 1}", DATA.x_train[ofs:ofs + n].astype(
            np.float32), DATA.y_train[ofs:ofs + n]))
        ofs += n
    return dcs


@pytest.mark.parametrize("run", [run_window_a2a, run_window_star])
def test_window_round(run):
    dcs = _window_dcs([55, 20, 10, 8, 4, 2, 1])
    ledger = Ledger()
    w = run(dcs, None, ledger, "4g", cap=160, num_classes=7)
    assert w.shape == (55, 7)
    assert np.isfinite(w).all()
    assert ledger.total("learning") > 0
    # second window with prev model should not be worse on average
    dcs2 = _window_dcs([55, 20, 10, 8, 4, 2, 1], start=200)
    w2 = run(dcs2, w, ledger, "4g", cap=160, num_classes=7)
    assert np.isfinite(w2).all()


def test_star_cheaper_than_a2a():
    dcs = _window_dcs([55, 20, 10, 8, 4, 2, 1])
    la, ls = Ledger(), Ledger()
    run_window_a2a(dcs, None, la, "4g", cap=160, num_classes=7)
    run_window_star(dcs, None, ls, "4g", cap=160, num_classes=7)
    assert ls.total("learning") < la.total("learning")


def test_aggregation_heuristic():
    dcs = _window_dcs([53, 19, 10, 7, 5, 4, 2])
    ledger = Ledger()
    merged = apply_aggregation_heuristic(dcs, ledger, "wifi")
    thresh = int(np.ceil(2 * MODEL_BYTES / OBS_BYTES))
    # participants drop (paper: 7 -> ~3-4); data conserved
    assert len(merged) < len(dcs)
    assert sum(d.n for d in merged) == sum(d.n for d in dcs)
    big = [d for d in merged if d.n >= thresh]
    assert len(big) >= len(merged) - 1
    assert ledger.total("learning") > 0
