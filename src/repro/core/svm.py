"""Linear multiclass SVM (one-vs-rest hinge + L2), trained in JAX.

This is the paper's Step-0 base learner. No sklearn in this environment —
full-batch gradient descent with momentum on the (masked) hinge objective.
Masking lets one jitted trainer handle every Data Collector regardless of its
local sample count (samples are padded to a fixed capacity).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import count_dispatch


SAMPLE_BUCKETS = (16, 64)   # bucketed per-DC sample capacities (< config cap)


def sample_cap(n: int, cap: int) -> int:
    """Bucketed per-DC sample capacity for n local samples.

    Masked (padded) rows contribute exactly zero to the hinge gradient and
    to GreedyTL's Gram system, so training a DC at the smallest bucket that
    holds its data gives the same model as padding to the full scenario
    ``cap`` — while skipping the dead rows' compute, which dominates for
    the paper's Zipf-allocated fleets (most mules hold <16 of a window's
    100 observations but were padded to cap=160). The bucket set is tiny so
    the jit cache stays small; ``cap`` itself is always the last bucket.
    """
    n = min(n, cap)
    for b in SAMPLE_BUCKETS:
        if n <= b < cap:
            return b
    return cap


def svm_scores(w: jax.Array, x: jax.Array) -> jax.Array:
    """w: (F+1, C) with bias row last; x: (n, F)."""
    return x @ w[:-1] + w[-1]


def svm_predict(w, x) -> jax.Array:
    return jnp.argmax(svm_scores(w, x), axis=-1)


def _hinge_loss(w, x, y_onehot_pm, mask, lam):
    scores = svm_scores(w, x)                       # (n, C)
    margins = jnp.maximum(0.0, 1.0 - y_onehot_pm * scores)
    per_sample = jnp.sum(margins, axis=-1) * mask
    denom = jnp.maximum(1.0, jnp.sum(mask))
    return jnp.sum(per_sample) / denom + lam * jnp.sum(w[:-1] ** 2)


def _train_svm(x: jax.Array, y: jax.Array, mask: jax.Array, *,
               num_classes: int, lam: float = 1e-3, lr: float = 0.5,
               iters: int = 200, w0: jax.Array = None) -> jax.Array:
    """Unjitted trainer core — also the vmap target of the fleet trainer."""
    n, F = x.shape
    y_pm = 2.0 * jax.nn.one_hot(y, num_classes) - 1.0
    w_init = jnp.zeros((F + 1, num_classes)) if w0 is None else w0
    grad_fn = jax.grad(_hinge_loss)

    def body(i, carry):
        w, v = carry
        g = grad_fn(w, x, y_pm, mask, lam)
        lr_i = lr * 0.5 * (1 + jnp.cos(jnp.pi * i / iters))
        v = 0.9 * v - lr_i * g
        return w + v, v

    w, _ = jax.lax.fori_loop(0, iters, body, (w_init, jnp.zeros_like(w_init)))
    return w


@count_dispatch("train_svm")
@partial(jax.jit, static_argnames=("num_classes", "iters"))
def train_svm(x: jax.Array, y: jax.Array, mask: jax.Array, *,
              num_classes: int, lam: float = 1e-3, lr: float = 0.5,
              iters: int = 200, w0: jax.Array = None) -> jax.Array:
    """x: (n,F) padded; y: (n,) int labels; mask: (n,) {0,1}.

    Returns w: (F+1, C). Momentum GD with cosine-decayed lr; warm start w0.
    """
    return _train_svm(x, y, mask, num_classes=num_classes, lam=lam, lr=lr,
                      iters=iters, w0=w0)


@count_dispatch("train_svm_fleet")
@partial(jax.jit, static_argnames=("num_classes", "iters"))
def train_svm_fleet(x: jax.Array, y: jax.Array, mask: jax.Array, *,
                    num_classes: int, lam: float = 1e-3, lr: float = 0.5,
                    iters: int = 200) -> jax.Array:
    """Batched base training over a padded DC fleet — ONE dispatch per window.

    x: (L, cap, F); y: (L, cap); mask: (L, cap) row validity (an all-zero
    mask row is a padding DC and trains to a harmless zero-ish model).
    Returns w: (L, F+1, C).
    """
    return jax.vmap(
        lambda xi, yi, mi: _train_svm(xi, yi, mi, num_classes=num_classes,
                                      lam=lam, lr=lr, iters=iters)
    )(x, y, mask)


def pad_fleet(xs, ys, cap: int, fleet_cap: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Pad a list of local datasets to a (fleet_cap, cap, F) fleet block.

    Returns (x, y, mask, dc_mask) where dc_mask (fleet_cap,) marks real DCs.
    """
    assert len(xs) <= fleet_cap
    F = xs[0].shape[1]
    x = np.zeros((fleet_cap, cap, F), np.float32)
    y = np.zeros((fleet_cap, cap), np.int32)
    m = np.zeros((fleet_cap, cap), np.float32)
    dcm = np.zeros((fleet_cap,), np.float32)
    for i, (xi, yi) in enumerate(zip(xs, ys)):
        x[i], y[i], m[i] = pad_local(xi, yi, cap)
        dcm[i] = 1.0
    return x, y, m, dcm


def pad_local(x: np.ndarray, y: np.ndarray, cap: int
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad a local dataset to ``cap`` rows with a validity mask."""
    n = min(len(x), cap)
    F = x.shape[1]
    xp = np.zeros((cap, F), np.float32)
    yp = np.zeros((cap,), np.int32)
    mp = np.zeros((cap,), np.float32)
    xp[:n] = x[:n]
    yp[:n] = y[:n]
    mp[:n] = 1.0
    return xp, yp, mp
