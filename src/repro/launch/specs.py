"""ShapeDtypeStruct input specs for every (architecture x input-shape) combo.

``input_specs`` returns sharding-annotated ShapeDtypeStructs — weak-type
correct, shardable, zero allocation — for the function the shape's kind
lowers:

* train_4k     -> ``train_step(params, opt_state, batch, step)``
* prefill_32k  -> ``prefill(params, batch)``
* decode_32k / long_500k -> ``decode_step(params, cache, tokens, pos)``

VLM note: seq_len is the *total* context; the anyres image prefix (2880
frontend tokens) is carved out of it. Whisper note: seq_len is the decoder
length; the encoder is fixed at 1500 stub-frontend frames.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.models.model import Model
from repro.sharding.partitioning import (DEFAULT_RULES, MULTIPOD_RULES,
                                         ParamSpec, logical_to_pspec,
                                         param_pspecs)

LLAMA_LONG_WINDOW = 8192   # documented sliding-window variant for long_500k


def rules_for(mesh: Mesh) -> dict:
    return MULTIPOD_RULES if "pod" in mesh.shape else DEFAULT_RULES


def param_rules_for(mesh: Mesh, shape: Optional[InputShape] = None,
                    cfg: Optional[ModelConfig] = None,
                    weight_stationary_decode: bool = True) -> dict:
    """Weight sharding rules, specialised per workload.

    §Perf optimization (beyond-paper): for decode steps the FSDP 'embed'->
    data rule is catastrophic — every decoded token all-gathers the full
    weights (the paper's "ship raw data over the expensive link" failure
    mode). Decode instead keeps weights stationary: TP over 'model' only,
    with MoE experts additionally sharded over 'data' (256-way expert
    parallelism for deepseek-v3, which cannot fit TP-16 alone).
    """
    rules = dict(rules_for(mesh))
    if (weight_stationary_decode and shape is not None
            and shape.kind == "decode"):
        rules["embed"] = None
        rules["experts"] = rules["experts_both"]
    return rules


def arch_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply documented per-shape variants: llama sliding-window long ctx;
    MoE decode uses expert parallelism over both mesh axes (§Perf)."""
    if shape.name == "long_500k" and cfg.name == "llama3.2-3b":
        cfg = dataclasses.replace(cfg, sliding_window=LLAMA_LONG_WINDOW)
    if shape.kind == "decode" and cfg.moe is not None:
        cfg = dataclasses.replace(cfg, expert_parallel="both")
    return cfg


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) is in scope; reason when skipped (DESIGN.md §6)."""
    if shape.name == "long_500k":
        cfg = arch_for_shape(cfg, shape)
        if not (cfg.supports_long_context or cfg.sliding_window):
            return False, ("full attention is quadratic at 524k ctx; no "
                           "sub-quadratic variant implemented for this arch")
    return True, ""


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> dict:
    rules = rules_for(mesh)
    B = shape.global_batch
    dt = jnp.dtype(cfg.dtype)

    def spec(dims, axes):
        return logical_to_pspec(axes, dims, mesh, rules)

    if shape.kind in ("train",):
        S = shape.seq_len
        n_front = cfg.frontend.num_tokens if cfg.family == "vlm" else 0
        S_text = S - n_front
        out = {
            "tokens": _sds((B, S_text), jnp.int32, mesh,
                           spec((B, S_text), ("batch", "seq"))),
            "targets": _sds((B, S_text), jnp.int32, mesh,
                            spec((B, S_text), ("batch", "seq"))),
        }
        if cfg.family == "vlm":
            out["frontend_embeds"] = _sds(
                (B, n_front, cfg.d_model), dt, mesh,
                spec((B, n_front, cfg.d_model), ("batch", "seq", None)))
        if cfg.family == "audio":
            out["encoder_embeds"] = _sds(
                (B, cfg.encoder_seq_len, cfg.d_model), dt, mesh,
                spec((B, cfg.encoder_seq_len, cfg.d_model),
                     ("batch", "seq", None)))
        return out

    if shape.kind == "prefill":
        S = shape.seq_len
        n_front = cfg.frontend.num_tokens if cfg.family == "vlm" else 0
        S_text = S - n_front
        out = {"tokens": _sds((B, S_text), jnp.int32, mesh,
                              spec((B, S_text), ("batch", "seq")))}
        if cfg.family == "vlm":
            out["frontend_embeds"] = _sds(
                (B, n_front, cfg.d_model), dt, mesh,
                spec((B, n_front, cfg.d_model), ("batch", "seq", None)))
        if cfg.family == "audio":
            out["encoder_embeds"] = _sds(
                (B, cfg.encoder_seq_len, cfg.d_model), dt, mesh,
                spec((B, cfg.encoder_seq_len, cfg.d_model),
                     ("batch", "seq", None)))
        return out

    # decode kinds
    return {"tokens": _sds((B, 1), jnp.int32, mesh,
                           spec((B, 1), ("batch", None))),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def param_specs(model: Model, mesh: Mesh, rules: dict = None) -> dict:
    rules = rules or rules_for(mesh)
    t = model.template()
    pspecs = param_pspecs(t, mesh, rules)
    dt = jnp.dtype(model.cfg.dtype)
    return jax.tree.map(
        lambda s, p: _sds(s.shape, jnp.dtype(s.dtype or dt), mesh, p),
        t, pspecs, is_leaf=lambda x: isinstance(x, (ParamSpec, P)))


def _densify_spec(spec: P, shape, mesh: Mesh) -> P:
    """ZeRO-1: extend a spec by sharding replicated dims over unused mesh
    axes (largest dims first). Optimizer moments never need to be gathered
    whole — only updated element-wise and reduce-scattered — so sharding
    them maximally is free parallelism and a large memory win."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,) if e else ()):
            used.add(a)
    free = [a for a in mesh.shape if a not in used]
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in order:
        if entries[i] is not None or not free:
            continue
        for a in list(free):
            if shape[i] % mesh.shape[a] == 0:
                entries[i] = a
                free.remove(a)
                break
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def opt_state_specs(param_sds, mesh: Mesh, zero1: bool = False):
    """AdamW moments (float32). With ``zero1`` the moments shard over every
    mesh axis their dims allow — 2.6x memory win, but REFUTED as a pure
    GSPMD transformation: the partitioner reshards grads/updates through
    the mismatched layouts instead of the reduce-scatter + all-gather
    schedule (llama train: collectives 33 GB -> 1.5 TB/device). Off by
    default; the fix is a shard_map-manual optimizer step (§Perf log)."""
    from repro.optim.adamw import AdamWState

    def mom(s):
        sharding = s.sharding
        if zero1:
            sharding = NamedSharding(
                sharding.mesh,
                _densify_spec(sharding.spec, s.shape, sharding.mesh))
        return jax.ShapeDtypeStruct(s.shape, jnp.float32, sharding=sharding)

    m = jax.tree.map(mom, param_sds)
    return AdamWState(count=jax.ShapeDtypeStruct((), jnp.int32), mu=m,
                      nu=jax.tree.map(lambda x: x, m))


def cache_specs(model: Model, shape: InputShape, mesh: Mesh) -> dict:
    rules = rules_for(mesh)
    cfg = model.cfg
    t = model.cache_template(shape.global_batch, shape.seq_len)
    pspecs = param_pspecs(t, mesh, rules)
    dt = jnp.dtype(cfg.dtype)
    return jax.tree.map(
        lambda s, p: _sds(s.shape, jnp.dtype(s.dtype or dt), mesh, p),
        t, pspecs, is_leaf=lambda x: isinstance(x, (ParamSpec, P)))


def input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
                model: Optional[Model] = None,
                weight_stationary_decode: bool = True) -> dict:
    """All ShapeDtypeStructs needed to lower the step for this combo."""
    from repro.models.model import build_model
    cfg = arch_for_shape(cfg, shape)
    model = model or build_model(cfg)
    ps = param_specs(model, mesh,
                     param_rules_for(mesh, shape, cfg,
                                     weight_stationary_decode))
    out = {"params": ps, "batch": batch_specs(cfg, shape, mesh)}
    if shape.kind == "train":
        out["opt_state"] = opt_state_specs(ps, mesh)
        out["step"] = jax.ShapeDtypeStruct((), jnp.int32)
    if shape.kind == "decode":
        out["cache"] = cache_specs(model, shape, mesh)
    return out
