#!/usr/bin/env python
"""Hosts-launcher CI gate: multi-host dispatch — and worker loss — may
never change the numbers.

Runs a preset grid sequentially (``parallel="none"``) and under the
``hosts`` launcher (DESIGN.md §8), then diffs the serialized
``SweepResult`` JSON byte for byte. With ``--inject-failures`` it runs a
second launched pass in which one ``local:`` worker is SIGKILLed
mid-shard on its first attempt (the launcher's ``inject_kill`` hook):
the gate then also asserts the attempt log recorded exactly that crash
and the retry that healed it, while the merged bytes still match.

    python scripts/hosts_parity.py --preset smoke --windows 3 \
        --spec "hosts:channel=local,n=2,retries=1" --inject-failures

Wired into scripts/verify.sh (gates phase) and a named step of the CI
``gates`` job, mirroring scripts/parallel_parity.py.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def first_diff(a: str, b: str, context: int = 60) -> str:
    k = next((i for i, (x, y) in enumerate(zip(a, b)) if x != y),
             min(len(a), len(b)))
    return (f"first divergence at byte {k}: "
            f"...{a[max(0, k - context):k + context]!r} vs "
            f"...{b[max(0, k - context):k + context]!r}")


def check_attempts(meta: dict, inject_shard: int | None) -> list[str]:
    """Cross-check the attempt log against what the run was told to do."""
    problems = []
    shards = meta.get("launcher", {}).get("shards", [])
    if not shards:
        return ["no launcher attempt log in SweepResult.meta"]
    for s in shards:
        statuses = [a["status"] for a in s["attempts"]]
        want = (["crash", "ok"] if s["shard"] == inject_shard else ["ok"])
        if statuses != want:
            problems.append(f"shard {s['shard']}: attempt statuses "
                            f"{statuses}, expected {want}")
    return problems


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--windows", type=int, default=3)
    ap.add_argument("--spec", default="hosts:channel=local,n=2,retries=1",
                    help="hosts executor spec to diff against the "
                         "sequential run")
    ap.add_argument("--inject-failures", action="store_true",
                    help="also run with one local worker SIGKILLed "
                         "mid-shard on its first attempt and assert the "
                         "retry restores bitwise parity")
    args = ap.parse_args()

    from repro.core.experiment import get_preset
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    spec = get_preset(args.preset, windows=args.windows)
    ref = spec.run(data, parallel="none").to_json()
    rc = 0

    passes = [("clean", args.spec, None)]
    if args.inject_failures:
        passes.append(("fault-injected", f"{args.spec},backoff=0.01,"
                                         f"inject_kill=0", 0))
    for label, backend, inject_shard in passes:
        result = spec.run(data, parallel=backend)
        got = result.to_json()
        attempts = result.meta.get("launcher", {}).get("attempts_total", 0)
        if got == ref:
            print(f"hosts parity [{label}]: OK ({len(ref)} bytes "
                  f"identical, {attempts} shard attempts)")
        else:
            print(f"hosts parity [{label}]: MISMATCH — "
                  f"{first_diff(ref, got)}")
            rc = 1
        problems = check_attempts(result.meta, inject_shard)
        for p in problems:
            print(f"hosts attempt log [{label}]: {p}")
            rc = 1
    if rc == 0:
        print("hosts launcher: bitwise-identical to sequential"
              + (", clean and under injected worker crash"
                 if args.inject_failures else ""))
    return rc


if __name__ == "__main__":
    sys.exit(main())
