"""Incremental-factor GreedyTL: property suite against the
full-refactorization oracle, plus kernel-selection (autotuner /
REPRO_KERNEL_FORCE) contracts. DESIGN.md §11.

The carry contract: the greedy loop extends the active set's Cholesky
factor by the bordering column computed during trial scoring instead of
refactorizing, so selections must match the PR-2 refactorize-per-step path
and the final model must agree ≤ 1e-5 (it is in fact computed by the same
final full factorization of the selected set, so equal selections give
bit-equal downstream numerics).
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.greedytl import (_greedy_select_incremental,
                                 _greedy_select_refactor, greedytl,
                                 greedytl_fleet, greedytl_fleet_stacked)
from repro.kernels import ops as kernel_ops
from repro.kernels.loo_trials import loo_trials_ref
from repro.kernels.ref import greedy_select_refactor_reference

F, C, M_CAP = 54, 7, 16


@pytest.fixture(autouse=True)
def _isolated_kernel_selection(tmp_path, monkeypatch):
    """Every test here runs with a private autotune cache dir and no forced
    kernel, and leaves the process-global cache clean afterwards."""
    monkeypatch.setenv(kernel_ops.CACHE_DIR_ENV, str(tmp_path))
    monkeypatch.delenv(kernel_ops.FORCE_ENV, raising=False)
    kernel_ops.reset_autotune_cache()
    yield
    kernel_ops.reset_autotune_cache()


# ---------------------------------------------------------------------------
# problem builders
# ---------------------------------------------------------------------------

def _pad_problem(x, y, n_src, seed, scale=0.5):
    rng = np.random.default_rng(seed)
    n = len(y)
    cap = max(32, n)
    xp = np.zeros((cap, F), np.float32)
    xp[:n] = x
    yp = np.zeros(cap, np.int32)
    yp[:n] = y
    mp = np.zeros(cap, np.float32)
    mp[:n] = 1
    src = np.zeros((M_CAP, F + 1, C), np.float32)
    sm = np.zeros(M_CAP, np.float32)
    for i in range(n_src):
        src[i] = rng.normal(0, scale, (F + 1, C))
        sm[i] = 1
    return tuple(jnp.asarray(v) for v in (xp, yp, mp, src, sm))


def _deep_problem(n=160, n_src=12, seed=0):
    """Greedy accepts many sources: each explains a disjoint feature block
    of the true boundary (same construction as the dispatch gate)."""
    r = np.random.default_rng(seed)
    src = np.zeros((M_CAP, F + 1, C), np.float32)
    sm = np.zeros(M_CAP, np.float32)
    w_total = np.zeros((F + 1, C), np.float32)
    for i, blk in enumerate(np.array_split(np.arange(F), n_src)):
        w = np.zeros((F + 1, C), np.float32)
        w[blk] = r.normal(0, 1.0, (len(blk), C))
        src[i] = w
        sm[i] = 1.0
        w_total += w
    x = r.normal(size=(n, F)).astype(np.float32)
    y = np.argmax(x @ w_total[:-1] + w_total[-1], axis=1).astype(np.int32)
    return tuple(jnp.asarray(v) for v in
                 (x, y, np.ones(n, np.float32), src, sm))


def _random_stacked_system(M, rows, seed, p_src=0.8, p_row=0.85):
    """Random stacked Gram system in the Stage-1 layout: D = M + C columns,
    bias block trailing, random row validity and source validity masks."""
    rng = np.random.default_rng(seed)
    D = M + C
    A = rng.normal(size=(rows, D)).astype(np.float32)
    y = rng.normal(size=rows).astype(np.float32)
    rmask = (rng.random(rows) < p_row).astype(np.float32)
    src_mask = (rng.random(M) < p_src).astype(np.float32)
    lam_d = (np.abs(rng.normal(0.8, 0.5, D)) + 1e-3).astype(np.float32)
    A_rm = A * rmask[:, None]
    return (A_rm.T @ A_rm, A_rm.T @ (y * rmask), A_rm, y, rmask, src_mask,
            lam_d)


# ---------------------------------------------------------------------------
# tentpole: incremental carry == full refactorization
# ---------------------------------------------------------------------------

@given(seed=st.integers(min_value=0, max_value=200),
       m=st.sampled_from([2, 8, M_CAP]),
       rows=st.sampled_from([64, 224, 400]),
       k_max=st.sampled_from([1, 3, 16]))
@settings(max_examples=15, deadline=None)
def test_incremental_selection_matches_refactor_on_random_systems(
        seed, m, rows, k_max):
    """Property: on random masked Gram systems the carried-factor loop and
    the refactorize-per-step loop accept the same sources and report the
    same objective (≤ 1e-5 rel)."""
    AtA, Aty, A_rm, y, rmask, src_mask, lam_d = _random_stacked_system(
        m, rows, seed)
    args = tuple(jnp.asarray(v) for v in
                 (AtA, Aty, A_rm, y, rmask, src_mask, lam_d))
    sel_inc, best_inc = _greedy_select_incremental(*args, M=m, C=C,
                                                   k_max=k_max)
    sel_ref, best_ref = _greedy_select_refactor(*args, M=m, C=C,
                                                k_max=k_max)
    assert np.array_equal(np.asarray(sel_inc), np.asarray(sel_ref))
    rel = abs(float(best_inc) - float(best_ref)) / max(
        abs(float(best_ref)), 1e-6)
    assert rel < 1e-5, rel


@given(seed=st.integers(min_value=0, max_value=60),
       m=st.sampled_from([4, 8]),
       rows=st.sampled_from([64, 160]))
@settings(max_examples=8, deadline=None)
def test_incremental_matches_float64_inverse_oracle(seed, m, rows):
    """Property: against the float64 inverse-based host oracle
    (kernels/ref.py), the incremental loop selects the same sources with
    the same objective trajectory — modulo genuine float ties, where the
    oracle's own objectives for both choices must agree ≤ 1e-4."""
    AtA, Aty, A_rm, y, rmask, src_mask, lam_d = _random_stacked_system(
        m, rows, seed)
    sel_inc, best_inc = _greedy_select_incremental(
        *(jnp.asarray(v) for v in
          (AtA, Aty, A_rm, y, rmask, src_mask, lam_d)), M=m, C=C, k_max=16)
    sel_inc = np.asarray(sel_inc)
    sel_ref, traj = greedy_select_refactor_reference(
        AtA, Aty, A_rm, y, rmask, src_mask, lam_d, m, k_max=16)
    if np.array_equal(sel_inc, sel_ref):
        rel = abs(float(best_inc) - traj[-1]) / max(abs(traj[-1]), 1e-6)
        assert rel < 1e-4, rel
    else:
        # f32-vs-f64 tie at the acceptance boundary: both final sets must
        # be indistinguishable under the oracle's own objective
        def oracle_obj(sel):
            s, t = greedy_select_refactor_reference(
                AtA, Aty, A_rm, y, rmask, sel * src_mask, lam_d, m,
                k_max=int(sel.sum()))
            return t[-1]
        o_inc, o_ref = oracle_obj(sel_inc), oracle_obj(sel_ref)
        assert abs(o_inc - o_ref) / max(abs(o_ref), 1e-6) < 1e-4


@pytest.mark.parametrize("k_max", [1, 2, 4, 8, 12, 16])
def test_depth_sweep_matches_refactor_path(k_max):
    """Greedy depths 1–16 (k_max-bounded on a deep-accepting problem): the
    default incremental entry point equals the refactorizing oracle."""
    x, y, m, src, sm = _deep_problem()
    w_inc, sel_inc = greedytl(x, y, m, src, sm, num_classes=C, k_max=k_max)
    w_ref, sel_ref = greedytl(x, y, m, src, sm, num_classes=C, k_max=k_max,
                              incremental=False)
    assert np.array_equal(np.asarray(sel_inc), np.asarray(sel_ref))
    assert int(np.asarray(sel_inc).sum()) == min(k_max, 12)
    np.testing.assert_allclose(np.asarray(w_inc), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-5)


@given(n=st.integers(min_value=4, max_value=60),
       n_src=st.integers(min_value=0, max_value=8),
       seed=st.integers(min_value=0, max_value=100))
@settings(max_examples=10, deadline=None)
def test_random_problems_match_refactor_path(n, n_src, seed):
    """Random (possibly degenerate) local datasets and source pools: same
    selection, model ≤ 1e-5, through the public entry point."""
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (n, F)).astype(np.float32)
    y = rng.integers(0, C, n)
    args = _pad_problem(x, y, n_src, seed)
    w_inc, sel_inc = greedytl(*args, num_classes=C)
    w_ref, sel_ref = greedytl(*args, num_classes=C, incremental=False)
    assert np.array_equal(np.asarray(sel_inc), np.asarray(sel_ref))
    np.testing.assert_allclose(np.asarray(w_inc), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-5)


def test_all_fleet_entry_points_match_refactor_oracle():
    """greedytl / greedytl_fleet / greedytl_fleet_stacked on the deep
    problem: every entry point defaults to the incremental carry, stays
    bitwise equal across the lax.map variants, and agrees with the
    refactorizing oracle ≤ 1e-5."""
    x, y, m, src, sm = _deep_problem()
    L = 3
    xf, yf, mf = (jnp.stack([v] * L) for v in (x, y, m))
    srcs, sms = (jnp.stack([v] * L) for v in (src, sm))

    w1, s1 = greedytl(x, y, m, src, sm, num_classes=C)
    wf, sf = greedytl_fleet(xf, yf, mf, src, sm, num_classes=C)
    ws, ss = greedytl_fleet_stacked(xf, yf, mf, srcs, sms, num_classes=C)
    w_ref, _ = greedytl(x, y, m, src, sm, num_classes=C, incremental=False)
    for i in range(L):
        assert np.array_equal(np.asarray(wf)[i], np.asarray(w1))
        assert np.array_equal(np.asarray(ws)[i], np.asarray(w1))
        assert np.array_equal(np.asarray(sf)[i], np.asarray(s1))
        assert np.array_equal(np.asarray(ss)[i], np.asarray(s1))
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-5)


def test_scan_engine_threads_incremental_carry():
    """Fourth entry point (scan/city engines, core/cityscan.py): the
    whole-scenario lax.scan program compiles once around the incremental
    while_loop and reproduces the fleet engine's F1 trajectory."""
    from repro.core.scenario import ScenarioConfig, run_scenario
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    kw = dict(windows=3, eval_every=1, algo="a2a")
    r_scan = run_scenario(ScenarioConfig(engine="scan", **kw), data)
    r_fleet = run_scenario(ScenarioConfig(engine="fleet", **kw), data)
    assert r_scan.f1_curve == r_fleet.f1_curve
    assert r_scan.ledger.total() == r_fleet.ledger.total()


# ---------------------------------------------------------------------------
# kernel selection: force override + autotuner cache
# ---------------------------------------------------------------------------

def _kernel_inputs(R=64, D=23, M=16, seed=3):
    rng = np.random.default_rng(seed)
    f32 = np.float32
    return tuple(jnp.asarray(v) for v in (
        rng.standard_normal((R, D)).astype(f32),
        rng.standard_normal((D, M)).astype(f32),
        rng.standard_normal((R, M)).astype(f32),
        rng.standard_normal(R).astype(f32),
        np.abs(rng.standard_normal(R)).astype(f32) * 0.1,
        rng.standard_normal(R).astype(f32),
        (rng.random(R) < 0.8).astype(f32),
        rng.standard_normal(M).astype(f32),
        np.abs(rng.standard_normal(M)).astype(f32),
    ))


def test_kernel_force_jnp_and_pallas_agree(monkeypatch):
    """REPRO_KERNEL_FORCE=jnp and =pallas (interpret off-TPU) agree ≤ 1e-5
    on the same inputs; jnp-forced output is exactly the reference."""
    args = _kernel_inputs()
    monkeypatch.setenv(kernel_ops.FORCE_ENV, "jnp")
    out_jnp = np.asarray(kernel_ops.loo_trials(*args))
    assert np.array_equal(out_jnp, np.asarray(loo_trials_ref(*args)))
    monkeypatch.setenv(kernel_ops.FORCE_ENV, "pallas")
    out_pal = np.asarray(kernel_ops.loo_trials(*args))
    rel = np.max(np.abs(out_pal - out_jnp)) / (np.max(np.abs(out_jnp))
                                               + 1e-9)
    assert rel < 1e-5, rel


def test_kernel_force_rejects_garbage(monkeypatch):
    monkeypatch.setenv(kernel_ops.FORCE_ENV, "mosaic")
    with pytest.raises(ValueError):
        kernel_ops.loo_trials(*_kernel_inputs())


def test_autotune_persists_and_reloads(monkeypatch, tmp_path):
    """The autotuner measures candidates, persists the per-backend JSON
    table, and a fresh process-state reloads it WITHOUT re-measuring."""
    entry = kernel_ops.autotune_loo_trials(100, 23, 16, persist=True,
                                           candidates=[("jnp", 0)], reps=1)
    assert entry["impl"] == "jnp"
    path = tmp_path / kernel_ops.CACHE_FILE
    assert path.exists()
    payload = __import__("json").loads(path.read_text())
    import jax
    backend = jax.default_backend()
    key = kernel_ops.autotune_key(100, 23, 16)
    assert key == "R128_D23_M16"
    assert payload["backends"][backend][key]["timings_us"]["jnp"] >= 0

    kernel_ops.reset_autotune_cache()      # simulate a fresh process
    monkeypatch.setattr(kernel_ops, "_time_call",
                        lambda *a, **k: pytest.fail("re-measured a shape "
                                                    "already in the table"))
    again = kernel_ops.autotune_loo_trials(100, 23, 16)
    assert again == entry


def test_autotuned_block_r_reaches_the_kernel(monkeypatch):
    """A tuned non-default block_r is honored end to end: tune a tiny
    Pallas tile, force the pallas path, and check parity with the
    reference (exercises the small-R/odd-tile padding fix)."""
    entry = kernel_ops.autotune_loo_trials(
        64, 23, 16, candidates=[("pallas", 16)], reps=1)
    assert entry == {"impl": "pallas", "block_r": 16,
                     **{k: entry[k] for k in ("timings_us", "shape",
                                              "reps")}}
    args = _kernel_inputs(R=64)
    monkeypatch.setenv(kernel_ops.FORCE_ENV, "pallas")
    out = np.asarray(kernel_ops.loo_trials(*args))
    ref = np.asarray(loo_trials_ref(*args))
    rel = np.max(np.abs(out - ref)) / (np.max(np.abs(ref)) + 1e-9)
    assert rel < 1e-5, rel


def test_greedytl_result_is_invariant_to_kernel_selection(monkeypatch):
    """End to end: a forced-jnp and a forced-pallas (interpret) greedy
    refine agree ≤ 1e-5 on the deep problem — the selection layer may pick
    either implementation without changing results."""
    x, y, m, src, sm = _deep_problem(n=32, n_src=6)
    monkeypatch.setenv(kernel_ops.FORCE_ENV, "jnp")
    w_jnp, sel_jnp = greedytl(x, y, m, src, sm, num_classes=C)
    monkeypatch.setenv(kernel_ops.FORCE_ENV, "pallas")
    w_pal, sel_pal = greedytl(x, y, m, src, sm, num_classes=C)
    assert np.array_equal(np.asarray(sel_jnp), np.asarray(sel_pal))
    np.testing.assert_allclose(np.asarray(w_jnp), np.asarray(w_pal),
                               rtol=1e-5, atol=1e-5)
