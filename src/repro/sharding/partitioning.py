"""Logical-axis partitioning.

Models declare parameters as :class:`ParamSpec` templates — shape, dtype,
*logical* axis names, and an initializer tag. One template tree serves three
consumers:

* ``init_params``        — materialize real arrays (CPU smoke tests, examples)
* ``param_pspecs``       — map logical axes -> mesh axes (`PartitionSpec`s)
* ``param_shape_structs``— `ShapeDtypeStruct`s for the AOT multi-pod dry-run

Rules follow the MaxText-style FSDP+TP recipe: the contraction/embed dim of
large kernels shards over ``data`` (FSDP), heads/mlp/experts/vocab shard over
``model`` (TP), batch shards over ``data`` (and ``pod`` when present). A
logical axis is silently replicated when the concrete dim is not divisible by
the mesh-axis size (e.g. 8 KV heads on a 16-way model axis).
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated)
    init: str = "normal"              # normal|zeros|ones|scaled_normal|embed|ssm_a|conv
    dtype: Any = None                 # None => model default


# logical axis -> mesh axis (or tuple of mesh axes)
DEFAULT_RULES = {
    "dc": None,               # stacked Data-Collector dim (HTL trainer)
    "batch": "data",
    "cache_len": "model",
    "vocab": "model",
    "embed": "data",          # FSDP: shard the embed/contraction dim of kernels
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    # expert parallelism: 'model' alone for training/prefill (GSPMD's
    # dispatch lowering regresses at EP-256 there); decode uses
    # 'experts_both' = ('data','model') via workload-specific rules (§Perf)
    "experts": "model",
    "experts_both": ("data", "model"),
    "lru": "model",
    "layers": None,
    "head_dim": None,
    "state": None,
    "seq": None,
    "qseq": "model",          # context-parallel attention (§Perf)
    "conv": None,
    "qk_rope": None,
    "latent": None,
}

MULTIPOD_RULES = dict(DEFAULT_RULES, batch=("pod", "data"), dc="pod")

# Million-DC fleet engine (repro.core.cityscan): the stacked Data-Collector
# dim is a real mesh axis, not a vmap batch — fleet state lives sharded on
# device across the whole scan-over-windows program.
FLEET_RULES = dict(DEFAULT_RULES, dc="dc")

FLEET_AXIS = "dc"


def fleet_mesh(n_shards: Optional[int] = None) -> Mesh:
    """1-D device mesh over the first ``n_shards`` devices, axis ``"dc"``.

    The cityscan engine shard_maps its fleet round over this axis; with
    ``n_shards=None`` every visible device joins (8 under CI's
    ``--xla_force_host_platform_device_count=8``)."""
    devs = jax.devices()
    n = len(devs) if n_shards is None else int(n_shards)
    if not 1 <= n <= len(devs):
        raise ValueError(f"fleet_mesh wants 1..{len(devs)} shards, got {n}")
    return Mesh(np.asarray(devs[:n]), (FLEET_AXIS,))


def dc_shards(n_padded: int, max_shards: Optional[int] = None) -> int:
    """Largest usable shard count for a padded DC axis: the biggest device
    count (capped by ``max_shards``) that divides ``n_padded`` evenly, so
    shard_map never needs ragged shards. Padded fleet capacities are
    multiples of 32 (:func:`repro.core.fleet.fleet_cap`), so any
    power-of-two device count <= 32 divides them."""
    n_dev = len(jax.devices())
    n = n_dev if max_shards is None else min(int(max_shards), n_dev)
    n = max(1, n)
    while n > 1 and n_padded % n != 0:
        n -= 1
    return n


def dc_pspec(ndim: int) -> P:
    """PartitionSpec sharding the leading (DC) dim, rest replicated."""
    return P(*((FLEET_AXIS,) + (None,) * (ndim - 1)))


def _axis_size(mesh: Mesh, mesh_axes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    return math.prod(mesh.shape[a] for a in mesh_axes)


def logical_to_pspec(axes: Sequence[Optional[str]], shape: Sequence[int],
                     mesh: Mesh, rules: dict) -> P:
    """Resolve logical axes to a PartitionSpec, replicating non-divisible dims."""
    out = []
    used: set = set()
    for dim, name in zip(shape, axes):
        mesh_axes = rules.get(name) if name is not None else None
        if mesh_axes is None:
            out.append(None)
            continue
        flat = (mesh_axes,) if isinstance(mesh_axes, str) else tuple(mesh_axes)
        # never reuse a mesh axis within one spec
        flat = tuple(a for a in flat if a not in used and a in mesh.shape)
        # require divisibility; degrade gracefully by dropping leading axes
        # (e.g. experts=('data','model'): 64 experts can't shard 256-way but
        # can shard 16-way on 'model' alone)
        while flat and dim % math.prod(mesh.shape[a] for a in flat) != 0:
            flat = flat[1:]
        if not flat:
            out.append(None)
            continue
        used.update(flat)
        out.append(flat[0] if len(flat) == 1 else flat)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def param_pspecs(template, mesh: Mesh, rules: dict = None):
    rules = rules or DEFAULT_RULES
    return jax.tree.map(
        lambda s: logical_to_pspec(s.axes, s.shape, mesh, rules),
        template, is_leaf=lambda x: isinstance(x, ParamSpec))


def param_shape_structs(template, default_dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or default_dtype),
        template, is_leaf=lambda x: isinstance(x, ParamSpec))


def template_bytes(template, default_dtype=jnp.bfloat16) -> int:
    leaves = jax.tree.leaves(template, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype or default_dtype).itemsize
               for s in leaves)


def batch_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


# ---------------------------------------------------------------------------
# Ambient mesh + activation sharding hints
#
# FSDP shards the embed/contraction dim of *weights* over 'data'; without
# explicit activation constraints GSPMD propagates that onto activations and
# evicts batch sharding (observed: global-batch tensors inside layer scans).
# Models call ``hint(x, logical_axes)`` at activation boundaries; it is a
# no-op outside a ``use_compute_mesh`` context (CPU smoke tests).
# ---------------------------------------------------------------------------

_CURRENT_MESH: Optional[Mesh] = None


@contextmanager
def use_compute_mesh(mesh: Mesh):
    global _CURRENT_MESH
    prev = _CURRENT_MESH
    _CURRENT_MESH = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _CURRENT_MESH = prev


def current_mesh() -> Optional[Mesh]:
    return _CURRENT_MESH


def hint(x, axes: Sequence[Optional[str]]):
    """Constrain an activation to its logical sharding under the ambient mesh.

    Under the HTL trainer the model runs vmapped over a stacked Data-Collector
    dim; extra leading dims are treated as the 'dc' logical axis.
    """
    mesh = _CURRENT_MESH
    if mesh is None:
        return x
    axes = tuple(axes)
    while len(axes) < x.ndim:
        axes = ("dc",) + axes
    if len(axes) != x.ndim:
        return x
    rules = MULTIPOD_RULES if "pod" in mesh.axis_names else DEFAULT_RULES
    spec = logical_to_pspec(axes, x.shape, mesh, rules)
    manual = _manual_axes()
    if manual:
        spec = P(*[_strip_axes(e, manual) for e in spec])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _manual_axes() -> set:
    """Mesh axes currently under shard_map manual control (must not appear
    in sharding constraints issued from inside the mapped function)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is None or am.empty:
            return set()
        return {n for n, t in zip(am.axis_names, am.axis_types)
                if "Manual" in str(t)}
    except Exception:                      # noqa: BLE001
        return set()


def _strip_axes(entry, manual: set):
    if entry is None:
        return None
    t = entry if isinstance(entry, tuple) else (entry,)
    t = tuple(a for a in t if a not in manual)
    if not t:
        return None
    return t[0] if len(t) == 1 else t


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _init_leaf(key, spec: ParamSpec, default_dtype) -> jax.Array:
    dtype = spec.dtype or default_dtype
    shape = spec.shape
    if spec.init == "zeros":
        return jnp.zeros(shape, dtype)
    if spec.init == "ones":
        return jnp.ones(shape, dtype)
    if spec.init == "ssm_a":
        # mamba: A_log ~ log(Uniform[1, 16))
        u = jax.random.uniform(key, shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "dt_bias":
        u = jax.random.uniform(key, shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(dtype)   # inv softplus
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = {"normal": 0.02,
             "scaled_normal": 0.02,          # residual-out projections
             "embed": 0.02,
             "conv": 1.0 / math.sqrt(max(1, shape[0])),
             }.get(spec.init, 1.0 / math.sqrt(max(1, fan_in)))
    if spec.init == "fan_in":
        scale = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_params(template, key: jax.Array, default_dtype=jnp.float32):
    """Materialize a param tree from a template, one folded key per leaf path."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=lambda x: isinstance(x, ParamSpec))
    out = []
    for i, (path, spec) in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        out.append(_init_leaf(k, spec, default_dtype))
    return jax.tree.unflatten(treedef, out)


def make_shardings(template, mesh: Mesh, rules: dict = None):
    specs = param_pspecs(template, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
