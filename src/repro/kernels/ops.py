"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the kernels run compiled (Mosaic); on any
other backend (this CPU container) they run with ``interpret=True`` — the
kernel body executes in Python per grid cell, which is what the correctness
sweeps in tests/test_kernels.py rely on. Model code selects these via
``ModelConfig.attention_impl = 'pallas'``; the dry-run keeps the XLA
reference path because Pallas does not lower to CPU HLO.

``loo_trials`` (GreedyTL's greedy-loop hot path) is selected DATA-DRIVEN
instead: a small autotuner micro-benchmarks the Pallas kernel against the
pure-jnp reference at the bucketed (R, D, M) shapes actually seen, caches
the winner per backend (in memory, and as a JSON table under
``results/benchmarks/kernel_autotune.json`` when persisted by the bench
driver), and tunes ``block_r`` rather than hardcoding 256. The env var
``REPRO_KERNEL_FORCE=pallas|jnp`` overrides the selection outright — CI
pins ``jnp`` so gate results never depend on machine timing noise
(DESIGN.md §11).
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import loo_trials as _loo
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention_bshd(q, k, v, *, causal=True, window=0, q_offset=0):
    """(B,S,H,d) layout wrapper matching `models.blocks.chunked_attention`."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              q_offset=q_offset, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=128, block_kv=128):
    """(B,H,S,d) layout."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_kv=block_kv, interpret=_interpret())


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=128):
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                         interpret=_interpret())


def rglru_scan(a, b, *, chunk=128, block_w=128):
    return _rg.rglru_scan(a, b, chunk=chunk, block_w=block_w,
                          interpret=_interpret())


# ---------------------------------------------------------------------------
# loo_trials autotuner: measured jnp-vs-Pallas crossover + tuned block_r
# ---------------------------------------------------------------------------

FORCE_ENV = "REPRO_KERNEL_FORCE"
CACHE_DIR_ENV = "REPRO_KERNEL_CACHE_DIR"
CACHE_FILE = "kernel_autotune.json"
DEFAULT_BLOCK_R = 256
PALLAS_BLOCK_RS = (64, 128, 256, 512)

_tune_lock = threading.Lock()
_tune_mem: dict = {}        # (backend, bucket key) -> winning entry dict
_tune_disk_loaded = False


def kernel_force():
    """Validated REPRO_KERNEL_FORCE value (read per call, so tests and CI
    control it without import-order games)."""
    v = os.environ.get(FORCE_ENV)
    if v in (None, ""):
        return None
    if v not in ("pallas", "jnp"):
        raise ValueError(f"{FORCE_ENV} must be 'pallas' or 'jnp', got {v!r}")
    return v


def _cache_dir() -> Path:
    d = os.environ.get(CACHE_DIR_ENV)
    if d:
        return Path(d)
    # src/repro/kernels/ops.py -> repo root / results / benchmarks
    return Path(__file__).resolve().parents[3] / "results" / "benchmarks"


def bucket_rows(r: int) -> int:
    """Row-count bucket: next power of two, floored at one sublane tile (8).
    Stage-1 row counts are n*C over bucketed sample caps, so a handful of
    buckets covers every shape a sweep dispatches."""
    return max(8, 1 << max(0, int(r) - 1).bit_length())


def autotune_key(r: int, d: int, m: int) -> str:
    return f"R{bucket_rows(r)}_D{int(d)}_M{int(m)}"


def _load_disk_cache_locked() -> None:
    global _tune_disk_loaded
    if _tune_disk_loaded:
        return
    _tune_disk_loaded = True
    try:
        payload = json.loads((_cache_dir() / CACHE_FILE).read_text())
    except (OSError, ValueError):
        return
    for backend, entries in payload.get("backends", {}).items():
        for key, entry in entries.items():
            _tune_mem.setdefault((backend, key), entry)


def _persist_cache_locked() -> None:
    backends: dict = {}
    for (backend, key), entry in sorted(_tune_mem.items()):
        backends.setdefault(backend, {})[key] = entry
    payload = {
        "version": 1,
        "kernel": "loo_trials",
        "note": "per-backend measured impl selection for the GreedyTL "
                "trial-scoring kernel; keys are bucketed (R, D, M) shapes; "
                "regenerate with repro.kernels.ops.autotune_loo_trials("
                "..., persist=True) or benchmarks/run.py",
        "backends": backends,
    }
    path = _cache_dir() / CACHE_FILE
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(path.name + ".tmp")
        tmp.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
        os.replace(tmp, path)
    except OSError:
        pass                      # read-only checkout: memory cache only


def reset_autotune_cache() -> None:
    """Drop the in-memory cache and force a disk reload (test hook)."""
    global _tune_disk_loaded
    with _tune_lock:
        _tune_mem.clear()
        _tune_disk_loaded = False


def _default_candidates(backend: str):
    """(impl, block_r) candidates worth measuring on this backend. Off-TPU
    the compiled Mosaic path does not exist and interpret mode is orders of
    magnitude off the production regime, so jnp is the only honest
    candidate — the autotuner then just measures and records it."""
    cands = [("jnp", 0)]
    if backend == "tpu":
        cands += [("pallas", br) for br in PALLAS_BLOCK_RS]
    return cands


def _time_call(fn, args, reps: int) -> float:
    jax.block_until_ready(fn(*args))                 # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def autotune_loo_trials(r: int, d: int, m: int, *, backend=None,
                        persist: bool = False, refresh: bool = False,
                        candidates=None, reps: int = 5) -> dict:
    """Measure every candidate ``loo_trials`` implementation at the bucketed
    (r, d, m) shape and cache the winner.

    Returns the winning entry ``{"impl", "block_r", "timings_us", "shape"}``.
    Cached per backend; ``persist=True`` additionally writes the JSON table
    under results/benchmarks/ (the runtime path never writes — only the
    bench driver and explicit callers do, so test runs leave the repo
    clean). ``candidates`` overrides the measured set (tests use it to
    force tiny interpret-mode Pallas runs off-TPU)."""
    backend = backend or jax.default_backend()
    key = autotune_key(r, d, m)
    with _tune_lock:
        _load_disk_cache_locked()
        hit = _tune_mem.get((backend, key))
    if hit is not None and not refresh:
        # a memory hit must still reach the disk table: the runtime path
        # pre-populates buckets (memory-only) before the bench persists
        if persist:
            with _tune_lock:
                _persist_cache_locked()
        return hit

    rb, d, m = bucket_rows(r), int(d), int(m)
    rng = np.random.default_rng(0)
    f32 = np.float32
    args = tuple(jnp.asarray(v) for v in (
        rng.standard_normal((rb, d)).astype(f32),          # ut
        rng.standard_normal((d, m)).astype(f32),           # cc
        rng.standard_normal((rb, m)).astype(f32),          # a_cand
        rng.standard_normal(rb).astype(f32),               # fitted_base
        np.abs(rng.standard_normal(rb)).astype(f32) * 0.1,  # h_base
        rng.standard_normal(rb).astype(f32),               # y
        (rng.random(rb) < 0.8).astype(f32),                # rmask
        rng.standard_normal(m).astype(f32),                # zj
        np.abs(rng.standard_normal(m)).astype(f32),        # dinv
    ))

    timings = {}
    for impl, br in (candidates if candidates is not None
                     else _default_candidates(backend)):
        if impl == "jnp":
            label, fn = "jnp", jax.jit(_loo.loo_trials_ref)
        else:
            label = f"pallas@{br}"
            fn = functools.partial(_loo.loo_trials, block_r=br,
                                   interpret=backend != "tpu")
        try:
            timings[label] = round(_time_call(fn, args, reps), 2)
        except Exception:          # candidate fails to lower: skip it
            continue
    if not timings:
        timings["jnp"] = 0.0       # degenerate candidate list: fall back
    best = min(timings, key=timings.get)
    entry = {
        "impl": "jnp" if best == "jnp" else "pallas",
        "block_r": 0 if best == "jnp" else int(best.split("@")[1]),
        "timings_us": timings,
        "shape": [rb, d, m],
        "reps": reps,
    }
    with _tune_lock:
        _tune_mem[(backend, key)] = entry
        if persist:
            _persist_cache_locked()
    return entry


def loo_trials(ut, cc, a_cand, fitted_base, h_base, y, rmask, zj, dinv):
    """GreedyTL Cholesky-bordering trial scorer (see kernels.loo_trials).

    Selection is autotuned (see module doc): the measured winner for this
    (R, D, M) bucket on this backend runs, with its tuned ``block_r``.
    ``REPRO_KERNEL_FORCE`` short-circuits the tuner: ``jnp`` always takes
    the pure-jnp reference; ``pallas`` always takes the kernel (interpret
    mode off-TPU — correctness-path only, used by the CI parity test).
    Shapes are static at trace time, so the selection is resolved per
    traced shape and adds nothing to the compiled program."""
    shaped = (ut.shape[0], ut.shape[1], cc.shape[1])
    force = kernel_force()
    if force == "jnp":
        return _loo.loo_trials_ref(ut, cc, a_cand, fitted_base, h_base, y,
                                   rmask, zj, dinv)
    if force == "pallas":
        entry = _tune_mem.get((jax.default_backend(),
                               autotune_key(*shaped)))
        br = (entry or {}).get("block_r") or DEFAULT_BLOCK_R
        return _loo.loo_trials(ut, cc, a_cand, fitted_base, h_base, y,
                               rmask, zj, dinv, block_r=br,
                               interpret=_interpret())
    entry = autotune_loo_trials(*shaped)
    if entry["impl"] == "pallas" and not _interpret():
        return _loo.loo_trials(ut, cc, a_cand, fitted_base, h_base, y,
                               rmask, zj, dinv, block_r=entry["block_r"])
    return _loo.loo_trials_ref(ut, cc, a_cand, fitted_base, h_base, y,
                               rmask, zj, dinv)
