"""Pareto auto-tuner (DESIGN.md §14): slack-dominance order properties,
successive-halving schedule/pruning invariants, the search spec grammar,
the lossless ``ParetoResult`` artifact, and the service search path.

The hard promises under test: slack dominance is a strict partial order
(so pruning is consistent no matter the comparison order); ``keep=1.0``
degrades to the exhaustive search; no rung prunes a config the
full-budget exhaustive frontier keeps (the recovery property
scripts/pareto_smoke.py gates at the CI budget); and a search served
over the RPC control plane is byte-identical to the in-process run.
"""
import functools
import threading

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.experiment import get_preset
from repro.core.pareto import (HalvingSearch, ParetoPoint, ParetoResult,
                               dominates, frontier_spec, get_search,
                               pareto_frontier, subset_spec)
from repro.data.synthetic_covtype import make_covtype_like
from repro.service.client import ClientError, ServiceClient
from repro.service.server import make_server

DATA = make_covtype_like(n_total=1400, seed=0)
WINDOWS = 2


def _points(vals):
    return [ParetoPoint(label=f"p{i}", f1=f1, energy_mj=e)
            for i, (f1, e) in enumerate(vals)]


@functools.lru_cache(maxsize=None)
def _grid():
    """Shared mini-grid: smoke preset at 2 windows, plus its exhaustive
    search result (every candidate at full budget) as the oracle."""
    spec = get_preset("smoke", windows=WINDOWS)
    exhaustive = get_search("exhaustive").run(spec, DATA)
    return spec, exhaustive


# ---------------------------------------------------------------------------
# slack dominance is a strict partial order
# ---------------------------------------------------------------------------

POINT_SETS = st.lists(st.tuples(st.floats(0.0, 1.0),
                                st.floats(1.0, 100.0)),
                      min_size=1, max_size=10)
SLACKS = st.tuples(st.sampled_from([0.0, 0.02]),
                   st.sampled_from([0.0, 0.05]))


@settings(max_examples=40, deadline=None)
@given(vals=POINT_SETS, slacks=SLACKS)
def test_dominance_is_a_strict_partial_order(vals, slacks):
    f1_slack, energy_slack = slacks
    pts = _points(vals)

    def dom(a, b):
        return dominates(a, b, f1_slack=f1_slack,
                         energy_slack=energy_slack)

    for a in pts:
        assert not dom(a, a)                      # irreflexive
        for b in pts:
            if dom(a, b):
                assert not dom(b, a)              # asymmetric
            for c in pts:
                if dom(a, b) and dom(b, c):
                    assert dom(a, c)              # transitive


@settings(max_examples=40, deadline=None)
@given(vals=POINT_SETS, slacks=SLACKS)
def test_frontier_is_sound_complete_and_order_preserving(vals, slacks):
    f1_slack, energy_slack = slacks
    pts = _points(vals)
    front = pareto_frontier(pts, f1_slack=f1_slack,
                            energy_slack=energy_slack)
    kept = {p.label for p in front}
    for p in pts:
        dominated = any(
            dominates(q, p, f1_slack=f1_slack, energy_slack=energy_slack)
            for q in pts if q.label != p.label)
        assert (p.label in kept) == (not dominated)
    # frontier preserves candidate order (a subsequence of the input)
    order = [p.label for p in pts if p.label in kept]
    assert [p.label for p in front] == order


def test_slack_only_ever_prunes_less():
    # a barely-better point dominates with zero slack but not past it
    a = ParetoPoint(label="a", f1=0.801, energy_mj=100.0)
    b = ParetoPoint(label="b", f1=0.800, energy_mj=100.0)
    assert dominates(a, b)
    assert not dominates(a, b, f1_slack=0.02)
    c = ParetoPoint(label="c", f1=0.8, energy_mj=99.0)
    assert dominates(c, b)
    assert not dominates(c, b, energy_slack=0.05)
    with pytest.raises(ValueError):
        dominates(a, b, f1_slack=-0.1)


# ---------------------------------------------------------------------------
# halving schedule invariants (pure, no runs)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(full=st.integers(min_value=1, max_value=96),
       rungs=st.integers(min_value=1, max_value=5),
       eta=st.sampled_from([2.0, 3.0]))
def test_rung_budgets_monotone_and_final_rung_is_full(full, rungs, eta):
    s = HalvingSearch(rungs=rungs, eta=eta)
    ws = [s.rung_windows(full, r) for r in range(rungs)]
    assert ws == sorted(ws)
    assert ws[-1] == full
    assert all(1 <= w <= full for w in ws)


@settings(max_examples=40, deadline=None)
@given(n_seeds=st.integers(min_value=1, max_value=6),
       rungs=st.integers(min_value=1, max_value=4))
def test_rung_seeds_are_prefixes_growing_to_all(n_seeds, rungs):
    s = HalvingSearch(rungs=rungs)
    seeds = tuple(range(n_seeds))
    per_rung = [s.rung_seeds(seeds, r) for r in range(rungs)]
    for sub in per_rung:
        assert sub == seeds[:len(sub)] and len(sub) >= 1
    assert per_rung[-1] == seeds


# ---------------------------------------------------------------------------
# the search searched — and never lost an optimal config (real runs)
# ---------------------------------------------------------------------------

def test_keep_one_is_the_exhaustive_search():
    spec, exhaustive = _grid()
    full = get_search("halving:rungs=2,keep=1.0").run(spec, DATA)
    assert full.dominated_counts().get("pruned", 0) == 0
    assert full.frontier_labels() == exhaustive.frontier_labels()
    assert (full.frontier_result.to_json()
            == exhaustive.frontier_result.to_json())


def test_no_rung_prunes_a_full_budget_optimal_point():
    spec, exhaustive = _grid()
    optimal = set(exhaustive.frontier_labels())
    result = get_search("halving:rungs=2,keep=0.5").run(spec, DATA)
    pruned = {lbl for r in result.schedule for lbl in r["pruned_labels"]}
    assert not (optimal & pruned)
    assert result.frontier_labels() == exhaustive.frontier_labels()
    # the ledger covers the grid exactly once
    assert sorted(e["label"] for e in result.ledger) == \
        sorted(lbl for lbl, _ in spec.rows())


def test_frontier_result_is_bitwise_a_plain_sweep_run():
    spec, exhaustive = _grid()
    direct = frontier_spec(spec, exhaustive.frontier_labels()).run(DATA)
    assert exhaustive.frontier_result.to_json() == direct.to_json()


def test_pareto_result_json_round_trips_losslessly():
    _, exhaustive = _grid()
    clone = ParetoResult.from_json(exhaustive.to_json())
    assert clone == exhaustive
    assert clone.to_json() == exhaustive.to_json()


# ---------------------------------------------------------------------------
# spec grammar
# ---------------------------------------------------------------------------

def test_search_grammar_rejects_unknown_and_invalid():
    with pytest.raises(KeyError):
        get_search("simulated_annealing")
    with pytest.raises(ValueError):
        get_search("halving:rungs=0")
    with pytest.raises(ValueError):
        get_search("halving:keep=1.5")
    with pytest.raises(ValueError):
        get_search("halving:eta=0.5")


def test_search_spec_canonicalizes_param_order_and_float_spelling():
    a = get_search("halving:keep=0.5,rungs=2")
    b = get_search("halving:rungs=2,keep=0.5")
    c = get_search("halving:rungs=2,keep=.5,eta=2")
    assert a.spec == b.spec == c.spec


def test_subset_spec_rejects_empty_and_frontier_spec_unknown_label():
    spec, _ = _grid()
    with pytest.raises(ValueError):
        subset_spec("empty", [])
    with pytest.raises(KeyError):
        frontier_spec(spec, ["not_a_label"])


# ---------------------------------------------------------------------------
# the service search path (DESIGN.md §12 + §14)
# ---------------------------------------------------------------------------

def test_service_search_is_bitwise_the_in_process_run():
    spec, _ = _grid()
    local = get_search("halving:rungs=2,keep=0.5").run(spec, DATA)
    httpd, _service = make_server(backend="hosts:channel=inline,n=2")
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    try:
        client = ServiceClient(httpd.server_address[:2])
        rungs = []
        out = client.search(spec, DATA, "halving:rungs=2,keep=0.5",
                            on_rung=rungs.append)
        assert out.to_json() == local.to_json()
        assert [e["rung"] for e in rungs] == [0, 1]
        assert out.meta["service"]["cached"] is False
        # a respelled search spec hits the exact result cache
        again = client.search(spec, DATA, "halving:keep=0.5,rungs=2")
        assert again.meta["service"]["cached"] is True
        assert again.to_json() == local.to_json()
        # search jobs have no record pages
        with pytest.raises(ClientError) as err:
            client.result_page(out.meta["service"]["job"], 0, 5)
        assert err.value.status == 400
        # and a bogus search spec is a structured 400 at submit
        with pytest.raises(ClientError) as err:
            client.submit(spec, DATA, search="halving:rungs=0")
        assert err.value.status == 400
    finally:
        httpd.shutdown()
