"""Scenario simulation (paper Sections 3, 5, 6).

A slotted data-collection process: ``windows`` collection windows of
``obs_per_window`` observations each. Observations are either collected by
SmartMules (802.15.4) or shipped to the Edge Server (NB-IoT). The number of
mules per window is Poisson(lambda); the per-mule allocation follows a Zipf
ranking (or uniform, Scenario 3). After each window a learning round runs
(centralised on the ES, or A2AHTL/StarHTL among the Data Collectors) and the
global model is evaluated on the held-out test set.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.energy import Ledger
from repro.core.htl import (DC, apply_aggregation_heuristic, run_window_a2a,
                            run_window_star)
from repro.core.metrics import f_measure
from repro.core.svm import pad_local, svm_predict, train_svm
from repro.data.synthetic_covtype import Dataset, NUM_CLASSES


@dataclass(frozen=True)
class ScenarioConfig:
    windows: int = 100
    obs_per_window: int = 100
    lam_poisson: float = 7.0
    zipf_alpha: float = 1.5
    p_edge: float = 0.0           # fraction of each window shipped to the ES
    algo: str = "star"            # 'star' | 'a2a' | 'edge_only'
    tech: str = "4g"              # DC<->DC technology: '4g' | 'wifi'
    uniform: bool = False         # Scenario 3: uniform allocation over mules
    aggregate: bool = False       # data-aggregation heuristic (Section 6.3)
    n_subsample: Optional[int] = None   # GreedyTL points per class (Sec. 7)
    include_es_in_learning: bool = True
    cap: int = 160                # padded local-dataset capacity
    eval_every: int = 1
    seed: int = 0
    # "This model is used to update the model elaborated until the previous
    # time slot" (paper Section 3): the window model updates the global model
    # incrementally. We use an exponential moving average with this rate.
    global_update_rate: float = 0.3


@dataclass
class ScenarioResult:
    f1_curve: List[float]
    ledger: Ledger
    cfg: ScenarioConfig

    @property
    def final_f1(self) -> float:
        return self.f1_curve[-1]

    def converged_f1(self, start_frac: float = 0.5) -> float:
        """Paper: mean F1 over the converged interval (50th-100th window)."""
        k = int(len(self.f1_curve) * start_frac)
        return float(np.mean(self.f1_curve[k:]))

    @property
    def energy_total(self) -> float:
        return self.ledger.total()

    @property
    def energy_collection(self) -> float:
        return self.ledger.total("collection")

    @property
    def energy_learning(self) -> float:
        return self.ledger.total("learning")


def _zipf_probs(n: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return p / p.sum()


def run_scenario(cfg: ScenarioConfig, data: Dataset) -> ScenarioResult:
    rng = np.random.default_rng(cfg.seed)
    ledger = Ledger()
    n_total = cfg.windows * cfg.obs_per_window
    order = rng.permutation(len(data.y_train))[:n_total]
    stream_x = data.x_train[order].astype(np.float32)
    stream_y = data.y_train[order].astype(np.int32)

    f1_curve: List[float] = []
    prev_global: Optional[np.ndarray] = None

    # Edge-only: the ES accumulates everything and retrains each window
    if cfg.algo == "edge_only":
        xacc = np.zeros((n_total, stream_x.shape[1]), np.float32)
        yacc = np.zeros((n_total,), np.int32)
        macc = np.zeros((n_total,), np.float32)
        w = None
        for t in range(cfg.windows):
            s = slice(t * cfg.obs_per_window, (t + 1) * cfg.obs_per_window)
            ledger.collect_to_edge(cfg.obs_per_window)
            xacc[s] = stream_x[s]
            yacc[s] = stream_y[s]
            macc[s] = 1.0
            w = train_svm(jnp.asarray(xacc), jnp.asarray(yacc),
                          jnp.asarray(macc), num_classes=NUM_CLASSES,
                          iters=300,
                          w0=None if w is None else jnp.asarray(w))
            w = np.asarray(w)
            if (t + 1) % cfg.eval_every == 0:
                f1_curve.append(_eval(w, data))
        return ScenarioResult(f1_curve, ledger, cfg)

    for t in range(cfg.windows):
        s = slice(t * cfg.obs_per_window, (t + 1) * cfg.obs_per_window)
        wx, wy = stream_x[s], stream_y[s]

        n_edge = int(round(cfg.p_edge * cfg.obs_per_window))
        idx = rng.permutation(cfg.obs_per_window)
        edge_idx, mule_idx = idx[:n_edge], idx[n_edge:]

        L = max(1, rng.poisson(cfg.lam_poisson))
        if cfg.uniform:
            assign = rng.integers(0, L, size=len(mule_idx))
        else:
            assign = rng.choice(L, size=len(mule_idx),
                                p=_zipf_probs(L, cfg.zipf_alpha))

        dcs: List[DC] = []
        for m in range(L):
            sel = mule_idx[assign == m]
            if len(sel) == 0:
                continue
            ledger.collect_to_mule(len(sel))
            dcs.append(DC(f"SM{m + 1}", wx[sel], wy[sel]))
        if n_edge > 0:
            ledger.collect_to_edge(n_edge)
            if cfg.include_es_in_learning:
                dcs.append(DC("ES", wx[edge_idx], wy[edge_idx], is_es=True))

        if cfg.aggregate:
            dcs = apply_aggregation_heuristic(dcs, ledger, cfg.tech)

        run = run_window_a2a if cfg.algo == "a2a" else run_window_star
        new_global = run(dcs, prev_global, ledger, cfg.tech,
                         cap=cfg.cap, num_classes=NUM_CLASSES,
                         n_subsample=cfg.n_subsample, rng=rng)
        if prev_global is None or new_global is None:
            prev_global = new_global if new_global is not None else prev_global
        else:
            eta = cfg.global_update_rate
            prev_global = (1.0 - eta) * prev_global + eta * new_global
        if (t + 1) % cfg.eval_every == 0:
            f1_curve.append(_eval(prev_global, data))

    return ScenarioResult(f1_curve, ledger, cfg)


def _eval(w: np.ndarray, data: Dataset) -> float:
    pred = np.asarray(svm_predict(jnp.asarray(w),
                                  jnp.asarray(data.x_test.astype(np.float32))))
    return f_measure(data.y_test, pred, NUM_CLASSES)
