"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward/train step on CPU — output shapes + no NaNs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import OptimizerConfig
from repro.data.pipeline import make_lm_batch
from repro.launch.train import make_train_step
from repro.models import build_model
from repro.optim.adamw import adamw_init

B, S = 2, 64


def _batch(cfg):
    return make_lm_batch(
        cfg.vocab_size, B, S, d_model=cfg.d_model,
        frontend_tokens=(cfg.frontend.num_tokens if cfg.family == "vlm"
                         else 0),
        encoder_len=(cfg.encoder_seq_len if cfg.family == "audio" else 0))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    assert float(loss) > 0

    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, OptimizerConfig(lr=1e-3,
                                                          warmup_steps=2)))
    # step 1 = mid-warmup, lr > 0 (at step 0 the warmup lr is exactly 0)
    new_params, new_opt, m = step(params, opt, batch,
                                  jnp.asarray(1, jnp.int32))
    # params actually changed, no NaNs anywhere
    leaves_old = jax.tree.leaves(params)
    leaves_new = jax.tree.leaves(new_params)
    assert any(
        not jnp.allclose(a, b) for a, b in zip(leaves_old, leaves_new))
    assert all(not bool(jnp.isnan(x).any()) for x in leaves_new)
    assert not bool(jnp.isnan(m["gnorm"]))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_and_decode_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    n_front = cfg.frontend.num_tokens if cfg.family == "vlm" else 0
    pos = jnp.asarray(S + n_front - 1, jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, cache, tok, pos)
    assert logits2.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits2).any())
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_two_train_steps_reduce_loss():
    """A few steps on structured data should reduce the loss."""
    cfg = get_config("llama3.2-3b").reduced()
    cfg = dataclasses.replace(cfg, num_layers=2, d_model=64, num_heads=2,
                              num_kv_heads=2, head_dim=32, d_ff=128,
                              vocab_size=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(model, OptimizerConfig(
        lr=5e-3, warmup_steps=2, total_steps=30)))
    from repro.data.pipeline import TokenStream
    it = TokenStream(cfg.vocab_size, seed=0).batches(4, 64)
    losses = []
    for i in range(15):
        params, opt, m = step(params, opt, next(it),
                              jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses
