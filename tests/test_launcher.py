"""Multi-host sweep launcher (DESIGN.md §8): wire-format codecs, channel
spec grammar, retry/merge fault tolerance (hypothesis property over shard
failure masks), and real worker-subprocess crash faults.

The hard promise under test: ``parallel="hosts:..."`` merges bitwise
identical (JSON-identical ``SweepResult``) to the sequential run — clean,
under arbitrary ≤K per-shard failures, and under a worker SIGKILLed
mid-shard — because shards are deterministic functions of the partition
and a retry re-runs the identical payload.
"""
import functools
import json
import os
import threading

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import launcher
from repro.core.experiment import SweepResult, get_preset, records_from
from repro.core.launcher import (CHANNELS, ChannelError, HostChannel,
                                 HostsExecutor, LauncherError, LocalChannel,
                                 SlurmChannel, SSHChannel, build_request,
                                 decode_dataset, encode_dataset, frame_response,
                                 get_channel, parse_response, run_request)
from repro.core.parallel import (EXECUTORS, get_executor, partition_runs,
                                 run_shard_payload)
from repro.core.registry import format_spec, parse_spec
from repro.data.synthetic_covtype import Dataset, make_covtype_like

# small dataset: worker spawn cost is import+jit, not data, but the wire
# payload shrinks from ~11 MB to ~800 KB
DATA = make_covtype_like(n_total=1400, seed=0)
WINDOWS = 2


@functools.lru_cache(maxsize=None)
def _grid():
    """The shared mini-grid: spec, run list, partition, sequential
    reference JSON, and canned per-shard payloads (computed in-process
    once — FakeChannel replays them, so retry/merge property examples are
    instant)."""
    spec = get_preset("smoke", windows=WINDOWS)
    runs = spec.configs()
    labels = [l for l, _ in runs]
    cfgs = [c for _, c in runs]
    ref_json = spec.run(DATA).to_json()
    shards = [s for s in partition_runs(cfgs, 2) if s]
    canned = []
    for k, idxs in enumerate(shards):
        payload, counts = run_shard_payload(
            [labels[i] for i in idxs], [cfgs[i] for i in idxs], DATA, True)
        canned.append({"schema": launcher.PAYLOAD_SCHEMA, "shard": k,
                       "result": payload, "dispatch_counts": counts})
    return spec, labels, cfgs, shards, ref_json, canned


def _merge_to_json(spec, labels, results):
    return SweepResult(name=spec.name,
                       records=records_from(labels, results)).to_json()


# ---------------------------------------------------------------------------
# wire format
# ---------------------------------------------------------------------------

def test_dataset_codec_roundtrip_is_bitwise():
    back = decode_dataset(encode_dataset(DATA))
    for name, a, b in zip(Dataset._fields, DATA, back):
        assert a.dtype == b.dtype and a.shape == b.shape, name
        assert a.tobytes() == b.tobytes(), f"{name} bits drifted"
    # and the codec survives a JSON round-trip (the actual wire path)
    back2 = decode_dataset(json.loads(json.dumps(encode_dataset(DATA))))
    assert back2.x_train.tobytes() == DATA.x_train.tobytes()


def test_response_framing_ignores_stray_stdout():
    response = {"schema": launcher.PAYLOAD_SCHEMA, "shard": 3,
                "result": "{}", "dispatch_counts": {}}
    noisy = "jax warning: blah\n" + frame_response(response)
    assert parse_response(noisy) == response
    with pytest.raises(ChannelError, match="sentinel"):
        parse_response("no frame here at all")
    with pytest.raises(ChannelError, match="unparseable"):
        parse_response(f"\n{launcher.RESULT_SENTINEL}\nnot json")


def test_run_request_rejects_wrong_schema():
    with pytest.raises(ValueError, match="schema"):
        run_request({"schema": 999})


# ---------------------------------------------------------------------------
# channel spec grammar (nested specs, registry.py)
# ---------------------------------------------------------------------------

def test_nested_spec_grammar_list_continuation():
    # ";"-separated channel grammar: unkeyed segments continue the value
    assert parse_spec("ssh:hosts=a;b;c", sep=";", merge_unkeyed=True) == \
        ("ssh", {"hosts": "a;b;c"})
    assert parse_spec("slurm:array=4;submit=bash", sep=";",
                      merge_unkeyed=True) == \
        ("slurm", {"array": 4, "submit": "bash"})
    # without merge_unkeyed the same string is malformed (strictness of
    # the outer grammar is unchanged)
    with pytest.raises(ValueError):
        parse_spec("ssh:hosts=a;b;c", sep=";")
    # the outer grammar carries a whole channel spec as one value
    assert parse_spec("hosts:channel=ssh:hosts=a;b;c,n=3") == \
        ("hosts", {"channel": "ssh:hosts=a;b;c", "n": 3})
    assert format_spec("local", {"n": 4}, sep=";") == "local:n=4"


def test_get_channel_resolves_every_builtin():
    assert sorted(CHANNELS) == ["inline", "local", "slurm", "ssh"]
    assert get_channel("inline").slots() == ["inline/0"]
    assert get_channel("inline:n=2").slots() == ["inline/0", "inline/1"]
    assert get_channel("local").slots() == ["local/0", "local/1"]
    assert get_channel("local:", default_slots=3).slots() == \
        ["local/0", "local/1", "local/2"]          # trailing ':' tolerated
    assert get_channel("local:n=1").slots() == ["local/0"]
    ssh = get_channel("ssh:hosts=edge-a;edge-b")
    assert ssh.hosts == ["edge-a", "edge-b"]
    assert ssh.slots() == ["ssh/edge-a", "ssh/edge-b"]
    slurm = get_channel("slurm:array=8;submit=none")
    assert (slurm.array, slurm.submit, slurm.batch) == (8, "none", True)
    with pytest.raises(KeyError):
        get_channel("teleport")
    with pytest.raises(KeyError):
        get_channel("local:bogus=1")
    with pytest.raises(ValueError):
        get_channel("ssh:hosts=")      # trailing '=' -> malformed param


def test_hosts_executor_registered_in_spec_grammar():
    assert "hosts" in EXECUTORS
    ex = get_executor("hosts:channel=local,n=4,retries=2")
    assert isinstance(ex, HostsExecutor)
    assert (ex.n, ex.retries) == (4, 2)
    assert get_executor("hosts:channel=ssh:hosts=a;b;c").channel == \
        "ssh:hosts=a;b;c"
    with pytest.raises(ValueError):
        get_executor("hosts:n=0")
    with pytest.raises(ValueError):
        get_executor("hosts:retries=-1")


def test_ssh_channel_command_construction():
    ch = SSHChannel(hosts="a;b", python="python3.11", opts="-p 2222")
    cmd = ch.command("ssh/b")
    assert cmd[:3] == ["ssh", "-o", "BatchMode=yes"]
    assert "-p" in cmd and "2222" in cmd and "b" in cmd
    assert cmd[-1] == "python3.11 -m repro.core.launcher --worker"
    # injection env rides the remote command line, not the local env
    assert SSHChannel(hosts="h").command(
        "ssh/h", {launcher.INJECT_ENV: "sigkill"})[-1].startswith(
        f"{launcher.INJECT_ENV}=sigkill ")


def test_slurm_stage_writes_requests_and_array_script(tmp_path):
    ch = SlurmChannel(array=2, dir=str(tmp_path), submit="none")
    reqs = [build_request(k, ["r"], [_grid()[2][0]], DATA, True)
            for k in range(3)]
    script = ch.stage(reqs, str(tmp_path / "b1"))
    text = open(script).read()
    assert "#SBATCH --array=0-2%2" in text
    assert "--input" in text and "--output" in text
    assert "repro.core.launcher" in text
    for k in range(3):
        staged = json.load(open(tmp_path / "b1" / f"shard_{k:04d}.json"))
        assert staged["schema"] == launcher.PAYLOAD_SCHEMA
        assert staged["shard"] == k
    # submit=none: every shard reports pending as a crash ChannelError
    outs = ch.run_batch(reqs[:1])
    assert isinstance(outs[0], ChannelError) and outs[0].kind == "crash"


def test_slurm_never_collects_stale_results_from_a_previous_batch(tmp_path):
    """A fresh channel instance pointing at a dir with leftover batches
    must stage into a new batch dir — a stale result_*.json from an
    earlier run can never be read back as a fresh shard response."""
    req = build_request(0, ["r"], [_grid()[2][0]], DATA, True)
    ch1 = SlurmChannel(dir=str(tmp_path), submit="none")
    ch1.run_batch([req])
    # plant a bogus "result" where a naive second run would look
    with open(tmp_path / "batch_001" / "result_0000.json", "w") as f:
        json.dump({"schema": launcher.PAYLOAD_SCHEMA, "shard": 0,
                   "result": "STALE", "dispatch_counts": {}}, f)
    ch2 = SlurmChannel(dir=str(tmp_path), submit="none")   # _batch_no = 0
    outs = ch2.run_batch([req])
    assert isinstance(outs[0], ChannelError), \
        "stale batch_001 result was collected as fresh"
    assert "batch_002" in outs[0].detail


# ---------------------------------------------------------------------------
# retry/merge fault tolerance (in-process FakeChannel, canned payloads)
# ---------------------------------------------------------------------------

class FakeChannel(HostChannel):
    """Replays canned shard responses, failing scripted (shard, attempt)
    pairs — exercises the executor's retry/slot/merge machinery without
    subprocess cost. Thread-safe: shards dispatch concurrently."""

    def __init__(self, canned, fail_plan, n_slots=3):
        self.canned = canned
        self.fail_plan = dict(fail_plan)    # (shard, attempt) -> kind
        self.n_slots = n_slots
        self._attempts = {}
        self._lock = threading.Lock()

    def slots(self):
        return [f"fake/{i}" for i in range(self.n_slots)]

    def run(self, slot, request, *, timeout=None, extra_env=None):
        shard = request["shard"]
        with self._lock:
            attempt = self._attempts[shard] = \
                self._attempts.get(shard, 0) + 1
        kind = self.fail_plan.get((shard, attempt))
        if kind is not None:
            raise ChannelError(kind, f"scripted {kind} for shard {shard} "
                               f"attempt {attempt}")
        return self.canned[shard]


def _run_hosts(fail_plan, retries, n_slots=3):
    spec, labels, cfgs, shards, ref_json, canned = _grid()
    ch = FakeChannel(canned, fail_plan, n_slots=n_slots)
    ex = HostsExecutor(channel=ch, n=2, retries=retries, backoff=0.0)
    results, meta = ex.execute_with_meta(labels, cfgs, DATA, stack=True)
    return _merge_to_json(spec, labels, results), meta, ref_json


@settings(max_examples=20, deadline=None)
@given(fails=st.tuples(st.integers(min_value=0, max_value=2),
                       st.integers(min_value=0, max_value=2)),
       kind_i=st.integers(min_value=0, max_value=2))
def test_retry_merge_parity_under_any_failure_mask(fails, kind_i):
    """Property (issue satellite): for every per-shard failure count ≤ K,
    the merged SweepResult is JSON-identical to the sequential run and
    the attempt log is complete — k_s failures then one success, slots
    recorded, statuses faithful."""
    kind = ("crash", "timeout", "frame")[kind_i]
    retries = 2
    fail_plan = {(s, a): kind
                 for s, k_s in enumerate(fails) for a in range(1, k_s + 1)}
    got, meta, ref = _run_hosts(fail_plan, retries=retries)
    assert got == ref, f"merge drifted under failure mask {fails}"
    log = meta["launcher"]["shards"]
    assert len(log) == 2
    for s, k_s in enumerate(fails):
        attempts = log[s]["attempts"]
        assert len(attempts) == k_s + 1
        assert [a["status"] for a in attempts] == [kind] * k_s + ["ok"]
        assert all(a["slot"].startswith("fake/") for a in attempts)
        assert [a["attempt"] for a in attempts] == \
            list(range(1, k_s + 2))
    assert meta["launcher"]["attempts_total"] == sum(fails) + 2


def test_retry_prefers_a_different_surviving_slot():
    """With free alternative slots, a retry must not land on the slot
    that just failed."""
    got, meta, ref = _run_hosts({(0, 1): "crash"}, retries=1, n_slots=4)
    assert got == ref
    a = meta["launcher"]["shards"][0]["attempts"]
    assert a[0]["status"] == "crash" and a[1]["status"] == "ok"
    assert a[1]["slot"] != a[0]["slot"]


def test_exhausted_retries_raise_with_complete_attempt_log():
    spec, labels, cfgs, shards, ref_json, canned = _grid()
    ch = FakeChannel(canned, {(1, a): "crash" for a in range(1, 4)})
    ex = HostsExecutor(channel=ch, n=2, retries=1, backoff=0.0)
    with pytest.raises(LauncherError, match="retry budget 1 exhausted") \
            as ei:
        ex.execute_with_meta(labels, cfgs, DATA, stack=True)
    assert len(ei.value.attempts) == 2
    assert all(a["status"] == "crash" for a in ei.value.attempts)


def test_mismatched_shard_response_is_a_frame_failure_then_retries():
    """A response claiming the wrong shard id is a 'frame' failure; the
    retry must still converge to parity."""
    spec, labels, cfgs, shards, ref_json, canned = _grid()

    class SwappedOnce(FakeChannel):
        def run(self, slot, request, *, timeout=None, extra_env=None):
            response = super().run(slot, request, timeout=timeout,
                                   extra_env=extra_env)
            if request["shard"] == 0 and \
                    self._attempts[request["shard"]] == 1:
                return dict(response, shard=1)
            return response

    ex = HostsExecutor(channel=SwappedOnce(canned, {}), n=2, retries=1,
                       backoff=0.0)
    results, meta = ex.execute_with_meta(labels, cfgs, DATA, stack=True)
    assert _merge_to_json(spec, labels, results) == ref_json
    assert meta["launcher"]["shards"][0]["attempts"][0]["status"] == "frame"


def test_batch_channel_retries_only_failed_shards():
    """Batch (slurm-shaped) dispatch: a failed shard is re-batched alone;
    already-successful shards are not re-run."""
    spec, labels, cfgs, shards, ref_json, canned = _grid()
    calls = []

    class FakeBatch(HostChannel):
        batch = True

        def run_batch(self, requests, *, timeout=None):
            calls.append([r["shard"] for r in requests])
            outs = []
            for r in requests:
                if r["shard"] == 1 and len(calls) == 1:
                    outs.append(ChannelError("crash", "scripted"))
                else:
                    outs.append(canned[r["shard"]])
            return outs

        def slots(self):
            return ["fake/batch"]

    ex = HostsExecutor(channel=FakeBatch(), n=2, retries=1, backoff=0.0)
    results, meta = ex.execute_with_meta(labels, cfgs, DATA, stack=True)
    assert _merge_to_json(spec, labels, results) == ref_json
    assert calls == [[0, 1], [1]]
    assert [a["status"] for a in
            meta["launcher"]["shards"][1]["attempts"]] == ["crash", "ok"]


# ---------------------------------------------------------------------------
# SweepResult.meta stays out of the parity surface
# ---------------------------------------------------------------------------

def test_sweep_result_meta_excluded_from_json_and_equality():
    spec, labels, cfgs, shards, ref_json, canned = _grid()
    ex = HostsExecutor(channel=FakeChannel(canned, {}), n=2, retries=0)
    results, meta = ex.execute_with_meta(labels, cfgs, DATA, stack=True)
    r = SweepResult(name=spec.name,
                    records=records_from(labels, results))
    r.meta.update(meta)
    assert r.to_json() == ref_json                  # meta never serialized
    assert r == SweepResult.from_json(ref_json)     # nor compared
    with_meta = json.loads(r.to_json(include_meta=True))
    assert with_meta["meta"]["launcher"]["n_shards"] == 2
    assert SweepResult.from_json(
        r.to_json(include_meta=True)).meta["launcher"]["n_shards"] == 2


# ---------------------------------------------------------------------------
# real subprocess faults (the issue's crash test: worker SIGKILLed
# mid-shard) — one worker spawn per attempt, so keep the grid tiny
# ---------------------------------------------------------------------------

def test_local_channel_crash_fault_parity():
    """End to end over real ``local:`` workers with shard 0's first
    attempt SIGKILLed mid-shard (request parsed, dataset decoded, no
    response): the retried shard must restore bitwise parity and the
    attempt log must show crash -> ok."""
    spec, labels, cfgs, shards, ref_json, canned = _grid()
    r = spec.run(DATA,
                 parallel="hosts:channel=local,n=2,retries=1,"
                          "backoff=0.01,inject_kill=0")
    assert r.to_json() == ref_json
    log = r.meta["launcher"]["shards"]
    statuses0 = [a["status"] for a in log[0]["attempts"]]
    assert statuses0 == ["crash", "ok"]
    assert "SIGKILL" in log[0]["attempts"][0]["error"] or \
        "exited" in log[0]["attempts"][0]["error"]
    assert [a["status"] for a in log[1]["attempts"]] == ["ok"]


@pytest.mark.slow
def test_local_channel_clean_parity_both_stack_modes():
    spec, labels, cfgs, shards, ref_json, canned = _grid()
    for stack in ("auto", "off"):
        ref = spec.run(DATA, stack=stack).to_json()
        got = spec.run(DATA, stack=stack,
                       parallel="hosts:channel=local,n=2")
        assert got.to_json() == ref, f"hosts backend drifted (stack={stack})"


@pytest.mark.slow
def test_slurm_bash_simulation_parity(tmp_path):
    """The full slurm file flow with the array simulated locally
    (``submit=bash``): staged request files -> the emitted script's
    file-mode workers -> collected result files -> bitwise merge."""
    spec, labels, cfgs, shards, ref_json, canned = _grid()
    ch = SlurmChannel(array=2, dir=str(tmp_path), submit="bash")
    ex = HostsExecutor(channel=ch, n=2, retries=0, backoff=0.0)
    results, meta = ex.execute_with_meta(labels, cfgs, DATA, stack=True)
    assert _merge_to_json(spec, labels, results) == ref_json
    assert all(a["status"] == "ok"
               for s in meta["launcher"]["shards"] for a in s["attempts"])
    assert os.path.exists(tmp_path / "batch_001" / "launch_array.sh")
