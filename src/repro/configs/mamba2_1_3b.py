"""mamba2-1.3b — attention-free SSM with state-space duality (SSD)
[arXiv:2405.21060].

48L, d_model=2048, vocab=50280, ssm_state=128. d_inner = 2*d_model = 4096,
head_dim P=64 => 64 SSD heads. Sub-quadratic: runs long_500k decode.
"""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=64,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=256,
                  conv_width=4),
    tie_embeddings=True,
    supports_long_context=True,
    source="arXiv:2405.21060",
))
