#!/usr/bin/env python
"""Churn-smoke CI gate: battery-driven DC churn must degrade gracefully.

Runs one scenario twice on the fleet engine — without a battery budget and
with a depleting one — and asserts the energy-ledger feedback loop
(DESIGN.md §13) actually closes:

* the battery run emits zero-energy ``churn`` ledger events (mules DO
  deplete at this budget);
* a depleted mule stops accruing collection events from its death window
  on (the ledger shows no ``sensor->SMk`` charge after ``SMk``'s churn
  event) — dead DCs must not keep spending;
* the F1 curve stays finite (the shrinking fleet never poisons the
  model with NaNs) and the run is strictly cheaper than the un-churned
  baseline;
* fleet and scan engines agree bitwise on the churned scenario (curve
  AND ledger) — churn is host-replayed identically by both drivers.

    python scripts/churn_smoke.py --windows 6 --battery-mj 25

Wired into scripts/verify.sh and the CI ``churn-smoke`` step.
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--windows", type=int, default=6)
    ap.add_argument("--battery-mj", type=float, default=25.0)
    ap.add_argument("--algo", default="star")
    ap.add_argument("--tech", default="4g")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.core.scenario import (ScenarioConfig, run_scenario,
                                     validate_config)
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    base_cfg = ScenarioConfig(windows=args.windows, eval_every=1,
                              algo=args.algo, tech=args.tech,
                              seed=args.seed, engine="fleet")
    churn_cfg = dataclasses.replace(base_cfg, battery_mj=args.battery_mj)
    for cfg in (base_cfg, churn_cfg):
        validate_config(cfg)

    base = run_scenario(base_cfg, data)
    churned = run_scenario(churn_cfg, data)

    rc = 0
    churn_events = [e for e in churned.ledger.events
                    if e["purpose"] == "churn"]
    if not churn_events:
        print(f"FAIL: battery {args.battery_mj} mJ over {args.windows} "
              f"windows depleted no mule — the feedback loop never fired")
        rc = 1
    if any(e["mj"] != 0.0 for e in churn_events):
        print("FAIL: churn events must be zero-energy ledger markers")
        rc = 1

    # dead DCs stop accruing: no collection charge at or after the death
    # window (collection events are per-window, in window order)
    deaths = {}
    for e in churn_events:
        name, w = e["what"].split(" depleted@w")
        deaths[name] = int(w)
    for name, died_at in sorted(deaths.items()):
        seen = sum(1 for e in churned.ledger.events
                   if e["what"] == f"sensor->{name}")
        if seen > died_at:
            print(f"FAIL: {name} depleted at window {died_at} but has "
                  f"{seen} collection charges — dead DCs keep spending")
            rc = 1

    if not all(math.isfinite(v) for v in churned.f1_curve):
        print(f"FAIL: non-finite F1 under churn: {churned.f1_curve}")
        rc = 1
    if not churned.energy_total < base.energy_total:
        print(f"FAIL: churned run spent {churned.energy_total:.1f} mJ, "
              f"baseline {base.energy_total:.1f} mJ — depleted mules "
              f"must reduce fleet spend")
        rc = 1

    scan = run_scenario(dataclasses.replace(churn_cfg, engine="scan"),
                        data)
    if scan.f1_curve != churned.f1_curve or \
            scan.ledger.events != churned.ledger.events:
        print("FAIL: scan engine diverges from fleet engine under churn")
        rc = 1

    if rc == 0:
        print(f"churn smoke: OK ({len(deaths)} mule(s) depleted "
              f"{sorted(deaths)}, energy {churned.energy_total:.1f} < "
              f"{base.energy_total:.1f} mJ, final F1 "
              f"{churned.f1_curve[-1]:.3f}, scan==fleet bitwise)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
