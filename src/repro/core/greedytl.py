"""GreedyTL — transfer learning through greedy source selection
(Kuzborskij, Orabona, Caputo, ICIAP 2015 [28] / CVIU 2017 [37]).

The paper (Section 4, Step 2) describes it as solving "an optimisation
problem to find the linear combination of models m(0) which maximises the
prediction accuracy with respect to the local dataset". We implement exactly
that, in two regularized-least-squares stages, both gated by the closed-form
leave-one-out (LOO) error — the selection criterion of [28]:

* **Stage 1 — greedy source combination.** Candidate pool = source
  hypotheses; each source j enters with a single scalar coefficient alpha_j
  shared across classes (this preserves the source's cross-class calibration
  — the multiclass adaptation of the binary algorithm in [28]). Exact greedy
  forward selection: at every step each remaining source is trial-added and
  the LOO error of the joint ridge recomputed; the best is kept only if it
  improves.
* **Stage 2 — local correction.** A per-class ridge over the original
  features fits the residual; it is kept only if it improves the stacked LOO
  error (with few local samples it usually is not — which is exactly why
  GreedyTL works with 2-10 points per class, paper Section 7).

Because the base hypotheses are linear (paper: linear SVM), the result
collapses EXACTLY into one linear model:

    w_eff = sum_j (alpha_j / s_j) W_src_j + W_correction (+ biases)

so the deployed model is identical to the fitted one, the on-wire model size
stays constant, and the paper's Step-4 averaging is well-posed.

**Factorized LOO (DESIGN.md §4).** Every ridge here is solved through one
masked Cholesky factor G = LLᵀ of the column-masked Gram system — never
``jnp.linalg.inv``. Trial scoring in the greedy loop reuses the factor of
the *current* active set across all M candidates via the bordering identity
(Schur complement of the added row/column), which drops per-candidate cost
from O(D³) to O(D²) and collapses the whole trial sweep into one fused
kernel launch (``repro.kernels.loo_trials``; pure-jnp fallback on CPU).

**Incremental factor carry (DESIGN.md §11).** The greedy loop never
refactorizes at all: the factor of the active set is carried ACROSS
accepted steps in acceptance-permuted order. Accepting candidate j extends
the carried factor by the bordering column already computed during trial
scoring — c_j = L⁻¹g_j (a column of the carried ``Cc``), Schur pivot d_j —
so the whitened rows ``Ut``, the whitened RHS ``z``, the candidate
borderings ``Cc``, and the base fit/leverage all grow by one O(R) /
O(M) append instead of an O(D³) refactorization plus O(R·D²) re-solve.
All carries are fixed-shape (padded to C + min(k_max, M) active slots), so
``lax.scan``/``shard_map`` engines compile the loop once. The final
coefficients still come from one full masked factorization of the selected
set (one per call, as before), so downstream numerics are unchanged by the
carry. ``incremental=False`` keeps the PR-2 refactorize-per-step loop as
the in-tree oracle for property tests and the before/after benchmark.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.scipy.linalg import solve_triangular

from repro.core.dispatch import count_dispatch
from repro.core.svm import svm_scores
from repro.kernels import ops as kernel_ops


def _chol_masked(AtA, lam_d, cmask):
    """Cholesky factor of the column-masked ridge Gram system.

    Masked-out rows/columns reduce to their diagonal λ (the 0/1 mask zeroes
    every off-diagonal entry), so the factor keeps the same masked sparsity
    and the active block factors independently — no shape change needed.
    """
    cm2 = cmask[:, None] * cmask[None, :]
    return jnp.linalg.cholesky(AtA * cm2 + jnp.diag(lam_d))


def _loo_ridge_chol(AtA, Aty, A_rm, y, rmask, cmask, lam_d):
    """Column-masked ridge + closed-form LOO error from a PRECOMPUTED Gram
    system, via Cholesky. A_rm is the row-masked data (R, D); the O(R D²)
    products AᵀA and Aᵀy are shared across callers instead of rebuilt.

    Returns (loo_sse, coeffs (D,)). The LOO identity uses the whitened rows
    Ut = (L⁻¹ Amᵀ)ᵀ: leverage h_i = ‖u_i‖² and fit ŷ_i = u_iᵀz with
    z = L⁻¹(Aᵀy) — both O(D²) per row, no inverse materialised.
    """
    L = _chol_masked(AtA, lam_d, cmask)
    Am = A_rm * cmask[None, :]
    Ut = solve_triangular(L, Am.T, lower=True).T            # (R, D)
    z = solve_triangular(L, Aty * cmask, lower=True)        # (D,)
    v = solve_triangular(L.T, z, lower=False) * cmask
    resid = (Ut @ z - y) * rmask
    h = jnp.sum(Ut ** 2, axis=-1)
    loo = resid / jnp.maximum(1.0 - h, 0.1)
    return jnp.sum(loo ** 2), v


def _loo_ridge(A, y, rmask, cmask, lam):
    """Ridge with LOO error from raw data. A: (R,D); y: (R,); rmask: (R,);
    cmask: (D,). ``lam`` may be a scalar or a per-column vector (D,).
    Thin Gram-building wrapper over :func:`_loo_ridge_chol` (the Stage-2
    per-class correction shares the factorized path with Stage 1).
    """
    D = A.shape[1]
    A_rm = A * rmask[:, None]
    lam_d = jnp.broadcast_to(lam, (D,)) + 1e-4
    return _loo_ridge_chol(A_rm.T @ A_rm, A_rm.T @ (y * rmask), A_rm, y,
                           rmask, cmask, lam_d)


def _score_trials(AtA, Aty, A_rm, y, rmask, cmask, lam_d, M):
    """LOO SSE of every candidate bordering j < M of the active set cmask.

    Factors the active system once, then scores all M candidates through
    the bordering identity: with c_j = L⁻¹g_j and Schur pivot
    d_j² = (G_jj + λ_j) − ‖c_j‖², the bordered factor extends every shared
    solve by one entry — t_ij = (A_ij − u_iᵀc_j)/d_j — so leverage and fit
    update by rank 1 per row. The (R,M) sweep runs as one fused kernel.
    Candidates already active (or masked) get finite garbage here; the
    greedy loop overwrites them with +inf.
    """
    L = _chol_masked(AtA, lam_d, cmask)
    Am = A_rm * cmask[None, :]
    Ut = solve_triangular(L, Am.T, lower=True).T            # (R, D)
    z = solve_triangular(L, Aty * cmask, lower=True)        # (D,)
    h_base = jnp.sum(Ut ** 2, axis=-1)
    fitted_base = Ut @ z
    Cc = solve_triangular(L, AtA[:, :M] * cmask[:, None], lower=True)
    dsq = jnp.diagonal(AtA)[:M] + lam_d[:M] - jnp.sum(Cc ** 2, axis=0)
    # already-active candidates have a degenerate (≈0) Schur pivot whose
    # rsqrt would blow up; the kernel contract wants dinv=0 for them (their
    # objective then reads as the base set's — still finite, still masked
    # to +inf by the greedy body before argmin)
    dinv = jax.lax.rsqrt(jnp.maximum(dsq, 1e-8)) * (1.0 - cmask[:M])
    zj = (Aty[:M] - Cc.T @ z) * dinv
    return kernel_ops.loo_trials(Ut, Cc, A_rm[:, :M], fitted_base, h_base,
                                 y, rmask, zj, dinv)


def _greedy_select_refactor(AtA, Aty, A_rm, yr, rmask, src_mask, lam_d, *,
                            M: int, C: int, k_max: int):
    """PR-2 greedy source selection: full masked refactorization per step
    (``_score_trials`` re-factors the active system on every accepted
    candidate). Kept verbatim as the in-tree oracle for the incremental
    carry (property tests + before/after benchmark). Returns (sel, best)."""
    bias_cols = jnp.concatenate([jnp.zeros(M), jnp.ones(C)])

    def cond(state):
        k, sel, best, done = state
        return (~done) & (k < min(k_max, M))

    def body(state):
        k, sel, best, done = state
        cm = jnp.concatenate([sel * src_mask, jnp.ones(C)])
        objs = _score_trials(AtA, Aty, A_rm, yr, rmask, cm, lam_d, M)
        objs = jnp.where((sel > 0) | (src_mask == 0), jnp.inf, objs)
        j = jnp.argmin(objs)
        improved = (objs[j] < best) & ~done
        sel = jnp.where(improved, jnp.where(jnp.arange(M) == j, 1.0, sel),
                        sel)
        return (k + 1, sel, jnp.where(improved, objs[j], best),
                done | ~improved)

    obj0, _ = _loo_ridge_chol(AtA, Aty, A_rm, yr, rmask, bias_cols, lam_d)
    _, sel, best, _ = jax.lax.while_loop(
        cond, body, (0, jnp.zeros(M), obj0, jnp.asarray(False)))
    return sel, best


def _greedy_select_incremental(AtA, Aty, A_rm, yr, rmask, src_mask, lam_d, *,
                               M: int, C: int, k_max: int):
    """Greedy source selection with the factorization CARRIED across steps.

    The active set's Cholesky factor is maintained in acceptance-permuted
    order (bias columns in slots 0..C-1, then accepted sources in the order
    they were accepted) inside fixed-shape padded carries:

        Ut     (R, Dk)  whitened rows  (L⁻¹ A_activeᵀ)ᵀ, zero-padded cols
        Cc     (Dk, M)  candidate borderings L⁻¹ G[active, :M], zero rows
        z      (Dk,)    whitened RHS L⁻¹ (Aᵀy)_active
        fitted (R,)     active-set fit   Ut z
        h      (R,)     active-set leverage ‖u_i‖²

    with Dk = C + min(k_max, M). Per step, the Schur pivots d_j and
    bordered RHS z_j come straight from the carries (no factorization), the
    M-candidate sweep runs as one ``loo_trials`` kernel launch, and
    accepting j appends the bordering column t_j = (A_:j − Ut c_j)/d_j to
    ``Ut``, the row (G_j: − c_jᵀCc)/d_j to ``Cc``, and z_j to ``z`` — the
    exact forward-substitution rows a from-scratch factor of the grown set
    would produce (DESIGN.md §11). No downdates are ever needed: the loop
    only accepts (it exits on the first non-improving step), so the active
    set grows monotonically. Returns (sel, best)."""
    R = A_rm.shape[0]
    Kmax = min(k_max, M)
    Dk = C + Kmax

    # bias-only seed factor (the initial active set), permuted to the front
    Lb = jnp.linalg.cholesky(AtA[M:, M:] + jnp.diag(lam_d[M:]))
    Utb = solve_triangular(Lb, A_rm[:, M:].T, lower=True).T      # (R, C)
    zb = solve_triangular(Lb, Aty[M:], lower=True)               # (C,)
    Ccb = solve_triangular(Lb, AtA[M:, :M], lower=True)          # (C, M)

    Ut0 = jnp.zeros((R, Dk)).at[:, :C].set(Utb)
    Cc0 = jnp.zeros((Dk, M)).at[:C].set(Ccb)
    z0 = jnp.zeros((Dk,)).at[:C].set(zb)
    fitted0 = Utb @ zb
    h0 = jnp.sum(Utb ** 2, axis=-1)
    resid0 = (fitted0 - yr) * rmask
    obj0 = jnp.sum((resid0 / jnp.maximum(1.0 - h0, 0.1)) ** 2)
    diagG = jnp.diagonal(AtA)[:M] + lam_d[:M]

    def cond(state):
        k, sel, best, done = state[:4]
        return (~done) & (k < Kmax)

    def body(state):
        k, sel, best, done, Ut, Cc, z, fitted, h = state
        active = sel * src_mask
        dsq = diagG - jnp.sum(Cc ** 2, axis=0)
        dinv = jax.lax.rsqrt(jnp.maximum(dsq, 1e-8)) * (1.0 - active)
        zj = (Aty[:M] - Cc.T @ z) * dinv
        objs = kernel_ops.loo_trials(Ut, Cc, A_rm[:, :M], fitted, h, yr,
                                     rmask, zj, dinv)
        objs = jnp.where((sel > 0) | (src_mask == 0), jnp.inf, objs)
        j = jnp.argmin(objs)
        improved = (objs[j] < best) & ~done
        # border append at the next free slot (every prior step accepted,
        # or the loop would already have exited)
        slot = C + k
        tcol = (A_rm[:, j] - Ut @ Cc[:, j]) * dinv[j]            # (R,)
        ccrow = (AtA[j, :M] - Cc.T @ Cc[:, j]) * dinv[j]         # (M,)
        pick = lambda new, old: jnp.where(improved, new, old)
        return (k + 1,
                pick(sel.at[j].set(1.0), sel),
                pick(objs[j], best),
                done | ~improved,
                pick(Ut.at[:, slot].set(tcol), Ut),
                pick(Cc.at[slot].set(ccrow), Cc),
                pick(z.at[slot].set(zj[j]), z),
                pick(fitted + tcol * zj[j], fitted),
                pick(h + tcol * tcol, h))

    state0 = (0, jnp.zeros(M), obj0, jnp.asarray(False),
              Ut0, Cc0, z0, fitted0, h0)
    out = jax.lax.while_loop(cond, body, state0)
    return out[1], out[2]


def _greedytl(x, y, mask, src_w, src_mask, *, num_classes: int,
              lam_src: float = 0.1, lam_x: float = 10.0,
              lam_bias: float = 2.0, k_max: int = 16,
              incremental: bool = True):
    """Unjitted GreedyTL core — also the map target of the fleet refiner."""
    n, F = x.shape
    M, _, C = src_w.shape
    xm = x * mask[:, None]
    Yoh = (2.0 * jax.nn.one_hot(y, num_classes) - 1.0) * mask[:, None]  # (n,C)

    # source predictions H (M, n, C), normalised per source to unit RMS
    H = jax.vmap(lambda w: svm_scores(w, xm))(src_w) * mask[None, :, None]
    denom = jnp.maximum(1.0, jnp.sum(mask)) * C
    s = jnp.sqrt(jnp.sum(H ** 2, axis=(1, 2)) / denom) + 1e-6    # (M,)
    Hn = H / s[:, None, None]

    # ---- Stage 1: stacked system over (n*C) rows, unknowns = alpha + bias_c
    R = n * C
    A_src = Hn.transpose(1, 2, 0).reshape(R, M)          # (R, M)
    A_bias = jnp.tile(jnp.eye(C), (n, 1))                # (R, C)
    A = jnp.concatenate([A_src, A_bias], axis=1)         # (R, M+C)
    yr = Yoh.reshape(R)
    rmask = jnp.repeat(mask, C)
    lam_vec = jnp.concatenate([jnp.full((M,), lam_src),
                               jnp.full((C,), lam_bias)])

    # Gram system shared by every trial of every greedy step
    A_rm = A * rmask[:, None]
    AtA = A_rm.T @ A_rm
    Aty = A_rm.T @ (yr * rmask)
    lam_d = jnp.broadcast_to(lam_vec, (A.shape[1],)) + 1e-4

    # Early-exit greedy selection: once no trial improves, further steps are
    # provable no-ops, so a while_loop saves the (typically ~4x) dead steps
    # a fixed-length scan would still execute. The incremental path carries
    # the active-set factor across accepted steps; the refactorizing path is
    # the PR-2 oracle.
    select = (_greedy_select_incremental if incremental
              else _greedy_select_refactor)
    sel, _ = select(AtA, Aty, A_rm, yr, rmask, src_mask, lam_d,
                    M=M, C=C, k_max=k_max)

    cm = jnp.concatenate([sel * src_mask, jnp.ones(C)])
    # one full factorization of the SELECTED set per call (not per step)
    # keeps the final coefficients on the exact PR-2 numerical path
    _, v1 = _loo_ridge_chol(AtA, Aty, A_rm, yr, rmask, cm, lam_d)
    alpha = v1[:M] / s                                   # undo normalisation
    bias1 = v1[M:]                                       # (C,)

    w_src_part = jnp.einsum("m,mfc->fc", alpha, src_w)   # (F+1, C)
    w_src_part = w_src_part.at[F].add(bias1)

    # ---- Stage 2: per-class local correction on the residual, LOO-gated
    fitted = jnp.einsum("m,mnc->nc", v1[:M], Hn) + bias1[None, :]
    resid = (Yoh - fitted) * mask[:, None]               # (n, C)

    def fit_class(rc):
        return _loo_ridge(xm, rc, mask, jnp.ones(F), lam_x)

    loo_x, Vx = jax.vmap(fit_class, in_axes=1, out_axes=(0, 0))(resid)
    # gate: correction kept only if summed LOO improves over zero correction
    loo_zero = jnp.sum(resid ** 2)
    keep = jnp.sum(loo_x) < loo_zero
    Vx = jnp.where(keep, Vx.T, 0.0)                      # (F, C)

    w_eff = w_src_part.at[:F].add(Vx)
    return w_eff, sel


@count_dispatch("greedytl")
@partial(jax.jit, static_argnames=("num_classes", "k_max", "incremental"))
def greedytl(x, y, mask, src_w, src_mask, *, num_classes: int,
             lam_src: float = 0.1, lam_x: float = 10.0,
             lam_bias: float = 2.0, k_max: int = 16,
             incremental: bool = True):
    """Greedy source combination + gated local correction (see module doc).

    x: (n, F) padded local data; y: (n,); mask: (n,) row validity.
    src_w: (M, F+1, C) stacked source hypotheses; src_mask: (M,).
    Returns (w_eff (F+1, C), selected (M,) 0/1 source-selection mask).
    ``incremental=False`` selects the PR-2 refactorize-per-step oracle.
    """
    return _greedytl(x, y, mask, src_w, src_mask, num_classes=num_classes,
                     lam_src=lam_src, lam_x=lam_x, lam_bias=lam_bias,
                     k_max=k_max, incremental=incremental)


@count_dispatch("greedytl_fleet")
@partial(jax.jit, static_argnames=("num_classes", "k_max", "incremental"))
def greedytl_fleet(x, y, mask, src_w, src_mask, *, num_classes: int,
                   lam_src: float = 0.1, lam_x: float = 10.0,
                   lam_bias: float = 2.0, k_max: int = 16,
                   incremental: bool = True):
    """GreedyTL at every DC of a padded fleet — ONE dispatch per window.

    x: (L, cap, F); y: (L, cap); mask: (L, cap). The source pool
    src_w (M, F+1, C) / src_mask (M,) is SHARED across the fleet (paper
    Algorithm 1: every DC refines against the same m(0) exchange).
    Returns (w_eff (L, F+1, C), selected (L, M)).

    Uses ``lax.map`` rather than ``vmap``: each DC's slice then runs the
    exact per-call computation graph, so the result is bitwise identical to
    L separate :func:`greedytl` calls (the loop engine) — vmap's batched
    linalg is not — while still costing a single dispatch. Padding DCs
    (all-zero masks) leave the greedy while_loop after one step, so they
    are nearly free.
    """
    return jax.lax.map(
        lambda t: _greedytl(t[0], t[1], t[2], src_w, src_mask,
                            num_classes=num_classes, lam_src=lam_src,
                            lam_x=lam_x, lam_bias=lam_bias, k_max=k_max,
                            incremental=incremental),
        (x, y, mask))


@count_dispatch("greedytl_fleet_stacked")
@partial(jax.jit, static_argnames=("num_classes", "k_max", "incremental"))
def greedytl_fleet_stacked(x, y, mask, src_w, src_mask, *, num_classes: int,
                           lam_src: float = 0.1, lam_x: float = 10.0,
                           lam_bias: float = 2.0, k_max: int = 16,
                           incremental: bool = True):
    """GreedyTL over a fleet where every DC carries its OWN source pool.

    Seed-stacked variant of :func:`greedytl_fleet`: several scenario
    replicas' fleets concatenate into one flat DC axis (ROADMAP: batched
    multi-seed rounds), and since each replica's window exchanged different
    base models, the pool gains a leading DC axis. x: (N, cap, F); y/mask:
    (N, cap); src_w: (N, M, F+1, C); src_mask: (N, M).
    Returns (w_eff (N, F+1, C), selected (N, M)).

    ``lax.map`` keeps the per-DC slice graph identical to :func:`greedytl`,
    so results are bitwise equal to N separate calls — one executable
    launch serves every seed replica of a sweep configuration.
    """
    return jax.lax.map(
        lambda t: _greedytl(t[0], t[1], t[2], t[3], t[4],
                            num_classes=num_classes, lam_src=lam_src,
                            lam_x=lam_x, lam_bias=lam_bias, k_max=k_max,
                            incremental=incremental),
        (x, y, mask, src_w, src_mask))
