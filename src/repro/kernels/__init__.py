"""Pallas TPU kernels for the perf-critical compute hot spots.

Each kernel: ``<name>.py`` (pl.pallas_call + explicit BlockSpec VMEM tiling),
``ops.py`` (jit'd wrappers, interpret=True off-TPU), ``ref.py`` (pure-jnp
oracles swept by tests/test_kernels.py).
"""
from repro.kernels import ops, ref  # noqa: F401
