from repro.sharding.partitioning import (  # noqa: F401
    DEFAULT_RULES,
    MULTIPOD_RULES,
    ParamSpec,
    batch_axes,
    init_params,
    logical_to_pspec,
    param_pspecs,
    param_shape_structs,
    template_bytes,
)
