import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax-touching import: jax locks the
# device count on first backend initialisation. Only the dry-run uses 512
# placeholder host devices; smoke tests and benchmarks see the real 1.

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) combo.

For each combo this proves the distribution config is coherent — sharding
resolves, collectives lower, and the compiled module reports memory and cost
analysis — without any real hardware. Results are cached as JSON under
``results/dryrun/`` (one file per combo, resumable).

Usage:
    python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --mesh pod1
    python -m repro.launch.dryrun --all [--mesh pod1|pod2|both]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import OptimizerConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import arch_for_shape, input_specs, shape_supported
from repro.launch.train import make_train_step
from repro.models.model import build_model
from repro.roofline.analysis import model_flops_for
from repro.sharding.partitioning import use_compute_mesh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _mesh(kind: str):
    return make_production_mesh(multi_pod=(kind == "pod2"))


def run_combo(arch: str, shape_name: str, mesh_kind: str,
              out_dir: str = RESULTS_DIR, save_hlo: bool = False,
              weight_stationary_decode: bool = False) -> dict:
    shape = INPUT_SHAPES[shape_name]
    cfg0 = get_config(arch)
    ok, reason = shape_supported(cfg0, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "status": "skipped", "reason": reason}
    if not ok:
        return rec

    cfg = arch_for_shape(cfg0, shape)
    if os.environ.get("REPRO_CP_ATTN"):
        import dataclasses
        cfg = dataclasses.replace(cfg, context_parallel_attention=True)
        rec["context_parallel_attention"] = True
    if os.environ.get("REPRO_REMAT"):
        import dataclasses
        cfg = dataclasses.replace(cfg, remat=os.environ["REPRO_REMAT"])
        rec["remat"] = cfg.remat
    if os.environ.get("REPRO_EXPERT_PARALLEL"):
        import dataclasses
        cfg = dataclasses.replace(
            cfg, expert_parallel=os.environ["REPRO_EXPERT_PARALLEL"])
        rec["expert_parallel"] = cfg.expert_parallel
    model = build_model(cfg)
    mesh = _mesh(mesh_kind)
    rec["num_devices"] = mesh.size

    specs = input_specs(cfg, shape, mesh, model,
                        weight_stationary_decode=weight_stationary_decode)
    rec["weight_stationary_decode"] = weight_stationary_decode
    t0 = time.time()
    with use_compute_mesh(mesh):
        if shape.kind == "train":
            step = make_train_step(model, OptimizerConfig())
            fn = jax.jit(step, donate_argnums=(0, 1))
            lowered = fn.lower(specs["params"], specs["opt_state"],
                               specs["batch"], specs["step"])
        elif shape.kind == "prefill":
            fn = jax.jit(model.prefill)
            lowered = fn.lower(specs["params"], specs["batch"])
        else:
            fn = jax.jit(model.decode_step, donate_argnums=(1,))
            lowered = fn.lower(specs["params"], specs["cache"],
                               specs["batch"]["tokens"],
                               specs["batch"]["pos"])
        rec["lower_s"] = time.time() - t0

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = time.time() - t1

    # ---- memory analysis -------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(ma, k)}
    except Exception as e:            # noqa: BLE001
        rec["memory_error"] = str(e)

    # ---- cost analysis -----------------------------------------------------
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["flops"] = float(ca.get("flops", 0.0))
        rec["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
        rec["transcendentals"] = float(ca.get("transcendentals", 0.0))
    except Exception as e:            # noqa: BLE001
        rec["cost_error"] = str(e)

    # ---- FLOPs / bytes / collectives from the partitioned HLO --------------
    # (cost_analysis does not multiply while-body costs by trip count, so the
    # roofline uses our own HLO walk; both are recorded.)
    try:
        from repro.roofline.hlo import analyze_hlo
        hlo = compiled.as_text()
        ana = analyze_hlo(hlo)
        rec["hlo_flops"] = ana["flops"]
        rec["hlo_bytes_accessed"] = ana["bytes"]
        rec["collectives"] = ana["collectives"]
        rec["hlo_text_bytes"] = len(hlo)
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(
                    out_dir, f"{arch}_{shape_name}_{mesh_kind}.hlo"),
                    "w") as f:
                f.write(hlo)
    except Exception as e:            # noqa: BLE001
        rec["hlo_error"] = str(e)

    rec["model_flops"] = model_flops_for(cfg, shape)
    rec["param_count"] = cfg.param_count()
    rec["active_param_count"] = cfg.active_param_count()
    rec["status"] = "ok"
    return rec


def _result_path(out_dir, arch, shape, mesh_kind):
    return os.path.join(out_dir, f"{arch}_{shape}_{mesh_kind}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--ws-decode", action="store_true",
                    help="§Perf: weight-stationary decode sharding")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
    if args.all:
        combos = [(a, s, m) for a in ALL_ARCHS for s in INPUT_SHAPES
                  for m in meshes]
    else:
        combos = [(args.arch, args.shape, m) for m in meshes]

    failures = 0
    for arch, shape, mesh_kind in combos:
        path = _result_path(args.out, arch, shape, mesh_kind)
        if os.path.exists(path) and not args.force:
            print(f"[skip cached] {arch} x {shape} x {mesh_kind}")
            continue
        print(f"[run ] {arch} x {shape} x {mesh_kind} ...", flush=True)
        t0 = time.time()
        try:
            rec = run_combo(arch, shape, mesh_kind, args.out, args.save_hlo,
                            weight_stationary_decode=args.ws_decode)
        except Exception:             # noqa: BLE001
            rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "status": "error", "traceback": traceback.format_exc()}
            failures += 1
        rec["wall_s"] = time.time() - t0
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        msg = rec["status"]
        if rec["status"] == "ok":
            msg += (f" lower={rec['lower_s']:.1f}s "
                    f"compile={rec['compile_s']:.1f}s "
                    f"flops={rec.get('flops', 0):.3g} "
                    f"coll={rec.get('collectives', {}).get('total_bytes', 0):.3g}B")
        elif rec["status"] == "error":
            msg += "\n" + rec["traceback"].splitlines()[-1]
        print(f"[done] {arch} x {shape} x {mesh_kind}: {msg}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
