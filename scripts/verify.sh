#!/usr/bin/env bash
# Tier-1 verification: test suite + parity/fault gates + benchmark smoke.
#
# Usage: scripts/verify.sh [--fast] [--units|--gates|--bench]
#   --fast    deselect @slow tests
#   --units   only the unit/property test pass (gate files excluded —
#             they run once, in the gates phase, not twice)
#   --gates   only the explicit CI gates (dispatch/experiment/parallel/
#             launcher suites + the parity and fault-injection scripts)
#   --bench   only the benchmark smoke
# Default (no phase flag) runs all three phases in order. The CI matrix
# (.github/workflows/ci.yml) runs the phases as parallel jobs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MODE=all
MARK=()
for arg in "$@"; do
    case "$arg" in
        --fast) MARK=(-m "not slow") ;;
        --units|--gates|--bench) MODE="${arg#--}" ;;
        *) echo "unknown argument: $arg" >&2; exit 2 ;;
    esac
done

# Files re-run explicitly by the gates phase; the units pass excludes
# them so a full verify.sh executes every test file exactly once.
GATE_FILES=(
    tests/test_dispatch_gate.py
    tests/test_experiment.py
    tests/test_parallel_sweep.py
    tests/test_golden_tables.py
    tests/test_launcher.py
)

if [[ "$MODE" == "all" || "$MODE" == "units" ]]; then
    IGNORES=()
    for f in "${GATE_FILES[@]}"; do IGNORES+=("--ignore=$f"); done
    python -m pytest -x -q "${MARK[@]}" "${IGNORES[@]}"
fi

if [[ "$MODE" == "all" || "$MODE" == "gates" ]]; then
    # dispatch-count regression gate (O(1) jitted dispatches per window)
    # + experiment-API gate (SweepSpec preset == legacy grid, JSON
    # round-trip)
    python -m pytest -q "${MARK[@]}" tests/test_dispatch_gate.py \
        tests/test_experiment.py
    # parallel-sweep + hosts-launcher gates: partitioner/backend/golden
    # suites and the launcher retry/crash suite (slow members — clean
    # hosts parity, slurm bash-sim, fake-device subprocess — run here
    # too unless --fast, matching the old full-suite coverage)
    python -m pytest -q "${MARK[@]}" tests/test_parallel_sweep.py \
        tests/test_golden_tables.py tests/test_launcher.py
    # sharded-run parity under 8 fake CPU devices: a parallel run must
    # reproduce the sequential SweepResult bitwise (the flag must precede
    # jax init, so the gate owns its process; DESIGN.md §7)
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        python scripts/parallel_parity.py --preset smoke --windows 4 \
        --expect-devices 8 --backends devices:n=8,processes:n=2
    # multi-host launcher parity, clean AND with one local worker
    # SIGKILLed mid-shard on its first attempt (DESIGN.md §8)
    python scripts/hosts_parity.py --preset smoke --windows 3 \
        --spec "hosts:channel=local,n=2,retries=1" --inject-failures
    # sweep-service parity: sweeps submitted over HTTP stream per-shard
    # NDJSON and merge client-side — bitwise-identical to sequential,
    # clean, with one worker SIGKILLed mid-shard, and served from the
    # exact result cache (DESIGN.md §12). --statsd-e2e additionally
    # validates every emitted UDP datagram against the DogStatsD grammar
    python scripts/service_parity.py --preset smoke --windows 3 \
        --spec "hosts:channel=local,n=2,retries=1" --inject-failures \
        --statsd-e2e
    python scripts/service_parity.py --preset transport_grid --windows 3 \
        --spec "hosts:channel=inline,n=2,retries=1" --statsd-e2e
    # scan-engine parity: the scan-over-windows engine's SweepResult JSON
    # must be byte-identical to the sequential fleet engine (DESIGN.md §10)
    python scripts/scan_parity.py --preset smoke --windows 4
    python scripts/scan_parity.py --preset transport_grid --windows 5
    # city-smoke: the 10^5-DC city preset on 8 fake CPU devices, peak
    # memory flat in the window count (DESIGN.md §10)
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        python scripts/city_smoke.py --fleet-size 100000 --windows 6 \
        --baseline-windows 2 --expect-devices 8
    # churn-smoke: battery-driven DC churn degrades gracefully — depleted
    # mules stop accruing ledger events, F1 stays finite, scan==fleet
    # bitwise under churn (DESIGN.md §13)
    python scripts/churn_smoke.py --windows 6 --battery-mj 25
    # pareto-smoke: successive-halving search recovers the exhaustive
    # frontier exactly, and the frontier metrics are bitwise a plain
    # SweepSpec.run of the frontier configs (DESIGN.md §14)
    python scripts/pareto_smoke.py --windows 6 --seeds 1
fi

if [[ "$MODE" == "all" || "$MODE" == "bench" ]]; then
    python -m benchmarks.run --quick --skip-tables
fi
