"""Cost-accuracy Pareto-front search with successive-halving pruning.

The paper's headline — up to 94% energy saved for <=2% accuracy lost —
was found by hand-enumerating transport/placement configurations
(Tables 2-6); Valerio et al. (PAPERS.md) formalize it as a cost-accuracy
trade-off to be *searched*. This module is that search (DESIGN.md §14):
candidates come from any :class:`~repro.core.experiment.SweepSpec` grid,
evaluate through the ordinary executor machinery (so stack-compatible
configs run replica-stacked in lockstep, and any ``parallel`` backend —
devices/processes/hosts — applies), and are pruned rung by rung:

* **dominance** — ``a`` dominates ``b`` on (F1 up, energy_mJ down) iff
  ``a`` is no worse on both axes and strictly better on at least one.
  With *slack* the strictly-better clause needs a margin (``f1_slack``
  absolute F1, ``energy_slack`` relative energy), so slack > 0 prunes
  *less*: a candidate survives unless someone beats it clearly. Slack
  dominance is irreflexive, asymmetric and transitive for any slacks
  (property-tested in tests/test_pareto.py); slack 0 is exact Pareto
  dominance.
* **successive halving** — rung ``r`` of ``R`` evaluates the survivors
  at ``windows / eta**(R-1-r)`` windows (floored at ``min_windows``)
  and a matching fraction of the seed axis, discards at most
  ``(1-keep)`` of them (the most-dominated first; ``keep=1.0`` prunes
  nothing, making the search exhaustive), and promotes the rest. The
  final rung always runs the full budget.
* **bitwise frontier** — after the final rung picks the exact
  (slack-free) frontier, the frontier configs are rerun as a literal
  frontier-only :class:`SweepSpec` (:func:`frontier_spec`) through the
  same executor/stack mode. That rerun IS "a plain ``SweepSpec.run`` of
  the frontier configs", so the reported frontier numbers are
  bitwise-identical to one by construction — the property
  scripts/pareto_smoke.py gates, like every engine before it.

Searches are addressed by the shared spec-string grammar
(:func:`get_search`): ``"halving:rungs=3,keep=0.5"``,
``"exhaustive"`` — which is how the sweep service serves searches
through the PR-8 control plane (``POST /v1/jobs`` with a ``"search"``
key; rung progress streams as NDJSON ``rung`` events).
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

from repro.core.experiment import (LABEL_AXIS, SweepResult, SweepSpec,
                                   records_from)
from repro.core.registry import format_spec, register_factory, resolve_spec
from repro.core.scenario import ScenarioConfig, validate_config


class SearchCancelled(RuntimeError):
    """The search's stop event was set between rungs (job cancellation —
    the sweep service maps this to the ``cancelled`` job state)."""


# ---------------------------------------------------------------------------
# dominance
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParetoPoint:
    """One candidate's aggregated metrics (the two search objectives are
    ``f1`` and ``energy_mj``; the rest ride along for the table)."""
    label: str
    f1: float
    energy_mj: float
    f1_std: float = 0.0
    collection_mj: float = 0.0
    learning_mj: float = 0.0

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def dominates(a: ParetoPoint, b: ParetoPoint, *, f1_slack: float = 0.0,
              energy_slack: float = 0.0) -> bool:
    """``a`` dominates ``b``: no worse on both axes, better by the slack
    margin on at least one. ``f1_slack`` is an absolute F1 margin;
    ``energy_slack`` a relative energy margin (``a`` must undercut
    ``b``'s energy by that fraction). Both margin clauses stay *strict*
    at their floor, so ties never dominate each other and the relation
    is a strict partial order for any slack values."""
    if f1_slack < 0 or energy_slack < 0:
        raise ValueError(f"slacks must be >= 0, got f1_slack={f1_slack} "
                         f"energy_slack={energy_slack}")
    if not (a.f1 >= b.f1 and a.energy_mj <= b.energy_mj):
        return False
    better_f1 = (a.f1 >= b.f1 + f1_slack) if f1_slack > 0 else a.f1 > b.f1
    better_energy = (a.energy_mj < b.energy_mj
                     and (energy_slack == 0
                          or a.energy_mj <= b.energy_mj
                          * (1.0 - energy_slack)))
    return better_f1 or better_energy


def pareto_frontier(points: Sequence[ParetoPoint], *,
                    f1_slack: float = 0.0,
                    energy_slack: float = 0.0) -> List[ParetoPoint]:
    """The non-dominated subset, input order preserved. With slacks the
    frontier is a *superset* of the exact one (harder to dominate)."""
    return [p for p in points
            if not any(dominates(q, p, f1_slack=f1_slack,
                                 energy_slack=energy_slack)
                       for q in points if q.label != p.label)]


def point_from_summary(label: str, summary: Mapping[str, Any]
                       ) -> ParetoPoint:
    """A :class:`ParetoPoint` from ``SweepResult.summary(label)``."""
    return ParetoPoint(label=label, f1=summary["f1"],
                       energy_mj=summary["energy_mj"],
                       f1_std=summary["f1_std"],
                       collection_mj=summary["collection_mj"],
                       learning_mj=summary["learning_mj"])


# ---------------------------------------------------------------------------
# spec surgery: rung budgets and the frontier-only spec
# ---------------------------------------------------------------------------

def _row_spec(label: str, cfg: ScenarioConfig) -> SweepSpec:
    """A single-row spec with an *explicit* label (the ``_label`` zip
    axis, so labels containing ``{}`` never hit str.format)."""
    return SweepSpec(name=label, base=cfg, mode="zip",
                     axes={LABEL_AXIS: (label,)})


def subset_spec(name: str, rows: Sequence[Tuple[str, ScenarioConfig]],
                seeds: Sequence[int] = ()) -> SweepSpec:
    """A literal :class:`SweepSpec` expanding to exactly ``rows`` (in
    order) replicated over ``seeds`` — the shape both the rung specs and
    the frontier rerun use, so "what the search ran" is always equal to
    "a plain spec of those rows" by construction."""
    if not rows:
        raise ValueError(f"subset spec {name!r} needs at least one row")
    return SweepSpec.union(name, *[_row_spec(lbl, cfg)
                                   for lbl, cfg in rows],
                           seeds=tuple(seeds))


def frontier_spec(spec: SweepSpec,
                  labels: Sequence[str]) -> SweepSpec:
    """The frontier-only spec: ``spec``'s rows restricted to ``labels``
    (row order preserved), same seeds, full budget. Running this through
    ``SweepSpec.run`` reproduces ``ParetoResult.frontier_result``
    bitwise — the pareto-smoke gate's surface."""
    want = set(labels)
    rows = [(lbl, cfg) for lbl, cfg in spec.rows() if lbl in want]
    missing = want - {lbl for lbl, _ in rows}
    if missing:
        raise KeyError(f"labels {sorted(missing)} are not rows of "
                       f"spec {spec.name!r}")
    return subset_spec(f"{spec.name}_frontier", rows, seeds=spec.seeds)


# ---------------------------------------------------------------------------
# ParetoResult
# ---------------------------------------------------------------------------

@dataclass
class ParetoResult:
    """A search's structured output (JSON round-trips like
    :class:`SweepResult`):

    * ``frontier`` — the exact Pareto front at full budget, row order;
      metrics come from ``frontier_result`` (the bitwise surface).
    * ``frontier_result`` — the frontier rerun's :class:`SweepResult`;
      its ``to_json()`` is byte-identical to
      ``frontier_spec(spec, labels).run(data, ...)``.
    * ``ledger`` — per-candidate audit: final status
      (``frontier`` | ``dominated`` | ``pruned``), which rung pruned it,
      who dominated it, per-rung metrics.
    * ``schedule`` — per-rung budgets and survivor/pruned counts.
    * ``cost`` — window-evaluations spent vs the exhaustive grid.

    ``meta`` is the out-of-band side channel (excluded from equality and
    JSON), matching ``SweepResult.meta``."""
    name: str
    search: str
    frontier: List[ParetoPoint]
    frontier_result: SweepResult
    ledger: List[Dict[str, Any]]
    schedule: List[Dict[str, Any]]
    cost: Dict[str, Any]
    meta: Dict[str, Any] = field(default_factory=dict, compare=False,
                                 repr=False)
    SCHEMA = 1

    def frontier_labels(self) -> List[str]:
        return [p.label for p in self.frontier]

    def dominated_counts(self) -> Dict[str, int]:
        """How many candidates each ledger status absorbed — the
        one-line audit of where the grid went."""
        out: Dict[str, int] = {}
        for entry in self.ledger:
            out[entry["status"]] = out.get(entry["status"], 0) + 1
        return out

    def to_json(self, path: Optional[str] = None, *,
                indent: int = 1) -> str:
        payload = {
            "schema": self.SCHEMA,
            "name": self.name,
            "search": self.search,
            "frontier": [p.as_dict() for p in self.frontier],
            "frontier_result": json.loads(self.frontier_result.to_json()),
            "ledger": self.ledger,
            "schedule": self.schedule,
            "cost": self.cost,
        }
        text = json.dumps(payload, indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    @classmethod
    def from_json(cls, text: str) -> "ParetoResult":
        payload = json.loads(text)
        if payload.get("schema") != cls.SCHEMA:
            raise ValueError(f"unsupported ParetoResult schema "
                             f"{payload.get('schema')!r} (this build "
                             f"reads {cls.SCHEMA})")
        return cls(
            name=payload["name"],
            search=payload["search"],
            frontier=[ParetoPoint(**p) for p in payload["frontier"]],
            frontier_result=SweepResult.from_json(
                json.dumps(payload["frontier_result"])),
            ledger=list(payload["ledger"]),
            schedule=list(payload["schedule"]),
            cost=dict(payload["cost"]))

    @classmethod
    def load(cls, path: str) -> "ParetoResult":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# successive halving
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HalvingSearch:
    """Successive halving over a sweep grid (module docstring; spec form
    ``halving:rungs=R,keep=F,eta=E,f1_slack=A,energy_slack=B,
    min_windows=W``). ``rungs=1`` (or the ``exhaustive`` alias) is one
    full-budget rung over every candidate — plain exhaustive search."""
    rungs: int = 3
    keep: float = 0.5
    eta: float = 2.0
    f1_slack: float = 0.02
    energy_slack: float = 0.05
    min_windows: int = 2

    def __post_init__(self):
        object.__setattr__(self, "keep", float(self.keep))
        object.__setattr__(self, "eta", float(self.eta))
        object.__setattr__(self, "f1_slack", float(self.f1_slack))
        object.__setattr__(self, "energy_slack", float(self.energy_slack))
        if self.rungs < 1:
            raise ValueError(f"rungs must be >= 1, got {self.rungs}")
        if not 0.0 < self.keep <= 1.0:
            raise ValueError(f"keep must be in (0, 1], got {self.keep}")
        if self.eta <= 1.0:
            raise ValueError(f"eta must be > 1, got {self.eta}")
        if self.min_windows < 1:
            raise ValueError(f"min_windows must be >= 1, got "
                             f"{self.min_windows}")
        if self.f1_slack < 0 or self.energy_slack < 0:
            raise ValueError(f"slacks must be >= 0, got "
                             f"f1_slack={self.f1_slack} "
                             f"energy_slack={self.energy_slack}")

    @property
    def spec(self) -> str:
        """Canonical spec string — the cache-key component, so any
        spelling that parses to the same parameters keys identically."""
        return format_spec("halving", {
            "rungs": self.rungs, "keep": self.keep, "eta": self.eta,
            "f1_slack": self.f1_slack, "energy_slack": self.energy_slack,
            "min_windows": self.min_windows})

    # -- rung budgets --------------------------------------------------------
    def rung_windows(self, full_windows: int, rung: int) -> int:
        """Window budget at ``rung``: full budget shrunk by
        ``eta**(rungs-1-rung)``, floored at ``min_windows`` and capped
        at the full budget (the final rung is always the full budget)."""
        shrink = self.eta ** (self.rungs - 1 - rung)
        return min(full_windows,
                   max(self.min_windows,
                       math.ceil(full_windows / shrink)))

    def rung_seeds(self, seeds: Tuple[int, ...],
                   rung: int) -> Tuple[int, ...]:
        """Seed budget at ``rung``: the first ``ceil(n/shrink)`` seeds
        (prefixes, so later rungs strictly extend earlier ones). A
        seedless spec stays seedless at every rung."""
        if not seeds:
            return ()
        shrink = self.eta ** (self.rungs - 1 - rung)
        return seeds[:max(1, math.ceil(len(seeds) / shrink))]

    def _rung_rows(self, rows: Sequence[Tuple[str, ScenarioConfig]],
                   rung: int) -> List[Tuple[str, ScenarioConfig]]:
        out = []
        for lbl, cfg in rows:
            w = self.rung_windows(cfg.windows, rung)
            out.append((lbl, dataclasses.replace(
                cfg, windows=w, eval_every=min(cfg.eval_every, w))))
        return out

    # -- execution -----------------------------------------------------------
    def run(self, spec: SweepSpec, data: Any, *, stack: str = "auto",
            parallel: Any = "none",
            on_rung: Optional[Callable[[Dict[str, Any]], None]] = None,
            stop: Any = None) -> ParetoResult:
        """Search ``spec``'s grid. ``parallel`` is an executor spec
        string or an already-built executor (the sweep service passes
        its fresh per-job :class:`HostsExecutor`, so fault-injection
        parameters never leak through the shared executor cache).
        ``on_rung`` fires after each rung with the rung record the
        schedule keeps (the service streams these as NDJSON events);
        ``stop`` is an optional :class:`threading.Event` checked between
        rungs (and passed through to executors that accept it) —
        cancellation raises :class:`SearchCancelled`."""
        if stack not in ("auto", "off"):
            raise ValueError(f"stack must be 'auto' or 'off', got "
                             f"{stack!r}")
        if hasattr(parallel, "execute_with_meta"):
            executor = parallel
        else:
            from repro.core.parallel import get_executor
            executor = get_executor(parallel)

        rows = spec.rows()
        seeds = spec.seeds
        survivors = list(rows)
        audit: Dict[str, Dict[str, Any]] = {
            lbl: {"label": lbl, "status": "pruned", "pruned_at_rung": None,
                  "dominated_by": [], "rungs": []} for lbl, _ in rows}
        schedule: List[Dict[str, Any]] = []
        evals_windows = 0

        for rung in range(self.rungs):
            self._check_stop(stop)
            rung_rows = self._rung_rows(survivors, rung)
            rung_seeds = self.rung_seeds(seeds, rung)
            rung_spec = subset_spec(f"{spec.name}@rung{rung}", rung_rows,
                                    seeds=rung_seeds)
            result = self._run_spec(rung_spec, data, stack, executor,
                                    stop)
            n_seed = max(1, len(rung_seeds))
            evals_windows += sum(cfg.windows for _, cfg in rung_rows) \
                * n_seed
            points = {lbl: point_from_summary(lbl, result.summary(lbl))
                      for lbl, _ in rung_rows}
            rung_cfgs = dict(rung_rows)
            for lbl, p in points.items():
                audit[lbl]["rungs"].append({
                    "rung": rung, "windows": rung_cfgs[lbl].windows,
                    "seeds": n_seed, "f1": p.f1,
                    "energy_mj": p.energy_mj})

            final = rung == self.rungs - 1
            pruned_labels: List[str] = []
            if not final:
                pruned_labels = self._prune(list(points.values()), audit,
                                            rung)
                survivors = [(lbl, cfg) for lbl, cfg in survivors
                             if lbl not in set(pruned_labels)]
            record = {
                "rung": rung,
                "windows": max(cfg.windows for _, cfg in rung_rows),
                "seeds": n_seed,
                "candidates": len(rung_rows),
                "pruned": len(pruned_labels),
                "pruned_labels": pruned_labels,
                "survivors": [lbl for lbl, _ in survivors],
            }
            schedule.append(record)
            if on_rung is not None:
                on_rung(dict(record))

        # exact frontier at full budget, decided on the final rung's
        # metrics; then the bitwise rerun of just the frontier rows
        final_points = [points[lbl] for lbl, _ in survivors]
        front = pareto_frontier(final_points)
        front_labels = [p.label for p in front]
        for p in final_points:
            entry = audit[p.label]
            if p.label in front_labels:
                entry["status"] = "frontier"
            else:
                entry["status"] = "dominated"
                entry["dominated_by"] = [q.label for q in final_points
                                         if dominates(q, p)]

        self._check_stop(stop)
        front_rows = [(lbl, cfg) for lbl, cfg in survivors
                      if lbl in set(front_labels)]
        fspec = subset_spec(f"{spec.name}_frontier", front_rows,
                            seeds=seeds)
        if front_labels == [lbl for lbl, _ in survivors]:
            # the final rung already WAS the frontier-only full-budget
            # spec (identical construction), so its result is the rerun
            frontier_result = SweepResult(name=fspec.name,
                                          records=result.records)
        else:
            frontier_result = self._run_spec(fspec, data, stack,
                                             executor, stop)
            evals_windows += sum(cfg.windows for _, cfg in front_rows) \
                * max(1, len(seeds))

        frontier = [point_from_summary(lbl, frontier_result.summary(lbl))
                    for lbl in front_labels]
        exhaustive = sum(cfg.windows for _, cfg in rows) \
            * max(1, len(seeds))
        cost = {
            "evals_windows": evals_windows,
            "exhaustive_windows": exhaustive,
            "savings_pct": round(100.0 * (1.0 - evals_windows
                                          / exhaustive), 1),
        }
        return ParetoResult(name=spec.name, search=self.spec,
                            frontier=frontier,
                            frontier_result=frontier_result,
                            ledger=[audit[lbl] for lbl, _ in rows],
                            schedule=schedule, cost=cost)

    # -- internals -----------------------------------------------------------
    def _prune(self, points: List[ParetoPoint],
               audit: Dict[str, Dict[str, Any]], rung: int) -> List[str]:
        """Discard slack-dominated candidates, most-dominated first,
        never more than ``(1-keep)`` of the pool. Returns the pruned
        labels (deterministic order)."""
        doms = {p.label: [q.label for q in points
                          if q.label != p.label
                          and dominates(q, p, f1_slack=self.f1_slack,
                                        energy_slack=self.energy_slack)]
                for p in points}
        prunable = sorted((p for p in points if doms[p.label]),
                          key=lambda p: (-len(doms[p.label]), p.f1,
                                         -p.energy_mj, p.label))
        max_prune = len(points) - max(1, math.ceil(self.keep
                                                   * len(points)))
        pruned = prunable[:max_prune]
        for p in pruned:
            audit[p.label]["status"] = "pruned"
            audit[p.label]["pruned_at_rung"] = rung
            audit[p.label]["dominated_by"] = doms[p.label]
        return [p.label for p in pruned]

    @staticmethod
    def _check_stop(stop: Any) -> None:
        if stop is not None and stop.is_set():
            raise SearchCancelled("pareto search cancelled between rungs")

    @staticmethod
    def _run_spec(sub: SweepSpec, data: Any, stack: str, executor: Any,
                  stop: Any) -> SweepResult:
        """Exactly the body of ``SweepSpec.run`` (validate → execute →
        records), with the caller's executor — so every rung result, and
        in particular the frontier rerun, is bitwise what ``sub.run``
        would produce on the same backend."""
        import inspect

        runs = sub.configs()
        for _, cfg in runs:
            validate_config(cfg)
        labels = [lbl for lbl, _ in runs]
        cfgs = [cfg for _, cfg in runs]
        extra: Dict[str, Any] = {}
        if stop is not None and "stop" in inspect.signature(
                executor.execute_with_meta).parameters:
            extra["stop"] = stop
        results, exec_meta = executor.execute_with_meta(
            labels, cfgs, data, stack=(stack == "auto"), **extra)
        out = SweepResult(name=sub.name,
                          records=records_from(labels, results))
        if exec_meta:
            out.meta.update(exec_meta)
        return out


# ---------------------------------------------------------------------------
# search registry (spec-string grammar, DESIGN.md §5)
# ---------------------------------------------------------------------------

SEARCHES: Dict[str, Callable[..., HalvingSearch]] = {}
_SEARCH_CACHE: Dict[str, HalvingSearch] = {}


def register_search(name: str, factory: Callable[..., HalvingSearch]
                    ) -> None:
    register_factory(SEARCHES, name, factory, "search")


def get_search(spec: str) -> HalvingSearch:
    """Resolve a search spec string: ``"halving:rungs=3,keep=0.5"``,
    ``"exhaustive"``. Unknown names/parameters raise ``KeyError``;
    invalid values the constructor's ``ValueError`` — same contract as
    the transport/collection registries."""
    return resolve_spec(spec, SEARCHES, _SEARCH_CACHE, "search")


def _exhaustive(**params: Any) -> HalvingSearch:
    """One full-budget rung over every candidate; extra parameters (the
    slacks are irrelevant here, but accepted) pass through."""
    params.setdefault("rungs", 1)
    params.setdefault("keep", 1.0)
    return HalvingSearch(**params)


register_search("halving", HalvingSearch)
register_search("exhaustive", _exhaustive)
