"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the kernels run compiled (Mosaic); on any
other backend (this CPU container) they run with ``interpret=True`` — the
kernel body executes in Python per grid cell, which is what the correctness
sweeps in tests/test_kernels.py rely on. Model code selects these via
``ModelConfig.attention_impl = 'pallas'``; the dry-run keeps the XLA
reference path because Pallas does not lower to CPU HLO.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import loo_trials as _loo
from repro.kernels import rglru_scan as _rg
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention_bshd(q, k, v, *, causal=True, window=0, q_offset=0):
    """(B,S,H,d) layout wrapper matching `models.blocks.chunked_attention`."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              q_offset=q_offset, interpret=_interpret())
    return out.transpose(0, 2, 1, 3)


def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=128, block_kv=128):
    """(B,H,S,d) layout."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_kv=block_kv, interpret=_interpret())


def ssd_scan(x, dt, A, Bm, Cm, *, chunk=128):
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                         interpret=_interpret())


def rglru_scan(a, b, *, chunk=128, block_w=128):
    return _rg.rglru_scan(a, b, chunk=chunk, block_w=block_w,
                          interpret=_interpret())


def loo_trials(ut, cc, a_cand, fitted_base, h_base, y, rmask, zj, dinv):
    """GreedyTL Cholesky-bordering trial scorer (see kernels.loo_trials).

    Unlike the model kernels above, the non-TPU path here is the pure-jnp
    reference rather than ``interpret=True``: this runs inside GreedyTL's
    greedy while_loop, where interpret mode's Python-per-grid-cell cost
    would dwarf the linalg it fuses. Same contract either way.
    """
    if _interpret():
        return _loo.loo_trials_ref(ut, cc, a_cand, fitted_base, h_base, y,
                                   rmask, zj, dinv)
    return _loo.loo_trials(ut, cc, a_cand, fitted_base, h_base, y, rmask,
                           zj, dinv)
