"""llama3.2-3b — small Llama-3 dense GQA decoder [hf:meta-llama/Llama-3.2-1B].

28L, d_model=3072, 24H GQA kv=8, d_ff=8192, vocab=128256, tied embeddings.

long_500k: the base config is full attention; the dry-run uses a documented
sliding-window variant (window=8192) so this dense arch can also exercise the
long-context decode shape (beyond-paper addition, see DESIGN.md §6).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
    tie_embeddings=True,
    supports_long_context=False,   # variant with sliding_window=8192 runs it
    source="hf:meta-llama/Llama-3.2-1B",
))

LONG_CONTEXT_WINDOW = 8192
