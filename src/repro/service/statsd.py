"""Dependency-free statsd-style fleet-health metrics (DESIGN.md §12).

The sweep service and the multi-host launcher need the usual operational
trio — counters, timers, gauges — without dragging a metrics dependency
into a repo whose hard constraint is "stdlib + the baked-in jax stack".
This module is both halves of statsd in one place:

* **in-process aggregation** — every metric accumulates into a process-
  wide snapshot (:meth:`Statsd.snapshot`), which is what the service's
  ``GET /v1/metrics`` endpoint serves, what the cache hit-rate gate reads
  (scripts/service_parity.py), and what the tests assert against. Timers
  keep count/sum/min/max/last so rates and latency distributions are
  recoverable without storing samples.
* **optional wire emission** — when ``REPRO_STATSD_ADDR=host:port`` is
  set (or an address is passed explicitly), every metric is *also* sent
  as a standard statsd datagram (``name:value|c``, ``|ms``, ``|g``, with
  ``|#k:v`` DogStatsD-style tags) over UDP, fire-and-forget: a real
  statsd/telegraf agent can aggregate a fleet of services with zero code
  change here. Send failures are swallowed — metrics must never take
  down the control plane.

Metric names are dotted paths namespaced by subsystem — the service uses
``service.*`` (jobs, stream, cache hit/miss, queue depth) and the
launcher retry path uses ``launcher.shard.*`` (attempts, ok, failures by
kind, retries, attempt latency); the full catalogue is in DESIGN.md §12.
Tags are rendered into the aggregation key as ``name|k=v,...`` (sorted),
so tagged series stay distinguishable in snapshots too.

All mutation is lock-guarded: the launcher dispatches shards from worker
threads and the HTTP server handles requests from its own thread pool.
"""
from __future__ import annotations

import os
import socket
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, Mapping, Optional

ADDR_ENV = "REPRO_STATSD_ADDR"


def _series(name: str, tags: Optional[Mapping[str, Any]]) -> str:
    if not tags:
        return name
    body = ",".join(f"{k}={tags[k]}" for k in sorted(tags))
    return f"{name}|{body}"


class Statsd:
    """One metrics sink: in-process aggregation + optional UDP emission."""

    def __init__(self, namespace: str = "repro",
                 addr: Optional[str] = None):
        self.namespace = namespace
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._timers: Dict[str, Dict[str, float]] = {}
        self._sock: Optional[socket.socket] = None
        self._target = None
        addr = addr if addr is not None else os.environ.get(ADDR_ENV, "")
        if addr:
            host, _, port = addr.rpartition(":")
            try:
                self._target = (host or "127.0.0.1", int(port))
                self._sock = socket.socket(socket.AF_INET,
                                           socket.SOCK_DGRAM)
            except (ValueError, OSError):
                self._target = self._sock = None

    # -- the three statsd verbs ---------------------------------------------
    def increment(self, name: str, value: float = 1,
                  tags: Optional[Mapping[str, Any]] = None) -> None:
        key = _series(name, tags)
        with self._lock:
            self._counters[key] = self._counters.get(key, 0) + value
        self._emit(name, value, "c", tags)

    def gauge(self, name: str, value: float,
              tags: Optional[Mapping[str, Any]] = None) -> None:
        key = _series(name, tags)
        with self._lock:
            self._gauges[key] = float(value)
        self._emit(name, value, "g", tags)

    def timing(self, name: str, ms: float,
               tags: Optional[Mapping[str, Any]] = None) -> None:
        key = _series(name, tags)
        with self._lock:
            t = self._timers.get(key)
            if t is None:
                t = self._timers[key] = {"count": 0, "sum_ms": 0.0,
                                         "min_ms": float("inf"),
                                         "max_ms": 0.0, "last_ms": 0.0}
            t["count"] += 1
            t["sum_ms"] += ms
            t["min_ms"] = min(t["min_ms"], ms)
            t["max_ms"] = max(t["max_ms"], ms)
            t["last_ms"] = ms
        self._emit(name, ms, "ms", tags)

    @contextmanager
    def timed(self, name: str,
              tags: Optional[Mapping[str, Any]] = None) -> Iterator[None]:
        t0 = time.monotonic()
        try:
            yield
        finally:
            self.timing(name, (time.monotonic() - t0) * 1e3, tags)

    # -- observation --------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """A JSON-safe copy of every series: what ``GET /v1/metrics``
        serves. Timer aggregates gain a derived ``avg_ms``."""
        with self._lock:
            timers = {}
            for key, t in self._timers.items():
                timers[key] = dict(t, avg_ms=t["sum_ms"] / t["count"])
            return {"namespace": self.namespace,
                    "counters": dict(self._counters),
                    "gauges": dict(self._gauges),
                    "timers": timers}

    def counter(self, name: str,
                tags: Optional[Mapping[str, Any]] = None) -> float:
        with self._lock:
            return self._counters.get(_series(name, tags), 0)

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._timers.clear()

    # -- wire emission (optional) -------------------------------------------
    def _emit(self, name: str, value: float, kind: str,
              tags: Optional[Mapping[str, Any]]) -> None:
        if self._sock is None:
            return
        line = f"{self.namespace}.{name}:{value}|{kind}"
        if tags:
            line += "|#" + ",".join(f"{k}:{tags[k]}" for k in sorted(tags))
        try:
            self._sock.sendto(line.encode("ascii", "replace"),
                              self._target)
        except OSError:
            pass                 # fire-and-forget: never fail the caller


# The process-wide default sink, shared by the service, the launcher retry
# path and the benchmarks; tests needing isolation construct their own
# Statsd or call reset().
statsd = Statsd()
