"""The paper's primary contribution: HTL-based distributed learning with
energy accounting (faithful layer), plus the datacenter-scale hypothesis-
transfer trainer (`htl_trainer`, the TPU-native adaptation — DESIGN.md §3).
"""
from repro.core.energy import (  # noqa: F401
    Ledger,
    TECHS,
    MODEL_BYTES,
    OBS_BYTES,
    resolve_tech,
)
from repro.core.htl import DC, run_window_a2a, run_window_star  # noqa: F401
from repro.core.topology import (  # noqa: F401
    Node,
    Topology,
    TRANSPORT_FACTORIES,
    get_transport,
    register_transport,
    transfer_counts,
)
from repro.core.scenario import (  # noqa: F401
    COLLECTION_POLICIES,
    ScenarioConfig,
    ScenarioResult,
    register_collection_policy,
    run_scenario,
    run_sweep,
)
from repro.core.experiment import (  # noqa: F401
    SweepSpec,
    SweepResult,
    get_preset,
)
