"""Three-term roofline from the compiled dry-run artifact.

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

Hardware constants (TPU v5e-like, per task statement): 197 TFLOP/s bf16 per
chip, 819 GB/s HBM, ~50 GB/s/link ICI. ``MODEL_FLOPS = 6 N D`` (dense; N =
active params for MoE) per training step, ``2 N D`` for inference steps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class Hardware:
    peak_flops: float = 197e12        # bf16 FLOP/s per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    ici_bw: float = 50e9              # bytes/s per link
    dcn_bw: float = 25e9              # bytes/s per chip across pods (est.)


HW = Hardware()


def collective_stats(hlo_text: str) -> dict:
    from repro.roofline.hlo import summarize_collectives
    return summarize_collectives(hlo_text)


def roofline_from_record(rec: dict, hw: Hardware = HW) -> dict:
    """rec: one dry-run JSON record (see launch/dryrun.py)."""
    chips = rec["num_devices"]
    flops = rec.get("flops", 0.0) or 0.0
    bytes_acc = rec.get("bytes_accessed", 0.0) or 0.0
    # HLO walk reports per-device numbers (shapes are post-GSPMD)
    t_compute = flops / hw.peak_flops
    t_memory_hlo = bytes_acc / hw.hbm_bw
    t_memory = rec.get("analytic_bytes", bytes_acc) / hw.hbm_bw
    coll = rec.get("collectives", {})
    ici_b = coll.get("total_bytes", 0.0) - coll.get("dcn_bytes", 0.0)
    dcn_b = coll.get("dcn_bytes", 0.0)
    t_coll = ici_b / hw.ici_bw + dcn_b / hw.dcn_bw

    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)

    out = dict(terms)
    out["memory_hlo_s"] = t_memory_hlo
    out["dominant"] = dominant.replace("_s", "")
    model_flops = rec.get("model_flops")
    if model_flops:
        total_hlo_flops = flops * chips
        out["model_flops"] = model_flops
        out["useful_fraction"] = (model_flops / total_hlo_flops
                                  if total_hlo_flops else None)
    step_time = max(terms.values())
    out["roofline_step_s"] = step_time
    if model_flops and step_time > 0:
        out["mfu_bound"] = model_flops / (chips * hw.peak_flops * step_time)
    return out


def analytic_memory_bytes(cfg, shape, num_devices: int) -> float:
    """Analytic per-device HBM traffic per step (TPU fusion assumed).

    The CPU-compiled HLO fuses far less than XLA:TPU, so byte counts walked
    from it over-state TPU HBM traffic ~10-30x; this napkin model is what the
    dominant-term call uses (both numbers are reported).

    train:   params 3x (fwd + bwd + remat fwd) + grads w + adam m,v r/w (f32)
             + layer-boundary activation saves (w+r) + logits r/w (f32)
    prefill: params 1x + KV-cache write + boundary activations
    decode:  params 1x + KV-cache read + write of one entry
    """
    import jax.numpy as jnp

    p_bytes = cfg.param_count() * jnp.dtype(cfg.dtype).itemsize
    active_bytes = cfg.active_param_count() * jnp.dtype(cfg.dtype).itemsize
    d = cfg.d_model
    act_itm = jnp.dtype(cfg.dtype).itemsize
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    tok_dev = tokens / num_devices

    if shape.kind == "train":
        remat_factor = 3.0 if cfg.remat != "none" else 2.0
        traffic = p_bytes * remat_factor          # weight reads
        traffic += p_bytes                        # grad write
        traffic += cfg.param_count() * 4 * 4      # adam m,v read+write f32
        layer_acts = cfg.num_layers * tokens * d * act_itm
        traffic += 2 * layer_acts / num_devices * num_devices  # global
        traffic += 2 * tokens * cfg.vocab_size * 4 / 16        # logits (TP)
        # weights are sharded across all devices; activations per device
        return (traffic / num_devices
                + 2 * cfg.num_layers * tok_dev * d * act_itm)
    if shape.kind == "prefill":
        kv = _cache_bytes_per_token(cfg) * tokens
        return (active_bytes / num_devices
                + (kv + 2 * cfg.num_layers * tokens * d * act_itm)
                / num_devices)
    # decode: read whole cache + weights once per token step
    kv_total = _cache_bytes_per_token(cfg) * shape.seq_len * shape.global_batch
    return (active_bytes + kv_total) / num_devices


def _cache_bytes_per_token(cfg) -> float:
    import jax.numpy as jnp
    itm = jnp.dtype(cfg.dtype).itemsize
    if cfg.mla is not None:
        return cfg.num_layers * (cfg.mla.kv_lora_rank
                                 + cfg.mla.qk_rope_head_dim) * itm
    if cfg.family == "ssm":
        return 0.0        # O(1) state, not per token
    if cfg.family == "hybrid":
        # only local-attn layers cache, bounded by the window — amortised ~0
        n_att = cfg.num_layers // len(cfg.rglru.pattern)
        return n_att * 2 * cfg.num_kv_heads * cfg.head_dim * itm * \
            min(1.0, cfg.rglru.window / 32768)
    return cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim * itm


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D tokens (train) / 2·N_active·B (decode)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # one token per sequence
