"""The paper's own workload: CovType HTL scenarios (Section 5/6 defaults).

These are the exact settings behind EXPERIMENTS.md §Repro; benchmarks
(`benchmarks/paper_tables.py`) sweep variations of them.
"""
from repro.core.scenario import ScenarioConfig

# Fig. 2 benchmark: everything to the Edge Server over NB-IoT
EDGE_ONLY = ScenarioConfig(algo="edge_only", windows=100,
                           obs_per_window=100)

# Table 3 headline row: StarHTL over 802.11g, no data on the edge
SHTL_WIFI = ScenarioConfig(algo="star", tech="wifi", windows=100,
                           lam_poisson=7.0, zipf_alpha=1.5)

# Table 4: + the data-aggregation heuristic
SHTL_WIFI_AGG = ScenarioConfig(algo="star", tech="wifi", aggregate=True,
                               windows=100)

A2A_4G = ScenarioConfig(algo="a2a", tech="4g", windows=100)
