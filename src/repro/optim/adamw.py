"""AdamW + gradient clipping, from scratch (optax is not in this environment).

Optimizer state moments are kept in float32 regardless of param dtype (mixed
precision: bf16 params / fp32 moments), matching production practice.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


class AdamWState(NamedTuple):
    count: jax.Array
    mu: Any        # pytree like params, float32
    nu: Any        # pytree like params, float32


def adamw_init(params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        count=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(grads, state: AdamWState, params, lr, cfg: OptimizerConfig):
    """One AdamW step. ``lr`` may be a scalar array (from a schedule)."""
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    b1, b2 = cfg.betas
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # no decay on norms/biases
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_p = jax.tree.leaves(params)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (jax.tree.unflatten(treedef, new_p),
            AdamWState(count, jax.tree.unflatten(treedef, new_m),
                       jax.tree.unflatten(treedef, new_v)),
            gnorm)


def sgd_update(grads, params, lr):
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                      ).astype(p.dtype), params, grads)
