"""Sweep the paper's central trade-off: energy vs accuracy as a function of
how much data reaches the edge server, which radio links the mules use, and
the HTL variant. Prints a small ASCII table (the analogue of paper Fig. 3 +
Tables 2-4).

The whole grid goes through one :func:`repro.core.scenario.run_sweep` call
with ``stack_seeds=True``, so stack-compatible configurations (same
algorithm, any mix of technologies / p_edge / aggregation) run in lockstep
on a shared fleet axis — O(sample buckets) jitted dispatches per window for
each group — and every configuration reuses the batched fleet engine's
jitted executables.

    PYTHONPATH=src python examples/energy_tradeoff.py --windows 30
"""
import argparse
import dataclasses

from repro.core.scenario import ScenarioConfig, run_sweep
from repro.data.synthetic_covtype import make_covtype_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=30)
    ap.add_argument("--engine", default="fleet", choices=("fleet", "loop"))
    args = ap.parse_args()
    data = make_covtype_like(seed=0)
    base = ScenarioConfig(windows=args.windows, engine=args.engine,
                          eval_every=max(1, args.windows // 5))

    grid = [("edge-only (NB-IoT)", dataclasses.replace(base,
                                                       algo="edge_only"))]
    for pe in (0.5, 0.15, 0.03):
        grid.append((f"star 4g, {int(pe * 100)}% on edge",
                     dataclasses.replace(base, algo="star", p_edge=pe)))
    for algo in ("a2a", "star"):
        for tech in ("4g", "wifi"):
            grid.append((f"{algo} {tech}, 0% on edge",
                         dataclasses.replace(base, algo=algo, tech=tech)))
            grid.append((f"{algo} {tech} + aggregation",
                         dataclasses.replace(base, algo=algo, tech=tech,
                                             aggregate=True)))

    results = run_sweep([cfg for _, cfg in grid], data, stack_seeds=True)
    rows = list(zip((name for name, _ in grid), results))

    edge = rows[0][1]
    e0, f0 = edge.energy_total, edge.converged_f1()
    print(f"{'configuration':28s} {'energy mJ':>10s} {'saving':>7s} "
          f"{'F1':>6s} {'loss':>6s}")
    for name, r in rows:
        sav = 100 * (1 - r.energy_total / e0)
        loss = 100 * (f0 - r.converged_f1()) / f0
        bar = "#" * int(max(0.0, sav) // 4)
        print(f"{name:28s} {r.energy_total:10.0f} {sav:6.1f}% "
              f"{r.converged_f1():6.3f} {loss:5.1f}%  {bar}")


if __name__ == "__main__":
    main()
