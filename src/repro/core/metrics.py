"""Performance metrics exactly as defined in the paper (Section 5.2).

Precision (eq. 3) is the *overall accuracy* (the paper's idiosyncratic
definition), recall (eq. 4) is macro-averaged per-class accuracy, and the
F-measure (eq. 5) is their harmonic mean.
"""
from __future__ import annotations

import numpy as np


def precision(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    return float(np.mean(y_true == y_pred))


def recall(y_true: np.ndarray, y_pred: np.ndarray, num_classes: int) -> float:
    vals = []
    for c in range(num_classes):
        m = y_true == c
        if m.sum() == 0:
            continue
        vals.append(float(np.mean(y_pred[m] == c)))
    return float(np.mean(vals)) if vals else 0.0


def f_measure(y_true: np.ndarray, y_pred: np.ndarray,
              num_classes: int) -> float:
    p = precision(y_true, y_pred)
    r = recall(y_true, y_pred, num_classes)
    if p + r == 0:
        return 0.0
    return 2.0 * p * r / (p + r)
