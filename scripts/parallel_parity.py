#!/usr/bin/env python
"""Parallel-parity CI gate: a sharded sweep may never change the numbers.

Runs a preset grid sequentially (``parallel="none"``) and under each
requested parallel backend, then diffs the serialized ``SweepResult``
JSON byte for byte. Exits non-zero on any mismatch.

Run it under fake CPU devices so the ``devices`` backend actually spreads
shards across several devices (the flag must be set before jax
initializes, which is why this gate owns its process):

    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        python scripts/parallel_parity.py --preset smoke --windows 4 \
        --expect-devices 8 --backends devices:n=8,processes:n=2

Wired into scripts/verify.sh and .github/workflows/ci.yml.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def first_diff(a: str, b: str, context: int = 60) -> str:
    k = next((i for i, (x, y) in enumerate(zip(a, b)) if x != y),
             min(len(a), len(b)))
    return (f"first divergence at byte {k}: "
            f"...{a[max(0, k - context):k + context]!r} vs "
            f"...{b[max(0, k - context):k + context]!r}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--windows", type=int, default=4)
    ap.add_argument("--backends", default="devices:n=8",
                    help="comma-separated executor specs to diff against "
                         "the sequential run")
    ap.add_argument("--expect-devices", type=int, default=0,
                    help="fail unless jax sees exactly this many devices "
                         "(guards the XLA_FLAGS fake-device recipe)")
    args = ap.parse_args()

    import jax

    from repro.core.experiment import get_preset
    from repro.data.synthetic_covtype import make_covtype_like

    n_dev = len(jax.devices())
    print(f"devices={n_dev} backend={jax.default_backend()}")
    if args.expect_devices and n_dev != args.expect_devices:
        print(f"FAIL: expected {args.expect_devices} devices (did "
              f"XLA_FLAGS=--xla_force_host_platform_device_count get set "
              f"before jax initialized?)")
        return 1

    data = make_covtype_like(seed=0)
    spec = get_preset(args.preset, windows=args.windows)
    ref = spec.run(data, parallel="none").to_json()
    rc = 0
    for backend in args.backends.split(","):
        got = spec.run(data, parallel=backend.strip()).to_json()
        if got == ref:
            print(f"parity {backend}: OK ({len(ref)} bytes identical)")
        else:
            print(f"parity {backend}: MISMATCH — {first_diff(ref, got)}")
            rc = 1
    if rc == 0:
        print("parallel parity: all backends bitwise-identical")
    return rc


if __name__ == "__main__":
    sys.exit(main())
