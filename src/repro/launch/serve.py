"""Serving launcher: lower/compile (and on CPU, run reduced) the serve path.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --shape decode_32k
        lowers decode_step under the production mesh (same as dryrun decode)
    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --run
        runs a reduced-config batched generation locally
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k",
                    choices=["decode_32k", "long_500k", "prefill_32k"])
    ap.add_argument("--run", action="store_true",
                    help="run a reduced local generation instead of lowering")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    args = ap.parse_args()

    if args.run:
        import jax

        from repro.configs import get_config
        from repro.data.pipeline import make_lm_batch
        from repro.models import build_model
        from repro.serving import ServeEngine
        cfg = get_config(args.arch).reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_lm_batch(
            cfg.vocab_size, 2, 32, d_model=cfg.d_model,
            frontend_tokens=(cfg.frontend.num_tokens
                             if cfg.family == "vlm" else 0),
            encoder_len=(cfg.encoder_seq_len if cfg.family == "audio"
                         else 0))
        out = ServeEngine(model, params, max_new_tokens=8).generate(batch)
        print("generated:", out.tolist())
        return

    # AOT path: reuse the dry-run machinery (sets 512 host devices itself,
    # so run it as a module subprocess for device-count hygiene)
    import subprocess
    import sys
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
           "--shape", args.shape, "--mesh", args.mesh, "--ws-decode",
           "--force"]
    raise SystemExit(subprocess.call(cmd))


if __name__ == "__main__":
    main()
