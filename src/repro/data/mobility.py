"""Synthetic mobility traces for the ``trace_file:`` collection policy.

The paper's ``trace`` policy replays one fixed per-mule allocation every
window; real SmartMule fleets move. This module generates a deterministic
random-waypoint trace — static sensors scattered over a unit square, a
mule fleet walking waypoint to waypoint — and records, per window, how
many sensors each mule serves (every sensor uploads to its nearest mule,
so window loads shift as the fleet moves). The trace is a plain JSON
artifact:

    {"schema": 1, "windows": W, "mules": M, "seed": S,
     "speed": ..., "sensors": N, "loads": [[w0m0, w0m1, ...], ...]}

``loads`` is a ``(W, M)`` non-negative integer matrix with positive row
sums (every window someone collects). The generated filename embeds a
content digest, so a trace file referenced from a ``ScenarioConfig``
(and therefore from the sweep service's exact-result-cache key, which
hashes the config including the path) can never silently change content
under a stable name.

Consumption happens in :mod:`repro.core.scenario` via the
``trace_file:path=...`` collection policy: window ``t`` apportions the
mule share of the window's observations over ``loads[t % W]`` by largest
remainder — a *windowed cursor* over the trace, wrapping when the
scenario outlives it.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Optional

import numpy as np

TRACE_SCHEMA = 1


def _waypoint_positions(rng: np.random.Generator, windows: int, mules: int,
                        speed: float) -> np.ndarray:
    """Random-waypoint mule positions, one (M, 2) snapshot per window:
    each mule walks toward a waypoint at ``speed`` per window (unit-square
    units) and draws a fresh waypoint on arrival."""
    pos = rng.random((mules, 2))
    target = rng.random((mules, 2))
    out = np.empty((windows, mules, 2), np.float64)
    for t in range(windows):
        out[t] = pos
        delta = target - pos
        dist = np.linalg.norm(delta, axis=1)
        arrive = dist <= speed
        step = np.where(dist[:, None] > 0, delta / np.maximum(dist, 1e-12)
                        [:, None] * speed, 0.0)
        pos = np.where(arrive[:, None], target, pos + step)
        if arrive.any():
            target[arrive] = rng.random((int(arrive.sum()), 2))
    return out


def make_trace_loads(windows: int = 24, mules: int = 6, sensors: int = 36,
                     seed: int = 0, speed: float = 0.12) -> np.ndarray:
    """The ``(windows, mules)`` load matrix of a random-waypoint trace:
    per window, each static sensor counts toward its nearest mule."""
    if windows < 1 or mules < 1 or sensors < 1:
        raise ValueError(f"need windows/mules/sensors >= 1, got "
                         f"{windows}/{mules}/{sensors}")
    if speed <= 0:
        raise ValueError(f"mule speed must be positive, got {speed}")
    rng = np.random.default_rng([int(seed), 0x7EACE])
    sensor_xy = rng.random((sensors, 2))
    mule_xy = _waypoint_positions(rng, windows, mules, speed)
    loads = np.zeros((windows, mules), np.int64)
    for t in range(windows):
        d = np.linalg.norm(sensor_xy[:, None, :] - mule_xy[t][None, :, :],
                           axis=2)
        nearest = np.argmin(d, axis=1)          # ties -> lowest mule id
        loads[t] = np.bincount(nearest, minlength=mules)
    return loads


def _payload_digest(payload: dict) -> str:
    blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()[:12]


def generate_trace(out_dir: str, *, windows: int = 24, mules: int = 6,
                   sensors: int = 36, seed: int = 0,
                   speed: float = 0.12) -> str:
    """Write a trace file under ``out_dir`` and return its path.

    Deterministic: the same parameters always produce the same payload,
    digest and therefore the same path — regenerating is idempotent (the
    write is atomic, so concurrent generators agree too). The digest in
    the filename is what keeps ``trace_file:path=...`` specs (and the
    result-cache keys hashing them) honest about content.
    """
    loads = make_trace_loads(windows=windows, mules=mules, sensors=sensors,
                             seed=seed, speed=speed)
    payload = {"schema": TRACE_SCHEMA, "windows": int(windows),
               "mules": int(mules), "sensors": int(sensors),
               "seed": int(seed), "speed": float(speed),
               "loads": [[int(v) for v in row] for row in loads]}
    digest = _payload_digest(payload)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, f"trace_w{windows}_m{mules}_s{seed}_{digest}.json")
    if not os.path.exists(path):
        fd, tmp = tempfile.mkstemp(dir=out_dir, prefix=".trace.",
                                   suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=1)
        os.replace(tmp, path)
    return path


def load_trace(path: str) -> np.ndarray:
    """Read and validate a trace file; returns the ``(W, M)`` load matrix.
    Raises :class:`ValueError` on schema/shape violations — the collection
    policy resolves traces at config-validation time, so a bad file fails
    before any window runs."""
    with open(path) as f:
        payload = json.load(f)
    if payload.get("schema") != TRACE_SCHEMA:
        raise ValueError(f"unsupported trace schema "
                         f"{payload.get('schema')!r} in {path} (this build "
                         f"reads {TRACE_SCHEMA})")
    loads = np.asarray(payload.get("loads", []), np.float64)
    if loads.ndim != 2 or loads.shape[0] < 1 or loads.shape[1] < 1:
        raise ValueError(f"trace {path} needs a (windows, mules) loads "
                         f"matrix, got shape {loads.shape}")
    if (loads < 0).any():
        raise ValueError(f"trace {path} has negative loads")
    if (loads.sum(axis=1) <= 0).any():
        raise ValueError(f"trace {path} has a window with zero total load "
                         f"(someone must collect every window)")
    return loads
