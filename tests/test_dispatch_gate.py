"""CI dispatch-count regression gate (run explicitly by scripts/verify.sh).

The fleet engine's contract: jitted dispatches per collection window are
bounded by the (fixed, tiny) sample-bucket set — independent of the Poisson
fleet size AND of how many seed/config replicas are stacked into the sweep
group. A regression to per-DC or per-replica dispatch loops (e.g. a Python
loop over DCs around ``train_svm``/``greedytl``) multiplies the count by
~7x per window and fails these assertions.
"""
import dataclasses

import numpy as np
import pytest

from repro.core.dispatch import (dispatch_counts, dispatch_scope,
                                 reset_dispatch_counts)
from repro.core.scenario import ScenarioConfig, run_scenario, run_sweep
from repro.core.svm import SAMPLE_BUCKETS
from repro.data.synthetic_covtype import make_covtype_like

DATA = make_covtype_like(seed=0)
WINDOWS = 5
# per window: at most one train + one refine dispatch per sample bucket
BUCKETS = len(SAMPLE_BUCKETS) + 1
PER_WINDOW_BOUND = 2 * BUCKETS


def _counts(cfgs, stack):
    reset_dispatch_counts()
    if stack:
        run_sweep(cfgs, DATA, stack_seeds=True)
    else:
        for c in cfgs:
            run_scenario(c, DATA)
    return dispatch_counts()


@pytest.mark.parametrize("algo", ["a2a", "star"])
def test_fleet_window_dispatches_bounded_by_buckets(algo):
    cfg = ScenarioConfig(windows=WINDOWS, eval_every=WINDOWS, algo=algo)
    c = _counts([cfg], stack=False)
    # the fleet engine must never fall back to per-DC entry points
    assert c.get("train_svm", 0) == 0
    assert c.get("greedytl", 0) == 0
    jitted = c.get("train_svm_fleet", 0) + c.get("greedytl_fleet", 0) \
        + c.get("greedytl_fleet_stacked", 0)
    assert 0 < jitted <= WINDOWS * PER_WINDOW_BOUND, c


@pytest.mark.parametrize("algo", ["a2a", "star"])
def test_stacked_sweep_dispatches_independent_of_replicas(algo):
    """Stacking S replicas must NOT multiply dispatches by S."""
    base = ScenarioConfig(windows=WINDOWS, eval_every=WINDOWS, algo=algo)
    cfgs = [dataclasses.replace(base, seed=s) for s in range(4)]
    c = _counts(cfgs, stack=True)
    assert c.get("train_svm", 0) == 0 and c.get("greedytl", 0) == 0
    jitted = c.get("train_svm_fleet", 0) + c.get("greedytl_fleet", 0) \
        + c.get("greedytl_fleet_stacked", 0)
    assert 0 < jitted <= WINDOWS * PER_WINDOW_BOUND, c

    # ... while the same group run sequentially costs ~S times as much
    seq = _counts(cfgs, stack=False)
    seq_jitted = seq.get("train_svm_fleet", 0) \
        + seq.get("greedytl_fleet", 0) + seq.get("greedytl_fleet_stacked", 0)
    assert seq_jitted >= 2 * jitted, (seq, c)


def test_loop_engine_still_counts_per_dc():
    """The counter itself must see the loop engine's per-DC dispatches
    (guards against the gate silently counting nothing)."""
    cfg = ScenarioConfig(windows=WINDOWS, eval_every=WINDOWS, algo="a2a",
                         engine="loop")
    c = _counts([cfg], stack=False)
    assert c.get("train_svm", 0) > WINDOWS      # one per DC, Poisson(7)
    assert c.get("greedytl", 0) > WINDOWS


# ---------------------------------------------------------------------------
# scan engine: per-WINDOW dispatch is banned outright — a scenario is O(1)
# jitted dispatches no matter how many windows it runs (the whole run is one
# lax.scan program; repro.core.cityscan)
# ---------------------------------------------------------------------------

PER_WINDOW_NAMES = ("train_svm", "greedytl", "train_svm_fleet",
                    "greedytl_fleet", "greedytl_fleet_stacked")


def _scan_counts(cfg):
    reset_dispatch_counts()
    run_scenario(cfg, DATA)
    return dispatch_counts()


@pytest.mark.parametrize("algo", ["a2a", "star"])
def test_scan_engine_O1_dispatches_regardless_of_windows(algo):
    counts = {}
    for w in (3, 9):
        cfg = ScenarioConfig(windows=w, eval_every=w, algo=algo,
                             engine="scan")
        c = _scan_counts(cfg)
        # never a per-window or per-DC entry point
        for name in PER_WINDOW_NAMES:
            assert c.get(name, 0) == 0, c
        assert c.get("scan_windows", 0) == 1, c
        counts[w] = c
    # tripling the window count must not change the dispatch profile
    assert counts[3] == counts[9], counts


# ---------------------------------------------------------------------------
# greedy inner loop: the incremental factor carry must live INSIDE the
# existing while_loop — accepting k candidates is still exactly ONE jitted
# dispatch per entry point, never k extra dispatches (a fallback to
# host-side iteration over accepted steps would multiply every count below
# by the greedy depth)
# ---------------------------------------------------------------------------

def _deep_greedy_fixture(n=160, n_src=12, seed=0):
    """A problem whose greedy selection accepts many sources: each source
    explains a disjoint feature block of the true boundary, so every
    accepted step keeps improving the LOO error."""
    import jax.numpy as jnp
    F, C, M = 54, 7, 16
    r = np.random.default_rng(seed)
    src = np.zeros((M, F + 1, C), np.float32)
    sm = np.zeros(M, np.float32)
    w_total = np.zeros((F + 1, C), np.float32)
    for i, blk in enumerate(np.array_split(np.arange(F), n_src)):
        w = np.zeros((F + 1, C), np.float32)
        w[blk] = r.normal(0, 1.0, (len(blk), C))
        src[i] = w
        sm[i] = 1.0
        w_total += w
    x = r.normal(size=(n, F)).astype(np.float32)
    y = np.argmax(x @ w_total[:-1] + w_total[-1], axis=1).astype(np.int32)
    return (jnp.asarray(x), jnp.asarray(y),
            jnp.asarray(np.ones(n, np.float32)), jnp.asarray(src),
            jnp.asarray(sm))


def test_deep_greedy_refine_is_one_dispatch_per_entry_point():
    import jax.numpy as jnp

    from repro.core.greedytl import (greedytl, greedytl_fleet,
                                     greedytl_fleet_stacked)

    x, y, m, src, sm = _deep_greedy_fixture()
    with dispatch_scope() as single:
        _, sel = greedytl(x, y, m, src, sm, num_classes=7)
    depth = int(np.asarray(sel).sum())
    assert depth >= 8, f"fixture too shallow for the gate: depth={depth}"
    assert single == {"greedytl": 1}, single

    L = 2
    xf, yf, mf = (jnp.stack([v] * L) for v in (x, y, m))
    with dispatch_scope() as fleet:
        greedytl_fleet(xf, yf, mf, src, sm, num_classes=7)
    assert fleet == {"greedytl_fleet": 1}, fleet

    srcs, sms = (jnp.stack([v] * L) for v in (src, sm))
    with dispatch_scope() as stacked:
        greedytl_fleet_stacked(xf, yf, mf, srcs, sms, num_classes=7)
    assert stacked == {"greedytl_fleet_stacked": 1}, stacked


def test_city_engine_O1_dispatches_regardless_of_windows():
    counts = {}
    for w in (2, 5):
        cfg = ScenarioConfig(windows=w, eval_every=w, algo="star",
                             engine="scan", fleet_size=64, obs_per_dc=4,
                             train_iters=5)
        c = _scan_counts(cfg)
        for name in PER_WINDOW_NAMES:
            assert c.get(name, 0) == 0, c
        assert c.get("city_scan", 0) == 1, c
        counts[w] = c
    assert counts[2] == counts[5], counts
