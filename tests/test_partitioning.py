"""DC-axis sharding helpers (repro.sharding.partitioning): fleet mesh
construction, shard-count selection, and the FLEET_RULES PartitionSpecs the
cityscan engine shard_maps over. The bitwise sharded-vs-unsharded fleet
round check itself lives in tests/test_cityscan.py (it needs 8 fake
devices, hence its own subprocess)."""
import jax
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # deterministic shim, tests/_hypothesis_fallback.py
    from _hypothesis_fallback import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core.fleet import fleet_cap
from repro.sharding.partitioning import (DEFAULT_RULES, FLEET_AXIS,
                                         FLEET_RULES, dc_pspec, dc_shards,
                                         fleet_mesh, logical_to_pspec)


class FakeMesh:
    """Stand-in with just .shape (logical_to_pspec only uses that)."""
    def __init__(self, **axes):
        self.shape = dict(axes)


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

def test_fleet_mesh_default_uses_every_device():
    mesh = fleet_mesh()
    assert mesh.axis_names == (FLEET_AXIS,)
    assert mesh.shape[FLEET_AXIS] == len(jax.devices())


def test_fleet_mesh_explicit_width():
    mesh = fleet_mesh(1)
    assert mesh.shape[FLEET_AXIS] == 1
    assert mesh.devices.flatten()[0] == jax.devices()[0]


def test_fleet_mesh_rejects_bad_widths():
    with pytest.raises(ValueError):
        fleet_mesh(0)
    with pytest.raises(ValueError):
        fleet_mesh(len(jax.devices()) + 1)


# ---------------------------------------------------------------------------
# shard-count selection
# ---------------------------------------------------------------------------

def test_dc_shards_single_device_host():
    # this process sees one real CPU device
    assert dc_shards(128) == min(len(jax.devices()), 128)


def test_dc_shards_respects_max_shards_cap():
    assert dc_shards(128, max_shards=1) == 1


def test_dc_shards_picks_largest_divisor(monkeypatch):
    monkeypatch.setattr(jax, "devices", lambda: [object()] * 6)
    assert dc_shards(64) == 4         # 6 and 5 don't divide 64; 4 does
    assert dc_shards(96) == 6
    assert dc_shards(7) == 1          # prime below every usable width
    assert dc_shards(35, max_shards=4) == 1   # no width in 2..4 divides 35


@settings(max_examples=60, deadline=None)
@given(n_dc=st.integers(min_value=1, max_value=200_000),
       n_dev=st.integers(min_value=1, max_value=16))
def test_dc_shards_always_divides_padded_caps(n_dc, n_dev):
    """The contract the city engine relies on: for any Poisson fleet size,
    the padded capacity (multiples of 32 past the small buckets) is evenly
    divided by the chosen shard count — shard_map never sees ragged
    shards."""
    import repro.sharding.partitioning as part
    real = jax.devices
    jax.devices = lambda: [object()] * n_dev
    try:
        padded = fleet_cap(n_dc)
        s = part.dc_shards(padded)
        assert 1 <= s <= n_dev
        assert padded % s == 0
        # maximality: no larger usable device count divides evenly
        assert all(padded % k != 0 for k in range(s + 1, n_dev + 1))
    finally:
        jax.devices = real


# ---------------------------------------------------------------------------
# DC-axis PartitionSpecs
# ---------------------------------------------------------------------------

def test_fleet_rules_only_override_dc():
    assert FLEET_RULES["dc"] == FLEET_AXIS
    assert DEFAULT_RULES["dc"] is None
    assert {k: v for k, v in FLEET_RULES.items() if k != "dc"} == \
        {k: v for k, v in DEFAULT_RULES.items() if k != "dc"}


def test_dc_pspec_shards_leading_dim_only():
    assert dc_pspec(1) == P(FLEET_AXIS)
    assert dc_pspec(3) == P(FLEET_AXIS, None, None)


def test_logical_to_pspec_fleet_rules_divisible():
    mesh = FakeMesh(dc=8)
    spec = logical_to_pspec(("dc", None), (128, 55), mesh, FLEET_RULES)
    assert spec == P("dc")            # trailing None trimmed


def test_logical_to_pspec_fleet_rules_non_divisible_replicates():
    mesh = FakeMesh(dc=8)
    spec = logical_to_pspec(("dc", None), (130, 55), mesh, FLEET_RULES)
    assert spec == P()


def test_default_rules_keep_dc_replicated():
    mesh = FakeMesh(data=4, model=2, dc=8)
    spec = logical_to_pspec(("dc", "embed"), (128, 64), mesh, DEFAULT_RULES)
    assert spec == P(None, "data")
