"""Quickstart: the paper in one script.

Reproduces the core claim — HTL-based distributed learning among SmartMules
saves ~90+% of communication energy vs shipping everything to the edge
server over NB-IoT, at a few percent accuracy loss.

    PYTHONPATH=src python examples/quickstart.py [--windows 40]
"""
import argparse
import dataclasses

from repro.core.scenario import ScenarioConfig, run_scenario
from repro.data.synthetic_covtype import make_covtype_like


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=40)
    args = ap.parse_args()

    data = make_covtype_like(seed=0)
    base = ScenarioConfig(windows=args.windows,
                          eval_every=max(1, args.windows // 8))

    print("== Edge-Only benchmark (all data -> ES over NB-IoT) ==")
    edge = run_scenario(dataclasses.replace(base, algo="edge_only"), data)
    print(f"   F1 curve: {[round(f, 3) for f in edge.f1_curve]}")
    print(f"   energy:   {edge.energy_total:8.0f} mJ")

    for algo, tech in [("star", "wifi"), ("a2a", "wifi"), ("star", "4g")]:
        r = run_scenario(dataclasses.replace(base, algo=algo, tech=tech,
                                             aggregate=True), data)
        gain = 100 * (1 - r.energy_total / edge.energy_total)
        loss = 100 * (edge.converged_f1() - r.converged_f1()) \
            / edge.converged_f1()
        print(f"== {algo.upper():4s} + {tech:4s} + aggregation ==")
        print(f"   F1 curve: {[round(f, 3) for f in r.f1_curve]}")
        print(f"   energy:   {r.energy_total:8.0f} mJ "
              f"(saving {gain:.1f}%, accuracy loss {loss:.1f}%)")
        print(f"   breakdown: collection {r.energy_collection:.0f} mJ, "
              f"learning {r.energy_learning:.0f} mJ")


if __name__ == "__main__":
    main()
