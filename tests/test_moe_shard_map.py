"""shard_map MoE dispatch: bit-exact vs the auto (GSPMD) path on a multi-
device host mesh. Runs in a subprocess (needs >1 device; the pytest process
is pinned to 1)."""
import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp, dataclasses
from repro.configs import get_config
from repro.models.blocks import moe_ffn, moe_ffn_shard_map, moe_template
from repro.sharding.partitioning import init_params, use_compute_mesh

mesh = jax.make_mesh((2, 4), ('data', 'model'))
cfg = get_config('olmoe-1b-7b').reduced()
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
    cfg.moe, num_experts=8, top_k=2, capacity_factor=8.0))
p = init_params(moe_template(cfg), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model))
y_ref, aux_ref = jax.jit(lambda p, x: moe_ffn(p, x, cfg))(p, x)
with use_compute_mesh(mesh):
    y_sm, aux_sm = jax.jit(lambda p, x: moe_ffn_shard_map(p, x, cfg))(p, x)
err = float(jnp.max(jnp.abs(y_ref - y_sm)))
aerr = abs(float(aux_ref) - float(aux_sm))
assert err < 1e-5, err
assert aerr < 1e-6, aerr
print('OK', err)
"""


def test_shard_map_moe_matches_auto():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == 0, proc.stdout[-1500:] + proc.stderr[-1500:]
    assert "OK" in proc.stdout
