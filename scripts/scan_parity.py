#!/usr/bin/env python
"""Scan-engine parity CI gate: the scan-over-windows engine may never
change the numbers.

Runs a preset grid on the PR-1 fleet engine (sequential — the parity
oracle) and again on the scan engine (one jitted lax.scan dispatch per
scenario), then diffs the serialized ``SweepResult`` JSON byte for byte.
The records differ only in the declared ``cfg.engine`` field, which is
normalized before the diff; everything observable — F1 curves, every
energy-ledger event, order included — must be identical. Exits non-zero
on any mismatch.

    python scripts/scan_parity.py --preset smoke --windows 4
    python scripts/scan_parity.py --preset transport_grid --windows 5

Wired into scripts/verify.sh and .github/workflows/ci.yml.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def first_diff(a: str, b: str, context: int = 60) -> str:
    k = next((i for i, (x, y) in enumerate(zip(a, b)) if x != y),
             min(len(a), len(b)))
    return (f"first divergence at byte {k}: "
            f"...{a[max(0, k - context):k + context]!r} vs "
            f"...{b[max(0, k - context):k + context]!r}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="smoke")
    ap.add_argument("--windows", type=int, default=4)
    args = ap.parse_args()

    from repro.core.experiment import SweepResult, get_preset
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    # stack="off": the sequential fleet engine is the validated oracle
    # (stacked fleet runs agree with it only to engine-parity tolerance)
    ref = get_preset(args.preset, windows=args.windows,
                     engine="fleet").run(data, stack="off").to_json()
    scan = get_preset(args.preset, windows=args.windows,
                      engine="scan").run(data, stack="off")
    normalized = SweepResult(
        name=scan.name,
        records=[dataclasses.replace(
            r, cfg=dataclasses.replace(r.cfg, engine="fleet"))
            for r in scan.records])
    got = normalized.to_json()
    if got != ref:
        print(f"scan parity {args.preset}: MISMATCH — "
              f"{first_diff(ref, got)}")
        return 1
    print(f"scan parity {args.preset}: OK ({len(ref)} bytes identical, "
          f"{len(scan.records)} runs, {args.windows} windows)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
