#!/usr/bin/env python
"""Pareto auto-tuner CI gate (DESIGN.md §14): the halving search may
prune cost, never correctness.

On the ``pareto`` preset at the gate budget it asserts:

1. **frontier recovery** — the successive-halving search recovers
   exactly the frontier the exhaustive grid (``rungs=1`` over every
   candidate at full budget) produces — same labels, same order;
2. **bitwise frontier** — the search's ``frontier_result`` JSON is
   byte-identical to a plain ``SweepSpec.run`` of the frontier configs
   (:func:`repro.core.pareto.frontier_spec`), clean of any search-path
   influence — the same contract every engine/backend gate pins;
3. the search actually *searched*: at least one candidate was pruned
   before the final rung, the ledger covers every candidate exactly
   once, and the rung schedule grows monotonically to the full budget;
4. ``ParetoResult`` JSON round-trips losslessly.

    python scripts/pareto_smoke.py --windows 6 --seeds 1

Wired into scripts/verify.sh (gates phase) and the named
``pareto-smoke`` CI step, mirroring scripts/churn_smoke.py.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def first_diff(a: str, b: str, context: int = 60) -> str:
    k = next((i for i, (x, y) in enumerate(zip(a, b)) if x != y),
             min(len(a), len(b)))
    return (f"first divergence at byte {k}: "
            f"...{a[max(0, k - context):k + context]!r} vs "
            f"...{b[max(0, k - context):k + context]!r}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="pareto")
    ap.add_argument("--windows", type=int, default=6)
    ap.add_argument("--seeds", type=int, default=1)
    ap.add_argument("--search", default="halving:rungs=3,keep=0.5",
                    help="the pruning search under test")
    args = ap.parse_args()

    from repro.core.experiment import get_preset
    from repro.core.pareto import ParetoResult, frontier_spec, get_search
    from repro.data.synthetic_covtype import make_covtype_like

    data = make_covtype_like(seed=0)
    spec = get_preset(args.preset, windows=args.windows,
                      n_seeds=args.seeds)
    rows = spec.rows()
    rc = 0

    exhaustive = get_search("exhaustive").run(spec, data)
    search = get_search(args.search)
    result = search.run(spec, data)

    # 1. frontier recovery: pruning never loses a Pareto-optimal config
    if result.frontier_labels() == exhaustive.frontier_labels():
        print(f"pareto smoke [recovery]: OK — {args.search} recovered "
              f"the exhaustive frontier "
              f"{result.frontier_labels()} over {len(rows)} candidates")
    else:
        print(f"pareto smoke [recovery]: MISMATCH — search frontier "
              f"{result.frontier_labels()} != exhaustive "
              f"{exhaustive.frontier_labels()}")
        rc = 1

    # 2. bitwise frontier: the reported numbers ARE a plain SweepSpec.run
    direct = frontier_spec(spec, result.frontier_labels()).run(data)
    got = result.frontier_result.to_json()
    ref = direct.to_json()
    if got == ref:
        print(f"pareto smoke [bitwise]: OK — frontier SweepResult "
              f"identical to direct SweepSpec.run ({len(ref)} bytes)")
    else:
        print(f"pareto smoke [bitwise]: MISMATCH — {first_diff(ref, got)}")
        rc = 1

    # 3. the search searched: pruning happened, the ledger is complete,
    #    the budget schedule is monotone and ends at the full budget
    counts = result.dominated_counts()
    pruned = counts.get("pruned", 0)
    if pruned < 1:
        print(f"pareto smoke [pruning]: no candidate was pruned "
              f"(ledger: {counts}) — the halving path never ran")
        rc = 1
    ledger_labels = sorted(e["label"] for e in result.ledger)
    if ledger_labels != sorted(lbl for lbl, _ in rows):
        print(f"pareto smoke [ledger]: ledger does not cover the grid "
              f"exactly once ({len(ledger_labels)} entries, "
              f"{len(rows)} rows)")
        rc = 1
    budgets = [r["windows"] for r in result.schedule]
    if budgets != sorted(budgets) or budgets[-1] != args.windows:
        print(f"pareto smoke [schedule]: rung budgets {budgets} are not "
              f"monotone to the full budget {args.windows}")
        rc = 1
    if rc == 0:
        print(f"pareto smoke [schedule]: OK — rungs {budgets} windows, "
              f"pruned {pruned}/{len(rows)}, cost "
              f"{result.cost['evals_windows']} vs exhaustive "
              f"{result.cost['exhaustive_windows']} window-evals")

    # 4. lossless artifact
    clone = ParetoResult.from_json(result.to_json())
    if clone != result:
        print("pareto smoke [json]: ParetoResult round-trip drifted")
        rc = 1

    if rc == 0:
        print("pareto auto-tuner: halving recovers the exhaustive "
              "frontier, and frontier metrics are bitwise a plain "
              "SweepSpec.run")
    return rc


if __name__ == "__main__":
    sys.exit(main())
