#!/usr/bin/env bash
# Tier-1 verification: full test suite + benchmark smoke.
# Usage: scripts/verify.sh [--fast]   (--fast deselects @slow tests)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

MARK=()
if [[ "${1:-}" == "--fast" ]]; then
    MARK=(-m "not slow")
fi

python -m pytest -x -q "${MARK[@]}"
python -m benchmarks.run --quick --skip-tables
