"""Roofline report: turn results/dryrun/*.json into the §Roofline table.

Usage: python -m repro.roofline.report [--dir results/dryrun] [--mesh pod1]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.roofline.analysis import HW, roofline_from_record

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(dirname: str, mesh: str = None):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def analyze(rec: dict) -> dict:
    from repro.configs import INPUT_SHAPES, get_config
    from repro.launch.specs import arch_for_shape
    from repro.roofline.analysis import analytic_memory_bytes

    r = dict(rec)
    r["flops"] = rec.get("hlo_flops", 0.0)
    r["bytes_accessed"] = rec.get("hlo_bytes_accessed", 0.0)
    cfg = arch_for_shape(get_config(rec["arch"]), INPUT_SHAPES[rec["shape"]])
    r["analytic_bytes"] = analytic_memory_bytes(
        cfg, INPUT_SHAPES[rec["shape"]], rec["num_devices"])
    out = roofline_from_record(r)
    out.update({k: rec[k] for k in ("arch", "shape", "mesh", "status")})
    out["compile_s"] = rec.get("compile_s")
    coll = rec.get("collectives", {})
    out["coll_bytes"] = coll.get("total_bytes", 0.0)
    out["dcn_bytes"] = coll.get("dcn_bytes", 0.0)
    return out


def one_liner(a: dict) -> str:
    uf = a.get("useful_fraction")
    return (f"{a['arch']:24s} {a['shape']:11s} {a['mesh']:5s} "
            f"compute={a['compute_s']:9.3e}s memory={a['memory_s']:9.3e}s "
            f"coll={a['collective_s']:9.3e}s dom={a['dominant']:10s} "
            f"useful={uf:.3f}" if uf is not None else
            f"{a['arch']:24s} {a['shape']:11s} {a['mesh']:5s} (no flops)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()

    recs = load_records(args.dir, args.mesh)
    rows = []
    for r in recs:
        if r["status"] != "ok":
            continue
        rows.append(analyze(r))
    rows.sort(key=lambda a: (a["arch"], SHAPE_ORDER.index(a["shape"]),
                             a["mesh"]))

    if args.markdown:
        print("| arch | shape | mesh | compute (s) | memory (s) | "
              "collective (s) | dominant | useful frac | bound-MFU |")
        print("|---|---|---|---|---|---|---|---|---|")
        for a in rows:
            uf = a.get("useful_fraction")
            mfu = a.get("mfu_bound")
            print(f"| {a['arch']} | {a['shape']} | {a['mesh']} "
                  f"| {a['compute_s']:.3e} | {a['memory_s']:.3e} "
                  f"| {a['collective_s']:.3e} | **{a['dominant']}** "
                  f"| {uf:.3f} | {mfu:.3f} |" if uf is not None else
                  f"| {a['arch']} | {a['shape']} | {a['mesh']} | - | - | - "
                  f"| {a['dominant']} | - | - |")
    else:
        for a in rows:
            print(one_liner(a))

    doms = {}
    for a in rows:
        doms[a["dominant"]] = doms.get(a["dominant"], 0) + 1
    print(f"\n# {len(rows)} rows; dominant-term distribution: {doms}",
          flush=True)


if __name__ == "__main__":
    main()
