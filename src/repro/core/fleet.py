"""Batched fleet-round engine: one window = O(1) jitted dispatches.

The loop engine in :mod:`repro.core.htl` issues one ``train_svm`` and (for
A2AHTL) one ``greedytl`` dispatch *per Data Collector*, so a sweep over many
scenario configurations (paper Tables 2-6) pays thousands of tiny dispatches
and host syncs. This engine pads the per-window DC fleet to a bucketed
capacity and runs

* base training as a single :func:`~repro.core.svm.train_svm_fleet`
  (``vmap`` over the DC axis), and
* the A2AHTL refine step as a single
  :func:`~repro.core.greedytl.greedytl_fleet` against the shared source pool,

so dispatch count per window is constant and shapes are stable across
windows (Poisson-varying fleet sizes land in the same bucket — no
recompiles). Energy is charged through the same
:class:`~repro.core.topology.Topology` patterns as the loop engine, so
ledger totals match exactly; model updates match numerically — the refine
step maps the exact per-call computation graph over the fleet (bitwise),
base training is vmapped (equal to low-order bits) — so F1 curves agree
within 1e-4 (tests/test_fleet_engine.py).

Election/subsampling policies are resolved through the :mod:`~repro.core.
htl` module at call time, so policy ablations that monkey-patch the loop
engine (benchmarks/ablations.py) apply to this engine too.
"""
from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import htl
from repro.core.energy import INDEX_BYTES, Ledger, MODEL_BYTES
from repro.core.greedytl import greedytl_fleet
from repro.core.htl import DC, build_source_pool
from repro.core.svm import pad_fleet, train_svm_fleet
from repro.core.topology import Topology, fleet_nodes

FLEET_BUCKETS = (4, 8, 16)   # padded DC-axis capacities (cover Poisson(7))


def fleet_cap(n_dcs: int) -> int:
    """Bucketed DC-axis capacity: Poisson-varying fleet sizes land on a
    handful of stable shapes (powers of two beyond the largest bucket), so
    the jit cache stays tiny and padding waste stays below ~2x."""
    for b in FLEET_BUCKETS:
        if n_dcs <= b:
            return b
    return 1 << (n_dcs - 1).bit_length()


def _train_base_fleet(dcs: List[DC], cap: int, num_classes: int
                      ) -> np.ndarray:
    """Base SVMs for the whole fleet in ONE dispatch. Returns (L, F+1, C)."""
    x, y, m, _ = pad_fleet([d.x for d in dcs], [d.y for d in dcs],
                           cap, fleet_cap(len(dcs)))
    w = train_svm_fleet(jnp.asarray(x), jnp.asarray(y), jnp.asarray(m),
                        num_classes=num_classes)
    return np.asarray(w)[:len(dcs)]


def run_window_a2a(dcs: List[DC], prev_global: Optional[np.ndarray],
                   ledger: Ledger, tech: str, *, cap: int, num_classes: int,
                   n_subsample: Optional[int] = None,
                   rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """One A2AHTL round (Algorithm 1), batched. Returns the new global
    model. Drop-in replacement for :func:`repro.core.htl.run_window_a2a`."""
    rng = rng or np.random.default_rng(0)
    dcs = [d for d in dcs if d.n > 0]
    if not dcs:
        return prev_global
    ap = htl._ap_name(dcs)

    base = _train_base_fleet(dcs, cap, num_classes)
    if len(dcs) == 1:
        only = base[0]
        return only if prev_global is None else 0.5 * (only + prev_global)
    topo = Topology(ledger, tech, fleet_nodes(dcs, ap))

    # Step 1: every DC sends its base model to every other DC
    topo.exchange_all(MODEL_BYTES, what="m0 exchange")

    # Step 2: GreedyTL at every DC against the shared source pool — one
    # vmapped dispatch for the whole fleet
    src, src_mask = build_source_pool(list(base), prev_global)
    sub = [htl._subsample(d, n_subsample, num_classes, rng)
           for d in dcs]
    x, y, m, _ = pad_fleet([d.x for d in sub], [d.y for d in sub],
                           cap, fleet_cap(len(dcs)))
    refined, _ = greedytl_fleet(jnp.asarray(x), jnp.asarray(y),
                                jnp.asarray(m), jnp.asarray(src),
                                jnp.asarray(src_mask),
                                num_classes=num_classes)
    refined = np.asarray(refined)[:len(dcs)]

    # Step 3: send refined models to one DC (the AP / largest mule)
    center = next((d for d in dcs if d.name == ap), dcs[0])
    topo.gather(topo.node(center.name), MODEL_BYTES, what="m1 gather")

    # Step 4: average
    return np.mean(refined, axis=0)


def run_window_star(dcs: List[DC], prev_global: Optional[np.ndarray],
                    ledger: Ledger, tech: str, *, cap: int, num_classes: int,
                    n_subsample: Optional[int] = None,
                    rng: Optional[np.random.Generator] = None) -> np.ndarray:
    """One StarHTL round (Algorithm 2), batched base training. Drop-in
    replacement for :func:`repro.core.htl.run_window_star`."""
    rng = rng or np.random.default_rng(0)
    dcs = [d for d in dcs if d.n > 0]
    if not dcs:
        return prev_global
    ap = htl._ap_name(dcs)

    base = _train_base_fleet(dcs, cap, num_classes)
    if len(dcs) == 1:
        only = base[0]
        return only if prev_global is None else 0.5 * (only + prev_global)
    topo = Topology(ledger, tech, fleet_nodes(dcs, ap))

    # Step 1: entropy index exchange + center id broadcast (tiny messages)
    topo.exchange_all(INDEX_BYTES, what="entropy index")
    c_idx = int(np.argmax([htl.label_entropy(d.y, num_classes)
                           for d in dcs]))
    center = dcs[c_idx]
    topo.broadcast(topo.node(center.name), INDEX_BYTES, what="center id")

    # Step 2: base models to the center only
    topo.gather(topo.node(center.name), MODEL_BYTES, what="m0 to center")

    # Step 3: GreedyTL at the center only (one dispatch, batch of one)
    src, src_mask = build_source_pool(list(base), prev_global)
    c_sub = htl._subsample(center, n_subsample, num_classes, rng)
    x, y, m, _ = pad_fleet([c_sub.x], [c_sub.y], cap, 1)
    w, _ = greedytl_fleet(jnp.asarray(x), jnp.asarray(y), jnp.asarray(m),
                          jnp.asarray(src), jnp.asarray(src_mask),
                          num_classes=num_classes)
    return np.asarray(w)[0]
