"""Incremental decode must match the full forward pass (KV-cache / SSM-state
/ RG-LRU-state correctness across every cache family)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data.pipeline import make_lm_batch
from repro.models import build_model
from repro.serving import pad_cache

S = 64


def _err(arch, cfg_mod=None):
    cfg = get_config(arch).reduced()
    if cfg_mod:
        cfg = cfg_mod(cfg)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = make_lm_batch(cfg.vocab_size, 2, S, seed=3,
                         d_model=cfg.d_model)["tokens"]
    lg_full, _ = jax.jit(m.prefill)(params, {"tokens": toks})
    _, cache = jax.jit(m.prefill)(params, {"tokens": toks[:, :S - 1]})
    cache = pad_cache(m, cache, 1, 2, S - 1)
    lg_inc, _ = jax.jit(m.decode_step)(params, cache, toks[:, S - 1:S],
                                       jnp.asarray(S - 1, jnp.int32))
    scale = float(jnp.max(jnp.abs(lg_full))) + 1e-9
    return float(jnp.max(jnp.abs(lg_full - lg_inc))) / scale


@pytest.mark.parametrize("arch", ["llama3.2-3b", "granite-3-8b",
                                  "qwen2-72b", "mamba2-1.3b",
                                  "recurrentgemma-9b"])
def test_exact_families(arch):
    assert _err(arch) < 2e-3


def test_mla_absorbed_decode():
    # absorbed decode reorders matmuls -> small fp tolerance
    assert _err("minicpm3-4b") < 5e-3


def test_moe_decode_no_drops():
    # capacity dropping is prefill-set dependent; at high capacity factor the
    # incremental path must match exactly
    mod = lambda c: dataclasses.replace(
        c, moe=dataclasses.replace(c.moe, capacity_factor=8.0))
    assert _err("olmoe-1b-7b", mod) < 2e-3


def test_sliding_window_decode():
    mod = lambda c: dataclasses.replace(c, sliding_window=32)
    # with window smaller than context the rolling cache must agree with the
    # windowed full forward
    assert _err("llama3.2-3b", mod) < 2e-3


def test_multi_step_generation_consistency():
    """N decode steps == full forward on the extended sequence (greedy)."""
    cfg = get_config("llama3.2-3b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    toks = make_lm_batch(cfg.vocab_size, 1, S, seed=5,
                         d_model=cfg.d_model)["tokens"]
    n_new = 4
    lg, cache = jax.jit(m.prefill)(params, {"tokens": toks})
    cache = pad_cache(m, cache, n_new, 1, S)
    dec = jax.jit(m.decode_step)
    out = []
    cur = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    for i in range(n_new):
        out.append(int(cur[0, 0]))
        lg, cache = dec(params, cache, cur, jnp.asarray(S + i, jnp.int32))
        cur = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)

    # reference: greedy continuation via repeated full prefill
    seq = toks
    ref = []
    for _ in range(n_new):
        lg_f, _ = jax.jit(m.prefill)(params, {"tokens": seq})
        nxt = jnp.argmax(lg_f, -1)[:, None].astype(jnp.int32)
        ref.append(int(nxt[0, 0]))
        seq = jnp.concatenate([seq, nxt], axis=1)
    assert out == ref
